"""Layer-1 correctness: the Pallas kernel vs. the pure-jnp oracle.

The generator is all-integer except one f32 ``pow``; kernel and oracle
must agree exactly (same XLA ops underneath). Hypothesis sweeps the
parameter space: region geometry, run lengths, thresholds, stream counts.
"""

import hypothesis as hyp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.trace_gen import TILE_T, trace_gen


def mk_args(
    n_regions=2,
    run_len=4,
    write_frac=0.3,
    gap=20,
    streams=8,
    lines_scale=10_000,
    thetas=(0.0, 0.9, 0.5, 0.7),
    seqs=(1, 0, 0, 1),
):
    """Build padded region tables the way rust's TraceGen does."""
    r = ref.MAX_REGIONS
    cum_w = np.ones(r, np.float32)
    cum_w[:n_regions] = (np.arange(n_regions) + 1) / n_regions
    lines = np.full(r, run_len, np.uint32)
    base = np.zeros(r, np.uint32)
    off = 0
    for i in range(n_regions):
        ln = max(lines_scale * (i + 1) // n_regions // run_len, 1) * run_len
        lines[i] = ln
        base[i] = off
        off += ln
    runs = np.maximum(lines // run_len, 1).astype(np.uint32)
    wruns = np.maximum(runs // 4, 1).astype(np.uint32)  # 25% working set
    alpha = np.array(
        [1.0 / (1.0 - t) if t < 1.0 else 64.0 for t in thetas], np.float32
    )
    seq = np.array(seqs, np.uint32)
    epoch_runs = int(max(8 * wruns.max(), 1))
    params = np.array(
        [run_len, int(write_frac * 65536), max(2 * gap, 1), n_regions,
         epoch_runs, 0],
        np.uint32,
    )
    return (
        np.arange(streams, dtype=np.uint32),
        np.zeros(1, np.uint32),
        np.zeros(streams, np.uint32),
        cum_w,
        base,
        lines,
        runs,
        wruns,
        alpha,
        seq,
        params,
    )


def run_both(args, steps=TILE_T):
    got = trace_gen(*[jnp.asarray(a) for a in args], steps=steps)
    want = ref.trace_gen_ref(*[jnp.asarray(a) for a in args], steps=steps)
    return got, want


def test_kernel_matches_ref_default():
    got, want = run_both(mk_args())
    for g, w, name in zip(got, want, ["addr", "write", "gap"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_kernel_multi_tile_grid():
    got, want = run_both(mk_args(), steps=4 * TILE_T)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_nonzero_step0_continues_stream():
    args = list(mk_args())
    a0 = trace_gen(*[jnp.asarray(a) for a in args], steps=TILE_T)
    args[1] = np.array([TILE_T], np.uint32)
    a1 = trace_gen(*[jnp.asarray(a) for a in args], steps=TILE_T)
    full_args = list(mk_args())
    full = trace_gen(*[jnp.asarray(a) for a in full_args], steps=2 * TILE_T)
    np.testing.assert_array_equal(np.asarray(full[0][:, :TILE_T]), np.asarray(a0[0]))
    np.testing.assert_array_equal(np.asarray(full[0][:, TILE_T:]), np.asarray(a1[0]))


def test_addresses_stay_in_regions():
    args = mk_args()
    got, _ = run_both(args)
    addr = np.asarray(got[0])
    total_lines = int(args[4][-1]) if args[4][-1] else None
    span = int(args[4][1] + args[5][1])  # last region base + lines
    assert addr.max() < span
    del total_lines


def test_write_fraction_matches_threshold():
    got, _ = run_both(mk_args(write_frac=0.25))
    w = np.asarray(got[1])
    frac = w.mean()
    assert abs(frac - 0.25) < 0.02, frac


def test_gap_range():
    got, _ = run_both(mk_args(gap=16))
    g = np.asarray(got[2])
    assert g.max() < 32
    assert abs(g.mean() - 15.5) < 1.0


def test_zipf_region_skew():
    # Single zipf region, theta=0.9: the hot working set dominates even
    # though the hash scatter spreads it across the region — the most
    # popular 10% of *distinct* lines must absorb most accesses.
    got, _ = run_both(
        mk_args(n_regions=1, thetas=(0.9, 0, 0, 0), seqs=(0, 0, 0, 0))
    )
    addr = np.asarray(got[0]).reshape(-1)
    _, counts = np.unique(addr, return_counts=True)
    counts.sort()
    top = counts[-max(len(counts) // 10, 1):].sum()
    frac = top / counts.sum()
    assert frac > 0.5, frac


@hyp.settings(max_examples=25, deadline=None)
@hyp.given(
    n_regions=st.integers(1, 4),
    run_len=st.sampled_from([1, 2, 4, 16, 64]),
    write_frac=st.floats(0.0, 1.0),
    gap=st.integers(0, 200),
    streams=st.sampled_from([1, 4, 16]),
    lines_scale=st.integers(64, 1_000_000),
    theta=st.floats(0.0, 0.99),
)
def test_kernel_matches_ref_hypothesis(
    n_regions, run_len, write_frac, gap, streams, lines_scale, theta
):
    args = mk_args(
        n_regions=n_regions,
        run_len=run_len,
        write_frac=write_frac,
        gap=gap,
        streams=streams,
        lines_scale=lines_scale,
        thetas=(theta, 0.5, 0.0, 0.9),
        seqs=(0, 1, 1, 0),
    )
    got, want = run_both(args)
    for g, w, name in zip(got, want, ["addr", "write", "gap"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_lowbias32_reference_values():
    # Must match rust's pinned constants (workloads/synth.rs).
    vals = np.asarray(ref.lowbias32(np.array([0, 1, 0xDEADBEEF], np.uint32)))
    assert vals.tolist() == [0, 1753845952, 3861431939]


def test_steps_must_be_tile_multiple():
    args = mk_args()
    with pytest.raises(ValueError):
        trace_gen(*[jnp.asarray(a) for a in args], steps=TILE_T + 1)

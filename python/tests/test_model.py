"""Layer-2 graph tests: shapes, composition, and AOT lowering."""

import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from tests.test_kernel import mk_args


def full_args():
    return [jnp.asarray(a) for a in mk_args(streams=model.STREAMS)]


def test_trace_batch_shapes():
    out = model.trace_batch(*full_args())
    assert len(out) == 3
    for o in out:
        assert o.shape == (model.STREAMS, model.STEPS)
        assert o.dtype == jnp.uint32


def test_hotness_accumulates_and_decays():
    args = full_args()
    hot0 = jnp.zeros((model.HOT_BUCKETS,), jnp.float32)
    decay = jnp.ones((1,), jnp.float32)
    hot1, wf, mg = model.hotness(*args, hot0, decay)
    assert hot1.shape == (model.HOT_BUCKETS,)
    # One access per (stream, step) lands in exactly one bucket.
    np.testing.assert_allclose(
        np.asarray(hot1).sum(), model.STREAMS * model.STEPS, rtol=1e-6
    )
    assert 0.0 <= float(wf[0]) <= 1.0
    assert float(mg[0]) >= 0.0
    # Decay halves the history before adding the new tile.
    hot2, _, _ = model.hotness(*args, hot1, jnp.asarray([0.5], jnp.float32))
    np.testing.assert_allclose(
        np.asarray(hot2).sum(),
        1.5 * model.STREAMS * model.STEPS,
        rtol=1e-6,
    )


def test_hotness_skew_visible_in_histogram():
    # A zipf-only profile should concentrate mass in few buckets.
    args = [
        jnp.asarray(a)
        for a in mk_args(
            streams=model.STREAMS,
            n_regions=1,
            thetas=(0.95, 0, 0, 0),
            seqs=(0, 0, 0, 0),
            lines_scale=500_000,
        )
    ]
    hot0 = jnp.zeros((model.HOT_BUCKETS,), jnp.float32)
    hot, _, _ = model.hotness(*args, hot0, jnp.ones((1,), jnp.float32))
    h = np.sort(np.asarray(hot))[::-1]
    top_frac = h[:64].sum() / h.sum()
    assert top_frac > 0.5, top_frac


def test_aot_lowering_produces_hlo_text(tmp_path: pathlib.Path):
    written = aot.build_artifacts(tmp_path)
    names = {p.name for p in written}
    assert {"trace_gen.hlo.txt", "hotness.hlo.txt", "manifest.txt"} <= names
    hlo = (tmp_path / "trace_gen.hlo.txt").read_text()
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # No custom-calls the CPU PJRT client can't run (interpret-mode pallas
    # lowers to plain HLO).
    assert "custom-call" not in hlo or "mosaic" not in hlo.lower()


def test_lowered_module_is_single_fusion_domain():
    lowered = jax.jit(model.trace_batch).lower(*model.example_args())
    txt = lowered.compiler_ir("stablehlo")
    # One module, no host callbacks.
    assert "stablehlo" in str(txt)
    assert "callback" not in str(txt)


if __name__ == "__main__":
    sys.exit(0)

"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (from python/).
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: pathlib.Path) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []

    lowered = jax.jit(model.trace_batch).lower(*model.example_args())
    p = out_dir / "trace_gen.hlo.txt"
    p.write_text(to_hlo_text(lowered))
    written.append(p)

    lowered = jax.jit(model.hotness).lower(*model.hotness_example_args())
    p = out_dir / "hotness.hlo.txt"
    p.write_text(to_hlo_text(lowered))
    written.append(p)

    # Shape manifest for the rust loader (hand-parsed: no serde offline).
    manifest = out_dir / "manifest.txt"
    manifest.write_text(
        "trace_gen streams={s} steps={t} regions=4\n"
        "hotness buckets={b}\n".format(
            s=model.STREAMS, t=model.STEPS, b=model.HOT_BUCKETS
        )
    )
    written.append(manifest)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    for p in build_artifacts(pathlib.Path(args.out)):
        print(f"wrote {p} ({p.stat().st_size} bytes)")


if __name__ == "__main__":
    main()

"""Pure-jnp reference oracle for the trace-generation kernel.

This is the correctness ground truth: the Pallas kernel in
``trace_gen.py`` must reproduce these outputs exactly (all-integer fields
bit-for-bit; the zipf rank uses one f32 ``pow`` and matches because both
paths lower to the same XLA op).

The algorithm is the stateless counter-based generator of
``rust/src/workloads/synth.rs`` — see that module's docs for the design.
"""

import jax.numpy as jnp

# Number of (padded) region slots every profile is encoded into.
MAX_REGIONS = 4


def lowbias32(x):
    """The low-bias 32-bit integer hash (u32 in, u32 out)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def trace_gen_ref(
    streams,      # u32[S]   stream ids
    step0,        # u32[1]   base step of this batch
    slice_base,   # u32[S]   per-stream slice base, in 64 B lines
    cum_w,        # f32[R]   cumulative region weights (increasing, last=1)
    base_line,    # u32[R]   region base, in lines
    lines,        # u32[R]   region size, in lines
    runs,         # u32[R]   region size, in runs (lines / run_len)
    wruns,        # u32[R]   per-epoch working-set size, in runs
    alpha,        # f32[R]   zipf exponent 1/(1-theta)
    seq,          # u32[R]   1 = streaming sweep, 0 = zipf runs
    params,       # u32[6]   [run_len, write_threshold, gap_mod,
                  #           n_regions, epoch_runs, 0]
    steps,        # static: batch length T
):
    """Generate a (S, T) tile of accesses.

    Returns (addr_line u32[S,T], is_write u32[S,T], gap u32[S,T]).
    """
    run_len = params[0]
    write_thresh = params[1]
    gap_mod = jnp.maximum(params[2], jnp.uint32(1))

    s = streams[:, None].astype(jnp.uint32)                      # (S,1)
    t = step0[0] + jnp.arange(steps, dtype=jnp.uint32)[None, :]  # (1,T)

    run_id = t // run_len
    pos = t % run_len

    stream_key = lowbias32(s * jnp.uint32(0x9E3779B9) + jnp.uint32(1))
    h1 = lowbias32(stream_key ^ lowbias32(run_id))
    h2 = lowbias32(h1 ^ jnp.uint32(0x9E3779B9))
    h3 = lowbias32(h2 ^ jnp.uint32(0x85EBCA6B))

    # Region pick: first index with u_r < cum_w (== count of cum_w <= u_r).
    u_r = h1.astype(jnp.float32) / jnp.float32(4294967296.0)
    n_regions = params[3].astype(jnp.int32)
    ge = (u_r[..., None] >= cum_w[None, None, :]).astype(jnp.int32)
    ri = jnp.minimum(ge.sum(-1), n_regions - 1)                  # (S,T)

    g_base = base_line[ri]
    g_lines = lines[ri]
    g_runs = runs[ri]
    g_wruns = wruns[ri]
    g_alpha = alpha[ri]
    g_seq = seq[ri]

    # Streaming sweep line.
    seq_line = (run_id * run_len + pos) % g_lines
    # Zipf (continuous pareto) rank over the epoch's working set, then a
    # stateless hash scatter over the whole region: the epoch salt shifts
    # the working set periodically (phased reuse), the hash spreads hot
    # runs across the address space (collisions merge popularity mass and
    # preserve the skew).
    u = (h2 >> jnp.uint32(8)).astype(jnp.float32) / jnp.float32(16777216.0)
    wrank = (g_wruns.astype(jnp.float32) * jnp.power(u, g_alpha)).astype(jnp.uint32)
    epoch = run_id // jnp.maximum(params[4], jnp.uint32(1))
    salt = lowbias32(
        epoch
        ^ (ri.astype(jnp.uint32) * jnp.uint32(0x01000193))
        ^ jnp.uint32(0x5EED5EED)
    )
    scattered = lowbias32(wrank ^ salt) % g_runs
    zipf_line = (scattered * run_len + pos) % g_lines

    line = jnp.where(g_seq != 0, seq_line, zipf_line)
    addr_line = slice_base[:, None] + g_base + line

    is_write = ((h3 & jnp.uint32(0xFFFF)) < write_thresh).astype(jnp.uint32)
    gap = (h3 >> jnp.uint32(16)) % gap_mod
    return addr_line, is_write, gap

"""Layer-1 Pallas kernel: the trace-generation hot spot.

The whole (streams x steps) tile is evaluated in one kernel invocation —
the generator is stateless per (stream, step), so there is no sequential
dependence to serialize on.

TPU mapping (DESIGN.md §Hardware-Adaptation): the per-element pipeline is
pure VPU work (integer hash rounds + one f32 ``pow``); the small region
tables (4 entries each) live in VMEM alongside the (S, T) tile. The grid
tiles the step axis in TILE_T-sized chunks so arbitrarily long batches
stream through VMEM. ``interpret=True`` is mandatory on this CPU-only
image — real-TPU lowering emits a Mosaic custom-call the CPU PJRT plugin
cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Step-axis tile: 8 * 128-lane friendly.
TILE_T = 1024


def _kernel(
    streams_ref,
    step0_ref,
    slice_base_ref,
    cum_w_ref,
    base_line_ref,
    lines_ref,
    runs_ref,
    wruns_ref,
    alpha_ref,
    seq_ref,
    params_ref,
    addr_ref,
    write_ref,
    gap_ref,
):
    """One (S, TILE_T) tile of the generator."""
    tile = pl.program_id(0)
    run_len = params_ref[0]
    write_thresh = params_ref[1]
    gap_mod = jnp.maximum(params_ref[2], jnp.uint32(1))
    n_regions = params_ref[3].astype(jnp.int32)

    s = streams_ref[...][:, None]  # (S,1)
    t0 = step0_ref[0] + jnp.uint32(tile * TILE_T)
    t = t0 + jax.lax.broadcasted_iota(jnp.uint32, (1, TILE_T), 1)

    run_id = t // run_len
    pos = t % run_len

    stream_key = ref.lowbias32(s * jnp.uint32(0x9E3779B9) + jnp.uint32(1))
    h1 = ref.lowbias32(stream_key ^ ref.lowbias32(run_id))
    h2 = ref.lowbias32(h1 ^ jnp.uint32(0x9E3779B9))
    h3 = ref.lowbias32(h2 ^ jnp.uint32(0x85EBCA6B))

    u_r = h1.astype(jnp.float32) / jnp.float32(4294967296.0)
    cum_w = cum_w_ref[...]
    ge = (u_r[..., None] >= cum_w[None, None, :]).astype(jnp.int32)
    ri = jnp.minimum(ge.sum(-1), n_regions - 1)

    g_base = base_line_ref[...][ri]
    g_lines = lines_ref[...][ri]
    g_runs = runs_ref[...][ri]
    g_wruns = wruns_ref[...][ri]
    g_alpha = alpha_ref[...][ri]
    g_seq = seq_ref[...][ri]

    seq_line = (run_id * run_len + pos) % g_lines
    u = (h2 >> jnp.uint32(8)).astype(jnp.float32) / jnp.float32(16777216.0)
    wrank = (g_wruns.astype(jnp.float32) * jnp.power(u, g_alpha)).astype(jnp.uint32)
    epoch = run_id // jnp.maximum(params_ref[4], jnp.uint32(1))
    salt = ref.lowbias32(
        epoch
        ^ (ri.astype(jnp.uint32) * jnp.uint32(0x01000193))
        ^ jnp.uint32(0x5EED5EED)
    )
    scattered = ref.lowbias32(wrank ^ salt) % g_runs
    zipf_line = (scattered * run_len + pos) % g_lines

    line = jnp.where(g_seq != 0, seq_line, zipf_line)
    addr_ref[...] = slice_base_ref[...][:, None] + g_base + line
    write_ref[...] = ((h3 & jnp.uint32(0xFFFF)) < write_thresh).astype(jnp.uint32)
    gap_ref[...] = (h3 >> jnp.uint32(16)) % gap_mod


@functools.partial(jax.jit, static_argnames=("steps",))
def trace_gen(
    streams, step0, slice_base, cum_w, base_line, lines, runs, wruns, alpha,
    seq, params, *, steps,
):
    """Pallas-backed trace generation; same contract as ref.trace_gen_ref."""
    if steps % TILE_T != 0:
        raise ValueError(f"steps must be a multiple of {TILE_T}")
    n_streams = streams.shape[0]
    grid = (steps // TILE_T,)
    tile = (n_streams, TILE_T)
    out_shape = [jax.ShapeDtypeStruct((n_streams, steps), jnp.uint32)] * 3

    small = lambda n: pl.BlockSpec((n,), lambda i: (0,))  # noqa: E731
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            small(n_streams),          # streams
            small(1),                  # step0
            small(n_streams),          # slice_base
            small(ref.MAX_REGIONS),    # cum_w
            small(ref.MAX_REGIONS),    # base_line
            small(ref.MAX_REGIONS),    # lines
            small(ref.MAX_REGIONS),    # runs
            small(ref.MAX_REGIONS),    # wruns
            small(ref.MAX_REGIONS),    # alpha
            small(ref.MAX_REGIONS),    # seq
            small(6),                  # params
        ],
        out_specs=[pl.BlockSpec(tile, lambda i: (0, i)) for _ in range(3)],
        out_shape=out_shape,
        interpret=True,
    )(streams, step0, slice_base, cum_w, base_line, lines, runs, wruns,
      alpha, seq, params)

"""Layer-2 JAX compute graphs, built on the Layer-1 Pallas kernel.

Two graphs are AOT-lowered (see ``aot.py``):

* ``trace_batch`` — the batched trace generator the rust simulator calls
  at runtime through PJRT (``rust/src/workloads/pjrt.rs``). One call
  produces a (streams x steps) tile of (address-line, is-write, gap)
  triples.
* ``hotness`` — a per-bucket access histogram + exponentially-decayed
  hotness update over a generated tile: the analysis graph behind the
  CLI's workload-calibration report (``trimma analyze``). It demonstrates
  the L2 graph *composing* the L1 kernel with further jnp compute inside
  one lowered module (single fusion domain, no host round-trip).
"""

import jax
import jax.numpy as jnp

from .kernels import trace_gen as tg

# Fixed AOT shapes: 16 streams (cores), 4096 steps per batch.
STREAMS = 16
STEPS = 4096
HOT_BUCKETS = 1024


def trace_batch(streams, step0, slice_base, cum_w, base_line, lines, runs,
                wruns, alpha, seq, params):
    """The runtime trace batch: 3 x u32[STREAMS, STEPS]."""
    return tg.trace_gen(
        streams, step0, slice_base, cum_w, base_line, lines, runs, wruns,
        alpha, seq, params, steps=STEPS,
    )


def hotness(streams, step0, slice_base, cum_w, base_line, lines, runs,
            wruns, alpha, seq, params, hot_in, decay):
    """Generate a tile and fold it into a decayed hotness histogram.

    hot_in: f32[HOT_BUCKETS]; decay: f32[1].
    Returns (hot_out f32[HOT_BUCKETS], write_frac f32[1], mean_gap f32[1]).
    """
    addr_line, is_write, gap = trace_batch(
        streams, step0, slice_base, cum_w, base_line, lines, runs, wruns,
        alpha, seq, params,
    )
    buckets = (addr_line % jnp.uint32(HOT_BUCKETS)).reshape(-1)
    hist = jnp.zeros((HOT_BUCKETS,), jnp.float32).at[buckets].add(1.0)
    hot_out = hot_in * decay[0] + hist
    write_frac = is_write.astype(jnp.float32).mean()[None]
    mean_gap = gap.astype(jnp.float32).mean()[None]
    return hot_out, write_frac, mean_gap


def example_args():
    """ShapeDtypeStructs for AOT lowering of trace_batch."""
    u32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.uint32)  # noqa: E731
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    r = 4  # MAX_REGIONS
    return (
        u32(STREAMS),   # streams
        u32(1),         # step0
        u32(STREAMS),   # slice_base
        f32(r),         # cum_w
        u32(r),         # base_line
        u32(r),         # lines
        u32(r),         # runs
        u32(r),         # wruns
        f32(r),         # alpha
        u32(r),         # seq
        u32(6),         # params
    )


def hotness_example_args():
    args = list(example_args())
    args.append(jax.ShapeDtypeStruct((HOT_BUCKETS,), jnp.float32))  # hot_in
    args.append(jax.ShapeDtypeStruct((1,), jnp.float32))            # decay
    return tuple(args)

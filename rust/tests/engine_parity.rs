//! Dispatch parity: the enum-dispatched `engine::AnyController` must
//! reproduce the seed `Box<dyn Controller>` path byte for byte.
//!
//! The golden harness (`tests/golden.rs`) locks the enum path's stat
//! vectors against `tests/golden/stats.json`; this file closes the loop
//! by driving the *same* controllers through a boxed `dyn Controller`
//! (the pre-engine dispatch mechanism, kept alive by the blanket
//! `impl Controller for Box<T>`) and asserting canonical stat equality on
//! every design point x adversarial scenario. Together they prove the
//! devirtualization refactor changed dispatch only — not one counter.

mod common;

use trimma::config::presets::DesignPoint;
use trimma::engine::{AnyController, EngineBuilder};
use trimma::hybrid::Controller;
use trimma::sim::Simulation;
use trimma::stats::Stats;
use trimma::workloads::{self, adversarial::ADVERSARIAL};

/// Run `dp` on `wl` with the controller driven through a boxed trait
/// object — the seed dispatch path.
fn run_dyn(dp: DesignPoint, cfg: &trimma::config::SystemConfig, wl: &str) -> Stats {
    let w = workloads::by_name(wl, cfg).unwrap_or_else(|e| panic!("{e}"));
    let ctrl: Box<dyn Controller> =
        Box::new(AnyController::from_config(cfg, dp == DesignPoint::Ideal));
    Simulation::with_controller(cfg, w, ctrl).run().stats
}

#[test]
fn enum_dispatch_matches_dyn_dispatch_byte_for_byte() {
    for dp in DesignPoint::ALL {
        for sc in ADVERSARIAL {
            let cfg = common::tiny(*dp);
            let enum_stats = common::run(*dp, &cfg, sc).canonical();
            let dyn_stats = run_dyn(*dp, &cfg, sc).canonical();
            assert_eq!(
                enum_stats, dyn_stats,
                "{}/{sc}: enum-dispatched engine diverged from the boxed dyn path",
                dp.label()
            );
        }
    }
}

#[test]
fn builder_route_matches_direct_construction() {
    // EngineBuilder -> Session -> report must equal the hand-assembled
    // Simulation::new path on a representative design point per mode.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::AlloyCache] {
        let cfg = common::tiny(dp);
        let direct = common::run(dp, &cfg, "adv_set_thrash").canonical();
        let built = EngineBuilder::from_config(cfg.clone())
            .workload("adv_set_thrash")
            .run()
            .unwrap()
            .stats
            .canonical();
        assert_eq!(direct, built, "{}: builder route diverged", dp.label());
    }
}

#[test]
fn builder_ideal_toggle_matches_new_ideal() {
    let cfg = common::tiny(DesignPoint::Ideal);
    let direct = common::run(DesignPoint::Ideal, &cfg, "adv_drift").canonical();
    let built = EngineBuilder::from_config(cfg.clone())
        .workload("adv_drift")
        .ideal(true)
        .run()
        .unwrap()
        .stats
        .canonical();
    assert_eq!(direct, built, "ideal toggle must match Simulation::new_ideal");
}

#[test]
fn verify_toggle_is_observation_only_through_builder() {
    let cfg = common::tiny(DesignPoint::TrimmaFlat);
    let plain = EngineBuilder::from_config(cfg.clone())
        .workload("adv_migration_storm")
        .run()
        .unwrap()
        .stats
        .canonical();
    let verified = EngineBuilder::from_config(cfg)
        .workload("adv_migration_storm")
        .verify(true)
        .run()
        .unwrap()
        .stats
        .canonical();
    assert_eq!(plain, verified, "the oracle must not perturb a single counter");
}

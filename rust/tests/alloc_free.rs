//! The zero-allocation guarantee of the hot path, asserted with a
//! counting global allocator: once a controller reaches steady state
//! (tables populated, remap caches warm, free stacks settled), demand
//! accesses — lookup, walk, fill, eviction, table update, remap-cache
//! maintenance — must never touch the heap. Every scratch buffer
//! (`ev_buf`, `walk_buf`, `hot_buf`, the pre-sized free stacks, the MEA
//! drain scratch) exists to make this hold.
//!
//! The trace replay path carries the same guarantee: once a
//! `TraceWorkload`'s chunk buffers are warm, `next_batch` performs zero
//! allocations in both I/O modes — buffered inline reads reuse the
//! reader's pre-sized payload scratch, and the read-ahead mode circulates
//! its preallocated buffer pool through the SPSC rings (DESIGN.md §13).
//!
//! This file contains exactly one #[test] so no concurrent test can
//! pollute the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use trimma::config::presets::{self, DesignPoint};
use trimma::engine::AnyController;
use trimma::hybrid::{Access, Controller};
use trimma::types::{AccessKind, Rng64};

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

/// System allocator shim that counts every allocating call (alloc,
/// alloc_zeroed, realloc). Deallocation is free and uncounted.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn drive<C: Controller>(c: &mut C, rng: &mut Rng64, t: &mut u64, n: u64, span: u64) {
    let f = c.layout().fast_per_set;
    let sets = c.layout().num_sets as u64;
    for _ in 0..n {
        let set = rng.next_below(sets) as u32;
        let idx = f + rng.next_below(span);
        let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
        *t += 700;
        c.access(set, idx, 0, kind, *t);
    }
}

/// Same traffic shape as [`drive`], but pushed through the batched
/// [`Controller::access_block`] entry point in 64-access blocks — the path
/// the two-phase prefetched translate walk lives on. The batch is a stack
/// array, so the walk itself is the only thing under test.
fn drive_batch<C: Controller>(c: &mut C, rng: &mut Rng64, t: &mut u64, batches: u64, span: u64) {
    let f = c.layout().fast_per_set;
    let sets = c.layout().num_sets as u64;
    let mut batch = [Access::default(); 64];
    for _ in 0..batches {
        for slot in batch.iter_mut() {
            let set = rng.next_below(sets) as u32;
            let idx = f + rng.next_below(span);
            let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
            *t += 700;
            *slot = Access { set, idx, line: 0, kind, now: *t };
        }
        c.access_block(&batch);
    }
}

#[test]
fn translate_path_is_allocation_free_in_steady_state() {
    // Each design point runs plain and (where the remap table supports it)
    // with the decay sweep firing hard — epoch every 64 per-set accesses,
    // no pressure gate, one-epoch coldness — since the sweep shares the
    // steady-state path and must live off preallocated scratch too. The
    // prefetch variants additionally push batched traffic through
    // `access_block` inside the measured window: the phase-1
    // `prefetch_targets` walk must be allocation-free as well.
    for (dp, decay, prefetch) in [
        (DesignPoint::TrimmaCache, false, false),
        (DesignPoint::TrimmaFlat, false, false),
        (DesignPoint::LinearCache, false, false),
        (DesignPoint::TrimmaCache, true, false),
        (DesignPoint::TrimmaFlat, true, false),
        (DesignPoint::TrimmaCache, false, true),
        (DesignPoint::TrimmaFlat, false, true),
        (DesignPoint::LinearCache, false, true),
        (DesignPoint::TrimmaCache, true, true),
    ] {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        if decay {
            cfg.hybrid.decay.enabled = true;
            cfg.hybrid.decay.epoch_accesses = 64;
            cfg.hybrid.decay.pressure_milli = 0;
            cfg.hybrid.decay.sweep_budget = 128;
            cfg.hybrid.decay.cold_epochs = 1;
        }
        cfg.hybrid.batch.prefetch = prefetch;
        // The enum-dispatched engine path must stay allocation-free too.
        let mut c = AnyController::from_config(&cfg, false);
        let span = c.layout().slow_per_set.min(6000);
        let mut rng = Rng64::new(0xA110C ^ dp as u64);
        let mut t = 0u64;

        // Warmup: populate tables/caches, churn evictions and (flat mode)
        // MEA epochs until every reusable buffer has reached capacity.
        drive(&mut c, &mut rng, &mut t, 60_000, span);

        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        drive(&mut c, &mut rng, &mut t, 20_000, span);
        if prefetch {
            // 312 x 64 = 19,968 batched accesses, each prefetched exactly
            // once by the two-phase walk — all inside the counted window.
            drive_batch(&mut c, &mut rng, &mut t, 312, span);
        }
        let delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{dp:?} (decay={decay}, prefetch={prefetch}): {delta} heap \
             allocation(s) on the steady-state translate path"
        );

        // The controller still works and saw the traffic; the decay
        // variants really exercised the sweep inside the measured window,
        // and the prefetch variants really walked every batched access.
        let expected = 80_000 + if prefetch { 19_968 } else { 0 };
        assert_eq!(c.stats().mem_accesses, expected);
        assert_eq!(
            c.stats().batch_prefetches,
            if prefetch { 19_968 } else { 0 },
            "{dp:?}: two-phase walk must touch each batched access exactly once"
        );
        if decay {
            assert!(
                c.stats().decay_checked > 0,
                "{dp:?}: decay sweep never ran during the alloc-free check"
            );
        }
    }

    trace_replay_is_allocation_free_in_steady_state();
}

/// Record a small trace (2 cores, 256-record chunks so the measured
/// window crosses several refills per core), then draw batches through
/// both replay modes with the allocation counter armed. Called from the
/// file's single #[test] (see the module docs). In read-ahead mode the
/// I/O thread runs concurrently with the measured window, and the global
/// counter sees its allocations too — so this asserts the whole
/// buffer-pool circulation, not just the consumer side.
fn trace_replay_is_allocation_free_in_steady_state() {
    use trimma::config::{TraceConfig, TraceReplayMode};
    use trimma::trace::TraceWorkload;
    use trimma::types::MemAccess;
    use trimma::workloads::Workload;

    let path =
        std::env::temp_dir().join(format!("trimma-allocfree-{}.trimtrace", std::process::id()));
    let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    cfg.hybrid.fast_bytes = 1 << 20;
    cfg.hybrid.slow_bytes = 32 << 20;
    cfg.hybrid.num_sets = 4;
    cfg.workload.cores = 2;
    cfg.workload.accesses_per_core = 6_000;
    cfg.workload.warmup_per_core = 1_000;
    cfg.trace = TraceConfig { enabled: true, chunk_records: 256, ..TraceConfig::off() };
    trimma::engine::EngineBuilder::from_config(cfg.clone())
        .workload("gap_pr")
        .run_recorded(&path)
        .expect("trace recording");

    for mode in [TraceReplayMode::Buffered, TraceReplayMode::ReadAhead] {
        cfg.trace.replay = mode;
        let mut wl = TraceWorkload::open(&path, &cfg).expect("trace open");
        let mut batch = vec![MemAccess::read(0, 0); 64];
        // Warm: prime each cursor past its first refill so every pool
        // buffer has circulated at least once.
        for core in 0..2 {
            for _ in 0..8 {
                wl.next_batch(core, &mut batch);
            }
        }
        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        // 20 x 64 records per core: crosses ~5 chunk refills per core,
        // all mid-stream (far from end-of-trace filler territory).
        for _ in 0..20 {
            for core in 0..2 {
                wl.next_batch(core, &mut batch);
            }
        }
        let delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{mode:?}: {delta} heap allocation(s) in steady-state trace replay"
        );
    }
    std::fs::remove_file(&path).unwrap();
}

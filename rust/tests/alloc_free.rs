//! The zero-allocation guarantee of the hot path, asserted with a
//! counting global allocator: once a controller reaches steady state
//! (tables populated, remap caches warm, free stacks settled), demand
//! accesses — lookup, walk, fill, eviction, table update, remap-cache
//! maintenance — must never touch the heap. Every scratch buffer
//! (`ev_buf`, `walk_buf`, `hot_buf`, the pre-sized free stacks, the MEA
//! drain scratch) exists to make this hold.
//!
//! This file contains exactly one #[test] so no concurrent test can
//! pollute the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use trimma::config::presets::{self, DesignPoint};
use trimma::engine::AnyController;
use trimma::hybrid::Controller;
use trimma::types::{AccessKind, Rng64};

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

/// System allocator shim that counts every allocating call (alloc,
/// alloc_zeroed, realloc). Deallocation is free and uncounted.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn drive<C: Controller>(c: &mut C, rng: &mut Rng64, t: &mut u64, n: u64, span: u64) {
    let f = c.layout().fast_per_set;
    let sets = c.layout().num_sets as u64;
    for _ in 0..n {
        let set = rng.next_below(sets) as u32;
        let idx = f + rng.next_below(span);
        let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
        *t += 700;
        c.access(set, idx, 0, kind, *t);
    }
}

#[test]
fn translate_path_is_allocation_free_in_steady_state() {
    // Each design point runs plain and (where the remap table supports it)
    // with the decay sweep firing hard — epoch every 64 per-set accesses,
    // no pressure gate, one-epoch coldness — since the sweep shares the
    // steady-state path and must live off preallocated scratch too.
    for (dp, decay) in [
        (DesignPoint::TrimmaCache, false),
        (DesignPoint::TrimmaFlat, false),
        (DesignPoint::LinearCache, false),
        (DesignPoint::TrimmaCache, true),
        (DesignPoint::TrimmaFlat, true),
    ] {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        if decay {
            cfg.hybrid.decay.enabled = true;
            cfg.hybrid.decay.epoch_accesses = 64;
            cfg.hybrid.decay.pressure_milli = 0;
            cfg.hybrid.decay.sweep_budget = 128;
            cfg.hybrid.decay.cold_epochs = 1;
        }
        // The enum-dispatched engine path must stay allocation-free too.
        let mut c = AnyController::from_config(&cfg, false);
        let span = c.layout().slow_per_set.min(6000);
        let mut rng = Rng64::new(0xA110C ^ dp as u64);
        let mut t = 0u64;

        // Warmup: populate tables/caches, churn evictions and (flat mode)
        // MEA epochs until every reusable buffer has reached capacity.
        drive(&mut c, &mut rng, &mut t, 60_000, span);

        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        drive(&mut c, &mut rng, &mut t, 20_000, span);
        let delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{dp:?} (decay={decay}): {delta} heap allocation(s) on the \
             steady-state translate path"
        );

        // The controller still works and saw the traffic; the decay
        // variants really exercised the sweep inside the measured window.
        assert_eq!(c.stats().mem_accesses, 80_000);
        if decay {
            assert!(
                c.stats().decay_checked > 0,
                "{dp:?}: decay sweep never ran during the alloc-free check"
            );
        }
    }
}

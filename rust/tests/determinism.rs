//! Determinism matrix: every design point must produce a byte-identical
//! stat vector across repeated runs, and `coordinator::run_jobs` must
//! produce byte-identical results regardless of the worker-thread count
//! (1 vs. all cores). This is what makes the golden-snapshot harness and
//! the paper-claim comparisons trustworthy at all.

mod common;

use trimma::config::presets::DesignPoint;
use trimma::coordinator::{run_jobs, Job};

#[test]
fn every_design_point_is_run_to_run_deterministic() {
    for dp in DesignPoint::ALL {
        let cfg = common::tiny(*dp);
        let a = common::run(*dp, &cfg, "adv_drift").canonical();
        let b = common::run(*dp, &cfg, "adv_drift").canonical();
        assert_eq!(a, b, "{dp:?}: two identical runs diverged");
    }
}

#[test]
fn verification_does_not_change_determinism() {
    // verify=true runs the oracle but must leave the stat vector alone.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::MemPod] {
        let plain = common::run(dp, &common::tiny(dp), "adv_identity_flip").canonical();
        let mut vcfg = common::tiny(dp);
        vcfg.hybrid.verify = true;
        let verified = common::run(dp, &vcfg, "adv_identity_flip").canonical();
        assert_eq!(plain, verified, "{dp:?}");
    }
}

#[test]
fn metadata_bloat_and_decay_are_deterministic() {
    // The phase-change scenario plus an aggressively firing decay sweep:
    // epoch pacing, the pressure gate, and the rotating sweep cursor are
    // all per-set state, so repeated runs must stay byte-identical.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let mut cfg = common::tiny(dp);
        cfg.hybrid.decay.enabled = true;
        cfg.hybrid.decay.epoch_accesses = 32;
        cfg.hybrid.decay.pressure_milli = 0;
        cfg.hybrid.decay.cold_epochs = 1;
        let a = common::run(dp, &cfg, "adv_metadata_bloat").canonical();
        let b = common::run(dp, &cfg, "adv_metadata_bloat").canonical();
        assert_eq!(a, b, "{dp:?}: decay runs diverged");
    }
}

#[test]
fn run_jobs_thread_count_invariant() {
    // One job per design point, all on the same adversarial workload; the
    // coordinator must return identical stat vectors whether it runs them
    // on one worker or on every core.
    let jobs: Vec<Job> = DesignPoint::ALL
        .iter()
        .map(|dp| {
            let mut job = Job::new(dp.label(), common::tiny(*dp), "adv_pointer_chase");
            job.ideal = *dp == DesignPoint::Ideal;
            job
        })
        .collect();
    let serial = run_jobs(&jobs, 1).unwrap();
    let parallel = run_jobs(&jobs, 0).unwrap(); // 0 = all cores
    assert_eq!(serial.len(), parallel.len());
    for ((s, p), job) in serial.iter().zip(&parallel).zip(&jobs) {
        assert_eq!(
            s.stats.canonical(),
            p.stats.canonical(),
            "{}: thread count changed the result",
            job.label
        );
    }
}

#[test]
fn distinct_seeds_distinct_results() {
    // Sanity check that determinism is not degeneracy: the seed matters.
    let dp = DesignPoint::TrimmaCache;
    let a = common::run(dp, &common::tiny(dp), "adv_drift").canonical();
    let mut cfg = common::tiny(dp);
    cfg.workload.seed = 0x0DD5EED;
    let b = common::run(dp, &cfg, "adv_drift").canonical();
    assert_ne!(a, b, "different seeds should not collide on the full vector");
}

//! Trace record/replay parity: the headline invariant of the trace
//! subsystem is that replaying a recording reproduces the live run's
//! merged canonical stat vector **byte-identically** — in the closed
//! loop, and across every open-loop execution mode (shard counts 1/2/4,
//! inline and pipelined front ends, both replay I/O strategies).
//!
//! Why this must hold: a trace stores exactly the consumed per-core
//! stream (warmup included), every execution mode consumes exactly
//! `warmup + accesses` records per core, and workload streams are
//! per-core pure — so the replayed front end feeds every slice the same
//! sub-stream the live generator would have (see `trace::replay`'s
//! module docs). The second half of the file locks the failure side:
//! corruption anywhere in a trace file surfaces as a *typed*
//! `TraceError` (wrapped in `EngineError::Trace` by the engine), never
//! as a panic or a garbage replay.

mod common;

use std::path::{Path, PathBuf};

use trimma::config::presets::DesignPoint;
use trimma::config::{SystemConfig, TraceReplayMode};
use trimma::engine::{EngineBuilder, EngineError};
use trimma::trace::{self, TraceError};
use trimma::workloads::adversarial::ADVERSARIAL;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("trimma-parity-{}-{tag}.trimtrace", std::process::id()))
}

/// Record `wl` under `cfg` through the closed loop, returning the live
/// run's canonical stats (the recording tap is pure observation, so the
/// report `run_recorded` returns *is* the live closed-loop run).
fn record(cfg: &SystemConfig, wl: &str, path: &Path) -> Vec<u64> {
    EngineBuilder::from_config(cfg.clone())
        .workload(wl)
        .run_recorded(path)
        .unwrap_or_else(|e| panic!("recording {wl}: {e}"))
        .stats
        .canonical()
}

/// Replay `path` under `cfg` through the sharded open loop.
fn replay_sharded(
    cfg: &SystemConfig,
    path: &Path,
    mode: TraceReplayMode,
    shards: usize,
    pipeline: bool,
) -> Vec<u64> {
    let mut cfg = cfg.clone();
    cfg.trace.replay = mode;
    EngineBuilder::from_config(cfg)
        .trace(path)
        .shards(shards)
        .pipeline(pipeline)
        .run_sharded()
        .unwrap_or_else(|e| panic!("replay x{shards} pipeline={pipeline} {mode:?}: {e}"))
        .stats
        .canonical()
}

/// The full parity matrix, per adversarial scenario: the closed-loop
/// replay must equal the live closed-loop run, and the sharded replays
/// (shards 1/2/4 x inline/pipelined x buffered/read-ahead) must equal
/// the live 1-shard open-loop run (open- and closed-loop stats differ by
/// design — constant nominal vs. real miss latencies — so each replay is
/// compared against the live run of its own execution model).
#[test]
fn replaying_a_recording_reproduces_the_live_stats_everywhere() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    for wl in ADVERSARIAL {
        let path = tmp(wl);
        let live_closed = record(&cfg, wl, &path);

        let replay_closed = EngineBuilder::from_config(cfg.clone())
            .trace(&path)
            .run()
            .unwrap_or_else(|e| panic!("{wl}: closed replay: {e}"));
        assert_eq!(replay_closed.name, *wl, "{wl}: replay must report the recorded label");
        assert!(replay_closed.stats.mem_accesses > 0, "{wl}: nothing reached memory");
        assert_eq!(
            replay_closed.stats.canonical(),
            live_closed,
            "{wl}: closed-loop replay diverged from the live run"
        );

        let live_sharded = EngineBuilder::from_config(cfg.clone())
            .workload(*wl)
            .shards(1)
            .run_sharded()
            .unwrap_or_else(|e| panic!("{wl}: live sharded: {e}"))
            .stats
            .canonical();
        for mode in [TraceReplayMode::Buffered, TraceReplayMode::ReadAhead] {
            for shards in [1usize, 2, 4] {
                for pipeline in [false, true] {
                    assert_eq!(
                        replay_sharded(&cfg, &path, mode, shards, pipeline),
                        live_sharded,
                        "{wl}: {mode:?} replay x{shards} pipeline={pipeline} \
                         diverged from the live open-loop run"
                    );
                }
            }
        }
        std::fs::remove_file(&path).unwrap();
    }
}

/// Replay is deterministic run-to-run, including the read-ahead mode
/// (fresh I/O thread, fresh ring schedule each time).
#[test]
fn readahead_replay_is_deterministic_run_to_run() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let path = tmp("determinism");
    record(&cfg, "adv_migration_storm", &path);
    let a = replay_sharded(&cfg, &path, TraceReplayMode::ReadAhead, 4, true);
    let b = replay_sharded(&cfg, &path, TraceReplayMode::ReadAhead, 4, true);
    assert_eq!(a, b);
    std::fs::remove_file(&path).unwrap();
}

/// The `trace:<path>` workload-registry entry drives the same replay:
/// one recording, replayed by name through the ordinary workload-building
/// path, reproduces the live closed-loop run.
#[test]
fn trace_name_prefix_replays_through_the_registry() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let path = tmp("registry");
    let live = record(&cfg, "adv_pointer_chase", &path);
    let rep = EngineBuilder::from_config(cfg.clone())
        .workload(format!("trace:{}", path.display()))
        .run()
        .unwrap();
    assert_eq!(rep.stats.canonical(), live);
    std::fs::remove_file(&path).unwrap();
}

/// Write a corrupted copy of `good` (mutated by `mutate`), then assert
/// that both the standalone validator and an engine-level replay attempt
/// reject it with the expected *typed* error (checked by `is_expected`) —
/// no panics, no garbage replays.
fn check_corruption(
    cfg: &SystemConfig,
    good: &Path,
    tag: &str,
    mutate: impl FnOnce(&mut Vec<u8>),
    is_expected: impl Fn(&TraceError) -> bool,
) {
    let bad = tmp(&format!("corrupt-{tag}"));
    let mut bytes = std::fs::read(good).unwrap();
    mutate(&mut bytes);
    std::fs::write(&bad, &bytes).unwrap();

    let err = trace::validate(&bad).expect_err(tag);
    assert!(is_expected(&err), "{tag}: unexpected error {err:?}");
    let engine_err = EngineBuilder::from_config(cfg.clone()).trace(&bad).run().expect_err(tag);
    match &engine_err {
        EngineError::Trace(e) => {
            assert!(is_expected(e), "{tag}: engine wrapped the wrong error: {e:?}")
        }
        other => panic!("{tag}: expected EngineError::Trace, got {other:?}"),
    }
    std::fs::remove_file(&bad).unwrap();
}

/// Every corruption mode yields a *typed* error — from the standalone
/// validator and from an engine-level replay attempt alike — and never a
/// panic. The validator on the pristine file doubles as the
/// record-totals check.
#[test]
fn corruption_is_rejected_with_typed_errors_not_panics() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let good = tmp("corrupt-src");
    record(&cfg, "adv_set_thrash", &good);

    let summary = trace::validate(&good).expect("pristine file validates");
    let w = &cfg.workload;
    assert_eq!(
        summary.total_records,
        u64::from(w.cores) * (w.warmup_per_core + w.accesses_per_core),
        "trace must store exactly the consumed stream"
    );
    // The first chunk's payload starts right after the variable-length
    // header: 88 fixed bytes, the workload name, the header CRC, then the
    // 12-byte chunk header.
    let name_len = {
        let bytes = std::fs::read(&good).unwrap();
        u32::from_le_bytes(bytes[84..88].try_into().unwrap()) as usize
    };
    let first_payload_byte = 88 + name_len + 4 + 12;

    check_corruption(&cfg, &good, "magic", |b| b[0] ^= 0xFF, |e| {
        matches!(e, TraceError::BadMagic)
    });
    check_corruption(&cfg, &good, "header", |b| b[16] ^= 0xFF, |e| {
        matches!(e, TraceError::CorruptHeader(_))
    });
    check_corruption(
        &cfg,
        &good,
        "truncated",
        |b| b.truncate(b.len() / 2),
        |e| matches!(e, TraceError::CorruptIndex(_)),
    );
    check_corruption(
        &cfg,
        &good,
        "chunk-crc",
        move |b| b[first_payload_byte] ^= 0xFF,
        |e| matches!(e, TraceError::ChunkCrcMismatch { .. }),
    );
    std::fs::remove_file(&good).unwrap();
}

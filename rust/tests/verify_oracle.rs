//! The differential remap oracle under fire: every adversarial scenario
//! runs green on every design point with `cfg.hybrid.verify` enabled, and
//! the oracle demonstrably *fires* when fed a controller that commits the
//! canonical remap sin (writing a forward mapping without its inverse —
//! exactly the mutation class a bad refactor of `hybrid/remap.rs` would
//! introduce).

mod common;

use std::panic::{catch_unwind, AssertUnwindSafe};

use trimma::config::presets::DesignPoint;
use trimma::hybrid::Controller;
use trimma::metadata::SetLayout;
use trimma::sim::Simulation;
use trimma::stats::Stats;
use trimma::types::{AccessKind, Cycle};
use trimma::verify::CheckedController;
use trimma::workloads::{self, adversarial::ADVERSARIAL};

/// The six evaluated design points (plus the Ideal oracle, which must also
/// stay self-consistent under verification).
const DESIGNS: &[DesignPoint] = &[
    DesignPoint::AlloyCache,
    DesignPoint::LohHill,
    DesignPoint::TrimmaCache,
    DesignPoint::MemPod,
    DesignPoint::TrimmaFlat,
    DesignPoint::LinearCache,
    DesignPoint::Ideal,
];

#[test]
fn adversarial_scenarios_green_under_oracle_all_design_points() {
    for dp in DESIGNS {
        for sc in ADVERSARIAL {
            let mut cfg = common::tiny(*dp);
            cfg.hybrid.verify = true;
            cfg.workload.accesses_per_core = 1200;
            cfg.workload.warmup_per_core = 400;
            let stats = common::run(*dp, &cfg, sc);
            assert!(
                stats.mem_accesses > 0,
                "{dp:?}/{sc}: scenario must reach the memory controller"
            );
        }
    }
}

#[test]
fn suite_workloads_green_under_oracle() {
    // A cross-section of the calibrated suite also passes verification on
    // the two Trimma design points (streaming, pointer-chase, key-value).
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        for wl in ["519.lbm_r", "505.mcf_r", "ycsb_a"] {
            let mut cfg = common::tiny(dp);
            cfg.hybrid.verify = true;
            cfg.workload.accesses_per_core = 1200;
            cfg.workload.warmup_per_core = 400;
            let stats = common::run(dp, &cfg, wl);
            assert!(stats.mem_accesses > 0, "{dp:?}/{wl}");
        }
    }
}

#[test]
fn oracle_stats_match_unverified_run() {
    // The wrapper must be observation-only: enabling verification changes
    // no stat anywhere, for any scenario.
    for sc in ADVERSARIAL {
        let dp = DesignPoint::TrimmaCache;
        let plain = common::run(dp, &common::tiny(dp), sc);
        let mut vcfg = common::tiny(dp);
        vcfg.hybrid.verify = true;
        let checked = common::run(dp, &vcfg, sc);
        assert_eq!(
            plain.canonical(),
            checked.canonical(),
            "{sc}: verification must not perturb the simulation"
        );
    }
}

// ---------------- the oracle must actually fire ----------------

/// A deliberately broken controller: on a slow-tier miss it installs the
/// forward remap entry but "forgets" the inverse entry — the seeded
/// mutation of the acceptance criteria (skipping the inverse-entry write
/// on a swap/fill in `hybrid/remap.rs`).
struct ForgottenInverse {
    layout: SetLayout,
    map: std::collections::HashMap<(u32, u64), u64>,
    next_slot: u64,
    stats: Stats,
}

impl ForgottenInverse {
    fn new(layout: SetLayout) -> Self {
        ForgottenInverse {
            layout,
            map: std::collections::HashMap::new(),
            next_slot: 0,
            stats: Stats::default(),
        }
    }

    fn lookup(&self, set: u32, idx: u64) -> u64 {
        *self.map.get(&(set, idx)).unwrap_or(&idx)
    }
}

impl Controller for ForgottenInverse {
    fn access(&mut self, set: u32, idx: u64, _line: u32, kind: AccessKind, _now: Cycle) -> Cycle {
        self.stats.mem_accesses += 1;
        match kind {
            AccessKind::Read => self.stats.mem_reads += 1,
            AccessKind::Write => self.stats.mem_writes += 1,
        }
        let device = self.lookup(set, idx);
        let lat = if self.layout.is_fast_idx(device) {
            self.stats.fast_served += 1;
            self.stats.fast_data_cycles += 50;
            50
        } else {
            self.stats.slow_served += 1;
            self.stats.slow_data_cycles += 200;
            // Demand "fill": forward entry only. A correct controller would
            // also write the inverse entry for the claimed slot.
            let slot = self.next_slot % self.layout.fast_per_set;
            self.next_slot += 1;
            self.map.insert((set, idx), slot);
            200
        };
        lat
    }

    fn finalize(&mut self) {}

    fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    fn stats(&self) -> &Stats {
        &self.stats
    }

    fn layout(&self) -> &SetLayout {
        &self.layout
    }

    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        Some(self.lookup(set, idx))
    }
}

#[test]
fn oracle_kills_missing_inverse_entry() {
    let cfg = {
        let mut c = common::tiny(DesignPoint::TrimmaCache);
        c.hybrid.verify = true;
        c
    };
    let layout = SetLayout::for_config(&cfg.hybrid, false);
    // The generic wrapper takes the mutant directly — no boxing needed.
    let mut checked = CheckedController::new(ForgottenInverse::new(layout), &cfg);
    let slow_idx = layout.fast_per_set + 7;
    let result = catch_unwind(AssertUnwindSafe(|| {
        // Miss installs the one-sided mapping; the post-access involution
        // check must already reject it.
        checked.access(0, slow_idx, 0, AccessKind::Read, 0);
        // Belt and braces: a second access trips the pre-access check too.
        checked.access(0, slow_idx, 0, AccessKind::Read, 1000);
    }));
    assert!(
        result.is_err(),
        "the oracle must reject a forward mapping without its inverse"
    );
}

#[test]
fn oracle_kills_wrong_tier_serve() {
    /// Serves from the fast tier while the translation says slow.
    struct WrongTier {
        layout: SetLayout,
        stats: Stats,
    }
    impl Controller for WrongTier {
        fn access(
            &mut self,
            _set: u32,
            _idx: u64,
            _line: u32,
            kind: AccessKind,
            _now: Cycle,
        ) -> Cycle {
            self.stats.mem_accesses += 1;
            match kind {
                AccessKind::Read => self.stats.mem_reads += 1,
                AccessKind::Write => self.stats.mem_writes += 1,
            }
            self.stats.fast_served += 1; // translation says slow: lie
            self.stats.fast_data_cycles += 50;
            50
        }
        fn finalize(&mut self) {}
        fn reset_stats(&mut self) {
            self.stats = Stats::default();
        }
        fn stats(&self) -> &Stats {
            &self.stats
        }
        fn layout(&self) -> &SetLayout {
            &self.layout
        }
        fn debug_translate(&self, _set: u32, idx: u64) -> Option<u64> {
            Some(idx) // identity: a slow idx stays slow
        }
    }

    let cfg = {
        let mut c = common::tiny(DesignPoint::TrimmaCache);
        c.hybrid.verify = true;
        c
    };
    let layout = SetLayout::for_config(&cfg.hybrid, false);
    let mut checked = CheckedController::new(WrongTier { layout, stats: Stats::default() }, &cfg);
    let slow_idx = layout.fast_per_set + 3;
    let result = catch_unwind(AssertUnwindSafe(|| {
        checked.access(0, slow_idx, 0, AccessKind::Read, 0);
    }));
    assert!(result.is_err(), "fast-serving a slow-mapped block must be rejected");
}

#[test]
fn oracle_end_to_end_through_simulation() {
    // Full stack: Simulation -> AnyController::Checked -> controller.
    let mut cfg = common::tiny(DesignPoint::TrimmaFlat);
    cfg.hybrid.verify = true;
    let wl = workloads::by_name("adv_migration_storm", &cfg).unwrap();
    let rep = Simulation::new(&cfg, wl).run();
    assert!(rep.stats.mem_accesses > 0);
    assert!(rep.stats.fills > 0, "the storm must trigger migrations");
}

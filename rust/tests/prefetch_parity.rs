//! Batched-prefetch parity: the headline invariant of the two-phase
//! prefetched translate stage (DESIGN.md §15) is that it is
//! **semantically invisible** — phase 1 only walks read-only
//! `prefetch_targets` addresses and issues `prefetch` hints, phase 2 runs
//! the exact per-access loop the non-prefetched path runs, in the exact
//! original order. So the merged canonical stat vector of a prefetch-on
//! run must equal the prefetch-off run byte-for-byte once the
//! `batch_prefetches` counter itself (the only counter the walk touches)
//! is stripped; and with prefetch on on *both* sides, runs must stay
//! byte-identical — `batch_prefetches` included — across shard counts and
//! across the inline/pipelined front ends, because every access passes
//! through `access_block` exactly once in the sharded model.

mod common;

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::engine::EngineBuilder;
use trimma::sim::SimReport;
use trimma::workloads::adversarial::ADVERSARIAL;

fn run(
    dp: DesignPoint,
    cfg: &SystemConfig,
    wl: &str,
    shards: usize,
    pipeline: bool,
    prefetch: bool,
) -> SimReport {
    EngineBuilder::from_config(cfg.clone())
        .workload(wl)
        .ideal(dp == DesignPoint::Ideal)
        .shards(shards)
        .pipeline(pipeline)
        .prefetch(prefetch)
        .run_sharded()
        .unwrap_or_else(|e| panic!("{dp:?}/{wl} x{shards} prefetch={prefetch}: {e}"))
}

/// Drop one `name=value` pair from a canonical stat string — the on/off
/// comparisons below strip `batch_prefetches`, which by design is the only
/// counter allowed to differ between the two modes.
fn strip(canon: &str, counter: &str) -> String {
    let prefix = format!("{counter}=");
    canon.split(';').filter(|p| !p.starts_with(&prefix)).collect::<Vec<_>>().join(";")
}

/// The full matrix: every design point x every adversarial scenario,
/// prefetch off vs on. Everything except the `batch_prefetches` count must
/// be byte-identical; the off run must never prefetch, and the on run must
/// actually walk batches on every non-ideal design point.
#[test]
fn prefetch_never_changes_the_canonical_stats() {
    for dp in DesignPoint::ALL {
        let cfg = common::tiny(*dp);
        for wl in ADVERSARIAL {
            let off = run(*dp, &cfg, wl, 1, false, false);
            assert!(off.stats.mem_accesses > 0, "{dp:?}/{wl}: nothing reached memory");
            assert_eq!(off.stats.batch_prefetches, 0, "{dp:?}/{wl}: off run prefetched");
            let on = run(*dp, &cfg, wl, 1, false, true);
            // Only the remap-backed design points carry the two-phase
            // walk; the tag-based controllers (Alloy, Loh-Hill) and the
            // metadata-free Ideal oracle use the default per-access loop
            // and must leave the counter at zero even with the knob on.
            let walks = matches!(
                *dp,
                DesignPoint::TrimmaCache
                    | DesignPoint::TrimmaFlat
                    | DesignPoint::LinearCache
                    | DesignPoint::MemPod
            );
            if walks {
                assert!(
                    on.stats.batch_prefetches > 0,
                    "{dp:?}/{wl}: prefetch-on run never walked a batch"
                );
            } else {
                assert_eq!(
                    on.stats.batch_prefetches, 0,
                    "{dp:?}/{wl}: a non-remap controller prefetched"
                );
            }
            assert_eq!(
                strip(&on.stats.canonical(), "batch_prefetches"),
                strip(&off.stats.canonical(), "batch_prefetches"),
                "{dp:?}/{wl}: the prefetched walk changed observable behavior"
            );
        }
    }
}

/// With prefetch on on both sides, no stripping: the reference 1-shard
/// inline run must be reproduced byte-for-byte — `batch_prefetches`
/// included — at 1, 2, and 4 shards, inline and pipelined. Every access
/// flows through `access_block` exactly once regardless of sharding, so
/// even the prefetch count is invariant.
#[test]
fn prefetch_on_is_byte_identical_across_shards_and_pipeline() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
        let cfg = common::tiny(dp);
        let base = run(dp, &cfg, "adv_set_thrash", 1, false, true);
        assert!(base.stats.batch_prefetches > 0, "{dp:?}: reference run never prefetched");
        let base_canon = base.stats.canonical();
        for n in [1usize, 2, 4] {
            for pipeline in [false, true] {
                let got = run(dp, &cfg, "adv_set_thrash", n, pipeline, true).stats.canonical();
                assert_eq!(
                    got, base_canon,
                    "{dp:?}: prefetch-on {n}-shard pipeline={pipeline} run diverged"
                );
            }
        }
    }
}

/// The differential remap oracle composes with the prefetch knob: the
/// checked controller wraps the real one behind the per-access `access`
/// entry point (it carries no `access_block` override), so under `verify`
/// the prefetched walk is simply never reached — the run must stay green
/// and the counter must stay zero.
#[test]
fn prefetch_composes_with_the_differential_oracle() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
        let cfg = presets::with_verify(common::tiny(dp));
        let rep = run(dp, &cfg, "adv_migration_storm", 2, true, true);
        assert!(rep.stats.mem_accesses > 0, "{dp:?}");
        assert_eq!(
            rep.stats.batch_prefetches, 0,
            "{dp:?}: the checked controller must keep the prefetched walk inert"
        );
    }
}

/// Prefetch composes with the other steady-state subsystems riding the
/// same translate path: with decay and fault injection both firing, the
/// on/off runs must still agree on everything but the prefetch counter.
#[test]
fn prefetch_composes_with_decay_and_faults() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let build = |prefetch: bool| {
        EngineBuilder::from_config(cfg.clone())
            .workload("adv_metadata_bloat")
            .shards(2)
            .decay(true)
            .faults(true)
            .prefetch(prefetch)
            .run_sharded()
            .unwrap_or_else(|e| panic!("decay+faults prefetch={prefetch}: {e}"))
    };
    let off = build(false);
    let on = build(true);
    assert!(on.stats.batch_prefetches > 0, "composed run never prefetched");
    assert_eq!(
        strip(&on.stats.canonical(), "batch_prefetches"),
        strip(&off.stats.canonical(), "batch_prefetches"),
        "prefetch changed behavior under decay + fault injection"
    );
}

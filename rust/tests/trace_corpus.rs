//! Golden trace corpus: tiny checked-in `.trimtrc` files, constructed
//! byte-by-byte from the format spec by `scripts/make_trace_corpus.py`
//! (NOT by the Rust writer), that pin the on-disk trace format and its
//! replay semantics. Whatever `trace::format` evolves into, it must keep
//! parsing these files, and replaying them must keep producing the
//! canonical stat vectors locked in `tests/golden/trace_stats.json`
//! (same insta-style bless-on-first-run workflow as `tests/golden.rs`:
//! absent combinations are blessed and printed — commit the file;
//! re-bless intentional changes with `TRIMMA_BLESS=1`).

mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use trimma::config::presets::DesignPoint;
use trimma::config::{SystemConfig, TraceReplayMode};
use trimma::sim::Simulation;
use trimma::stats::Stats;
use trimma::trace;
use trimma::workloads;

/// Per-file expectations, mirrored from the generator script.
struct Corpus {
    file: &'static str,
    name: &'static str,
    cores: u32,
    warmup: u64,
    accesses: u64,
    chunks: u32,
}

const CORPUS: &[Corpus] = &[
    Corpus {
        file: "corpus_seq_raw.trimtrc",
        name: "corpus_seq_raw",
        cores: 2,
        warmup: 64,
        accesses: 192,
        chunks: 2,
    },
    Corpus {
        file: "corpus_stride_delta.trimtrc",
        name: "corpus_stride_delta",
        cores: 2,
        warmup: 32,
        accesses: 288,
        chunks: 6,
    },
    Corpus {
        file: "corpus_solo_delta.trimtrc",
        name: "corpus_solo_delta",
        cores: 1,
        warmup: 16,
        accesses: 240,
        chunks: 3,
    },
];

fn trace_path(file: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/traces").join(file)
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/trace_stats.json")
}

/// Same one-pair-per-line snapshot format as `tests/golden.rs`.
fn load(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((key, rest)) = rest.split_once("\": \"") else { continue };
        let Some(value) = rest.strip_suffix('"') else { continue };
        map.insert(key.to_string(), value.to_string());
    }
    map
}

fn save(map: &BTreeMap<String, String>) -> String {
    let mut out = String::from("{\n");
    for (i, (k, v)) in map.iter().enumerate() {
        out.push_str(&format!("  \"{k}\": \"{v}\""));
        out.push_str(if i + 1 < map.len() { ",\n" } else { "\n" });
    }
    out.push_str("}\n");
    out
}

fn first_diff(want: &str, got: &str) -> String {
    for (w, g) in want.split(';').zip(got.split(';')) {
        if w != g {
            return format!("expected `{w}`, got `{g}`");
        }
    }
    "vectors differ".to_string()
}

/// Shape a tiny config to the trace header's run identity (which
/// `TraceWorkload::open` insists on) for design point `dp`.
fn cfg_for(c: &Corpus, dp: DesignPoint, replay: TraceReplayMode) -> SystemConfig {
    let mut cfg = common::tiny(dp);
    cfg.workload.cores = c.cores;
    cfg.workload.warmup_per_core = c.warmup;
    cfg.workload.accesses_per_core = c.accesses;
    cfg.trace.replay = replay;
    cfg
}

fn replay(c: &Corpus, dp: DesignPoint, mode: TraceReplayMode) -> Stats {
    let cfg = cfg_for(c, dp, mode);
    let spec = format!("trace:{}", trace_path(c.file).display());
    let wl = workloads::by_name(&spec, &cfg).unwrap_or_else(|e| panic!("{}: {e}", c.file));
    Simulation::new(&cfg, wl).run().stats
}

#[test]
fn corpus_files_validate_against_their_spec() {
    for c in CORPUS {
        let s = trace::validate(&trace_path(c.file)).unwrap_or_else(|e| panic!("{}: {e}", c.file));
        assert_eq!(s.meta.name, c.name, "{}", c.file);
        assert_eq!(s.meta.cores, c.cores, "{}", c.file);
        assert_eq!(s.meta.warmup_per_core, c.warmup, "{}", c.file);
        assert_eq!(s.meta.accesses_per_core, c.accesses, "{}", c.file);
        assert_eq!(s.chunk_count, c.chunks, "{}", c.file);
        assert_eq!(s.total_records, c.cores as u64 * (c.warmup + c.accesses), "{}", c.file);
    }
}

#[test]
fn corpus_replay_stats_match_golden() {
    let path = golden_path();
    let mut golden = load(&std::fs::read_to_string(&path).unwrap_or_default());
    let bless_all = std::env::var("TRIMMA_BLESS").is_ok();

    let mut blessed = Vec::new();
    let mut failures = Vec::new();
    for c in CORPUS {
        for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
            let key = format!("{}/{}", c.name, dp.label());
            let stats = replay(c, dp, TraceReplayMode::Buffered);
            assert!(stats.mem_accesses > 0, "{key}: replay never reached memory");
            let got = stats.canonical();
            match golden.get(&key).cloned() {
                Some(want) if want == got => {}
                Some(_) if bless_all => {
                    golden.insert(key.clone(), got);
                    blessed.push(key);
                }
                Some(want) => failures.push(format!("  {key}: {}", first_diff(&want, &got))),
                None => {
                    golden.insert(key.clone(), got);
                    blessed.push(key);
                }
            }
        }
    }

    if !blessed.is_empty() {
        std::fs::write(&path, save(&golden)).expect("write trace golden snapshots");
        eprintln!(
            "trace corpus: blessed {} new snapshot(s) into {} — commit the file:\n  {}",
            blessed.len(),
            path.display(),
            blessed.join("\n  ")
        );
    }
    assert!(
        failures.is_empty(),
        "trace-corpus replay stats drifted (re-bless intentional changes with \
         TRIMMA_BLESS=1):\n{}",
        failures.join("\n")
    );
}

#[test]
fn corpus_replay_is_io_mode_invariant() {
    // Buffered and read-ahead replay must be byte-identical — the corpus
    // exercises both the raw and the delta decode paths through each.
    for c in CORPUS {
        let buf = replay(c, DesignPoint::TrimmaCache, TraceReplayMode::Buffered);
        let ra = replay(c, DesignPoint::TrimmaCache, TraceReplayMode::ReadAhead);
        assert_eq!(buf.canonical(), ra.canonical(), "{}: I/O modes diverged", c.file);
    }
}

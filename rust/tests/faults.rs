//! Chaos-matrix tests of deterministic fault injection and degraded-mode
//! recovery (DESIGN.md §14): every design point must run green under the
//! verify oracle with faults firing, merged stats must stay byte-identical
//! across shard counts and frontend modes with faults on, a disabled
//! injector must be byte-identical to a config that never heard of faults,
//! quarantine must compose with MEA-epoch decay under the oracle, and
//! retry exhaustion must surface as a typed error.

mod common;

use trimma::config::presets::DesignPoint;
use trimma::config::{FaultConfig, SystemConfig};
use trimma::engine::EngineBuilder;
use trimma::hybrid::fault::FaultInjector;
use trimma::stats::Stats;

/// The scenario built for the injector: a drifting hot region keeps live
/// remapped pairs in every set (flip targets) while wide probes keep
/// slow-tier reads flowing (transient targets).
const STORM: &str = "adv_fault_storm";

/// Design points whose controller is the remap engine (and not the Ideal
/// oracle): the only ones where the injector actually fires.
const REMAP: &[DesignPoint] = &[
    DesignPoint::TrimmaCache,
    DesignPoint::MemPod,
    DesignPoint::TrimmaFlat,
    DesignPoint::LinearCache,
];

/// Moderate profile: every class armed at rates a tiny run crosses many
/// times, without drowning the workload in quarantines.
fn moderate(cfg: &mut SystemConfig) {
    cfg.hybrid.fault.enabled = true;
    cfg.hybrid.fault.metadata_flip_milli = 50;
    cfg.hybrid.fault.transient_read_milli = 100;
    cfg.hybrid.fault.stuck_set_milli = 0;
}

/// Storm profile: high flip and transient rates plus a real chance of
/// stuck sets, so scrub, rebuild, retry and quarantine all trigger.
fn storm(cfg: &mut SystemConfig) {
    cfg.hybrid.fault.enabled = true;
    cfg.hybrid.fault.metadata_flip_milli = 300;
    cfg.hybrid.fault.transient_read_milli = 500;
    cfg.hybrid.fault.stuck_set_milli = 250;
    cfg.hybrid.fault.max_retries = 3;
    cfg.hybrid.fault.backoff_base = 32;
}

fn fault_counters(s: &Stats) -> [u64; 5] {
    [s.fault_injected, s.fault_retried, s.fault_scrubbed, s.fault_rebuilt, s.fault_quarantined]
}

#[test]
fn chaos_matrix_is_green_under_oracle() {
    // Every design point x scenario x fault profile runs to completion
    // with the verify oracle checking mappings and the latency breakdown
    // on every access. The injector is structurally inert on the
    // tag-matching baselines and the Ideal oracle.
    let scenarios = [STORM, "adv_migration_storm", "adv_identity_flip"];
    let profiles: [(&str, fn(&mut SystemConfig)); 2] =
        [("moderate", moderate), ("storm", storm)];
    for dp in DesignPoint::ALL {
        for wl in scenarios {
            for (pname, profile) in profiles {
                let mut cfg = common::tiny(*dp);
                profile(&mut cfg);
                cfg.hybrid.verify = true;
                let stats = common::run(*dp, &cfg, wl);
                if REMAP.contains(dp) {
                    assert!(
                        stats.fault_injected > 0,
                        "{dp:?}/{wl}/{pname}: armed injector never fired"
                    );
                } else {
                    assert_eq!(
                        fault_counters(&stats),
                        [0; 5],
                        "{dp:?}/{wl}/{pname}: injector must be inert here"
                    );
                }
            }
        }
    }
}

#[test]
fn faulted_stats_shard_and_pipeline_invariant() {
    // Fault decisions are pure hashes of (seed, set, per-set counter) and
    // slice partitioning is geometry-only, so merged stats with faults
    // firing must stay byte-identical across shard counts and across the
    // inline vs pipelined frontend.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let run = |shards: usize, pipeline: bool| {
            EngineBuilder::new(dp)
                .workload(STORM)
                .faults(true)
                .configure(|cfg| {
                    cfg.hybrid.fast_bytes = 1 << 20;
                    cfg.hybrid.slow_bytes = 32 << 20;
                    cfg.hybrid.num_sets = 4;
                    cfg.workload.cores = 2;
                    cfg.workload.accesses_per_core = 3000;
                    cfg.workload.warmup_per_core = 500;
                    storm(cfg);
                })
                .shards(shards)
                .pipeline(pipeline)
                .run_sharded()
                .unwrap_or_else(|e| panic!("{e}"))
                .stats
        };
        let base = run(1, false);
        assert!(base.fault_injected > 0, "{dp:?}: parity run must exercise faults");
        for shards in [1usize, 2, 4] {
            for pipeline in [false, true] {
                assert_eq!(
                    base.canonical(),
                    run(shards, pipeline).canonical(),
                    "{dp:?}: {shards} shards / pipeline={pipeline} diverged"
                );
            }
        }
    }
}

#[test]
fn disabled_injector_is_byte_identical_to_no_injector() {
    // `--faults` left off must not perturb a single stat: a config with
    // every fault knob cranked but `enabled = false` is byte-identical to
    // the untouched config, for every design point.
    for dp in DesignPoint::ALL {
        let plain = common::run(*dp, &common::tiny(*dp), STORM);
        let mut cfg = common::tiny(*dp);
        storm(&mut cfg);
        cfg.hybrid.fault.enabled = false;
        let off = common::run(*dp, &cfg, STORM);
        assert_eq!(fault_counters(&off), [0; 5], "{dp:?}");
        assert_eq!(plain.canonical(), off.canonical(), "{dp:?}: disabled injector perturbed stats");
    }
}

#[test]
fn quarantine_composes_with_decay_under_oracle() {
    // Retry exhaustion quarantines sets mid-run while MEA-epoch decay is
    // sweeping the same sets: cursors, free stacks and donated-slot
    // accounting must survive both (the oracle audits every access).
    for dp in [DesignPoint::TrimmaFlat, DesignPoint::MemPod] {
        let mut cfg = common::tiny(dp);
        cfg.workload.accesses_per_core = 6000;
        cfg.hybrid.verify = true;
        cfg.hybrid.decay.enabled = true;
        cfg.hybrid.decay.epoch_accesses = 32;
        cfg.hybrid.decay.pressure_milli = 0;
        cfg.hybrid.decay.sweep_budget = 256;
        cfg.hybrid.decay.cold_epochs = 1;
        cfg.hybrid.fault.enabled = true;
        cfg.hybrid.fault.metadata_flip_milli = 100;
        cfg.hybrid.fault.transient_read_milli = 450;
        let stats = common::run(dp, &cfg, STORM);
        assert!(stats.fault_quarantined > 0, "{dp:?}: run must reach quarantine");
        assert!(stats.decay_epochs > 0, "{dp:?}: run must tick decay epochs");
    }
}

#[test]
fn retry_exhaustion_is_a_typed_error() {
    // A certain-to-fail transient stream exhausts its retry budget on the
    // first probe and surfaces the full deterministic backoff as a typed,
    // std::error::Error-implementing value.
    let cfg = FaultConfig {
        enabled: true,
        transient_read_milli: 1000,
        max_retries: 3,
        backoff_base: 64,
        ..FaultConfig::off()
    };
    let mut inj = FaultInjector::new(cfg, true, 4);
    let err = inj
        .transient_read(2)
        .expect("certain rate must fire")
        .expect_err("certain rate must exhaust every retry");
    assert_eq!(err.set, 2);
    assert_eq!(err.attempts, 3);
    assert_eq!(err.backoff, 64 + 128 + 256);
    let msg = format!("{err}");
    assert!(msg.contains("set 2"), "display must name the set: {msg}");
    let _: &dyn std::error::Error = &err;
}

//! Golden-stats snapshot harness: locks the full end-of-run stat vector of
//! every `DesignPoint x adversarial scenario` combination, byte for byte,
//! against `tests/golden/stats.json`.
//!
//! This is the safety net for hot-path refactors of `hybrid/remap.rs` and
//! the metadata structures: any change that perturbs a single counter in a
//! single combination fails here with the exact field that moved.
//!
//! Snapshot workflow (insta-style bless-on-first-run):
//! * a combination present in the JSON must match exactly — mismatch fails
//!   the test and names the first differing counter;
//! * a combination absent from the JSON is *blessed*: the harness appends
//!   it and passes, printing what it added. Commit the updated file;
//! * an intentional behavior change is re-blessed by running with
//!   `TRIMMA_BLESS=1` and committing the rewritten file.
//!
//! The file is JSON with one string value per combination — the value is
//! the canonical `name=value;...` stat vector of [`trimma::stats::Stats::canonical`],
//! so "byte-for-byte" comparison is plain string equality and the file
//! stays mergeable line by line.

mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use trimma::config::presets::DesignPoint;
use trimma::workloads::adversarial::ADVERSARIAL;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/stats.json")
}

/// Parse the snapshot file. Format (written by `save` below): one
/// `"key": "value"` pair per line inside a single object, no escapes.
fn load(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((key, rest)) = rest.split_once("\": \"") else { continue };
        let Some(value) = rest.strip_suffix('"') else { continue };
        map.insert(key.to_string(), value.to_string());
    }
    map
}

fn save(map: &BTreeMap<String, String>) -> String {
    let mut out = String::from("{\n");
    for (i, (k, v)) in map.iter().enumerate() {
        out.push_str(&format!("  \"{k}\": \"{v}\""));
        out.push_str(if i + 1 < map.len() { ",\n" } else { "\n" });
    }
    out.push_str("}\n");
    out
}

/// Point out the first differing counter between two canonical vectors.
fn first_diff(want: &str, got: &str) -> String {
    for (w, g) in want.split(';').zip(got.split(';')) {
        if w != g {
            return format!("expected `{w}`, got `{g}`");
        }
    }
    let (nw, ng) = (want.split(';').count(), got.split(';').count());
    if nw != ng {
        return format!("field count changed: {nw} -> {ng}");
    }
    "vectors differ".to_string()
}

#[test]
fn golden_stats_all_design_points_all_scenarios() {
    let path = golden_path();
    let mut golden = load(&std::fs::read_to_string(&path).unwrap_or_default());
    let bless_all = std::env::var("TRIMMA_BLESS").is_ok();

    let mut blessed = Vec::new();
    let mut failures = Vec::new();
    for dp in DesignPoint::ALL {
        for sc in ADVERSARIAL {
            let key = format!("{}/{}", dp.label(), sc);
            let stats = common::run(*dp, &common::tiny(*dp), sc);
            assert!(stats.mem_accesses > 0, "{key}: scenario never reached memory");
            let got = stats.canonical();
            match golden.get(&key).cloned() {
                Some(want) if want == got => {}
                Some(_) if bless_all => {
                    golden.insert(key.clone(), got);
                    blessed.push(key);
                }
                Some(want) => {
                    failures.push(format!("  {key}: {}", first_diff(&want, &got)));
                }
                None => {
                    golden.insert(key.clone(), got);
                    blessed.push(key);
                }
            }
        }
    }

    if !blessed.is_empty() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
        std::fs::write(&path, save(&golden)).expect("write golden snapshots");
        eprintln!(
            "golden: blessed {} new snapshot(s) into {} — commit the file:\n  {}",
            blessed.len(),
            path.display(),
            blessed.join("\n  ")
        );
    }
    assert!(
        failures.is_empty(),
        "golden stat vectors drifted (re-bless intentional changes with \
         TRIMMA_BLESS=1):\n{}",
        failures.join("\n")
    );
}

#[test]
fn golden_roundtrip_format() {
    let mut m = BTreeMap::new();
    m.insert("trimma-c/adv_drift".to_string(), "a=1;b=2".to_string());
    m.insert("ideal/adv_set_thrash".to_string(), "a=0".to_string());
    assert_eq!(load(&save(&m)), m);
    assert_eq!(load("{}"), BTreeMap::new());
    assert_eq!(load(""), BTreeMap::new());
}

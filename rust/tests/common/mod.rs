//! Shared helpers for the integration tests: tiny per-design-point system
//! configurations (small enough that debug-mode runs finish quickly, big
//! enough that the adversarial scenarios actually reach the hybrid memory
//! controller) and a uniform way to run any design point on any workload.
#![allow(dead_code)]

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::sim::Simulation;
use trimma::stats::Stats;
use trimma::workloads;

/// Tiny, fixed-geometry config for `dp`: 1 MiB fast / 32 MiB slow (the
/// paper's 32:1 ratio), 2 cores, short runs. Geometry knobs that are
/// design-point-specific (Alloy's direct mapping, Loh-Hill's row-sized
/// sets) are derived the same way the full presets derive them.
pub fn tiny(dp: DesignPoint) -> SystemConfig {
    let mut cfg = presets::hbm3_ddr5(dp);
    cfg.hybrid.fast_bytes = 1 << 20;
    cfg.hybrid.slow_bytes = 32 << 20;
    cfg.hybrid.num_sets = match dp {
        DesignPoint::AlloyCache => {
            (cfg.hybrid.fast_bytes / cfg.hybrid.block_bytes as u64) as u32
        }
        DesignPoint::LohHill => (cfg.hybrid.fast_bytes / 8192) as u32,
        _ => 4,
    };
    cfg.workload.cores = 2;
    cfg.workload.accesses_per_core = 1500;
    cfg.workload.warmup_per_core = 500;
    cfg
}

/// Run `dp` on workload `wl` under `cfg` (handles the Ideal oracle's
/// special construction) and return the end-of-run stats.
pub fn run(dp: DesignPoint, cfg: &SystemConfig, wl: &str) -> Stats {
    let w = workloads::by_name(wl, cfg).unwrap_or_else(|e| panic!("{e}"));
    let mut sim = if dp == DesignPoint::Ideal {
        Simulation::new_ideal(cfg, w)
    } else {
        Simulation::new(cfg, w)
    };
    sim.run().stats
}

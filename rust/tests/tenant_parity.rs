//! Multi-tenant parity: the TenantMix front end (DESIGN.md §12) inherits
//! the sharded path's headline invariant — the merged canonical stat
//! vector AND every per-tenant stat row of an N-shard run are
//! **byte-identical** to the 1-shard run, pipelined or inline, for every
//! contention scenario. Per-tenant attribution is a pure function of the
//! composite address stream, so it must never see the shard topology.
//!
//! Also locked here: run-to-run determinism under tenant churn (sessions
//! arriving/departing mid-run must not introduce any hidden state), and a
//! verify-oracle-green noisy-neighbor run on the closed loop (the
//! adversarial tenant's migration churn preserves every remap invariant).

mod common;

use trimma::config::presets::{self, DesignPoint};
use trimma::config::{MixProfile, SystemConfig, TenantMixConfig, TenantScenario};
use trimma::engine::EngineBuilder;
use trimma::sim::TenantReport;

/// Tiny tenant-mix config on the common tiny geometry: `tenants` tenants
/// under `scenario`, short phases so churn/flash-crowd phases actually
/// turn over within the run.
fn tiny(dp: DesignPoint, tenants: u32, scenario: TenantScenario) -> SystemConfig {
    let mut cfg = common::tiny(dp);
    cfg.tenant_mix = TenantMixConfig {
        enabled: true,
        tenants,
        scenario,
        mix: MixProfile::General,
        phase_len: 256,
        ..TenantMixConfig::off()
    };
    cfg
}

fn run_mix(cfg: &SystemConfig, shards: usize, pipeline: bool) -> TenantReport {
    EngineBuilder::from_config(cfg.clone())
        .shards(shards)
        .pipeline(pipeline)
        .run_tenant_mix()
        .unwrap_or_else(|e| panic!("{} x{shards} pipeline={pipeline}: {e}", cfg.name))
}

/// Shard counts {1, 2, 4} and pipelined vs inline, for every contention
/// scenario: merged and per-tenant canonical stats must be byte-identical
/// to the 1-shard inline run.
#[test]
fn shard_count_and_pipelining_never_change_tenant_stats() {
    for scenario in TenantScenario::ALL {
        let cfg = tiny(DesignPoint::TrimmaCache, 4, *scenario);
        let base = run_mix(&cfg, 1, false);
        assert!(
            base.merged.stats.mem_accesses > 0,
            "{}: nothing reached memory",
            scenario.label()
        );
        assert_eq!(base.tenants.len(), 4, "{}", scenario.label());
        let base_merged = base.merged.stats.canonical();
        let base_tenants = base.canonical_tenants();
        for shards in [1usize, 2, 4] {
            for pipeline in [false, true] {
                let got = run_mix(&cfg, shards, pipeline);
                assert_eq!(
                    got.merged.stats.canonical(),
                    base_merged,
                    "{}: merged stats diverged at {shards} shards (pipeline={pipeline})",
                    scenario.label()
                );
                assert_eq!(
                    got.canonical_tenants(),
                    base_tenants,
                    "{}: per-tenant stats diverged at {shards} shards (pipeline={pipeline})",
                    scenario.label()
                );
            }
        }
    }
}

/// Tenant churn (sessions arriving/departing at phase boundaries) is
/// deterministic run-to-run on both execution models, and the anchor
/// tenant (0) never goes idle.
#[test]
fn churn_is_deterministic_and_keeps_the_anchor_busy() {
    let cfg = tiny(DesignPoint::TrimmaCache, 6, TenantScenario::Churn);
    for shards in [0usize, 2] {
        let a = run_mix(&cfg, shards, false);
        let b = run_mix(&cfg, shards, false);
        assert_eq!(a.merged.stats.canonical(), b.merged.stats.canonical(), "x{shards}");
        assert_eq!(a.canonical_tenants(), b.canonical_tenants(), "x{shards}");
        assert!(a.tenants[0].accesses > 0, "x{shards}: anchor tenant idled");
    }
}

/// Every measured access lands in exactly one tenant's row: the per-tenant
/// access counts sum to the merged demand access count, on the closed loop
/// and on every shard count of the open loop.
#[test]
fn attribution_is_exhaustive_across_execution_models() {
    let cfg = tiny(DesignPoint::TrimmaFlat, 3, TenantScenario::FlashCrowd);
    for shards in [0usize, 1, 4] {
        let rep = run_mix(&cfg, shards, false);
        let attributed: u64 = rep.tenants.iter().map(|t| t.accesses).sum();
        let expected =
            cfg.workload.cores as u64 * cfg.workload.accesses_per_core;
        assert_eq!(attributed, expected, "x{shards}");
        let rw: u64 = rep.tenants.iter().map(|t| t.reads + t.writes).sum();
        assert_eq!(rw, attributed, "x{shards}: reads+writes must partition accesses");
    }
}

/// The noisy-neighbor scenario under the differential remap oracle
/// (`cfg.hybrid.verify`) on the closed loop: the adversarial tenant's
/// set-thrash traffic exercises eviction and migration against every
/// other tenant, and the oracle checks each translation, placement, and
/// identity classification against ground truth. A green run proves
/// multi-tenant interleaving preserves every remap invariant.
#[test]
fn noisy_neighbor_passes_the_differential_oracle() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let cfg = presets::with_verify(tiny(dp, 4, TenantScenario::NoisyNeighbor));
        let rep = run_mix(&cfg, 0, false);
        assert!(rep.merged.stats.mem_accesses > 0, "{dp:?}");
        // The pinned adversary must actually dominate the schedule.
        let noisy = &rep.tenants[0];
        assert_eq!(noisy.workload, "adv_set_thrash", "{dp:?}");
        let rest: u64 = rep.tenants[1..].iter().map(|t| t.accesses).sum();
        assert!(
            noisy.accesses > rest / 2,
            "{dp:?}: noisy neighbor got {} accesses vs {} for the rest",
            noisy.accesses,
            rest
        );
    }
}

//! Directional paper-claim tests: each asserts the *shape* of a headline
//! result from the paper's evaluation at reduced scale (absolute numbers
//! differ — our substrate is a calibrated synthetic simulator, see
//! DESIGN.md §4 — but who wins, and roughly why, must hold).

use trimma::config::presets::{self, DesignPoint};
use trimma::config::{MetadataScheme, SystemConfig};
use trimma::sim::{SimReport, Simulation};
use trimma::workloads;

const WLS: &[&str] = &["505.mcf_r", "557.xz_r", "gap_pr", "ycsb_a", "silo_tpcc"];

fn run(mut cfg: SystemConfig, wl: &str) -> SimReport {
    cfg.workload.cores = 8;
    cfg.workload.accesses_per_core = 30_000;
    cfg.workload.warmup_per_core = 15_000;
    let w = workloads::by_name(wl, &cfg).unwrap();
    Simulation::new(&cfg, w).run()
}

fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

/// §5.1 / Fig. 7a: Trimma-C outperforms Alloy Cache on average (paper:
/// 1.33x); the linear-table design trails Trimma.
#[test]
fn trimma_c_beats_alloy_on_average() {
    let mut speedups = vec![];
    for wl in WLS {
        let a = run(presets::hbm3_ddr5(DesignPoint::AlloyCache), wl).performance();
        let t = run(presets::hbm3_ddr5(DesignPoint::TrimmaCache), wl).performance();
        speedups.push(t / a);
    }
    let g = geomean(&speedups);
    assert!(g > 1.0, "Trimma-C geomean speedup over Alloy = {g:.3} (paper: 1.33)");
}

/// §5.1 / Fig. 7a: Trimma-F outperforms MemPod on average (paper: 1.30x).
#[test]
fn trimma_f_beats_mempod_on_average() {
    let mut speedups = vec![];
    for wl in WLS {
        let m = run(presets::hbm3_ddr5(DesignPoint::MemPod), wl).performance();
        let t = run(presets::hbm3_ddr5(DesignPoint::TrimmaFlat), wl).performance();
        speedups.push(t / m);
    }
    let g = geomean(&speedups);
    assert!(g > 1.0, "Trimma-F geomean speedup over MemPod = {g:.3} (paper: 1.30)");
}

/// Fig. 9: iRT metadata footprint is far below the always-resident linear
/// table (paper: 43% average saving, up to 85%; §3.2: 52% -> ~11% of fast).
#[test]
fn irt_saves_metadata_storage() {
    for wl in ["gap_pr", "ycsb_a"] {
        let m = run(presets::hbm3_ddr5(DesignPoint::MemPod), wl);
        let t = run(presets::hbm3_ddr5(DesignPoint::TrimmaFlat), wl);
        let lin = m.stats.metadata_bytes_used as f64;
        let irt = t.stats.metadata_bytes_used as f64;
        assert!(
            irt < 0.8 * lin,
            "{wl}: iRT ({irt}) should be well below linear ({lin})"
        );
        assert!(t.stats.donated_slots > 0, "{wl}: saved space must be donated");
    }
}

/// Fig. 10a: Trimma-F serves more accesses from the fast tier than MemPod
/// (paper: +7.9% on average).
#[test]
fn trimma_f_improves_serve_rate() {
    let mut deltas = vec![];
    for wl in WLS {
        let m = run(presets::hbm3_ddr5(DesignPoint::MemPod), wl);
        let t = run(presets::hbm3_ddr5(DesignPoint::TrimmaFlat), wl);
        deltas.push(t.stats.fast_serve_rate() - m.stats.fast_serve_rate());
    }
    let avg = deltas.iter().sum::<f64>() / deltas.len() as f64;
    assert!(avg > 0.05, "avg serve-rate delta = {avg:.3} (paper: +0.079)");
}

/// Fig. 11: iRC raises the overall remap-cache hit rate over a
/// conventional remap cache of the same SRAM budget (paper: 54% -> 67%),
/// and raises the identity-mapping hit rate dramatically (6% -> 32%).
#[test]
fn irc_raises_remap_cache_hit_rate() {
    let mut conv_rates = vec![];
    let mut irc_rates = vec![];
    let mut conv_id = vec![];
    let mut irc_id = vec![];
    for wl in WLS {
        let mut c = presets::hbm3_ddr5(DesignPoint::TrimmaFlat);
        c.hybrid.remap_cache = presets::conventional_rc();
        let conv = run(c, wl);
        let irc = run(presets::hbm3_ddr5(DesignPoint::TrimmaFlat), wl);
        conv_rates.push(conv.stats.rc_hit_rate());
        irc_rates.push(irc.stats.rc_hit_rate());
        conv_id.push(conv.stats.rc_id_hit_rate());
        irc_id.push(irc.stats.rc_id_hit_rate());
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        avg(&irc_rates) > avg(&conv_rates),
        "iRC {:.3} must beat conventional {:.3}",
        avg(&irc_rates),
        avg(&conv_rates)
    );
    assert!(
        avg(&irc_id) > avg(&conv_id),
        "identity hit rate: iRC {:.3} vs conventional {:.3}",
        avg(&irc_id),
        avg(&conv_id)
    );
}

/// Fig. 12a: Trimma's advantage over the linear-table baseline grows with
/// the slow-to-fast capacity ratio (paper: 1.07x @8:1 -> 3.19x @64:1).
#[test]
fn speedup_grows_with_capacity_ratio() {
    let speedup_at = |ratio: u64| {
        let mut v = vec![];
        for wl in ["gap_pr", "ycsb_a"] {
            let m = run(
                presets::with_capacity_ratio(presets::hbm3_ddr5(DesignPoint::MemPod), ratio),
                wl,
            )
            .performance();
            let t = run(
                presets::with_capacity_ratio(presets::hbm3_ddr5(DesignPoint::TrimmaFlat), ratio),
                wl,
            )
            .performance();
            v.push(t / m);
        }
        geomean(&v)
    };
    let low = speedup_at(8);
    let high = speedup_at(64);
    assert!(
        high > low,
        "speedup must grow with ratio: {low:.3} @8:1 vs {high:.3} @64:1"
    );
}

/// Fig. 13a: more iRT levels than 2 do not pay off (4-level ~ Tag Tables);
/// 2-level must be at least as good as 4-level (paper: 2-level best).
#[test]
fn two_level_irt_is_sweet_spot() {
    let perf_at = |levels: u32| {
        let mut v = vec![];
        for wl in ["gap_pr", "ycsb_a"] {
            let mut c = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
            c.hybrid.scheme = MetadataScheme::Irt { levels };
            v.push(run(c, wl).performance());
        }
        geomean(&v)
    };
    let two = perf_at(2);
    let four = perf_at(4);
    assert!(
        two >= 0.97 * four,
        "2-level ({two:.3}) should not lose to 4-level ({four:.3})"
    );
}

/// §5.2: iRT's multi-level walks cost little extra latency because the
/// levels are probed in parallel — metadata time must stay a minor share
/// of the AMAT for Trimma (paper: lookups "insignificant"; +4.6% vs Alloy).
#[test]
fn metadata_latency_is_minor_share() {
    for wl in WLS {
        let t = run(presets::hbm3_ddr5(DesignPoint::TrimmaCache), wl);
        let (m, f, s) = t.stats.amat_breakdown();
        let share = m / (m + f + s);
        assert!(share < 0.30, "{wl}: metadata share {share:.2} too large");
    }
}

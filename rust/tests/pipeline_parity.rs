//! Pipelined-front-end parity: the headline invariant of the pipelined
//! trace stage is that the merged canonical stat vector of a pipelined
//! run is **byte-identical** to the inline open-loop run of the same
//! sharded driver, for every design point, every adversarial scenario,
//! and every shard count — the pipelined sibling of
//! `tests/sharded_parity.rs`.
//!
//! Why this must hold: clocks never depend on the routed work in the
//! open loop (constant nominal miss latency), translation happens on the
//! generating thread in stream order, and the hand-off ring between the
//! generation and routing stages is FIFO — so every slice consumes
//! exactly the same sub-stream either way, with the end-of-warmup reset
//! marker at the same in-stream point (see `sim::core`'s module docs).

mod common;

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::engine::EngineBuilder;
use trimma::sim::SimReport;
use trimma::workloads::adversarial::ADVERSARIAL;

fn run(
    dp: DesignPoint,
    cfg: &SystemConfig,
    wl: &str,
    shards: usize,
    pipeline: bool,
) -> SimReport {
    EngineBuilder::from_config(cfg.clone())
        .workload(wl)
        .ideal(dp == DesignPoint::Ideal)
        .shards(shards)
        .pipeline(pipeline)
        .run_sharded()
        .unwrap_or_else(|e| panic!("{dp:?}/{wl} x{shards} pipeline={pipeline}: {e}"))
}

/// The full matrix: every design point x every adversarial scenario; the
/// inline 1-shard run is the reference, and the pipelined front end must
/// reproduce it at 1, 2, and 4 shards (1 exercises the router stage with
/// a single consumer; 2 and 4 cross slice-group boundaries).
#[test]
fn pipelining_never_changes_the_canonical_stats() {
    for dp in DesignPoint::ALL {
        let cfg = common::tiny(*dp);
        for wl in ADVERSARIAL {
            let base = run(*dp, &cfg, wl, 1, false);
            assert!(base.stats.mem_accesses > 0, "{dp:?}/{wl}: nothing reached memory");
            let base_canon = base.stats.canonical();
            for n in [1usize, 2, 4] {
                let got = run(*dp, &cfg, wl, n, true).stats.canonical();
                assert_eq!(
                    got, base_canon,
                    "{dp:?}/{wl}: pipelined {n}-shard run diverged from the inline run"
                );
            }
        }
    }
}

/// Pipelined runs are deterministic run-to-run (fresh OS threads for the
/// router stage and the shard workers each time).
#[test]
fn pipelined_runs_are_deterministic_run_to_run() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let a = run(DesignPoint::TrimmaCache, &cfg, "adv_set_thrash", 4, true);
    let b = run(DesignPoint::TrimmaCache, &cfg, "adv_set_thrash", 4, true);
    assert_eq!(a.stats.canonical(), b.stats.canonical());
}

/// The differential remap oracle composes with the pipelined front end:
/// every slice's controller is shadowed by its own reference model, so a
/// green run proves the router stage preserves every per-slice remap
/// invariant (in-order delivery, set locality, reset placement).
#[test]
fn pipelined_remap_designs_pass_the_differential_oracle() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
        let cfg = presets::with_verify(common::tiny(dp));
        let rep = run(dp, &cfg, "adv_migration_storm", 2, true);
        assert!(rep.stats.mem_accesses > 0, "{dp:?}");
    }
}

/// Cross-mode, cross-count closure: inline at N must also equal pipelined
/// at M for N != M (transitively implied by the two parity matrices, but
/// asserted directly once so a joint regression cannot hide).
#[test]
fn inline_and_pipelined_agree_across_different_shard_counts() {
    let cfg = common::tiny(DesignPoint::MemPod);
    let inline2 = run(DesignPoint::MemPod, &cfg, "adv_drift", 2, false);
    let piped4 = run(DesignPoint::MemPod, &cfg, "adv_drift", 4, true);
    assert_eq!(inline2.stats.canonical(), piped4.stats.canonical());
}

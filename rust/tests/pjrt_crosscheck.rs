//! Integration test: the AOT Pallas artifact (through PJRT) must agree
//! with the pure-rust generator twin.
//!
//! Integer-derived fields (`is_write`, `gap_instrs`) must match
//! bit-exactly. Addresses match except where the single f32 `powf` in the
//! zipf rank differs in the last ULP between libm and XLA — we allow a
//! small mismatch rate and require the mismatches to be rank-adjacent.
//!
//! Requires `make artifacts` (skips with a message otherwise) and the
//! `pjrt` cargo feature (the offline build image lacks the XLA crates, so
//! this whole test compiles away without it).
#![cfg(feature = "pjrt")]

use trimma::runtime::{artifacts_dir, Runtime, STEPS};
use trimma::workloads::pjrt::PjrtWorkload;
use trimma::workloads::suite;
use trimma::workloads::synth::TraceGen;
use trimma::workloads::Workload;

fn artifact_available() -> bool {
    artifacts_dir().join("trace_gen.hlo.txt").exists()
}

#[test]
fn pjrt_matches_rust_generator() {
    if !artifact_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let cores = 8u32;
    let seed = 0xD1CEu32;
    for name in ["gap_pr", "505.mcf_r", "ycsb_a", "519.lbm_r"] {
        let profile = suite::profile(name).unwrap();
        let gen = TraceGen::new(profile, 256 << 20, cores);
        let mut pj =
            PjrtWorkload::from_trace_gen(&gen, name, cores, seed).expect("load artifact");

        let n = 2 * STEPS; // crosses a tile boundary
        let mut addr_mismatch = 0u64;
        let mut total = 0u64;
        for core in 0..cores as usize {
            for step in 0..n as u32 {
                let got = pj.next(core);
                let want = gen.gen(core as u32 ^ seed, step);
                assert_eq!(got.kind, want.kind, "{name} core {core} step {step}");
                assert_eq!(
                    got.gap_instrs, want.gap_instrs,
                    "{name} core {core} step {step}"
                );
                if got.addr != want.addr {
                    addr_mismatch += 1;
                }
                total += 1;
            }
        }
        let rate = addr_mismatch as f64 / total as f64;
        assert!(
            rate < 0.001,
            "{name}: address mismatch rate {rate} (powf ULP differences should be rare)"
        );
    }
}

#[test]
fn hotness_artifact_runs_and_conserves_mass() {
    if !artifact_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let hx = rt.hotness(&artifacts_dir()).unwrap();
    let gen = TraceGen::new(suite::profile("ycsb_a").unwrap(), 128 << 20, 16);
    let streams: Vec<u32> = (0..16).collect();
    let (tables, slice) = gen.to_region_tables(&streams);
    let hot0 = vec![0f32; trimma::runtime::HOT_BUCKETS];
    let (hot, wf, mg) = hx.run(&streams, 0, &slice, &tables, &hot0, 1.0).unwrap();
    let sum: f32 = hot.iter().sum();
    assert!((sum - (16 * STEPS) as f32).abs() < 1.0, "mass {sum}");
    assert!((0.0..=1.0).contains(&wf));
    assert!(mg >= 0.0);
    // ycsb_a is write-heavy (50%).
    assert!((wf - 0.5).abs() < 0.05, "write frac {wf}");
}

#[test]
fn pjrt_workload_behaves_like_synth_in_sim() {
    if !artifact_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    use trimma::config::presets::{self, DesignPoint};
    use trimma::sim::Simulation;
    let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    cfg.hybrid.fast_bytes = 1 << 20;
    cfg.hybrid.slow_bytes = 32 << 20;
    cfg.hybrid.num_sets = 4;
    cfg.workload.cores = 8;
    cfg.workload.accesses_per_core = 2000;
    cfg.workload.warmup_per_core = 500;

    let profile = suite::profile("gap_pr").unwrap();
    let cap = suite::os_capacity(&cfg);
    let gen = TraceGen::new(profile, cap, cfg.workload.cores);
    let pj = PjrtWorkload::from_trace_gen(
        &gen,
        "gap_pr",
        cfg.workload.cores,
        cfg.workload.seed as u32,
    )
    .unwrap();
    let rep_pjrt = Simulation::new(&cfg, Box::new(pj)).run();

    let wl = trimma::workloads::by_name("gap_pr", &cfg).unwrap();
    let rep_synth = Simulation::new(&cfg, wl).run();

    // Same generator, same machine: headline metrics must agree closely.
    let a = rep_pjrt.stats.fast_serve_rate();
    let b = rep_synth.stats.fast_serve_rate();
    assert!((a - b).abs() < 0.02, "serve rates diverged: {a} vs {b}");
    let pa = rep_pjrt.performance();
    let pb = rep_synth.performance();
    assert!((pa / pb - 1.0).abs() < 0.05, "perf diverged: {pa} vs {pb}");
}

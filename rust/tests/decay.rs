//! Differential tests of pressure-driven metadata decay (DESIGN.md §11):
//! an *inert* decay pass (pressure gate that can never open) must be
//! observation-only, an *aggressive* pass on the metadata-bloat scenario
//! must actually reclaim stale remaps and end with strictly lower
//! non-identity iRT occupancy than a decay-off run, the sweep must stay
//! green under the verify oracle, and merged stats must remain
//! byte-identical across shard counts with decay enabled.

mod common;

use trimma::config::presets::DesignPoint;
use trimma::config::SystemConfig;
use trimma::engine::EngineBuilder;
use trimma::hybrid::Controller;
use trimma::sim::Simulation;
use trimma::stats::Stats;
use trimma::workloads;

/// The scenario built to leave stale non-identity mappings behind: each
/// phase touches a fresh region and abandons the previous one.
const BLOAT: &str = "adv_metadata_bloat";

/// Like [`common::tiny`], but with enough accesses that flat mode crosses
/// many MEA epoch boundaries per set (the decay epoch piggybacks on them).
fn decay_cfg(dp: DesignPoint) -> SystemConfig {
    let mut cfg = common::tiny(dp);
    cfg.workload.accesses_per_core = 6000;
    cfg.workload.warmup_per_core = 500;
    cfg
}

/// Knobs that make the sweep fire hard: epoch every 32 per-set accesses
/// (cache mode), sweep on any non-identity entry, cold after one untouched
/// epoch, generous budget.
fn aggressive(cfg: &mut SystemConfig) {
    cfg.hybrid.decay.enabled = true;
    cfg.hybrid.decay.epoch_accesses = 32;
    cfg.hybrid.decay.pressure_milli = 0;
    cfg.hybrid.decay.sweep_budget = 256;
    cfg.hybrid.decay.cold_epochs = 1;
}

fn zero_decay_counters(mut s: Stats) -> Stats {
    s.decay_epochs = 0;
    s.decay_checked = 0;
    s.decay_reclaims = 0;
    s
}

/// Run `cfg` on the bloat scenario and return `(final stats, total
/// non-identity iRT entries summed over all sets)`.
fn run_with_occupancy(cfg: &SystemConfig) -> (Stats, u64) {
    let wl = workloads::by_name(BLOAT, cfg).unwrap_or_else(|e| panic!("{e}"));
    let mut sim = Simulation::new(cfg, wl);
    let stats = sim.run().stats;
    let ctrl = sim.session().controller();
    let occ = (0..ctrl.layout().num_sets)
        .map(|s| ctrl.debug_nonidentity_entries(s).expect("remap design"))
        .sum();
    (stats, occ)
}

#[test]
fn inert_decay_is_observation_only() {
    // pressure_milli = 1000 sets the gate at the occupancy ceiling, which
    // live occupancy can never exceed: epochs tick, the sweep never runs,
    // and the stat vector must match a decay-off run exactly — modulo the
    // three decay counters themselves.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let off = common::run(dp, &decay_cfg(dp), BLOAT);
        let mut icfg = decay_cfg(dp);
        icfg.hybrid.decay.enabled = true;
        icfg.hybrid.decay.epoch_accesses = 32;
        icfg.hybrid.decay.pressure_milli = 1000;
        let on = common::run(dp, &icfg, BLOAT);
        assert!(on.decay_epochs > 0, "{dp:?}: inert decay must still tick epochs");
        assert_eq!(on.decay_checked, 0, "{dp:?}: the gated sweep must never run");
        assert_eq!(on.decay_reclaims, 0, "{dp:?}");
        assert_eq!(off.decay_epochs, 0, "{dp:?}: decay off must not tick");
        assert_eq!(
            zero_decay_counters(off).canonical(),
            zero_decay_counters(on).canonical(),
            "{dp:?}: inert decay perturbed the simulation"
        );
    }
}

#[test]
fn aggressive_decay_reclaims_and_shrinks_occupancy() {
    // The acceptance criterion: on the phase-change scenario, decay-on
    // must end with strictly lower steady-state non-identity occupancy
    // than decay-off, having actually reclaimed entries.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let (off_stats, off_occ) = run_with_occupancy(&decay_cfg(dp));
        let mut acfg = decay_cfg(dp);
        aggressive(&mut acfg);
        let (on_stats, on_occ) = run_with_occupancy(&acfg);
        assert_eq!(off_stats.decay_reclaims, 0, "{dp:?}");
        assert!(
            on_stats.decay_reclaims > 0,
            "{dp:?}: the sweep found nothing to reclaim (checked {})",
            on_stats.decay_checked
        );
        assert!(
            on_occ < off_occ,
            "{dp:?}: decay-on occupancy {on_occ} must be strictly below decay-off {off_occ}"
        );
    }
}

#[test]
fn aggressive_decay_is_green_under_oracle() {
    // Every decay reclamation path (dirty writeback, moved-pair swap
    // restore, free-stack return) must uphold the oracle's involution,
    // tier and occupancy-bookkeeping invariants.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let mut cfg = decay_cfg(dp);
        aggressive(&mut cfg);
        cfg.hybrid.verify = true;
        let stats = common::run(dp, &cfg, BLOAT);
        assert!(stats.decay_reclaims > 0, "{dp:?}: oracle run must exercise reclaim");
    }
}

#[test]
fn decay_merged_stats_shard_invariant() {
    // Decay state is per-set and its epochs are driven by per-set access
    // streams, so the sharded path must stay byte-identical across shard
    // counts with the sweep firing.
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat] {
        let run = |shards: usize| {
            EngineBuilder::new(dp)
                .workload(BLOAT)
                .decay(true)
                .configure(|cfg| {
                    cfg.hybrid.fast_bytes = 1 << 20;
                    cfg.hybrid.slow_bytes = 32 << 20;
                    cfg.hybrid.num_sets = 4;
                    cfg.workload.cores = 2;
                    cfg.workload.accesses_per_core = 6000;
                    cfg.workload.warmup_per_core = 500;
                    aggressive(cfg);
                })
                .shards(shards)
                .run_sharded()
                .unwrap_or_else(|e| panic!("{e}"))
                .stats
        };
        let one = run(1);
        assert!(one.decay_reclaims > 0, "{dp:?}: sharded run must exercise reclaim");
        for shards in [2usize, 4] {
            assert_eq!(
                one.canonical(),
                run(shards).canonical(),
                "{dp:?}: {shards} shards diverged from 1 shard"
            );
        }
    }
}

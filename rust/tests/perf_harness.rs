//! Coverage for the PR-2 perf work: the bench report schema, the batched
//! `access_block` entry point, and the packed (flat-array / bitset)
//! iRT/iRC lookups against the `ReferenceRemap` oracle on every
//! adversarial scenario.

mod common;

use trimma::bench_util::{BenchReport, Record, SCHEMA_VERSION};
use trimma::config::presets::{self, DesignPoint};
use trimma::engine::AnyController;
use trimma::hybrid::{Access, Controller};
use trimma::types::{AccessKind, Rng64};
use trimma::workloads::adversarial::ADVERSARIAL;

// ---------------- JSON report schema ----------------

#[test]
fn bench_report_round_trips_through_schema() {
    let report = BenchReport {
        schema_version: SCHEMA_VERSION,
        tag: "pr2".to_string(),
        quick: false,
        geomean_sim_msteps_per_s: 2.625,
        records: vec![
            Record { label: "irt_lookup".into(), ns_per_iter: 3.5, reps: 5_000_000, throughput: None },
            Record {
                label: "sim/trimma-c/adv_set_thrash".into(),
                ns_per_iter: 2.25e9,
                reps: 1,
                throughput: Some(3.125),
            },
            Record { label: "dram_access".into(), ns_per_iter: 21.0, reps: 952_380, throughput: None },
        ],
    };
    report.validate().expect("schema-valid by construction");
    let json = report.to_json();
    let parsed = BenchReport::from_json(&json).expect("own output must parse");
    assert_eq!(parsed, report, "round trip must be lossless");
    parsed.validate().expect("round-tripped report stays valid");
    // And a second generation is byte-stable (the CI artifact diff relies
    // on deterministic serialization).
    assert_eq!(parsed.to_json(), json);
}

#[test]
fn bench_report_schema_rejects_drift() {
    let mut report = BenchReport {
        schema_version: SCHEMA_VERSION + 1,
        tag: "future".to_string(),
        quick: true,
        geomean_sim_msteps_per_s: 1.0,
        records: vec![],
    };
    assert!(report.validate().is_err(), "unknown schema version must be rejected");
    report.schema_version = SCHEMA_VERSION;
    report.validate().expect("placeholder-shaped report (no records) is valid");
}

// ---------------- access_block == N x access ----------------

fn small_cfg(dp: DesignPoint) -> trimma::config::SystemConfig {
    let mut cfg = presets::hbm3_ddr5(dp);
    cfg.hybrid.fast_bytes = 1 << 20;
    cfg.hybrid.slow_bytes = 32 << 20;
    cfg.hybrid.num_sets = 4;
    cfg
}

/// Deterministic mixed access stream over the slow tier of `cfg`.
fn stream(cfg: &trimma::config::SystemConfig, n: usize) -> Vec<Access> {
    let layout = trimma::metadata::SetLayout::for_config(&cfg.hybrid, false);
    let span = layout.slow_per_set.min(5000);
    let mut rng = Rng64::new(0xB10C_FEED);
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            t += 700;
            Access {
                set: rng.next_below(cfg.hybrid.num_sets as u64) as u32,
                idx: layout.fast_per_set + rng.next_below(span),
                line: 0,
                kind: if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read },
                now: t,
            }
        })
        .collect()
}

#[test]
fn access_block_matches_single_accesses_stat_for_stat() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
        let cfg = small_cfg(dp);
        let accesses = stream(&cfg, 6000);

        let mut single = AnyController::from_config(&cfg, false);
        let mut single_lat = 0u64;
        for a in &accesses {
            single_lat += single.access(a.set, a.idx, a.line, a.kind, a.now);
        }
        single.finalize();

        let mut batched = AnyController::from_config(&cfg, false);
        let mut batched_lat = 0u64;
        // Uneven chunk size on purpose: exercises partial batches.
        for chunk in accesses.chunks(7) {
            batched_lat += batched.access_block(chunk);
        }
        batched.finalize();

        assert_eq!(single_lat, batched_lat, "{dp:?}: summed demand latency");
        assert_eq!(
            single.stats().canonical(),
            batched.stats().canonical(),
            "{dp:?}: access_block must be stat-for-stat identical to N access calls"
        );
    }
}

#[test]
fn access_block_empty_batch_is_a_no_op() {
    let cfg = small_cfg(DesignPoint::TrimmaCache);
    let mut c = AnyController::from_config(&cfg, false);
    assert_eq!(c.access_block(&[]), 0);
    assert_eq!(c.stats().mem_accesses, 0);
}

// ---------------- packed lookups vs the oracle ----------------

#[test]
fn packed_irt_irc_agree_with_reference_oracle_on_all_adversarial_scenarios() {
    // The flat-array iRT (entry strides + alloc bitset), flat linear
    // table, and SoA remap caches all sit under these design points; the
    // CheckedController panics on any translation, classification, or
    // occupancy disagreement with the ReferenceRemap ground truth, and
    // sweeps every set at finalize (bijectivity + donated-slot
    // accounting).
    for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
        for sc in ADVERSARIAL {
            let mut cfg = common::tiny(dp);
            cfg.hybrid.verify = true;
            cfg.workload.accesses_per_core = 1000;
            cfg.workload.warmup_per_core = 300;
            let stats = common::run(dp, &cfg, sc);
            assert!(stats.mem_accesses > 0, "{dp:?}/{sc}: must reach the controller");
        }
    }
}

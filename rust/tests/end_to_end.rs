//! End-to-end integration: full simulations across modes, technologies,
//! and design points at reduced scale, exercising the entire L3 stack
//! (workload gen -> cache hierarchy -> controller -> devices -> stats).

use trimma::config::presets::{self, DesignPoint};
use trimma::config::{MetadataScheme, SystemConfig};
use trimma::coordinator::{figures, run_job, run_jobs, Job};
use trimma::sim::Simulation;
use trimma::workloads;

fn small(dp: DesignPoint, accesses: u64) -> SystemConfig {
    let mut cfg = presets::hbm3_ddr5(dp);
    cfg.workload.cores = 8;
    cfg.workload.accesses_per_core = accesses;
    cfg.workload.warmup_per_core = accesses / 2;
    cfg
}

#[test]
fn every_design_point_runs_every_workload_class() {
    for dp in DesignPoint::ALL {
        for wl in ["519.lbm_r", "gap_pr", "ycsb_b"] {
            let cfg = small(*dp, 4000);
            let w = workloads::by_name(wl, &cfg).unwrap();
            let mut sim = if *dp == DesignPoint::Ideal {
                Simulation::new_ideal(&cfg, w)
            } else {
                Simulation::new(&cfg, w)
            };
            let rep = sim.run();
            assert!(rep.stats.mem_accesses > 0, "{dp:?}/{wl}");
            assert!(rep.performance() > 0.0, "{dp:?}/{wl}");
            assert_eq!(
                rep.stats.fast_served + rep.stats.slow_served,
                rep.stats.mem_accesses,
                "{dp:?}/{wl}: every access is served somewhere"
            );
        }
    }
}

#[test]
fn ddr5_nvm_technology_runs() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::MemPod] {
        let mut cfg = presets::ddr5_nvm(dp);
        cfg.workload.cores = 8;
        cfg.workload.accesses_per_core = 4000;
        cfg.workload.warmup_per_core = 2000;
        let w = workloads::by_name("gap_sssp", &cfg).unwrap();
        let rep = Simulation::new(&cfg, w).run();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.slow_data_cycles > 0);
    }
}

#[test]
fn trimma_outperforms_linear_table_design() {
    // The core claim at the heart of the paper, at small scale: same mode,
    // same associativity; iRT + saved-space + iRC should win.
    let perf = |dp: DesignPoint| {
        let cfg = small(dp, 25_000);
        let w = workloads::by_name("ycsb_a", &cfg).unwrap();
        Simulation::new(&cfg, w).run().performance()
    };
    let trimma = perf(DesignPoint::TrimmaCache);
    let linear = perf(DesignPoint::LinearCache);
    assert!(
        trimma > linear,
        "Trimma-C ({trimma:.3}) must beat the linear-table design ({linear:.3})"
    );
}

#[test]
fn irt_levels_all_run() {
    for levels in [1, 2, 4] {
        let mut cfg = small(DesignPoint::TrimmaCache, 4000);
        cfg.hybrid.scheme = MetadataScheme::Irt { levels };
        let w = workloads::by_name("gap_cc", &cfg).unwrap();
        let rep = Simulation::new(&cfg, w).run();
        assert!(rep.stats.mem_accesses > 0, "levels={levels}");
    }
}

#[test]
fn block_size_sweep_runs() {
    for block in [64u32, 1024, 4096] {
        let cfg = presets::with_block_bytes(small(DesignPoint::TrimmaCache, 3000), block);
        cfg.validate().unwrap();
        let w = workloads::by_name("519.lbm_r", &cfg).unwrap();
        let rep = Simulation::new(&cfg, w).run();
        assert!(rep.stats.mem_accesses > 0, "block={block}");
    }
}

#[test]
fn capacity_ratio_sweep_runs() {
    for ratio in [8u64, 64] {
        let cfg = presets::with_capacity_ratio(small(DesignPoint::TrimmaFlat, 3000), ratio);
        cfg.validate().unwrap();
        let w = workloads::by_name("gap_bfs", &cfg).unwrap();
        let rep = Simulation::new(&cfg, w).run();
        assert!(rep.stats.mem_accesses > 0, "ratio={ratio}");
    }
}

#[test]
fn figure_harness_produces_tables_and_csv() {
    let tables = figures::run_figure("fig9", 0.01, 0).expect("fig9 must run");
    assert_eq!(tables.len(), 1);
    assert!(tables[0].columns.contains(&"irt(trimma)".to_string()));
    assert_eq!(tables[0].rows.len(), workloads::SUITE.len() + 1); // + MEAN
    assert!(std::fs::read_dir("results").map(|d| d.count() > 0).unwrap_or(false));
}

#[test]
fn parallel_jobs_deterministic() {
    let jobs: Vec<Job> = ["gap_pr", "ycsb_b", "519.lbm_r"]
        .iter()
        .map(|w| Job::new(w.to_string(), small(DesignPoint::TrimmaFlat, 3000), w))
        .collect();
    let a = run_jobs(&jobs, 3).unwrap();
    let b: Vec<_> = jobs.iter().map(|j| run_job(j).unwrap()).collect();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.stats.max_core_cycles, y.stats.max_core_cycles);
        assert_eq!(x.stats.fast_served, y.stats.fast_served);
    }
}

#[test]
fn stats_conservation_invariants() {
    let cfg = small(DesignPoint::TrimmaCache, 10_000);
    let w = workloads::by_name("silo_tpcc", &cfg).unwrap();
    let rep = Simulation::new(&cfg, w).run();
    let s = &rep.stats;
    // Remap-cache probes either hit or miss into walks.
    assert_eq!(s.rc_probes, s.rc_hits_nonid + s.rc_hits_id + s.table_walks);
    // Every probe resolved to identity or non-identity.
    assert_eq!(s.rc_probes, s.lookups_identity + s.lookups_nonidentity);
    // Traffic sanity: the tiers carry at least the demand bytes.
    assert!(s.fast_traffic_bytes + s.slow_traffic_bytes >= s.useful_bytes);
    // Reads + writes partition accesses.
    assert_eq!(s.mem_accesses, s.mem_reads + s.mem_writes);
}

//! Sharded-execution parity: the headline invariant of the sharded path
//! is that the merged canonical stat vector of an N-shard run is
//! **byte-identical** to the serial (1-shard) run of the same sharded
//! driver, for every design point, every adversarial scenario, and every
//! shard count — including counts that don't divide the slice count
//! evenly (N = 7) and counts larger than the slice count (clamped).
//!
//! Also locked here: sharding never crosses a set boundary (each slice's
//! controller only ever sees its own local sets, proven structurally and
//! under the [`trimma::verify`] differential oracle), and the merged
//! storage gauges equal the full config's reservation (the gauge-summing
//! merge reassembles exactly the unsliced metadata budget).

mod common;

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::engine::EngineBuilder;
use trimma::hybrid::Controller;
use trimma::sim::SimReport;
use trimma::workloads::adversarial::ADVERSARIAL;

fn run_sharded(dp: DesignPoint, cfg: &SystemConfig, wl: &str, shards: usize) -> SimReport {
    EngineBuilder::from_config(cfg.clone())
        .workload(wl)
        .ideal(dp == DesignPoint::Ideal)
        .shards(shards)
        .run_sharded()
        .unwrap_or_else(|e| panic!("{dp:?}/{wl} x{shards}: {e}"))
}

/// The full matrix: every design point x every adversarial scenario, at
/// 1, 2, 4, and 7 shards. 7 exercises uneven contiguous slice groups
/// (64 slices -> groups of 10/9) and, for 4-set flat designs, the clamp
/// down to 4 workers.
#[test]
fn shard_count_never_changes_the_canonical_stats() {
    for dp in DesignPoint::ALL {
        let cfg = common::tiny(*dp);
        for wl in ADVERSARIAL {
            let base = run_sharded(*dp, &cfg, wl, 1);
            assert!(base.stats.mem_accesses > 0, "{dp:?}/{wl}: nothing reached memory");
            let base_canon = base.stats.canonical();
            for n in [2usize, 4, 7] {
                let got = run_sharded(*dp, &cfg, wl, n).stats.canonical();
                assert_eq!(
                    got, base_canon,
                    "{dp:?}/{wl}: {n}-shard run diverged from the 1-shard run"
                );
            }
        }
    }
}

/// Sharded runs are also deterministic run-to-run (same config, same
/// shard count, fresh OS threads).
#[test]
fn sharded_runs_are_deterministic_run_to_run() {
    let cfg = common::tiny(DesignPoint::TrimmaCache);
    let a = run_sharded(DesignPoint::TrimmaCache, &cfg, "adv_set_thrash", 4);
    let b = run_sharded(DesignPoint::TrimmaCache, &cfg, "adv_set_thrash", 4);
    assert_eq!(a.stats.canonical(), b.stats.canonical());
}

/// Each slice is a self-contained sub-machine: its controller's layout
/// covers exactly the plan's per-slice set count with the full config's
/// per-set geometry, and its remap state answers only local sets.
#[test]
fn slices_are_structurally_set_local() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::MemPod, DesignPoint::AlloyCache] {
        let cfg = common::tiny(dp);
        let session = EngineBuilder::from_config(cfg.clone())
            .shards(4)
            .build_sharded()
            .unwrap();
        let plan = *session.plan();
        assert_eq!(
            plan.num_slices() * plan.sets_per_slice(),
            cfg.hybrid.num_sets,
            "{dp:?}: slices must tile the set space"
        );
        for sess in session.sessions() {
            let l = sess.layout();
            assert_eq!(l.num_sets, plan.sets_per_slice(), "{dp:?}");
            assert_eq!(l.fast_per_set, session.full_layout().fast_per_set, "{dp:?}");
            assert_eq!(l.slow_per_set, session.full_layout().slow_per_set, "{dp:?}");
            // The slice's own self-check must hold for every local set.
            for set in 0..l.num_sets {
                sess.controller()
                    .debug_check_set(set)
                    .unwrap_or_else(|e| panic!("{dp:?} set {set}: {e}"));
            }
        }
    }
}

/// Run the remap designs sharded under the differential remap oracle
/// (`cfg.hybrid.verify`): every slice's controller is shadowed by its own
/// [`trimma::verify`] reference model, which checks each translation,
/// placement, and identity classification against ground truth and sweeps
/// the tables for bijectivity — inside the slice's local set space. A
/// green run proves the sharded router never hands a slice an access
/// outside its sets (the oracle would reject the out-of-range state) and
/// that slicing preserves every remap invariant.
#[test]
fn sharded_remap_designs_pass_the_differential_oracle() {
    for dp in [
        DesignPoint::TrimmaCache,
        DesignPoint::TrimmaFlat,
        DesignPoint::MemPod,
        DesignPoint::LinearCache,
    ] {
        let cfg = presets::with_verify(common::tiny(dp));
        let rep = run_sharded(dp, &cfg, "adv_migration_storm", 4);
        assert!(rep.stats.mem_accesses > 0, "{dp:?}");
    }
}

/// The gauge-summing merge reassembles the unsliced metadata budget: the
/// summed per-slice reservations equal the classic closed-loop run's
/// reservation (a pure function of the geometry, so the two execution
/// models must agree on it exactly).
#[test]
fn merged_storage_gauges_match_the_serial_reservation() {
    for dp in [DesignPoint::TrimmaCache, DesignPoint::MemPod, DesignPoint::LinearCache] {
        let cfg = common::tiny(dp);
        let serial = common::run(dp, &cfg, "adv_drift");
        let sharded = run_sharded(dp, &cfg, "adv_drift", 4);
        assert_eq!(
            sharded.stats.metadata_bytes_reserved, serial.metadata_bytes_reserved,
            "{dp:?}: summed slice reservations must equal the full reservation"
        );
        assert!(sharded.stats.metadata_bytes_reserved > 0, "{dp:?}");
    }
}

//! Loh-Hill Cache baseline (MICRO'11): a DRAM cache organized so each 8 kB
//! DRAM row is one set — 2 blocks of tags followed by 30 data blocks (at
//! 256 B granularity). A hit reads the tag block (a row-buffer hit, since
//! the subsequent data access targets the same row) and then the data.
//! Following the paper's optimistic treatment we model a *perfect* MissMap,
//! so misses skip the tag probe entirely and go straight to the slow tier.
//! Replacement is RRIP (the paper grants Loh-Hill RRIP for +2.1% over LRU).

use crate::config::SystemConfig;
use crate::hybrid::Controller;
use crate::mem::MemDevice;
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

const LINE_BYTES: u32 = 64;
/// Bytes streamed per tag probe: the tag store of one row (2 x 256 B
/// blocks hold the 30 ways' tags + replacement state).
const TAG_READ_BYTES: u32 = 192;
/// Data ways per 8 kB row (30 x 256 B data + 2 x 256 B tags).
const WAYS: usize = 30;
const TAG_BLOCKS: u64 = 2;
/// RRIP: 2-bit re-reference prediction values.
const RRPV_MAX: u8 = 3;
const RRPV_INSERT: u8 = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct WayState {
    phys: u32,
    dirty: bool,
    valid: bool,
    rrpv: u8,
}

impl Default for WayState {
    fn default() -> Self {
        WayState { phys: 0, dirty: false, valid: false, rrpv: RRPV_MAX }
    }
}

/// 30-way tags-in-row DRAM cache with perfect MissMap.
pub struct LohHillController {
    layout: SetLayout,
    fast: MemDevice,
    slow: MemDevice,
    ways: Vec<WayState>, // set * WAYS + way
    stats: Stats,
    block_bytes: u32,
}

impl LohHillController {
    pub fn new(cfg: &SystemConfig) -> Self {
        let layout = SetLayout::for_config(&cfg.hybrid, false);
        assert!(
            layout.fast_per_set >= TAG_BLOCKS + WAYS as u64,
            "Loh-Hill sets must be one 8 kB row (32 blocks at 256 B)"
        );
        LohHillController {
            layout,
            fast: MemDevice::new(cfg.fast_mem),
            slow: MemDevice::new(cfg.slow_mem),
            ways: vec![WayState::default(); layout.num_sets as usize * WAYS],
            stats: Stats::default(),
            block_bytes: cfg.hybrid.block_bytes,
        }
    }

    #[inline]
    fn set_ways(&mut self, set: u32) -> &mut [WayState] {
        let base = set as usize * WAYS;
        &mut self.ways[base..base + WAYS]
    }

    /// Fast-tier byte address of data way `w` in `set` (after the tags).
    #[inline]
    fn way_addr(&self, set: u32, w: usize) -> u64 {
        self.layout.device_byte_addr(set, TAG_BLOCKS + w as u64)
    }

    /// Fast-tier byte address of the set's tag blocks (row head).
    #[inline]
    fn tag_addr(&self, set: u32) -> u64 {
        self.layout.device_byte_addr(set, 0)
    }

    /// RRIP victim: first way with RRPV == max, aging until one appears.
    fn rrip_victim(&mut self, set: u32) -> usize {
        loop {
            let ways = self.set_ways(set);
            if let Some(w) = ways.iter().position(|x| !x.valid) {
                return w;
            }
            if let Some(w) = ways.iter().position(|x| x.rrpv >= RRPV_MAX) {
                return w;
            }
            for x in ways.iter_mut() {
                x.rrpv += 1;
            }
        }
    }

    fn fill(&mut self, set: u32, p: u64, dirty: bool, t: Cycle) {
        let bb = self.block_bytes;
        let w = self.rrip_victim(set);
        let victim = self.set_ways(set)[w];
        if victim.valid {
            self.stats.evictions += 1;
            if victim.dirty {
                let home = self.layout.device_byte_addr(set, victim.phys as u64);
                self.fast.access(self.way_addr(set, w), bb, AccessKind::Read, t);
                self.slow.access(home, bb, AccessKind::Write, t);
                self.stats.writeback_bytes += bb as u64;
                self.stats.migration_bytes += bb as u64;
                self.stats.fast_traffic_bytes += bb as u64;
                self.stats.slow_traffic_bytes += bb as u64;
            }
        }
        let home = self.layout.device_byte_addr(set, p);
        self.slow.access(home, bb, AccessKind::Read, t);
        self.fast.access(self.way_addr(set, w), bb, AccessKind::Write, t);
        // Tag update written alongside (same row, off critical path).
        self.fast.access(self.tag_addr(set), LINE_BYTES, AccessKind::Write, t);
        self.stats.metadata_traffic_bytes += LINE_BYTES as u64;
        self.stats.migration_bytes += bb as u64;
        self.stats.fast_traffic_bytes += bb as u64 + LINE_BYTES as u64;
        self.stats.slow_traffic_bytes += bb as u64;
        self.stats.fills += 1;
        self.set_ways(set)[w] =
            WayState { phys: p as u32, dirty, valid: true, rrpv: RRPV_INSERT };
    }
}

impl Controller for LohHillController {
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        let _ = line; // whole-block designs ignore the sub-block offset
        self.stats.mem_accesses += 1;
        match kind {
            AccessKind::Read => self.stats.mem_reads += 1,
            AccessKind::Write => self.stats.mem_writes += 1,
        }
        self.stats.useful_bytes += LINE_BYTES as u64;

        let hit_way = {
            let base = set as usize * WAYS;
            self.ways[base..base + WAYS]
                .iter()
                .position(|w| w.valid && w.phys as u64 == idx)
        };
        if let Some(w) = hit_way {
            // Tag probe first (opens the row), then the data access hits
            // the open row — the Loh-Hill compound access. The tag read
            // streams both tag blocks (30 ways x ~6 B spans 2 blocks).
            let tr = self.fast.access(self.tag_addr(set), TAG_READ_BYTES, AccessKind::Read, now);
            let tag_lat = tr.done - now;
            self.stats.metadata_cycles += tag_lat;
            self.stats.metadata_traffic_bytes += TAG_READ_BYTES as u64;
            let dr = self.fast.access(self.way_addr(set, w), LINE_BYTES, kind, tr.done);
            self.stats.fast_served += 1;
            self.stats.fast_traffic_bytes += (TAG_READ_BYTES + LINE_BYTES) as u64;
            self.stats.fast_data_cycles += dr.done - tr.done;
            let ways = self.set_ways(set);
            ways[w].rrpv = 0;
            ways[w].dirty |= kind.is_write();
            dr.done - now
        } else {
            // Perfect MissMap: straight to the slow tier.
            let addr = self.layout.device_byte_addr(set, idx);
            let r = self.slow.access(addr, LINE_BYTES, kind, now);
            self.stats.slow_served += 1;
            self.stats.slow_traffic_bytes += LINE_BYTES as u64;
            self.stats.slow_data_cycles += r.done - now;
            self.fill(set, idx, kind.is_write(), r.done);
            r.done - now
        }
    }

    fn finalize(&mut self) {
        // 2 of 32 blocks per row hold tags.
        let sets = self.layout.num_sets as u64;
        self.stats.metadata_bytes_used = sets * TAG_BLOCKS * self.block_bytes as u64;
        self.stats.metadata_bytes_reserved = self.stats.metadata_bytes_used;
    }

    fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    fn stats(&self) -> &Stats {
        &self.stats
    }

    fn layout(&self) -> &SetLayout {
        &self.layout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn small() -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::LohHill);
        cfg.hybrid.fast_bytes = 256 << 10;
        cfg.hybrid.slow_bytes = 8 << 20;
        cfg.hybrid.num_sets = (cfg.hybrid.fast_bytes / 8192) as u32;
        cfg
    }

    #[test]
    fn thirty_way_before_eviction() {
        let mut c = LohHillController::new(&small());
        let f = c.layout.fast_per_set;
        let mut t = 0;
        for n in 0..30u64 {
            c.access(0, f + n, 0, AccessKind::Read, t);
            t += 2000;
        }
        assert_eq!(c.stats.evictions, 0, "30 ways fit without eviction");
        // All 30 hit now.
        for n in 0..30u64 {
            c.access(0, f + n, 0, AccessKind::Read, t);
            t += 2000;
        }
        assert_eq!(c.stats.fast_served, 30);
        // The 31st block forces an eviction.
        c.access(0, f + 30, 0, AccessKind::Read, t);
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn hit_pays_tag_latency() {
        let mut c = LohHillController::new(&small());
        let f = c.layout.fast_per_set;
        c.access(0, f, 0, AccessKind::Read, 0);
        assert_eq!(c.stats.metadata_cycles, 0, "miss skips tags (MissMap)");
        c.access(0, f, 0, AccessKind::Read, 50_000);
        assert!(c.stats.metadata_cycles > 0, "hit pays the tag probe");
    }

    #[test]
    fn rrip_prefers_distant_reuse() {
        let mut c = LohHillController::new(&small());
        let f = c.layout.fast_per_set;
        let mut t = 0;
        for n in 0..30u64 {
            c.access(0, f + n, 0, AccessKind::Read, t);
            t += 2000;
        }
        // Re-touch block 0: rrpv 0. Insert a new block; victim must not be 0.
        c.access(0, f, 0, AccessKind::Read, t);
        c.access(0, f + 99, 0, AccessKind::Read, t + 2000);
        c.access(0, f, 0, AccessKind::Read, t + 4000);
        let hits_before = c.stats.fast_served;
        assert!(hits_before >= 2, "block 0 must survive RRIP eviction");
    }
}

//! Alloy Cache baseline (Qureshi & Loh, MICRO'12): a direct-mapped DRAM
//! cache that fuses tag and data into one "TAD" unit streamed in a single
//! burst, eliminating separate metadata accesses. Following the paper's
//! optimistic treatment, we model a *perfect* Memory Access Predictor: hits
//! access only the fast tier, misses go straight to the slow tier — Alloy
//! pays zero metadata latency and zero metadata storage, but is stuck at
//! associativity 1, which is exactly the regime Fig. 1 shows collapsing at
//! high capacity ratios.

use crate::config::SystemConfig;
use crate::hybrid::Controller;
use crate::mem::MemDevice;
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

/// Tag-and-data burst size: 64 B line + 8 B tag.
const TAD_BYTES: u32 = 72;
const LINE_BYTES: u32 = 64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Way {
    Empty,
    Data { phys: u32, dirty: bool },
}

/// Direct-mapped tag-with-data DRAM cache.
pub struct AlloyController {
    layout: SetLayout,
    fast: MemDevice,
    slow: MemDevice,
    /// One way per set (direct-mapped): `ways[set]`.
    ways: Vec<Way>,
    stats: Stats,
    block_bytes: u32,
}

impl AlloyController {
    pub fn new(cfg: &SystemConfig) -> Self {
        let layout = SetLayout::for_config(&cfg.hybrid, false);
        assert_eq!(layout.fast_per_set, 1, "Alloy Cache is direct-mapped");
        AlloyController {
            layout,
            fast: MemDevice::new(cfg.fast_mem),
            slow: MemDevice::new(cfg.slow_mem),
            ways: vec![Way::Empty; layout.num_sets as usize],
            stats: Stats::default(),
            block_bytes: cfg.hybrid.block_bytes,
        }
    }

    fn evict_and_fill(&mut self, set: u32, p: u64, dirty: bool, t: Cycle) {
        let bb = self.block_bytes;
        let slot_addr = self.layout.device_byte_addr(set, 0);
        if let Way::Data { phys, dirty: was_dirty } = self.ways[set as usize] {
            self.stats.evictions += 1;
            if was_dirty {
                let home = self.layout.device_byte_addr(set, phys as u64);
                self.fast.access(slot_addr, bb, AccessKind::Read, t);
                self.slow.access(home, bb, AccessKind::Write, t);
                self.stats.writeback_bytes += bb as u64;
                self.stats.migration_bytes += bb as u64;
                self.stats.fast_traffic_bytes += bb as u64;
                self.stats.slow_traffic_bytes += bb as u64;
            }
        }
        let home = self.layout.device_byte_addr(set, p);
        self.slow.access(home, bb, AccessKind::Read, t);
        self.fast.access(slot_addr, bb, AccessKind::Write, t);
        self.stats.migration_bytes += bb as u64;
        self.stats.fast_traffic_bytes += bb as u64;
        self.stats.slow_traffic_bytes += bb as u64;
        self.stats.fills += 1;
        self.ways[set as usize] = Way::Data { phys: p as u32, dirty };
    }
}

impl Controller for AlloyController {
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        let _ = line; // whole-block designs ignore the sub-block offset
        self.stats.mem_accesses += 1;
        match kind {
            AccessKind::Read => self.stats.mem_reads += 1,
            AccessKind::Write => self.stats.mem_writes += 1,
        }
        self.stats.useful_bytes += LINE_BYTES as u64;

        let hit = matches!(self.ways[set as usize], Way::Data { phys, .. } if phys as u64 == idx);
        if hit {
            // One TAD burst serves tag check + data.
            let addr = self.layout.device_byte_addr(set, 0);
            let r = self.fast.access(addr, TAD_BYTES, kind, now);
            self.stats.fast_served += 1;
            self.stats.fast_traffic_bytes += TAD_BYTES as u64;
            self.stats.fast_data_cycles += r.done - now;
            if kind.is_write() {
                if let Way::Data { phys, .. } = self.ways[set as usize] {
                    self.ways[set as usize] = Way::Data { phys, dirty: true };
                }
            }
            r.done - now
        } else {
            // Perfect MAP: go straight to the slow tier.
            let addr = self.layout.device_byte_addr(set, idx);
            let r = self.slow.access(addr, LINE_BYTES, kind, now);
            self.stats.slow_served += 1;
            self.stats.slow_traffic_bytes += LINE_BYTES as u64;
            self.stats.slow_data_cycles += r.done - now;
            self.evict_and_fill(set, idx, kind.is_write(), r.done);
            r.done - now
        }
    }

    fn finalize(&mut self) {
        // Tags travel with data: no dedicated metadata storage modelled
        // (the paper's optimistic baseline treatment).
        self.stats.metadata_bytes_used = 0;
        self.stats.metadata_bytes_reserved = 0;
    }

    fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    fn stats(&self) -> &Stats {
        &self.stats
    }

    fn layout(&self) -> &SetLayout {
        &self.layout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn small() -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.fast_bytes = 64 << 10;
        cfg.hybrid.slow_bytes = 2 << 20;
        cfg.hybrid.num_sets = (cfg.hybrid.fast_bytes / 256) as u32;
        cfg
    }

    #[test]
    fn miss_fill_hit() {
        let mut c = AlloyController::new(&small());
        let idx = c.layout.fast_per_set + 5;
        c.access(0, idx, 0, AccessKind::Read, 0);
        assert_eq!(c.stats.slow_served, 1);
        c.access(0, idx, 0, AccessKind::Read, 10_000);
        assert_eq!(c.stats.fast_served, 1);
    }

    #[test]
    fn conflict_eviction_direct_mapped() {
        let mut c = AlloyController::new(&small());
        let a = c.layout.fast_per_set + 5;
        let b = c.layout.fast_per_set + 6; // same set, different block
        c.access(0, a, 0, AccessKind::Write, 0);
        c.access(0, b, 0, AccessKind::Read, 10_000); // evicts dirty a
        assert_eq!(c.stats.evictions, 1);
        assert!(c.stats.writeback_bytes > 0);
        c.access(0, a, 0, AccessKind::Read, 20_000);
        assert_eq!(c.stats.slow_served, 3, "a was evicted: miss again");
    }

    #[test]
    fn zero_metadata_latency() {
        let mut c = AlloyController::new(&small());
        let idx = c.layout.fast_per_set + 1;
        c.access(0, idx, 0, AccessKind::Read, 0);
        c.access(0, idx, 0, AccessKind::Read, 9_000);
        assert_eq!(c.stats.metadata_cycles, 0);
    }
}

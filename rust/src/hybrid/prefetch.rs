//! Portable software-prefetch shim for the batched translate stage
//! (DESIGN.md §15).
//!
//! The two-phase [`access_block`](super::Controller::access_block) in
//! [`super::remap::RemapController`] walks a batch ahead of execution and
//! issues read prefetches for every metadata address the upcoming
//! `probe`/`lookup` calls will touch (the `prefetch_targets` hooks on
//! [`crate::metadata::remap_cache::RemapCache`],
//! [`crate::metadata::irc::Irc`], and the two
//! table kinds expose exactly those addresses). This module is the single
//! point where that intent meets the ISA:
//!
//! * On `x86_64` it lowers to `_mm_prefetch(_MM_HINT_T0)` — a hint
//!   instruction that **never faults**, regardless of the address handed
//!   to it (unmapped, misaligned, null: the hardware drops the hint).
//!   That is what makes taking raw `*const u8` here sound without any
//!   validity precondition beyond "derived from a live allocation" —
//!   which the hooks guarantee by construction, since they index the same
//!   arrays the subsequent probe reads.
//! * On every other target it compiles to nothing. The behavioral
//!   contract is unchanged either way: prefetching is semantically
//!   invisible, so canonical stats are byte-identical with the knob on or
//!   off on *every* architecture (locked by `rust/tests/prefetch_parity.rs`).
//!
//! Panic audit (crate lint: `clippy::unwrap_used`): no fallible calls —
//! the x86_64 arm is a single hint intrinsic behind a documented `unsafe`
//! block, the fallback is a no-op.

/// Hint the cache hierarchy to pull the line containing `p` toward L1
/// (read intent, all cache levels). No-op on non-x86_64 targets and a
/// pure hint on x86_64: no loads are architecturally performed, nothing
/// can fault, and program semantics are unaffected.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
pub fn prefetch_read(p: *const u8) {
    // SAFETY: PREFETCHT0 is a hint; it performs no architectural memory
    // access and never raises a fault for any address value. `p` is only
    // handed to the hint, never dereferenced.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p.cast::<i8>());
    }
}

/// Portable fallback: accepted and ignored (see the module docs).
#[cfg(not(target_arch = "x86_64"))]
#[inline(always)]
pub fn prefetch_read(p: *const u8) {
    let _ = p;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shim must accept any pointer value without faulting — that is
    /// the whole portability contract (the hooks never dereference, and
    /// the hint may receive addresses whose line is about to be probed or
    /// already evicted).
    #[test]
    fn prefetch_accepts_arbitrary_pointers() {
        let v = [0u8; 64];
        prefetch_read(v.as_ptr());
        prefetch_read(std::ptr::null());
        prefetch_read(usize::MAX as *const u8);
    }
}

//! The general remap-table hybrid memory controller: Trimma-C, Trimma-F,
//! the linear-table cache-mode design, MemPod, and the Ideal oracle are all
//! configurations of this engine.
//!
//! ## Access flow (paper Fig. 3)
//!
//! 1. Probe the on-chip remap cache (conventional or iRC) for the physical
//!    block's mapping; on miss, walk the off-chip table (linear: one fast
//!    memory access; iRT: one access *per level*, all in parallel thanks to
//!    fixed entry addresses) and refill the remap cache.
//! 2. Access the resolved device block on the fast or slow tier — this plus
//!    step 1 is the demand latency.
//! 3. Off the critical path: demand caching / MEA migration, evictions,
//!    table updates, and remap-cache invalidations. These occupy memory
//!    banks (bandwidth contention) but do not stall the request.
//!
//! ## Slot model
//!
//! Every fast-tier block of a set is a slot:
//!
//! * data-area slots (`idx < data_ways`) are plain cache ways (cache mode)
//!   or OS-visible flat memory (flat mode);
//! * metadata-region slots (`data_ways <= idx < F`) hold table blocks when
//!   allocated; with `use_saved_space` (Trimma), unallocated ones are
//!   *donated* to the set as extra cache ways (§3.3) — metadata reclaims
//!   them with priority, evicting whatever data they cache.
//!
//! Cached blocks are *copies* (write-back on eviction if dirty); flat-mode
//! migrations are *swaps* under the slow-swap policy — an evicted block
//! always returns to its original location, and the displaced home data
//! comes back, exactly the bidirectional-entry dance of §3.3.

use crate::config::{Mode, RemapCacheKind, ReplacementPolicy, SystemConfig};
use crate::hybrid::decay::DecayState;
use crate::hybrid::fault::FaultInjector;
use crate::hybrid::mea::MeaTracker;
use crate::hybrid::prefetch::prefetch_read;
use crate::hybrid::{Access, Controller};
use crate::mem::MemDevice;
use crate::metadata::irc::{Irc, IrcProbe};
use crate::metadata::irt::IrtTable;
use crate::metadata::linear::LinearTable;
use crate::metadata::remap_cache::RemapCache;
use crate::metadata::{MetaEvent, SetLayout, Table};
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle, Rng64};

/// Demand-access transfer size (one LLC line).
const LINE_BYTES: u32 = 64;
/// Metadata transfer size per table access (one DRAM burst).
const META_BYTES: u32 = 64;
/// MEA configuration (MemPod: 32 counters per pod; epochs scaled from
/// MemPod's 50 us to per-set access counts). All counter survivors migrate
/// at the epoch boundary — that is the MEA guarantee MemPod exploits.
const MEA_COUNTERS: usize = 64;
const MEA_EPOCH_ACCESSES: u64 = 256;
const MEA_THRESHOLD: u32 = 1;
/// Logical table updates coalesced per 64 B metadata write-back burst
/// (a 64 B line holds 16 4 B entries; ~half are amortized by locality).
const META_WC_RATIO: u64 = 8;

/// State of one fast-tier slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Slot {
    /// Vacant, usable cache slot (data area, cache mode).
    Empty,
    /// Flat-mode data-area slot holding its own home block (identity).
    Home,
    /// Holds a foreign block. `moved`: flat swap (sole copy, always written
    /// back) vs. cached copy (write-back only if dirty).
    Data { phys: u32, dirty: bool, moved: bool },
    /// Allocated metadata block (table contents live here).
    Meta,
    /// Reserved metadata block, currently unallocated and donated.
    DonatedEmpty,
    /// Reserved metadata block, unallocated but not donatable
    /// (linear table never donates; iRT with `use_saved_space = false`).
    ReservedUnusable,
}

/// On-chip remap-cache variant.
enum Rc {
    None,
    Conventional(RemapCache),
    Irc(Irc),
}

/// The engine. See module docs.
pub struct RemapController {
    layout: SetLayout,
    table: Table,
    rc: Rc,
    fast: MemDevice,
    slow: MemDevice,
    /// `set * fast_per_set + slot`.
    slots: Vec<Slot>,
    /// Per-set lazy free stack of usable vacant slots.
    free: Vec<Vec<u32>>,
    /// Per-set FIFO cursor for cache-mode victims (skips metadata slots).
    fifo: Vec<u64>,
    /// Per-set cursor over flat-area slots for MEA migration victims.
    flat_cursor: Vec<u64>,
    /// Per-set LRU timestamps (allocated only under the LRU policy).
    lru: Vec<Cycle>,
    mea: Vec<MeaTracker>,
    /// Pressure-driven metadata decay bookkeeping (DESIGN.md §11).
    decay: DecayState,
    /// Deterministic fault source (DESIGN.md §14); inert unless enabled.
    fault: FaultInjector,
    /// Per-set degraded-mode flag: a quarantined set is pinned to identity
    /// mapping (no fills, migration, or decay) after an unrecoverable
    /// fault. Allocated only when fault injection is enabled.
    quarantined: Vec<bool>,
    rng: Rng64,
    stats: Stats,
    /// Reusable table-update event buffers. Two, because a table update
    /// can nest exactly once: `table_set` -> `BlockAllocated` ->
    /// `evict_slot` -> `table_clear` (whose own events are only
    /// `BlockFreed`, which never evicts — so depth is bounded at 2 and the
    /// whole update path stays allocation-free).
    ev_buf: Vec<MetaEvent>,
    ev_buf2: Vec<MetaEvent>,
    walk_buf: Vec<u64>,
    /// Reusable MEA epoch-drain buffer (flat mode): keeps migration rounds
    /// off the allocator.
    hot_buf: Vec<u64>,
    meta_write_cursor: u64,
    meta_wc_pending: u64,
    /// Sub-block presence bitmask per fast slot (allocated when the
    /// sub-blocking extension is enabled; bit i = 64 B line i resident).
    present: Vec<u64>,
    /// CLOCK reference bits + per-set hands (allocated under Clock).
    clock_ref: Vec<bool>,
    clock_hand: Vec<u64>,
    subblock: bool,
    lines_per_block: u32,
    mode: Mode,
    replacement: ReplacementPolicy,
    use_saved_space: bool,
    ideal: bool,
    block_bytes: u32,
    rc_latency: Cycle,
    /// Batched two-phase translate (DESIGN.md §15): walk each batch ahead
    /// of execution and software-prefetch the metadata lines the probes
    /// will touch. Forced off for the Ideal oracle (no metadata to probe).
    prefetch_enabled: bool,
    /// Lookahead window of the prefetch walk, in accesses (>= 1).
    prefetch_distance: usize,
}

impl RemapController {
    pub fn new(cfg: &SystemConfig, ideal: bool) -> Self {
        let h = &cfg.hybrid;
        let layout = SetLayout::for_config(h, ideal);
        let table = if ideal {
            Table::Linear(LinearTable::new(&layout))
        } else {
            match h.scheme {
                crate::config::MetadataScheme::Irt { levels } => {
                    Table::Irt(IrtTable::new(&layout, levels))
                }
                _ => Table::Linear(LinearTable::new(&layout)),
            }
        };
        let rc = if ideal {
            Rc::None
        } else {
            match h.remap_cache {
                RemapCacheKind::None => Rc::None,
                RemapCacheKind::Conventional { sets, ways } => {
                    Rc::Conventional(RemapCache::new(sets, ways))
                }
                RemapCacheKind::Irc { nonid_sets, nonid_ways, id_sets, id_ways, superblock_blocks } => {
                    Rc::Irc(Irc::new(nonid_sets, nonid_ways, id_sets, id_ways, superblock_blocks))
                }
            }
        };

        let f = layout.fast_per_set as usize;
        let n_sets = layout.num_sets as usize;
        let mut slots = vec![Slot::Empty; n_sets * f];
        // Free stacks are pre-sized with headroom: pushes in steady state
        // (evictions, metadata frees, the occasional stale duplicate left
        // by a metadata reclaim) must never grow the allocation — the
        // translate path is locked allocation-free by a counting-allocator
        // test.
        let mut free: Vec<Vec<u32>> = (0..n_sets).map(|_| Vec::with_capacity(2 * f)).collect();
        for set in 0..n_sets {
            for s in 0..layout.fast_per_set {
                let state = if layout.is_meta_idx(s) {
                    match &table {
                        Table::Linear(_) => Slot::Meta, // full table resident
                        Table::Irt(t) => {
                            if t.slot_is_donatable(set as u32, s) {
                                if h.use_saved_space {
                                    Slot::DonatedEmpty
                                } else {
                                    Slot::ReservedUnusable
                                }
                            } else {
                                Slot::Meta // root level (or capped overflow)
                            }
                        }
                    }
                } else {
                    match h.mode {
                        Mode::Cache => Slot::Empty,
                        Mode::Flat => Slot::Home,
                    }
                };
                if matches!(state, Slot::Empty | Slot::DonatedEmpty) {
                    free[set].push(s as u32);
                }
                slots[set * f + s as usize] = state;
            }
            // Pop order: prefer data-area slots first (stack top).
            free[set].reverse();
        }

        let lru = if h.replacement == ReplacementPolicy::Lru {
            vec![0; n_sets * f]
        } else {
            Vec::new()
        };
        let clock_ref = if h.replacement == ReplacementPolicy::Clock {
            vec![false; n_sets * f]
        } else {
            Vec::new()
        };
        let present = if h.subblock { vec![0u64; n_sets * f] } else { Vec::new() };
        let mea = if h.mode == Mode::Flat {
            (0..n_sets).map(|_| MeaTracker::new(MEA_COUNTERS, MEA_EPOCH_ACCESSES)).collect()
        } else {
            Vec::new()
        };
        // The Ideal oracle has no metadata to trim: decay stays inert.
        let decay =
            DecayState::new(h.decay, h.decay.enabled && !ideal, n_sets, layout.fast_per_set);
        // Likewise no metadata to corrupt: the injector stays inert there.
        let fault = FaultInjector::new(h.fault, h.fault.enabled && !ideal, n_sets);
        let quarantined = if fault.enabled() { vec![false; n_sets] } else { Vec::new() };

        RemapController {
            layout,
            table,
            rc,
            fast: MemDevice::new(cfg.fast_mem),
            slow: MemDevice::new(cfg.slow_mem),
            slots,
            free,
            fifo: vec![0; n_sets],
            flat_cursor: vec![0; n_sets],
            lru,
            mea,
            decay,
            fault,
            quarantined,
            rng: Rng64::new(cfg.workload.seed ^ 0x5107),
            stats: Stats::default(),
            ev_buf: Vec::with_capacity(8),
            ev_buf2: Vec::with_capacity(8),
            walk_buf: Vec::with_capacity(4),
            hot_buf: Vec::with_capacity(MEA_COUNTERS),
            meta_write_cursor: 0,
            meta_wc_pending: 0,
            present,
            clock_ref,
            clock_hand: vec![0; n_sets],
            subblock: h.subblock,
            lines_per_block: (h.block_bytes / LINE_BYTES).max(1),
            mode: h.mode,
            replacement: h.replacement,
            use_saved_space: h.use_saved_space,
            ideal,
            block_bytes: h.block_bytes,
            rc_latency: h.remap_cache_latency,
            // The Ideal oracle has no metadata to prefetch: the walk
            // stays inert there, mirroring decay and fault injection.
            prefetch_enabled: h.batch.prefetch && !ideal,
            prefetch_distance: (h.batch.distance as usize).max(1),
        }
    }

    #[inline]
    fn slot(&self, set: u32, s: u64) -> Slot {
        self.slots[set as usize * self.layout.fast_per_set as usize + s as usize]
    }

    #[inline]
    fn slot_mut(&mut self, set: u32, s: u64) -> &mut Slot {
        &mut self.slots[set as usize * self.layout.fast_per_set as usize + s as usize]
    }

    // ---------------- metadata lookup ----------------

    /// Resolve `(set, idx)` to a device index, charging remap-cache and
    /// walk latency. Returns `(device_idx, metadata_cycles)`.
    fn lookup(&mut self, set: u32, idx: u64, now: Cycle) -> (u64, Cycle) {
        if self.ideal {
            return (self.table.lookup(set, idx), 0);
        }
        let key = self.layout.key(set, idx);
        let mut lat = 0;
        let device = match &mut self.rc {
            Rc::None => {
                let (d, wl) = self.walk(set, idx, now);
                lat += wl;
                d
            }
            Rc::Conventional(rc) => {
                self.stats.rc_probes += 1;
                lat += self.rc_latency;
                if let Some(v) = rc.probe(key) {
                    let d = v as u64;
                    if d == idx {
                        self.stats.rc_hits_id += 1;
                    } else {
                        self.stats.rc_hits_nonid += 1;
                    }
                    d
                } else {
                    let (d, wl) = self.walk(set, idx, now + lat);
                    lat += wl;
                    if let Rc::Conventional(rc) = &mut self.rc {
                        rc.insert(key, d as u32);
                    }
                    d
                }
            }
            Rc::Irc(irc) => {
                self.stats.rc_probes += 1;
                lat += self.rc_latency;
                match irc.probe(key) {
                    IrcProbe::HitNonId(v) => {
                        self.stats.rc_hits_nonid += 1;
                        v as u64
                    }
                    IrcProbe::HitId => {
                        self.stats.rc_hits_id += 1;
                        idx
                    }
                    miss => {
                        if miss == IrcProbe::BitZeroMiss {
                            self.stats.rc_sector_bit_miss += 1;
                        }
                        let (d, wl) = self.walk(set, idx, now + lat);
                        lat += wl;
                        self.fill_irc_after_walk(set, idx, key, d);
                        d
                    }
                }
            }
        };
        if device == idx {
            self.stats.lookups_identity += 1;
        } else {
            self.stats.lookups_nonidentity += 1;
        }
        (device, lat)
    }

    /// Off-chip table walk: returns `(device_idx, latency)`. iRT issues all
    /// levels in parallel (fixed addresses); the linear table issues one.
    ///
    /// Latency model: the metadata region is small (a few % to ~50% of the
    /// fast tier) and extremely hot, so its rows are effectively
    /// row-buffer-resident; walks see row-hit latency plus queueing, capped
    /// at the unloaded random-access cost ("handled by the fast memory with
    /// low latency and high bandwidth", §5.1). Bandwidth (bank occupancy +
    /// traffic bytes) is charged in full.
    fn walk(&mut self, set: u32, idx: u64, start: Cycle) -> (u64, Cycle) {
        self.stats.table_walks += 1;
        let cap = self.fast.unloaded_latency(META_BYTES);
        let mut lat_max = 0;
        let issue = |fast: &mut MemDevice, addr: u64, stats: &mut Stats| {
            let r = fast.access(addr, META_BYTES, AccessKind::Read, start);
            stats.table_walk_mem_accesses += 1;
            stats.metadata_traffic_bytes += META_BYTES as u64;
            stats.fast_traffic_bytes += META_BYTES as u64;
            (r.done - start).min(cap)
        };
        match &self.table {
            Table::Linear(_) => {
                let off = idx * 4 / self.block_bytes as u64;
                let addr = self.layout.meta_block_addr(set, off);
                lat_max = issue(&mut self.fast, addr, &mut self.stats);
            }
            Table::Irt(t) => {
                // The root-level bit vector is one block per set and is
                // buffered in the on-chip controller (§3.2: intermediate
                // entries are buffered during lookup; the root is tiny).
                // Lower levels are fetched from fast memory, all in
                // parallel thanks to the fixed linearized addresses.
                let mut buf = std::mem::take(&mut self.walk_buf);
                t.walk_offsets(idx, &mut buf);
                if t.levels() >= 2 {
                    buf.pop(); // root level: on-chip (one block per set)
                }
                for &off in &buf {
                    let addr = self.layout.meta_block_addr(set, off);
                    lat_max = lat_max.max(issue(&mut self.fast, addr, &mut self.stats));
                }
                self.walk_buf = buf;
            }
        }
        (self.table.lookup(set, idx), lat_max)
    }

    /// Refill iRC after a walk. Non-identity entries go to the NonIdCache;
    /// identity results install the full super-block bit vector (the walk
    /// fetched a whole leaf block, so neighbours' status is known).
    fn fill_irc_after_walk(&mut self, _set: u32, idx: u64, key: u64, device: u64) {
        let sb_blocks = match &self.rc {
            Rc::Irc(irc) => irc.superblock_blocks(),
            _ => return,
        };
        if device != idx {
            if let Rc::Irc(irc) = &mut self.rc {
                irc.fill_nonid(key, device as u32);
            }
            return;
        }
        let sb = key / sb_blocks;
        let mut bits: u32 = 0;
        for b in 0..sb_blocks {
            if let Some((s2, i2)) = self.layout.key_inverse(sb * sb_blocks + b) {
                if self.table.is_identity(s2, i2) {
                    bits |= 1 << b;
                }
            }
        }
        if let Rc::Irc(irc) = &mut self.rc {
            irc.fill_id_vector(sb, bits);
        }
    }

    /// Invalidate remap-cache state for a changed mapping.
    fn rc_update(&mut self, set: u32, idx: u64) {
        let key = self.layout.key(set, idx);
        match &mut self.rc {
            Rc::None => {}
            Rc::Conventional(rc) => {
                rc.invalidate(key);
            }
            Rc::Irc(irc) => irc.on_update(key),
        }
    }

    // ---------------- table updates ----------------

    /// Borrow a pre-sized event buffer: the primary one, or — when this
    /// update is nested inside another update's event handling and the
    /// primary is already out — the secondary.
    fn take_ev_buf(&mut self) -> Vec<MetaEvent> {
        let ev = std::mem::take(&mut self.ev_buf);
        if ev.capacity() > 0 { ev } else { std::mem::take(&mut self.ev_buf2) }
    }

    fn put_ev_buf(&mut self, ev: Vec<MetaEvent>) {
        if self.ev_buf.capacity() == 0 {
            self.ev_buf = ev;
        } else {
            self.ev_buf2 = ev;
        }
    }

    /// Apply a mapping update, then service metadata block alloc/free
    /// events (allocations evict any data in the claimed slot). Charges
    /// buffered metadata write-back traffic off the critical path.
    fn table_set(&mut self, set: u32, idx: u64, device: u64, t: Cycle) {
        let mut ev = self.take_ev_buf();
        ev.clear();
        self.table.set_mapping(set, idx, device, &mut ev);
        self.charge_meta_update(set, 1 + ev.len() as u64, t);
        self.handle_events(set, &ev, t);
        self.put_ev_buf(ev);
        self.rc_update(set, idx);
    }

    fn table_clear(&mut self, set: u32, idx: u64, t: Cycle) {
        let mut ev = self.take_ev_buf();
        ev.clear();
        self.table.clear_mapping(set, idx, &mut ev);
        self.charge_meta_update(set, 1 + ev.len() as u64, t);
        self.handle_events(set, &ev, t);
        self.put_ev_buf(ev);
        self.rc_update(set, idx);
    }

    fn charge_meta_update(&mut self, set: u32, writes: u64, t: Cycle) {
        // Updates are 4 B entries buffered in an on-chip write-combining
        // buffer and written back together off the critical path (§3.2):
        // spatially adjacent updates (e.g. a fill's forward + inverted
        // entries, or a stream's consecutive entries in one leaf block)
        // coalesce into shared 64 B bursts. We charge one burst per
        // `META_WC_RATIO` logical updates, at rotating region addresses.
        // The Ideal oracle has no metadata region and pays nothing.
        if self.ideal || self.layout.meta_per_set == 0 {
            return;
        }
        self.meta_wc_pending += writes;
        while self.meta_wc_pending >= META_WC_RATIO {
            self.meta_wc_pending -= META_WC_RATIO;
            let addr = self.layout.meta_block_addr(set, self.meta_write_cursor);
            self.fast.access(addr, META_BYTES, AccessKind::Write, t);
            self.meta_write_cursor = self.meta_write_cursor.wrapping_add(1);
            self.stats.metadata_traffic_bytes += META_BYTES as u64;
            self.stats.fast_traffic_bytes += META_BYTES as u64;
        }
    }

    fn handle_events(&mut self, set: u32, events: &[MetaEvent], t: Cycle) {
        for &e in events {
            match e {
                MetaEvent::BlockAllocated { slot } => {
                    if let Slot::Data { .. } = self.slot(set, slot) {
                        self.stats.metadata_priority_evictions += 1;
                        self.evict_slot(set, slot, t);
                    }
                    *self.slot_mut(set, slot) = Slot::Meta;
                }
                MetaEvent::BlockFreed { slot } => {
                    let state = if self.use_saved_space {
                        self.free[set as usize].push(slot as u32);
                        Slot::DonatedEmpty
                    } else {
                        Slot::ReservedUnusable
                    };
                    *self.slot_mut(set, slot) = state;
                }
            }
        }
    }

    // ---------------- data movement ----------------

    /// Evict whatever foreign block occupies `slot`, restoring invariants.
    /// Cached copies write back if dirty; flat swaps restore both blocks.
    fn evict_slot(&mut self, set: u32, s: u64, t: Cycle) {
        let Slot::Data { phys, dirty, moved } = self.slot(set, s) else {
            return;
        };
        let p = phys as u64;
        let bb = self.block_bytes;
        let fast_addr = self.layout.device_byte_addr(set, s);
        let home_addr = self.layout.device_byte_addr(set, p);
        self.stats.evictions += 1;
        if moved {
            // Flat swap restore: p's data goes home; this slot's home data
            // comes back from p's home location.
            self.fast.access(fast_addr, bb, AccessKind::Read, t);
            self.slow.access(home_addr, bb, AccessKind::Write, t);
            self.slow.access(home_addr, bb, AccessKind::Read, t);
            self.fast.access(fast_addr, bb, AccessKind::Write, t);
            self.stats.migration_bytes += 2 * bb as u64;
            self.stats.writeback_bytes += bb as u64;
            self.stats.fast_traffic_bytes += 2 * bb as u64;
            self.stats.slow_traffic_bytes += 2 * bb as u64;
            *self.slot_mut(set, s) = Slot::Home;
        } else {
            if dirty {
                // Sub-blocking writes back only the resident lines.
                let wb = if self.subblock {
                    let f = self.layout.fast_per_set as usize;
                    let present = self.present[set as usize * f + s as usize];
                    (present.count_ones() * LINE_BYTES).max(LINE_BYTES)
                } else {
                    bb
                };
                self.fast.access(fast_addr, wb, AccessKind::Read, t);
                self.slow.access(home_addr, wb, AccessKind::Write, t);
                self.stats.writeback_bytes += wb as u64;
                self.stats.fast_traffic_bytes += wb as u64;
                self.stats.slow_traffic_bytes += wb as u64;
                self.stats.migration_bytes += wb as u64;
            }
            let vacated = if self.layout.is_meta_idx(s) {
                if self.use_saved_space {
                    self.free[set as usize].push(s as u32);
                    Slot::DonatedEmpty
                } else {
                    Slot::ReservedUnusable
                }
            } else {
                self.free[set as usize].push(s as u32);
                Slot::Empty
            };
            *self.slot_mut(set, s) = vacated;
        }
        self.table_clear(set, p, t);
        self.table_clear(set, s, t);
    }

    /// Cache a *copy* of slow block `p` into vacant slot `s`. Under the
    /// sub-blocking extension only the demanded 64 B line is fetched; the
    /// rest of the block fills on demand (SILC-FM/Baryon-style).
    fn fill_copy(&mut self, set: u32, p: u64, s: u64, dirty: bool, line: u32, t: Cycle) {
        let bb = if self.subblock { LINE_BYTES } else { self.block_bytes };
        let fast_addr = self.layout.device_byte_addr(set, s);
        let home_addr = self.layout.device_byte_addr(set, p);
        self.slow.access(home_addr, bb, AccessKind::Read, t);
        self.fast.access(fast_addr, bb, AccessKind::Write, t);
        self.stats.migration_bytes += bb as u64;
        self.stats.fast_traffic_bytes += bb as u64;
        self.stats.slow_traffic_bytes += bb as u64;
        self.stats.fills += 1;
        if self.subblock {
            let f = self.layout.fast_per_set as usize;
            self.present[set as usize * f + s as usize] =
                1u64 << (line % self.lines_per_block);
        }
        if self.layout.is_meta_idx(s) {
            self.stats.saved_slot_fills += 1;
        }
        *self.slot_mut(set, s) = Slot::Data { phys: p as u32, dirty, moved: false };
        if self.decay.enabled() {
            self.decay.touch(set, s); // fresh fills start warm
        }
        self.table_set(set, p, s, t);
        self.table_set(set, s, p, t);
        // Metadata allocation may have reclaimed the very slot we filled
        // (the new entries' leaf block can land on `s` itself). The event
        // handler already evicted the data; drop the now-dangling mappings.
        let still_ours =
            matches!(self.slot(set, s), Slot::Data { phys, .. } if phys == p as u32);
        if !still_ours {
            self.table_clear(set, p, t);
            self.table_clear(set, s, t);
        }
    }

    /// Flat-mode swap: migrate slow block `p` into flat-area slot `s`
    /// (currently `Home`), parking the home block at `p`'s location.
    fn swap_in(&mut self, set: u32, p: u64, s: u64, t: Cycle) {
        debug_assert_eq!(self.slot(set, s), Slot::Home);
        let bb = self.block_bytes;
        let fast_addr = self.layout.device_byte_addr(set, s);
        let home_addr = self.layout.device_byte_addr(set, p);
        // p's data in, home data out.
        self.slow.access(home_addr, bb, AccessKind::Read, t);
        self.fast.access(fast_addr, bb, AccessKind::Write, t);
        self.fast.access(fast_addr, bb, AccessKind::Read, t);
        self.slow.access(home_addr, bb, AccessKind::Write, t);
        self.stats.migration_bytes += 2 * bb as u64;
        self.stats.fast_traffic_bytes += 2 * bb as u64;
        self.stats.slow_traffic_bytes += 2 * bb as u64;
        self.stats.fills += 1;
        *self.slot_mut(set, s) = Slot::Data { phys: p as u32, dirty: true, moved: true };
        if self.decay.enabled() {
            self.decay.touch(set, s); // fresh swaps start warm
        }
        self.table_set(set, p, s, t);
        self.table_set(set, s, p, t);
    }

    // ---------------- replacement ----------------

    /// Pop a validated vacant slot from the free stack.
    fn pop_free(&mut self, set: u32) -> Option<u64> {
        while let Some(s) = self.free[set as usize].pop() {
            let s = s as u64;
            if matches!(self.slot(set, s), Slot::Empty | Slot::DonatedEmpty) {
                return Some(s);
            }
            // Stale entry (slot was reclaimed for metadata): drop it.
        }
        None
    }

    /// Cache-mode victim: FIFO / random-with-resample / LRU over evictable
    /// `Data` slots, skipping metadata blocks via their index bits (§3.3).
    fn pick_victim(&mut self, set: u32, now: Cycle) -> Option<u64> {
        let f = self.layout.fast_per_set;
        match self.replacement {
            ReplacementPolicy::Random => {
                for _ in 0..8 {
                    let s = self.rng.next_below(f);
                    if matches!(self.slot(set, s), Slot::Data { moved: false, .. }) {
                        return Some(s);
                    }
                }
                self.fifo_victim(set)
            }
            ReplacementPolicy::Clock => {
                let f = self.layout.fast_per_set;
                let base = set as usize * f as usize;
                // Second chance: clear ref bits until an unreferenced
                // Data slot appears (bounded by two sweeps).
                for _ in 0..2 * f {
                    let hand = self.clock_hand[set as usize];
                    self.clock_hand[set as usize] = (hand + 1) % f;
                    if matches!(self.slot(set, hand), Slot::Data { moved: false, .. }) {
                        if self.clock_ref[base + hand as usize] {
                            self.clock_ref[base + hand as usize] = false;
                        } else {
                            return Some(hand);
                        }
                    }
                }
                self.fifo_victim(set)
            }
            ReplacementPolicy::Lru => {
                let base = set as usize * f as usize;
                let mut best: Option<(u64, Cycle)> = None;
                for s in 0..f {
                    if matches!(self.slot(set, s), Slot::Data { moved: false, .. }) {
                        let ts = self.lru[base + s as usize];
                        if best.map(|(_, b)| ts < b).unwrap_or(true) {
                            best = Some((s, ts));
                        }
                    }
                }
                let _ = now;
                best.map(|(s, _)| s)
            }
            _ => self.fifo_victim(set),
        }
    }

    fn fifo_victim(&mut self, set: u32) -> Option<u64> {
        let f = self.layout.fast_per_set;
        let start = self.fifo[set as usize];
        for i in 0..f {
            let s = (start + i) % f;
            if matches!(self.slot(set, s), Slot::Data { moved: false, .. }) {
                self.fifo[set as usize] = (s + 1) % f;
                return Some(s);
            }
        }
        None
    }

    /// Demand insertion after a slow-tier access (off the critical path).
    fn maybe_fill(&mut self, set: u32, p: u64, line: u32, kind: AccessKind, t: Cycle) {
        match self.mode {
            Mode::Cache => {
                let s = match self.pop_free(set) {
                    Some(s) => Some(s),
                    None => {
                        if let Some(v) = self.pick_victim(set, t) {
                            self.evict_slot(set, v, t);
                            self.pop_free(set)
                        } else {
                            None
                        }
                    }
                };
                if let Some(s) = s {
                    self.fill_copy(set, p, s, kind.is_write(), line, t);
                }
            }
            Mode::Flat => {
                // Demand caching only into donated metadata slots (the flat
                // area is migrated by MEA epochs, not demand-filled).
                if !self.use_saved_space {
                    return;
                }
                // A fast *home* block served slow is one whose data was
                // swapped out to its partner's location; it returns via the
                // swap restore, never via demand caching — caching it here
                // would overwrite its live swap mapping and orphan the
                // partner's inverse entry (the verify oracle flags this).
                if self.layout.is_fast_idx(p) {
                    return;
                }
                let s = match self.pop_free(set) {
                    Some(s) => Some(s),
                    None => {
                        // FIFO among donated Data slots.
                        let f = self.layout.fast_per_set;
                        let dw = self.layout.data_ways;
                        let span = f - dw;
                        if span == 0 {
                            None
                        } else {
                            let start = self.fifo[set as usize].max(dw);
                            let mut found = None;
                            for i in 0..span {
                                let s = dw + ((start - dw + i) % span);
                                if matches!(self.slot(set, s), Slot::Data { moved: false, .. }) {
                                    self.fifo[set as usize] = dw + ((s - dw + 1) % span);
                                    found = Some(s);
                                    break;
                                }
                            }
                            if let Some(v) = found {
                                self.evict_slot(set, v, t);
                                self.pop_free(set)
                            } else {
                                None
                            }
                        }
                    }
                };
                if let Some(s) = s {
                    self.fill_copy(set, p, s, kind.is_write(), line, t);
                }
            }
        }
    }

    /// Software deallocation hint (§3.5 "More saving opportunities"): the
    /// range will never be accessed again, so cached copies are dropped
    /// *without* write-back and their remap entries are recycled, giving
    /// the saved metadata blocks back to the cache immediately.
    pub fn dealloc_hint(&mut self, set: u32, idx: u64, t: Cycle) {
        let device = self.table.lookup(set, idx);
        if device == idx {
            return; // identity: nothing to recycle
        }
        if self.layout.is_fast_idx(device) {
            if let Slot::Data { moved, .. } = self.slot(set, device) {
                if !moved {
                    // Drop the dead copy silently: no write-back traffic.
                    let vacated = if self.layout.is_meta_idx(device) && self.use_saved_space {
                        self.free[set as usize].push(device as u32);
                        Slot::DonatedEmpty
                    } else if self.layout.is_meta_idx(device) {
                        Slot::ReservedUnusable
                    } else {
                        self.free[set as usize].push(device as u32);
                        Slot::Empty
                    };
                    *self.slot_mut(set, device) = vacated;
                } else {
                    // Migrated (sole copy): still restore the home block's
                    // data, but the dead block itself needs no transfer.
                    self.evict_slot(set, device, t);
                    return;
                }
            }
        }
        self.table_clear(set, idx, t);
        self.table_clear(set, device, t);
        self.stats.dealloc_recycled += 1;
    }

    /// MEA epoch migration (flat mode): swap the epoch's hottest slow
    /// blocks into the flat area, evicting previously migrated blocks
    /// round-robin (slow-swap: they return to their home locations).
    fn mea_epoch(&mut self, set: u32, t: Cycle) {
        let mut hot = std::mem::take(&mut self.hot_buf);
        self.mea[set as usize].drain_hot_into(MEA_THRESHOLD, &mut hot);
        let dw = self.layout.data_ways;
        if dw == 0 {
            self.hot_buf = hot;
            return;
        }
        for &p in &hot {
            // Skip if p has been cached/migrated meanwhile.
            if !self.table.is_identity(set, p) {
                continue;
            }
            // Victim flat slot, round-robin.
            let start = self.flat_cursor[set as usize];
            let mut target = None;
            for i in 0..dw {
                let s = (start + i) % dw;
                match self.slot(set, s) {
                    Slot::Home => {
                        target = Some(s);
                        self.flat_cursor[set as usize] = (s + 1) % dw;
                        break;
                    }
                    Slot::Data { moved: true, .. } => {
                        self.evict_slot(set, s, t); // restore, then reuse
                        target = Some(s);
                        self.flat_cursor[set as usize] = (s + 1) % dw;
                        break;
                    }
                    _ => {}
                }
            }
            if let Some(s) = target {
                self.swap_in(set, p, s, t);
            }
        }
        self.hot_buf = hot;
    }

    // ---------------- batched translate: phase-1 prefetch walk ----------

    /// Phase 1 of the batched two-phase translate (DESIGN.md §15): issue
    /// software prefetches for every metadata address the upcoming
    /// [`RemapController::lookup`] of `a` will touch — the remap-cache SoA
    /// lanes of `a`'s set (both iRC components under Trimma) and the
    /// packed table words (entry + leaf alloc bit for the iRT, the one
    /// stride-indexed entry for the linear table). Strictly read-only:
    /// the `prefetch_targets` hooks compute addresses without bumping the
    /// LRU tick or any stat, the shim never dereferences, and the only
    /// observable effect is the `batch_prefetches` telemetry counter —
    /// which is exactly why reordering phase 2 is forbidden but phase 1
    /// can run arbitrarily far ahead.
    #[inline]
    fn prefetch_access(&mut self, a: &Access) {
        let key = self.layout.key(a.set, a.idx);
        match &self.rc {
            Rc::None => {}
            Rc::Conventional(rc) => {
                for p in rc.prefetch_targets(key) {
                    prefetch_read(p);
                }
            }
            Rc::Irc(irc) => {
                for p in irc.prefetch_targets(key) {
                    prefetch_read(p);
                }
            }
        }
        for p in self.table.prefetch_targets(a.set, a.idx) {
            prefetch_read(p);
        }
        self.stats.batch_prefetches += 1;
    }

    // ---------------- the demand access itself ----------------

    /// One demand access — the monomorphic body behind both
    /// [`Controller::access`] and [`Controller::access_block`], so batched
    /// callers pay a single virtual dispatch for the whole batch.
    fn do_access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        self.stats.mem_accesses += 1;
        match kind {
            AccessKind::Read => self.stats.mem_reads += 1,
            AccessKind::Write => self.stats.mem_writes += 1,
        }

        // Fault class 2 (DESIGN.md §14): metadata bit flip, injected and
        // scrubbed within this same access — no corrupt mapping is ever
        // observable from outside the controller.
        if self.fault.enabled() && !self.is_quarantined(set) {
            if let Some(cursor) = self.fault.metadata_flip(set) {
                self.inject_flip(set, cursor, now);
            }
        }

        // 1. metadata lookup
        let (device, meta_lat) = self.lookup(set, idx, now);
        self.stats.metadata_cycles += meta_lat;

        // 2. data access at the resolved device block
        let daddr = self.layout.device_byte_addr(set, device);
        let t0 = now + meta_lat;
        let mut is_fast = self.layout.is_fast_idx(device);
        // Sub-blocking: a mapped block whose demanded line has not been
        // fetched yet is a *sub-block miss* served by the slow tier.
        let mut sub_fill: Option<u64> = None;
        if is_fast && self.subblock {
            let f = self.layout.fast_per_set as usize;
            let at = set as usize * f + device as usize;
            if matches!(self.slot(set, device), Slot::Data { moved: false, .. })
                && self.present[at] & (1u64 << (line % self.lines_per_block)) == 0
            {
                is_fast = false;
                sub_fill = Some(device);
            }
        }
        let mut retry_exhausted = false;
        let data_lat = if is_fast {
            let r = self.fast.access(daddr, LINE_BYTES, kind, t0);
            self.stats.fast_served += 1;
            self.stats.fast_traffic_bytes += LINE_BYTES as u64;
            self.stats.fast_data_cycles += r.done - t0;
            // Track dirtiness / LRU on the occupied slot.
            if kind.is_write() {
                if let Slot::Data { phys, moved, .. } = self.slot(set, device) {
                    *self.slot_mut(set, device) = Slot::Data { phys, dirty: true, moved };
                }
            }
            if !self.lru.is_empty() {
                let f = self.layout.fast_per_set as usize;
                self.lru[set as usize * f + device as usize] = now;
            }
            if !self.clock_ref.is_empty() {
                let f = self.layout.fast_per_set as usize;
                self.clock_ref[set as usize * f + device as usize] = true;
            }
            if self.decay.enabled() {
                self.decay.touch(set, device);
            }
            r.done - t0
        } else {
            // A sub-block miss reads the line from the block's home.
            let saddr = if sub_fill.is_some() {
                self.layout.device_byte_addr(set, idx)
            } else {
                daddr
            };
            let r = self.slow.access(saddr, LINE_BYTES, kind, t0);
            self.stats.slow_served += 1;
            self.stats.slow_traffic_bytes += LINE_BYTES as u64;
            let mut dl = r.done - t0;
            // Fault class 1: transient slow-tier read failure, recovered by
            // bounded retry; the backoff is demand latency on the slow tier.
            if self.fault.enabled() && kind == AccessKind::Read && !self.is_quarantined(set) {
                match self.fault.transient_read(set) {
                    None => {}
                    Some(Ok((backoff, retries))) => {
                        self.stats.fault_injected += 1;
                        self.stats.fault_retried += retries as u64;
                        dl += backoff;
                    }
                    Some(Err(err)) => {
                        // Typed exhaustion: charge the whole budget's
                        // backoff now, quarantine once `done` is known.
                        self.stats.fault_injected += 1;
                        self.stats.fault_retried += err.attempts as u64;
                        dl += err.backoff;
                        retry_exhausted = true;
                    }
                }
            }
            self.stats.slow_data_cycles += dl;
            dl
        };
        self.stats.useful_bytes += LINE_BYTES as u64;

        // 3. off the critical path: insertion / migration
        let done = t0 + data_lat;
        if retry_exhausted {
            // The device kept failing past the retry budget: take the set
            // out of service (identity-mapped, direct-to-slow).
            self.quarantine_set(set, done);
        }
        if self.is_quarantined(set) {
            // Degraded mode: no fills, migration, or decay — the set stays
            // identity-mapped and every access goes straight to its home.
        } else if let Some(slot) = sub_fill {
            // Install the fetched line into the partially-present block.
            let f = self.layout.fast_per_set as usize;
            self.present[set as usize * f + slot as usize] |=
                1u64 << (line % self.lines_per_block);
            let fast_addr = self.layout.device_byte_addr(set, slot);
            self.fast.access(fast_addr, LINE_BYTES, AccessKind::Write, done);
            self.stats.fast_traffic_bytes += LINE_BYTES as u64;
            self.stats.migration_bytes += LINE_BYTES as u64;
            self.stats.subblock_fetches += 1;
            if kind.is_write() {
                if let Slot::Data { phys, moved, .. } = self.slot(set, slot) {
                    *self.slot_mut(set, slot) = Slot::Data { phys, dirty: true, moved };
                }
            }
        } else if !is_fast {
            self.maybe_fill(set, idx, line, kind, done);
            if self.mode == Mode::Flat && self.mea[set as usize].record(idx) {
                self.mea_epoch(set, done);
                // Flat mode: the decay epoch piggybacks on the MEA epoch.
                if self.decay.enabled() {
                    self.decay_epoch(set, done);
                }
            }
        }
        // Cache mode paces decay epochs by demand-access count.
        if self.decay.enabled()
            && self.mode == Mode::Cache
            && !self.is_quarantined(set)
            && self.decay.on_access(set)
        {
            self.decay_epoch(set, done);
        }

        meta_lat + data_lat
    }

    /// One decay epoch boundary for `set` (DESIGN.md §11): advance the
    /// epoch, and — while non-identity occupancy is above the pressure
    /// threshold — sweep a budgeted window of slots under the rotating
    /// cursor, evicting cold remapped blocks. [`Self::evict_slot`] does
    /// the heavy lifting: flat swaps migrate back to their home frame,
    /// cached copies write back if dirty, both table entries reclaim to
    /// identity, and the freed slot returns to the free stack — so every
    /// oracle invariant (involution, tier crossing, free-stack coverage)
    /// holds by construction after the reclaim.
    fn decay_epoch(&mut self, set: u32, t: Cycle) {
        self.decay.advance_epoch(set);
        self.stats.decay_epochs += 1;
        if !self.decay.over_pressure(self.table.nonidentity_entries(set)) {
            return;
        }
        for _ in 0..self.decay.budget() {
            let s = self.decay.next_slot(set);
            self.stats.decay_checked += 1;
            if matches!(self.slot(set, s), Slot::Data { .. }) && self.decay.is_cold(set, s) {
                self.evict_slot(set, s, t);
                self.stats.decay_reclaims += 1;
            }
        }
    }

    // ---------------- fault injection & recovery (DESIGN.md §14) ----------------

    /// Whether `set` is in degraded identity-mapped mode.
    #[inline]
    fn is_quarantined(&self, set: u32) -> bool {
        !self.quarantined.is_empty() && self.quarantined[set as usize]
    }

    /// Deep invariant audit of one set: every slot state must agree with
    /// the remap table, donated-slot accounting must match iRT occupancy,
    /// and every vacant slot must be reachable through the free stack —
    /// the same checks the verify oracle runs through
    /// [`Controller::debug_check_set`], callable by the controller itself
    /// as the detection half of [`Self::scrub_set`].
    pub fn audit_set(&self, set: u32) -> Result<(), String> {
        let f = self.layout.fast_per_set;
        let mut non_meta_reserved = 0u64;
        for s in 0..f {
            let st = self.slot(set, s);
            match st {
                Slot::Data { phys, .. } => {
                    let p = phys as u64;
                    if self.table.lookup(set, p) != s {
                        return Err(format!(
                            "set {set} slot {s}: holds {p} but forward mapping is {}",
                            self.table.lookup(set, p)
                        ));
                    }
                    if self.table.lookup(set, s) != p {
                        return Err(format!(
                            "set {set} slot {s}: inverse mapping is {} not {p}",
                            self.table.lookup(set, s)
                        ));
                    }
                }
                Slot::Home | Slot::Empty => {
                    if !self.table.is_identity(set, s) {
                        return Err(format!(
                            "set {set} slot {s}: vacant/home but mapped to {}",
                            self.table.lookup(set, s)
                        ));
                    }
                }
                Slot::Meta => {
                    if !self.layout.is_meta_idx(s) {
                        return Err(format!("set {set} slot {s}: Meta outside the region"));
                    }
                    if self.table.slot_is_donatable(set, s) {
                        return Err(format!(
                            "set {set} slot {s}: Meta but table says donatable"
                        ));
                    }
                }
                Slot::DonatedEmpty | Slot::ReservedUnusable => {
                    if !self.layout.is_meta_idx(s) {
                        return Err(format!(
                            "set {set} slot {s}: reserved state outside the region"
                        ));
                    }
                    if !self.table.slot_is_donatable(set, s) {
                        return Err(format!(
                            "set {set} slot {s}: unallocated state but table says allocated"
                        ));
                    }
                }
            }
            if self.layout.is_meta_idx(s) && st != Slot::Meta {
                non_meta_reserved += 1;
            }
        }
        // Donated accounting: the table's per-set donated count must equal
        // the reserved slots not currently holding live metadata.
        if let Table::Irt(t) = &self.table {
            if t.levels() > 1 {
                let d = t.donated_blocks_in_set(set);
                if d != non_meta_reserved {
                    return Err(format!(
                        "set {set}: table donates {d} blocks but {non_meta_reserved} \
                         reserved slots are not Meta"
                    ));
                }
            }
        }
        // Free-stack coverage: every usable vacant slot must be poppable.
        for s in 0..f {
            if matches!(self.slot(set, s), Slot::Empty | Slot::DonatedEmpty)
                && !self.free[set as usize].contains(&(s as u32))
            {
                return Err(format!("set {set} slot {s}: vacant but absent from free stack"));
            }
        }
        Ok(())
    }

    /// Inject fault class 2: corrupt the forward (slow-side) entry of a
    /// live remapped pair in `set`, then immediately scrub. `cursor` seeds
    /// the deterministic victim choice. An all-identity set has no entry to
    /// corrupt and the flip is dropped.
    fn inject_flip(&mut self, set: u32, cursor: u64, t: Cycle) {
        let f = self.layout.fast_per_set;
        let start = cursor % f;
        let mut victim = None;
        for i in 0..f {
            let s = (start + i) % f;
            // The flipped device index `s ^ 1` must stay inside the fast
            // tier so the corruption is an in-range, plausible entry.
            if (s ^ 1) < f {
                if let Slot::Data { phys, .. } = self.slot(set, s) {
                    victim = Some((s, phys as u64));
                    break;
                }
            }
        }
        let Some((s, p)) = victim else {
            return;
        };
        // Flip the low bit of the forward entry's device index through the
        // normal table write so the table's internal occupancy bookkeeping
        // stays coherent — the *mapping* is now wrong (slot `s ^ 1` does
        // not hold block `p`), which is exactly what `audit_set` detects.
        let mut ev = self.take_ev_buf();
        ev.clear();
        self.table.set_mapping(set, p, s ^ 1, &mut ev);
        debug_assert!(ev.is_empty(), "rewriting a live entry must not move metadata blocks");
        self.handle_events(set, &ev, t);
        self.put_ev_buf(ev);
        // Any cached copy of the entry is equally suspect: drop it.
        self.rc_update(set, p);
        self.stats.fault_injected += 1;
        self.scrub_set(set, t);
        debug_assert!(
            self.audit_set(set).is_ok(),
            "scrub must leave the set consistent (rebuilt or quarantined)"
        );
    }

    /// Scrub `set`: audit its invariants, and on corruption rebuild the
    /// forward direction from the surviving inverse entries — or quarantine
    /// the set when it is stuck (persistent fault) or the rebuild fails.
    /// On a healthy set this is a pure read: no stats, table, or latency
    /// side effects (locked by `rust/tests/faults.rs`).
    pub fn scrub_set(&mut self, set: u32, t: Cycle) {
        if self.audit_set(set).is_ok() {
            return;
        }
        self.stats.fault_scrubbed += 1;
        if !self.fault.is_stuck(set) {
            self.rebuild_set(set, t);
            if self.audit_set(set).is_ok() {
                return;
            }
        }
        self.quarantine_set(set, t);
    }

    /// Rebuild forward entries from the surviving inverse direction: slot
    /// `s` holding block `p` guarantees the inverse entry `s -> p`, so the
    /// forward entry must read `p -> s`; restore it wherever the pair
    /// disagrees. Repairs are real table writes (metadata traffic, remap
    /// cache invalidation) charged at `t`.
    fn rebuild_set(&mut self, set: u32, t: Cycle) {
        for s in 0..self.layout.fast_per_set {
            let p = self.table.lookup(set, s);
            if p != s && self.table.lookup(set, p) != s {
                self.table_set(set, p, s, t);
                self.stats.fault_rebuilt += 1;
            }
        }
    }

    /// Take `set` out of service: migrate every resident foreign block home
    /// through the normal eviction path (which restores the involution and
    /// free-stack invariants by construction), leaving the set pinned to
    /// identity mapping. Fills, MEA migration, decay, and further fault
    /// injection are disabled for it — degraded but correct.
    fn quarantine_set(&mut self, set: u32, t: Cycle) {
        if self.is_quarantined(set) {
            return;
        }
        for s in 0..self.layout.fast_per_set {
            if matches!(self.slot(set, s), Slot::Data { .. }) {
                self.evict_slot(set, s, t);
            }
        }
        if self.quarantined.is_empty() {
            // Reachable only through a manual `scrub_set` call with faults
            // disabled; grow lazily rather than carrying the vector always.
            self.quarantined = vec![false; self.layout.num_sets as usize];
        }
        self.quarantined[set as usize] = true;
        self.stats.fault_quarantined += 1;
        debug_assert_eq!(self.table.nonidentity_entries(set), 0);
    }
}

impl Controller for RemapController {
    #[inline]
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        self.do_access(set, idx, line, kind, now)
    }

    /// Batched entry point: one dispatch, then a monomorphic loop over
    /// `Self::do_access` — stat-for-stat identical to `N` single
    /// `access` calls (locked by `rust/tests/perf_harness.rs`).
    ///
    /// With `batch.prefetch` enabled this becomes the two-phase,
    /// memory-parallel translate stage of DESIGN.md §15: a read-only walk
    /// primes the first `distance` accesses' metadata lines up front, and
    /// execution then proceeds **in original order** — never reordered,
    /// since `do_access` mutates tables, slots, and device bank state —
    /// topping the window up so the walk stays `distance` accesses ahead.
    /// Canonical stats are byte-identical on/off (modulo the
    /// `batch_prefetches` telemetry counter; locked by
    /// `rust/tests/prefetch_parity.rs`).
    fn access_block(&mut self, batch: &[Access]) -> Cycle {
        let mut total = 0;
        if self.prefetch_enabled && !batch.is_empty() {
            let d = self.prefetch_distance.min(batch.len());
            for a in &batch[..d] {
                self.prefetch_access(a);
            }
            for (i, a) in batch.iter().enumerate() {
                if i + d < batch.len() {
                    self.prefetch_access(&batch[i + d]);
                }
                total += self.do_access(a.set, a.idx, a.line, a.kind, a.now);
            }
        } else {
            for a in batch {
                total += self.do_access(a.set, a.idx, a.line, a.kind, a.now);
            }
        }
        total
    }

    fn finalize(&mut self) {
        self.stats.metadata_bytes_used = self.table.metadata_bytes_used();
        self.stats.metadata_bytes_reserved = self.layout.meta_per_set
            * self.layout.num_sets as u64
            * self.layout.block_bytes as u64;
        self.stats.donated_slots = self.table.donated_blocks();
    }

    fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    fn stats(&self) -> &Stats {
        &self.stats
    }

    fn layout(&self) -> &SetLayout {
        &self.layout
    }

    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        Some(self.table.lookup(set, idx))
    }

    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        Some(self.table.nonidentity_entries(set))
    }

    /// Deep invariant sweep of one set; see [`RemapController::audit_set`],
    /// which the controller's own scrub pass shares. The verify oracle
    /// calls this periodically and at finalize.
    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        self.audit_set(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn small(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20; // 1 MiB
        cfg.hybrid.slow_bytes = 32 << 20; // 32 MiB
        cfg.hybrid.num_sets = match dp {
            DesignPoint::MemPod | DesignPoint::TrimmaFlat => 4,
            _ => 4,
        };
        cfg
    }

    fn slow_idx(c: &RemapController, n: u64) -> (u32, u64) {
        let l = c.layout;
        (0, l.fast_per_set + n)
    }

    #[test]
    fn cache_mode_miss_then_hit() {
        let cfg = small(DesignPoint::TrimmaCache);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 10);
        let lat1 = c.access(set, idx, 0, AccessKind::Read, 0);
        assert_eq!(c.stats.slow_served, 1);
        // After the fill, the same block should be served by the fast tier.
        let lat2 = c.access(set, idx, 0, AccessKind::Read, 10_000);
        assert_eq!(c.stats.fast_served, 1, "block should have been cached");
        assert!(lat2 < lat1, "fast hit ({lat2}) should beat miss ({lat1})");
    }

    #[test]
    fn ideal_has_zero_metadata_cycles() {
        let cfg = small(DesignPoint::Ideal);
        let mut c = RemapController::new(&cfg, true);
        let (set, idx) = slow_idx(&c, 3);
        c.access(set, idx, 0, AccessKind::Read, 0);
        c.access(set, idx, 0, AccessKind::Read, 5000);
        assert_eq!(c.stats.metadata_cycles, 0);
        assert_eq!(c.stats.table_walks, 0);
    }

    /// `canon` with the one named `name=value` pair removed — the on/off
    /// prefetch comparison legitimately differs only in `batch_prefetches`.
    fn strip_counter(canon: &str, name: &str) -> String {
        let prefix = format!("{name}=");
        canon.split(';').filter(|p| !p.starts_with(&prefix)).collect::<Vec<_>>().join(";")
    }

    /// The two-phase walk is semantically invisible: the same batched
    /// traffic with prefetch on and off yields byte-identical canonical
    /// stats except the `batch_prefetches` telemetry counter, which counts
    /// exactly the batched accesses (integration-scale coverage across
    /// design points/shards/pipelining lives in tests/prefetch_parity.rs).
    #[test]
    fn batched_prefetch_walk_is_semantically_invisible() {
        for dp in [DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache] {
            let cfg_off = small(dp);
            let mut cfg_on = small(dp);
            cfg_on.hybrid.batch.prefetch = true;
            cfg_on.hybrid.batch.distance = 4;
            let mut off = RemapController::new(&cfg_off, false);
            let mut on = RemapController::new(&cfg_on, false);
            let mut batch = [Access::default(); 16];
            let mut now = 0u64;
            let f = off.layout.fast_per_set;
            for round in 0..40u64 {
                for (j, slot) in batch.iter_mut().enumerate() {
                    now += 500;
                    *slot = Access {
                        set: ((round + j as u64) % off.layout.num_sets as u64) as u32,
                        idx: f + (round * 31 + j as u64 * 7) % 600,
                        line: 0,
                        kind: if j % 3 == 0 { AccessKind::Write } else { AccessKind::Read },
                        now,
                    };
                }
                off.access_block(&batch);
                on.access_block(&batch);
            }
            off.finalize();
            on.finalize();
            assert_eq!(off.stats.batch_prefetches, 0, "{dp:?}: off run must never prefetch");
            assert_eq!(
                on.stats.batch_prefetches,
                40 * 16,
                "{dp:?}: every batched access gets exactly one phase-1 visit"
            );
            assert_eq!(
                strip_counter(&off.stats.canonical(), "batch_prefetches"),
                strip_counter(&on.stats.canonical(), "batch_prefetches"),
                "{dp:?}: prefetch changed an observable stat"
            );
        }
    }

    /// Ideal has no metadata to probe: the walk stays inert even with the
    /// knob on, mirroring decay and fault injection.
    #[test]
    fn ideal_forces_prefetch_inert() {
        let mut cfg = small(DesignPoint::Ideal);
        cfg.hybrid.batch.prefetch = true;
        let mut c = RemapController::new(&cfg, true);
        let (set, idx) = slow_idx(&c, 3);
        let batch =
            [Access { set, idx, line: 0, kind: AccessKind::Read, now: 0 }; 8];
        c.access_block(&batch);
        assert_eq!(c.stats.batch_prefetches, 0);
    }

    /// The lookahead window degenerates gracefully: distance >= batch len
    /// prefetches everything up front; distance 1 interleaves one ahead;
    /// both count every access exactly once and match the off-run stats.
    #[test]
    fn prefetch_distance_covers_the_batch_exactly_once() {
        for distance in [1u32, 3, 8, 64, 1000] {
            let mut cfg = small(DesignPoint::TrimmaCache);
            cfg.hybrid.batch.prefetch = true;
            cfg.hybrid.batch.distance = distance;
            let mut c = RemapController::new(&cfg, false);
            let f = c.layout.fast_per_set;
            let mut batch = [Access::default(); 11];
            for (j, slot) in batch.iter_mut().enumerate() {
                *slot = Access {
                    set: 0,
                    idx: f + j as u64,
                    line: 0,
                    kind: AccessKind::Read,
                    now: 500 * (j as u64 + 1),
                };
            }
            c.access_block(&batch);
            assert_eq!(c.stats.batch_prefetches, 11, "distance={distance}");
            c.access_block(&[]);
            assert_eq!(c.stats.batch_prefetches, 11, "empty batch must not walk");
        }
    }

    #[test]
    fn linear_charges_metadata_region() {
        let cfg = small(DesignPoint::LinearCache);
        let c = RemapController::new(&cfg, false);
        // ~52% of fast blocks at ratio 32:1.
        let frac = c.layout.meta_per_set as f64 / c.layout.fast_per_set as f64;
        assert!(frac > 0.5 && frac < 0.54, "frac={frac}");
        // Entire region is resident metadata: no donated slots.
        assert_eq!(c.table.donated_blocks(), 0);
    }

    #[test]
    fn trimma_donates_saved_space() {
        let cfg = small(DesignPoint::TrimmaCache);
        let c = RemapController::new(&cfg, false);
        assert!(c.table.donated_blocks() > 0);
        // Donated slots appear in the free lists.
        let donated_free: usize = c
            .free
            .iter()
            .flatten()
            .filter(|&&s| c.layout.is_meta_idx(s as u64))
            .count();
        assert!(donated_free > 0);
    }

    #[test]
    fn eviction_writes_back_dirty_copies() {
        let mut cfg = small(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 64 << 10; // tiny: force evictions
        cfg.hybrid.slow_bytes = 2 << 20;
        cfg.hybrid.num_sets = 1;
        let mut c = RemapController::new(&cfg, false);
        let span = c.layout.slow_per_set;
        let mut t = 0;
        for n in 0..span {
            let (set, idx) = slow_idx(&c, n);
            c.access(set, idx, 0, AccessKind::Write, t);
            t += 2000;
        }
        assert!(c.stats.evictions > 0, "small cache must evict");
        assert!(c.stats.writeback_bytes > 0, "dirty blocks must write back");
    }

    #[test]
    fn flat_mode_fast_home_hit() {
        let cfg = small(DesignPoint::TrimmaFlat);
        let mut c = RemapController::new(&cfg, false);
        // idx < data_ways is OS-visible flat fast memory: identity hit.
        c.access(0, 0, 0, AccessKind::Read, 0);
        assert_eq!(c.stats.fast_served, 1);
        assert_eq!(c.stats.slow_served, 0);
    }

    #[test]
    fn mea_migration_eventually_swaps_hot_block_in() {
        let cfg = small(DesignPoint::MemPod);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 42);
        let mut t = 0;
        // Hammer one slow block across several epochs.
        for _ in 0..3 * super::MEA_EPOCH_ACCESSES {
            c.access(set, idx, 0, AccessKind::Read, t);
            t += 500;
        }
        assert!(
            c.stats.fast_served > 0,
            "hot block should be migrated into the flat area by MEA"
        );
        // Slow-swap invariant: mapping is a 2-cycle (p -> s, s -> p).
        let dev = c.table.lookup(set, idx);
        assert_ne!(dev, idx);
        assert_eq!(c.table.lookup(set, dev), idx);
    }

    #[test]
    fn metadata_priority_eviction() {
        // Fill donated slots with data, then force an iRT allocation whose
        // leaf lands on one of them.
        let mut cfg = small(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 256 << 10;
        cfg.hybrid.slow_bytes = 8 << 20;
        cfg.hybrid.num_sets = 1;
        let mut c = RemapController::new(&cfg, false);
        let span = c.layout.slow_per_set;
        let mut t = 0;
        for n in 0..span.min(20_000) {
            let (set, idx) = slow_idx(&c, n);
            c.access(set, idx, 0, AccessKind::Read, t);
            t += 1500;
        }
        assert!(
            c.stats.metadata_priority_evictions > 0,
            "table growth should reclaim donated slots holding data"
        );
    }

    #[test]
    fn stats_breakdown_sums_to_latency() {
        let cfg = small(DesignPoint::TrimmaCache);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 5);
        let lat = c.access(set, idx, 0, AccessKind::Read, 0);
        let s = c.stats();
        assert_eq!(
            s.metadata_cycles + s.fast_data_cycles + s.slow_data_cycles,
            lat
        );
    }

    #[test]
    fn subblocking_fetches_lines_on_demand() {
        let mut cfg = small(DesignPoint::TrimmaCache);
        cfg.hybrid.subblock = true;
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 10);
        // Miss on line 0: fill brings only line 0.
        c.access(set, idx, 0, AccessKind::Read, 0);
        assert_eq!(c.stats.slow_served, 1);
        // Line 1 of the same block: sub-block miss served by slow tier.
        c.access(set, idx, 1, AccessKind::Read, 10_000);
        assert_eq!(c.stats.slow_served, 2);
        assert_eq!(c.stats.subblock_fetches, 1);
        // Both lines now resident.
        c.access(set, idx, 0, AccessKind::Read, 20_000);
        c.access(set, idx, 1, AccessKind::Read, 30_000);
        assert_eq!(c.stats.fast_served, 2);
        // Fill traffic was 64 B, not a whole 256 B block.
        assert!(c.stats.migration_bytes < 256);
    }

    #[test]
    fn clock_policy_gives_second_chance() {
        let mut cfg = small(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 64 << 10;
        cfg.hybrid.slow_bytes = 2 << 20;
        cfg.hybrid.num_sets = 1;
        cfg.hybrid.replacement = ReplacementPolicy::Clock;
        let mut c = RemapController::new(&cfg, false);
        let span = c.layout.slow_per_set;
        let mut t = 0;
        // Pressure: a wide sweep interleaved with a small hot set that the
        // ref bits should protect.
        for n in 0..3 * span {
            let (set, idx) = slow_idx(&c, n % span);
            c.access(set, idx, 0, AccessKind::Read, t);
            t += 1500;
            if n % 4 == 0 {
                let (hs, hi) = slow_idx(&c, n % 16);
                c.access(hs, hi, 0, AccessKind::Read, t);
                t += 1500;
            }
        }
        assert!(c.stats.evictions > 0, "clock must evict under pressure");
        assert!(c.stats.fast_served > 0, "hot set should survive via ref bits");
    }

    #[test]
    fn dealloc_hint_recycles_without_writeback() {
        let cfg = small(DesignPoint::TrimmaCache);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 10);
        c.access(set, idx, 0, AccessKind::Write, 0); // miss + dirty fill
        assert!(!c.table.is_identity(set, idx));
        let wb_before = c.stats.writeback_bytes;
        c.dealloc_hint(set, idx, 10_000);
        assert_eq!(c.stats.writeback_bytes, wb_before, "dead data: no write-back");
        assert!(c.table.is_identity(set, idx), "entry recycled");
        assert_eq!(c.stats.dealloc_recycled, 1);
        // Hinting an untouched block is a no-op.
        let (s2, i2) = slow_idx(&c, 999);
        c.dealloc_hint(s2, i2, 11_000);
        assert_eq!(c.stats.dealloc_recycled, 1);
    }

    #[test]
    fn controller_slots_agree_with_table() {
        // Invariant property: after a random access storm, every Data slot
        // has a consistent forward+inverted mapping pair, and every
        // non-identity fast mapping points at a Data slot holding it.
        let mut cfg = small(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 256 << 10;
        cfg.hybrid.slow_bytes = 8 << 20;
        cfg.hybrid.num_sets = 2;
        let mut c = RemapController::new(&cfg, false);
        let span = c.layout.slow_per_set;
        let mut rng = crate::types::Rng64::new(0xC0FFEE);
        let mut t = 0;
        for _ in 0..30_000 {
            let set = rng.next_below(2) as u32;
            let idx = c.layout.fast_per_set + rng.next_below(span.min(5000));
            let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
            c.access(set, idx, 0, kind, t);
            t += 700;
        }
        for set in 0..2u32 {
            for s in 0..c.layout.fast_per_set {
                if let Slot::Data { phys, .. } = c.slot(set, s) {
                    assert_eq!(c.table.lookup(set, phys as u64), s, "forward");
                    assert_eq!(c.table.lookup(set, s), phys as u64, "inverted");
                }
            }
            for i in 0..c.layout.indices_per_set() {
                let d = c.table.lookup(set, i);
                if d != i && c.layout.is_fast_idx(d) && !c.layout.is_fast_idx(i) {
                    assert!(
                        matches!(c.slot(set, d), Slot::Data { phys, .. } if phys as u64 == i),
                        "mapping {i}->{d} must match slot state"
                    );
                }
            }
        }
    }

    fn with_faults(mut cfg: SystemConfig, flip: u32, transient: u32, stuck: u32) -> SystemConfig {
        cfg.hybrid.fault.enabled = true;
        cfg.hybrid.fault.metadata_flip_milli = flip;
        cfg.hybrid.fault.transient_read_milli = transient;
        cfg.hybrid.fault.stuck_set_milli = stuck;
        cfg
    }

    fn storm(c: &mut RemapController, accesses: u64) -> Cycle {
        let span = c.layout.slow_per_set.min(4000);
        let sets = c.layout.num_sets as u64;
        let mut total = 0;
        let mut t = 0;
        for n in 0..accesses {
            let set = (n % sets) as u32;
            let idx = c.layout.fast_per_set + (n * 7) % span;
            let kind = if n % 5 == 0 { AccessKind::Write } else { AccessKind::Read };
            total += c.access(set, idx, 0, kind, t);
            t += 900;
        }
        total
    }

    #[test]
    fn flip_is_scrubbed_within_the_access() {
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 300, 0, 0);
        let mut c = RemapController::new(&cfg, false);
        storm(&mut c, 20_000);
        assert!(c.stats.fault_injected > 0, "flips must fire at 30%");
        assert_eq!(
            c.stats.fault_scrubbed, c.stats.fault_injected,
            "every landed flip must be detected by the audit"
        );
        assert!(c.stats.fault_rebuilt > 0, "non-stuck sets rebuild from the inverse");
        assert_eq!(c.stats.fault_quarantined, 0, "nothing is stuck here");
        for set in 0..c.layout.num_sets {
            c.audit_set(set).expect("post-run sets must be consistent");
        }
    }

    #[test]
    fn stuck_set_quarantines_and_serves_identity() {
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 500, 0, 1000);
        let mut c = RemapController::new(&cfg, false);
        let t_end = storm(&mut c, 20_000);
        assert!(c.stats.fault_quarantined > 0, "every set is stuck: first flip quarantines");
        assert_eq!(c.stats.fault_rebuilt, 0, "stuck sets are never rebuilt");
        for set in 0..c.layout.num_sets {
            c.audit_set(set).expect("quarantined set stays consistent");
            if c.quarantined[set as usize] {
                assert_eq!(c.table.nonidentity_entries(set), 0, "pinned to identity");
            }
        }
        // Degraded mode still serves accesses (direct-to-slow).
        let before = c.stats.slow_served;
        let (set, idx) = slow_idx(&c, 11);
        c.access(set, idx, 0, AccessKind::Read, t_end);
        assert_eq!(c.stats.slow_served, before + 1);
    }

    #[test]
    fn transient_faults_add_backoff_latency() {
        let mut off = RemapController::new(&small(DesignPoint::TrimmaCache), false);
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 0, 400, 0);
        let mut on = RemapController::new(&cfg, false);
        storm(&mut off, 5_000);
        storm(&mut on, 5_000);
        assert!(on.stats.fault_injected > 0);
        assert!(on.stats.fault_retried >= on.stats.fault_injected, "each fault retries >= once");
        assert!(
            on.stats.slow_data_cycles > off.stats.slow_data_cycles,
            "backoff must be charged as slow-tier demand latency"
        );
        assert_eq!(on.stats.slow_served, off.stats.slow_served, "recovered reads still serve");
    }

    #[test]
    fn faulted_latency_breakdown_still_sums() {
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 200, 300, 20);
        let mut c = RemapController::new(&cfg, false);
        let total = {
            let span = c.layout.slow_per_set.min(4000);
            let mut sum = 0;
            let mut t = 0;
            for n in 0..10_000u64 {
                let set = (n % 4) as u32;
                let idx = c.layout.fast_per_set + (n * 3) % span;
                sum += c.access(set, idx, 0, AccessKind::Read, t);
                t += 1100;
            }
            sum
        };
        let s = c.stats();
        assert!(s.fault_injected > 0);
        assert_eq!(
            s.metadata_cycles + s.fast_data_cycles + s.slow_data_cycles,
            total,
            "retry backoff must stay inside the demand-latency breakdown"
        );
    }

    #[test]
    fn retry_exhaustion_quarantines_the_set() {
        // transient_read_milli = 1000: the first slow read fails every
        // retry; the typed exhaustion quarantines instead of looping.
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 0, 1000, 0);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 3);
        c.access(set, idx, 0, AccessKind::Read, 0);
        assert!(c.quarantined[set as usize]);
        assert_eq!(c.stats.fault_quarantined, 1);
        assert_eq!(c.stats.fault_retried, cfg.hybrid.fault.max_retries as u64);
        // Quarantined: the injector is bypassed, accesses still complete.
        c.access(set, idx, 0, AccessKind::Read, 500_000);
        assert_eq!(c.stats.fault_injected, 1, "no further injection after quarantine");
        assert_eq!(c.stats.slow_served, 2, "identity-mapped set serves direct-to-slow");
        c.audit_set(set).expect("degraded set stays consistent");
    }

    #[test]
    fn scrub_on_clean_set_is_a_stats_identical_noop() {
        let cfg = with_faults(small(DesignPoint::TrimmaCache), 0, 0, 0);
        let mut c = RemapController::new(&cfg, false);
        storm(&mut c, 2_000);
        let before = c.stats.canonical();
        for set in 0..c.layout.num_sets {
            c.scrub_set(set, 1 << 40);
        }
        assert_eq!(c.stats.canonical(), before, "clean scrub must be a pure read");
    }

    #[test]
    fn finalize_snapshots_gauges() {
        let cfg = small(DesignPoint::TrimmaCache);
        let mut c = RemapController::new(&cfg, false);
        let (set, idx) = slow_idx(&c, 5);
        c.access(set, idx, 0, AccessKind::Read, 0);
        c.finalize();
        assert!(c.stats.metadata_bytes_reserved > 0);
        assert!(c.stats.metadata_bytes_used > 0);
        assert!(c.stats.metadata_bytes_used <= c.stats.metadata_bytes_reserved * 2);
    }
}

//! Generic a-way tag-matching controller — the "tag matching" series of
//! paper Fig. 1.
//!
//! Tags live in the fast memory next to the data (no dedicated region, as
//! in Alloy/Loh-Hill), but at associativity `a` a lookup must fetch
//! `ceil(a * 4 B / 64 B)` tag bursts before the data access — the cost that
//! makes cache-style tag matching collapse at high associativities (§2.2):
//! "for designs with associativities higher than 16, multiple metadata
//! lookups are needed".

use crate::config::SystemConfig;
use crate::hybrid::Controller;
use crate::mem::MemDevice;
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

const LINE_BYTES: u32 = 64;
const TAG_BYTES: u64 = 4;

#[derive(Debug, Clone, Copy, Default)]
struct WayState {
    phys: u32,
    dirty: bool,
    valid: bool,
}

/// a-way set-associative tag-matching DRAM cache, FIFO replacement.
pub struct TagMatchController {
    layout: SetLayout,
    fast: MemDevice,
    slow: MemDevice,
    ways: Vec<WayState>,
    fifo: Vec<u32>,
    assoc: usize,
    stats: Stats,
    block_bytes: u32,
    /// Tag bursts per lookup: ceil(assoc * 4 / 64).
    tag_bursts: u32,
}

impl TagMatchController {
    /// `cfg.hybrid.num_sets` must already encode the desired associativity
    /// (`fast_blocks / assoc`).
    pub fn new(cfg: &SystemConfig) -> Self {
        let layout = SetLayout::for_config(&cfg.hybrid, true); // no region
        let assoc = layout.fast_per_set as usize;
        TagMatchController {
            layout,
            fast: MemDevice::new(cfg.fast_mem),
            slow: MemDevice::new(cfg.slow_mem),
            ways: vec![WayState::default(); layout.num_sets as usize * assoc],
            fifo: vec![0; layout.num_sets as usize],
            assoc,
            stats: Stats::default(),
            block_bytes: cfg.hybrid.block_bytes,
            tag_bursts: ((assoc as u64 * TAG_BYTES).div_ceil(LINE_BYTES as u64)) as u32,
        }
    }

    /// Serial chain of tag-burst reads (row hits after the first).
    fn probe_tags(&mut self, set: u32, now: Cycle) -> Cycle {
        let mut t = now;
        let base = self.layout.device_byte_addr(set, 0);
        for i in 0..self.tag_bursts {
            let r = self.fast.access(
                base + (i as u64 * LINE_BYTES as u64) % (self.block_bytes as u64),
                LINE_BYTES,
                AccessKind::Read,
                t,
            );
            t = r.done;
            self.stats.metadata_traffic_bytes += LINE_BYTES as u64;
            self.stats.fast_traffic_bytes += LINE_BYTES as u64;
        }
        self.stats.metadata_cycles += t - now;
        t
    }
}

impl Controller for TagMatchController {
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        let _ = line; // whole-block designs ignore the sub-block offset
        self.stats.mem_accesses += 1;
        match kind {
            AccessKind::Read => self.stats.mem_reads += 1,
            AccessKind::Write => self.stats.mem_writes += 1,
        }
        self.stats.useful_bytes += LINE_BYTES as u64;

        // Tags must be checked before knowing hit/miss.
        let after_tags = self.probe_tags(set, now);

        let base = set as usize * self.assoc;
        let hit = self.ways[base..base + self.assoc]
            .iter()
            .position(|w| w.valid && w.phys as u64 == idx);
        if let Some(w) = hit {
            let addr = self.layout.device_byte_addr(set, w as u64);
            let r = self.fast.access(addr, LINE_BYTES, kind, after_tags);
            self.stats.fast_served += 1;
            self.stats.fast_traffic_bytes += LINE_BYTES as u64;
            self.stats.fast_data_cycles += r.done - after_tags;
            self.ways[base + w].dirty |= kind.is_write();
            r.done - now
        } else {
            let addr = self.layout.device_byte_addr(set, idx);
            let r = self.slow.access(addr, LINE_BYTES, kind, after_tags);
            self.stats.slow_served += 1;
            self.stats.slow_traffic_bytes += LINE_BYTES as u64;
            self.stats.slow_data_cycles += r.done - after_tags;
            // FIFO fill.
            let bb = self.block_bytes;
            let w = self.fifo[set as usize] as usize % self.assoc;
            self.fifo[set as usize] = (w as u32 + 1) % self.assoc as u32;
            let victim = self.ways[base + w];
            if victim.valid {
                self.stats.evictions += 1;
                if victim.dirty {
                    let home = self.layout.device_byte_addr(set, victim.phys as u64);
                    self.fast.access(self.layout.device_byte_addr(set, w as u64), bb, AccessKind::Read, r.done);
                    self.slow.access(home, bb, AccessKind::Write, r.done);
                    self.stats.writeback_bytes += bb as u64;
                    self.stats.migration_bytes += bb as u64;
                    self.stats.fast_traffic_bytes += bb as u64;
                    self.stats.slow_traffic_bytes += bb as u64;
                }
            }
            self.slow.access(self.layout.device_byte_addr(set, idx), bb, AccessKind::Read, r.done);
            self.fast.access(self.layout.device_byte_addr(set, w as u64), bb, AccessKind::Write, r.done);
            self.stats.migration_bytes += bb as u64;
            self.stats.fast_traffic_bytes += bb as u64;
            self.stats.slow_traffic_bytes += bb as u64;
            self.stats.fills += 1;
            self.ways[base + w] = WayState { phys: idx as u32, dirty: kind.is_write(), valid: true };
            r.done - now
        }
    }

    fn finalize(&mut self) {
        self.stats.metadata_bytes_used = 0; // tags embedded with data
        self.stats.metadata_bytes_reserved = 0;
    }

    fn reset_stats(&mut self) {
        self.stats = Stats::default();
    }

    fn stats(&self) -> &Stats {
        &self.stats
    }

    fn layout(&self) -> &SetLayout {
        &self.layout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn cfg(assoc: u32) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.fast_bytes = 256 << 10;
        cfg.hybrid.slow_bytes = 8 << 20;
        cfg.hybrid.num_sets = ((cfg.hybrid.fast_bytes / 256) / assoc as u64) as u32;
        cfg
    }

    #[test]
    fn tag_burst_count_scales_with_assoc() {
        assert_eq!(TagMatchController::new(&cfg(1)).tag_bursts, 1);
        assert_eq!(TagMatchController::new(&cfg(16)).tag_bursts, 1);
        assert_eq!(TagMatchController::new(&cfg(64)).tag_bursts, 4);
        assert_eq!(TagMatchController::new(&cfg(1024)).tag_bursts, 64);
    }

    #[test]
    fn high_assoc_pays_more_metadata_latency() {
        let run = |assoc| {
            let c = cfg(assoc);
            let mut ctl = TagMatchController::new(&c);
            let idx = ctl.layout.fast_per_set + 7;
            ctl.access(0, idx, 0, AccessKind::Read, 0);
            ctl.access(0, idx, 0, AccessKind::Read, 100_000);
            ctl.stats.metadata_cycles
        };
        assert!(run(1024) > 4 * run(16));
    }

    #[test]
    fn hit_after_fill_within_assoc() {
        let c = cfg(16);
        let mut ctl = TagMatchController::new(&c);
        let f = ctl.layout.fast_per_set;
        let mut t = 0;
        for n in 0..16 {
            ctl.access(0, f + n, 0, AccessKind::Read, t);
            t += 3000;
        }
        for n in 0..16 {
            ctl.access(0, f + n, 0, AccessKind::Read, t);
            t += 3000;
        }
        assert_eq!(ctl.stats.fast_served, 16);
        assert_eq!(ctl.stats.evictions, 0);
    }
}

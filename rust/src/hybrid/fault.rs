//! Deterministic fault injection for the hybrid controller (DESIGN.md §14).
//!
//! Trimma's remap metadata is a single point of failure: one corrupted
//! iRT/iRC entry silently misroutes every access to that block. This module
//! models three fault classes at the controller boundary so the recovery
//! paths in `hybrid/remap.rs` (scrub, rebuild, quarantine, retry) can be
//! exercised under load:
//!
//! 1. **Transient slow-tier read failures** — the device NACKs a read;
//!    recovered by bounded retry with deterministic exponential backoff,
//!    charged as extra slow-tier latency. A spent retry budget surfaces as
//!    the typed [`RetryExhausted`] error (never an unbounded loop) and the
//!    controller quarantines the set.
//! 2. **Metadata corruption** — a bit flip in a sampled remap-table entry
//!    (the forward, slow-side direction of a live pair). Detected by the
//!    controller's `audit_set` invariant sweep and repaired from the
//!    surviving inverse direction in the *same* access, so no corrupt state
//!    is ever observable from outside.
//! 3. **Stuck sets** — persistent faults sampled once per set at
//!    construction; a stuck set cannot be rebuilt and is quarantined on the
//!    first detected corruption (identity-mapped, direct-to-slow: degraded
//!    but correct).
//!
//! ## Determinism
//!
//! Every decision is a pure hash of `(seed, fault class, set, per-set event
//! counter)` — no wall clock, no global state. The sharded engine partitions
//! sets geometrically and slices see shard-count-invariant local set ids, so
//! the per-set decision stream is byte-identical across shard counts and
//! pipelined/inline frontends (locked by `rust/tests/faults.rs`).
//!
//! The Ideal oracle carries no remap metadata and constructs the injector
//! inert; the tag-based baselines (Alloy, LohHill) never instantiate it.
//! With `enabled = false` nothing is allocated and every hook reduces to a
//! single branch, keeping `--faults`-off runs byte-identical to builds that
//! predate this module.

use crate::config::FaultConfig;
use crate::types::Cycle;

/// Salt per fault class so the three decision streams are independent even
/// though they share one per-set counter.
const SALT_TRANSIENT: u64 = 0x7161_6E73_6965_6E74; // "transient"
const SALT_FLIP: u64 = 0x666C_6970_0BAD_F00D; // "flip"
const SALT_STUCK: u64 = 0x5374_7563_6B53_6574; // "StuckSet"

/// Retry budget spent without a successful read: the typed surface of fault
/// class 1. The controller reacts by charging the full backoff and
/// quarantining the set; callers probing the injector directly (tests) get
/// a real `Error` type instead of a hang.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryExhausted {
    /// Set whose slow-tier read kept failing.
    pub set: u32,
    /// Retries attempted (== `FaultConfig::max_retries`).
    pub attempts: u32,
    /// Total backoff latency spent across the failed attempts.
    pub backoff: Cycle,
}

impl std::fmt::Display for RetryExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "slow-tier read on set {} still failing after {} retries ({} cycles of backoff)",
            self.set, self.attempts, self.backoff
        )
    }
}

impl std::error::Error for RetryExhausted {}

/// Seeded, fully deterministic fault source. One per controller; all state
/// is preallocated at construction so the hot path stays allocation-free.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    cfg: FaultConfig,
    /// Config flag and the controller actually carries remap metadata
    /// (the Ideal oracle constructs this inert).
    enabled: bool,
    /// Per-set event counter: advances on every roll, making each set's
    /// decision stream independent of every other set's access pattern.
    counter: Vec<u64>,
    /// Per-set persistent-fault flag, sampled once at construction.
    stuck: Vec<bool>,
}

#[inline]
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultInjector {
    /// Build the injector for `num_sets` sets. When `enabled` is false no
    /// arrays are allocated and every hook reduces to a single branch.
    pub fn new(cfg: FaultConfig, enabled: bool, num_sets: usize) -> Self {
        let (counter, stuck) = if enabled {
            let stuck = (0..num_sets)
                .map(|set| {
                    splitmix64(cfg.seed ^ SALT_STUCK ^ (set as u64)) % 1000
                        < cfg.stuck_set_milli as u64
                })
                .collect();
            (vec![0u64; num_sets], stuck)
        } else {
            (Vec::new(), Vec::new())
        };
        FaultInjector { cfg, enabled, counter, stuck }
    }

    /// Whether the fault hooks are live for this controller.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Whether `set` was sampled as persistently faulty (cannot be rebuilt;
    /// quarantined on the first detected corruption). `false` when the
    /// injector is disabled.
    #[inline]
    pub fn is_stuck(&self, set: u32) -> bool {
        !self.stuck.is_empty() && self.stuck[set as usize]
    }

    /// One deterministic per-mille roll on `set`'s stream: advances the
    /// set's counter and fires with probability `milli / 1000`. Returns the
    /// raw hash on a hit so callers can derive secondary choices (e.g. a
    /// victim cursor) without consuming another roll.
    #[inline]
    fn roll(&mut self, set: u32, salt: u64, milli: u32) -> Option<u64> {
        let c = &mut self.counter[set as usize];
        *c += 1;
        let h = splitmix64(
            self.cfg.seed ^ salt ^ splitmix64((set as u64) << 32 | (*c & 0xFFFF_FFFF)) ^ (*c >> 32),
        );
        (h % 1000 < milli as u64).then_some(h)
    }

    /// Roll for a metadata bit flip on `set`. `Some(h)` means the flip
    /// fires; `h` is a deterministic cursor the controller uses to pick the
    /// victim entry. Caller gates on [`Self::enabled`].
    #[inline]
    pub fn metadata_flip(&mut self, set: u32) -> Option<u64> {
        self.roll(set, SALT_FLIP, self.cfg.metadata_flip_milli)
    }

    /// Roll for a transient slow-tier read failure on `set`.
    ///
    /// `None`: the read succeeded first try (the common case). Otherwise
    /// the injector replays the bounded-retry protocol — each attempt adds
    /// `backoff_base << attempt` cycles and re-rolls the fault — returning
    /// `Ok((backoff, retries))` when a retry lands, or the typed
    /// [`RetryExhausted`] (with the full budget's backoff) when all
    /// `max_retries` attempts fail. Caller gates on [`Self::enabled`].
    pub fn transient_read(&mut self, set: u32) -> Option<Result<(Cycle, u32), RetryExhausted>> {
        self.roll(set, SALT_TRANSIENT, self.cfg.transient_read_milli)?;
        let mut backoff: Cycle = 0;
        for attempt in 0..self.cfg.max_retries {
            backoff =
                backoff.saturating_add(self.cfg.backoff_base.saturating_mul(1u64 << attempt.min(31)));
            if self.roll(set, SALT_TRANSIENT, self.cfg.transient_read_milli).is_none() {
                return Some(Ok((backoff, attempt + 1)));
            }
        }
        Some(Err(RetryExhausted { set, attempts: self.cfg.max_retries, backoff }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(transient: u32, flip: u32, stuck: u32) -> FaultConfig {
        FaultConfig {
            enabled: true,
            transient_read_milli: transient,
            metadata_flip_milli: flip,
            stuck_set_milli: stuck,
            ..FaultConfig::off()
        }
    }

    #[test]
    fn disabled_injector_allocates_nothing() {
        let f = FaultInjector::new(FaultConfig::off(), false, 64);
        assert!(!f.enabled());
        assert!(f.counter.is_empty() && f.stuck.is_empty());
        assert!(!f.is_stuck(7));
    }

    #[test]
    fn decision_streams_are_deterministic() {
        let mut a = FaultInjector::new(cfg(100, 100, 50), true, 4);
        let mut b = FaultInjector::new(cfg(100, 100, 50), true, 4);
        for i in 0..2000u64 {
            let set = (i % 4) as u32;
            assert_eq!(a.metadata_flip(set), b.metadata_flip(set));
            assert_eq!(a.transient_read(set), b.transient_read(set));
        }
    }

    #[test]
    fn streams_are_per_set_independent() {
        // Interleaving accesses to other sets must not perturb set 0's
        // stream — this is the shard-count-invariance argument.
        let mut solo = FaultInjector::new(cfg(100, 100, 0), true, 4);
        let mut mixed = FaultInjector::new(cfg(100, 100, 0), true, 4);
        let mut got = Vec::new();
        for _ in 0..500 {
            got.push(solo.metadata_flip(0));
        }
        let mut interleaved = Vec::new();
        for i in 0..500u32 {
            mixed.metadata_flip(1 + i % 3);
            interleaved.push(mixed.metadata_flip(0));
            mixed.transient_read(1 + i % 3);
        }
        assert_eq!(got, interleaved);
    }

    #[test]
    fn milli_brackets_fire_rates() {
        let mut never = FaultInjector::new(cfg(0, 0, 0), true, 1);
        let mut always = FaultInjector::new(cfg(1000, 1000, 1000), true, 2);
        for _ in 0..200 {
            assert_eq!(never.metadata_flip(0), None);
            assert_eq!(never.transient_read(0), None);
            assert!(always.metadata_flip(0).is_some());
        }
        assert!(always.is_stuck(0) && always.is_stuck(1));
        let mut clean = FaultInjector::new(cfg(0, 0, 0), true, 8);
        assert!((0..8).all(|s| !clean.is_stuck(s)));
        let _ = clean.metadata_flip(0);
    }

    #[test]
    fn moderate_rate_fires_sometimes_not_always() {
        let mut f = FaultInjector::new(cfg(200, 200, 0), true, 1);
        let fired = (0..1000).filter(|_| f.metadata_flip(0).is_some()).count();
        assert!(fired > 100 && fired < 350, "~20% expected, got {fired}/1000");
    }

    #[test]
    fn exhaustion_is_a_typed_error_with_the_full_backoff() {
        // milli = 1000: every attempt fails, the budget is spent, and the
        // caller gets a typed error instead of a loop.
        let mut c = cfg(1000, 0, 0);
        c.max_retries = 3;
        c.backoff_base = 64;
        let mut f = FaultInjector::new(c, true, 1);
        let err = f.transient_read(0).expect("must fire at 1000 milli").unwrap_err();
        assert_eq!(err, RetryExhausted { set: 0, attempts: 3, backoff: 64 + 128 + 256 });
        let msg = err.to_string();
        assert!(msg.contains("3 retries"), "{msg}");
    }

    #[test]
    fn recovered_retries_charge_exponential_backoff() {
        // Scan a moderate rate until a fault recovers on a later attempt;
        // its backoff must be the exact prefix sum of the exponential.
        let mut c = cfg(500, 0, 0);
        c.max_retries = 4;
        c.backoff_base = 10;
        let mut f = FaultInjector::new(c, true, 1);
        let mut seen_multi = false;
        for _ in 0..2000 {
            if let Some(Ok((backoff, retries))) = f.transient_read(0) {
                let expect: u64 = (0..retries).map(|a| 10u64 << a).sum();
                assert_eq!(backoff, expect);
                seen_multi |= retries > 1;
            }
        }
        assert!(seen_multi, "at 50% per-attempt failure some recovery needs >1 retry");
    }

    #[test]
    fn stuck_sampling_is_seed_stable() {
        let a = FaultInjector::new(cfg(0, 0, 500), true, 64);
        let b = FaultInjector::new(cfg(0, 0, 500), true, 64);
        let stuck_a: Vec<bool> = (0..64).map(|s| a.is_stuck(s)).collect();
        let stuck_b: Vec<bool> = (0..64).map(|s| b.is_stuck(s)).collect();
        assert_eq!(stuck_a, stuck_b);
        let n = stuck_a.iter().filter(|&&x| x).count();
        assert!(n > 16 && n < 48, "~50% of 64 sets expected, got {n}");
    }
}

//! MemPod's Majority Element Algorithm (MEA) activity tracker
//! (Prodromou et al., HPCA'17).
//!
//! Per pod (set), a small array of `(candidate, count)` pairs tracks the
//! hottest slow-tier blocks using the classic Misra-Gries majority-element
//! scheme: a hit increments, an empty/zero slot adopts the new candidate,
//! otherwise *all* counters decrement. At every epoch boundary the blocks
//! still holding counters are (by the MEA guarantee) the most frequently
//! accessed of the epoch and get migrated into the fast tier.

/// MEA tracker for one set/pod.
#[derive(Debug, Clone)]
pub struct MeaTracker {
    entries: Vec<(u64, u32)>, // (per-set phys idx, count)
    /// Reusable sort buffer for epoch drains (keeps the per-epoch path
    /// allocation-free; capacity is fixed at `counters`).
    scratch: Vec<(u64, u32)>,
    accesses_this_epoch: u64,
    epoch_len: u64,
}

impl MeaTracker {
    /// `counters`: number of tracked candidates (MemPod uses 32 per pod).
    /// `epoch_len`: accesses per epoch before a migration round.
    pub fn new(counters: usize, epoch_len: u64) -> Self {
        MeaTracker {
            entries: vec![(u64::MAX, 0); counters],
            scratch: Vec::with_capacity(counters),
            accesses_this_epoch: 0,
            epoch_len,
        }
    }

    /// Record a slow-tier access. Returns `true` if an epoch boundary was
    /// reached (caller should then drain candidates and migrate).
    pub fn record(&mut self, idx: u64) -> bool {
        self.accesses_this_epoch += 1;
        let mut decrement_all = true;
        for e in self.entries.iter_mut() {
            if e.0 == idx {
                e.1 += 1;
                decrement_all = false;
                break;
            }
        }
        if decrement_all {
            // Adopt a free (zero-count) slot if any.
            if let Some(e) = self.entries.iter_mut().find(|e| e.1 == 0) {
                *e = (idx, 1);
                decrement_all = false;
            }
        }
        if decrement_all {
            for e in self.entries.iter_mut() {
                e.1 = e.1.saturating_sub(1);
            }
        }
        if self.accesses_this_epoch >= self.epoch_len {
            self.accesses_this_epoch = 0;
            true
        } else {
            false
        }
    }

    /// Candidates surviving the epoch with count >= `threshold`, hottest
    /// first, written into `out` (cleared first). Counters reset for the
    /// next epoch. Allocation-free given `out` has capacity `counters`:
    /// the sort is a stable insertion sort over at most `counters` pairs
    /// in the reusable scratch buffer (`slice::sort_by` would allocate).
    pub fn drain_hot_into(&mut self, threshold: u32, out: &mut Vec<u64>) {
        self.scratch.clear();
        self.scratch
            .extend(self.entries.iter().filter(|e| e.0 != u64::MAX && e.1 >= threshold));
        // Stable descending insertion sort: identical order to a stable
        // `sort_by(|a, b| b.1.cmp(&a.1))`.
        for i in 1..self.scratch.len() {
            let mut j = i;
            while j > 0 && self.scratch[j - 1].1 < self.scratch[j].1 {
                self.scratch.swap(j - 1, j);
                j -= 1;
            }
        }
        for e in self.entries.iter_mut() {
            *e = (u64::MAX, 0);
        }
        out.clear();
        out.extend(self.scratch.iter().map(|e| e.0));
    }

    /// Convenience wrapper around [`Self::drain_hot_into`] (tests / cold
    /// paths).
    pub fn drain_hot(&mut self, threshold: u32) -> Vec<u64> {
        let mut out = Vec::new();
        self.drain_hot_into(threshold, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_block_survives_epoch() {
        let mut m = MeaTracker::new(4, 100);
        for i in 0..99u64 {
            // Block 7 every other access; noise otherwise.
            m.record(if i % 2 == 0 { 7 } else { 1000 + i });
        }
        assert!(m.record(7)); // 100th access: epoch boundary
        let hot = m.drain_hot(2);
        assert_eq!(hot.first(), Some(&7));
    }

    #[test]
    fn uniform_noise_yields_no_hot_blocks() {
        let mut m = MeaTracker::new(4, 64);
        for i in 0..63u64 {
            m.record(i * 13);
        }
        m.record(9999);
        let hot = m.drain_hot(3);
        assert!(hot.is_empty(), "{hot:?}");
    }

    #[test]
    fn drain_resets_counters() {
        let mut m = MeaTracker::new(2, 10);
        for _ in 0..10 {
            m.record(5);
        }
        assert_eq!(m.drain_hot(1), vec![5]);
        assert!(m.drain_hot(1).is_empty());
    }

    #[test]
    fn epoch_boundary_cadence() {
        let mut m = MeaTracker::new(2, 3);
        assert!(!m.record(1));
        assert!(!m.record(1));
        assert!(m.record(1));
        assert!(!m.record(1));
    }
}

//! Hybrid memory controllers: the access flow of paper Fig. 3 over the two
//! memory tiers, for every evaluated design point.
//!
//! * [`remap`] — the general remap-table engine behind Trimma-C, Trimma-F,
//!   the linear-table cache design, MemPod, and the metadata-free Ideal
//!   oracle. Handles cache and flat modes, demand caching, MEA epoch
//!   migration, saved-metadata-space caching, and all table/remap-cache
//!   bookkeeping.
//! * [`alloy`] — Alloy Cache (Qureshi & Loh, MICRO'12): direct-mapped,
//!   tag-and-data in one burst, perfect memory-access predictor.
//! * [`lohhill`] — Loh-Hill Cache (MICRO'11): 30-way within an 8 kB row,
//!   tags-in-row, perfect MissMap, RRIP replacement.
//! * [`mea`] — MemPod's Majority Element Algorithm counters.
//!
//! All controllers implement [`Controller`]: the simulation engine feeds
//! them LLC-miss accesses in `(set, per-set index)` physical form and gets
//! back the demand latency; everything else (migration, metadata updates)
//! happens off the critical path but still occupies device banks.

pub mod alloy;
pub mod lohhill;
pub mod mea;
pub mod remap;
pub mod tagmatch;

use crate::config::{MetadataScheme, Mode, SystemConfig};
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

/// One controller-bound demand access in `(set, per-set index)` physical
/// form — the unit of the batched [`Controller::access_block`] entry point.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Access {
    pub set: u32,
    pub idx: u64,
    /// 64 B line offset within the migration block.
    pub line: u32,
    pub kind: AccessKind,
    /// Arrival cycle.
    pub now: Cycle,
}

/// A hybrid-memory controller under test.
pub trait Controller {
    /// One demand access (an LLC miss or LLC dirty writeback) to physical
    /// `(set, idx)`, 64 B line `line` within the block, arriving at cycle
    /// `now`. Returns the demand latency in cycles (metadata lookup + data
    /// access; fills/migrations excluded).
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle;

    /// Batched entry point: process `batch` in order, exactly as `N`
    /// single [`Controller::access`] calls would (stat-for-stat — the
    /// perf-harness tests lock this equivalence), returning the summed
    /// demand latency. The simulation engine routes posted LLC writebacks
    /// through this to amortize virtual dispatch; controllers with a
    /// monomorphic inner loop (e.g. [`remap::RemapController`]) override
    /// it so the per-access work is devirtualized.
    fn access_block(&mut self, batch: &[Access]) -> Cycle {
        let mut total = 0;
        for a in batch {
            total += self.access(a.set, a.idx, a.line, a.kind, a.now);
        }
        total
    }

    /// Snapshot end-of-run gauges (metadata size, donated slots) into stats.
    fn finalize(&mut self);

    /// Reset statistics (end of warmup). Structural state is kept.
    fn reset_stats(&mut self);

    fn stats(&self) -> &Stats;

    fn layout(&self) -> &SetLayout;

    /// Debug/verify introspection: the current physical->device translation
    /// for `(set, idx)`, with no stats or timing side effects. `None` means
    /// this controller has no remap table to introspect (the tag-matching
    /// baselines keep placement in cache tags instead); the verify oracle
    /// then skips remap-specific checks and runs only the generic ones.
    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        let _ = (set, idx);
        None
    }

    /// Deep self-check of one set's metadata/slot invariants (slot states
    /// vs. table entries, donated-slot accounting vs. iRT occupancy, free
    /// list coverage). Controllers without remap state accept by default.
    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        let _ = set;
        Ok(())
    }

    /// The remap table's own count of live non-identity entries in `set`
    /// (its internal occupancy bookkeeping). The verify oracle cross-checks
    /// this against the entries it can observe via [`Self::debug_translate`].
    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        let _ = set;
        None
    }
}

/// Build the controller for a system configuration. `ideal = true` builds
/// the metadata-free oracle of Fig. 1 regardless of `cfg.hybrid.scheme`.
/// With `cfg.hybrid.verify` the controller is shadowed by the
/// [`crate::verify::CheckedController`] oracle.
pub fn build_controller(cfg: &SystemConfig, ideal: bool) -> Box<dyn Controller> {
    let inner: Box<dyn Controller> = match (ideal, cfg.hybrid.scheme, cfg.hybrid.mode) {
        (true, _, _) => Box::new(remap::RemapController::new(cfg, true)),
        (_, MetadataScheme::TagAlloy, Mode::Cache) => Box::new(alloy::AlloyController::new(cfg)),
        (_, MetadataScheme::TagLohHill, Mode::Cache) => {
            Box::new(lohhill::LohHillController::new(cfg))
        }
        _ => Box::new(remap::RemapController::new(cfg, false)),
    };
    maybe_checked(inner, cfg)
}

/// Wrap `inner` in the verify oracle when the config asks for it.
pub fn maybe_checked(inner: Box<dyn Controller>, cfg: &SystemConfig) -> Box<dyn Controller> {
    if cfg.hybrid.verify {
        Box::new(crate::verify::CheckedController::new(inner, cfg))
    } else {
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    #[test]
    fn factory_builds_every_preset() {
        for dp in DesignPoint::ALL {
            let cfg = presets::hbm3_ddr5(*dp);
            let ideal = *dp == DesignPoint::Ideal;
            let c = build_controller(&cfg, ideal);
            assert_eq!(c.stats().mem_accesses, 0);
        }
    }
}

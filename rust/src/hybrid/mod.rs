//! Hybrid memory controllers: the access flow of paper Fig. 3 over the two
//! memory tiers, for every evaluated design point.
//!
//! * [`remap`] — the general remap-table engine behind Trimma-C, Trimma-F,
//!   the linear-table cache design, MemPod, and the metadata-free Ideal
//!   oracle. Handles cache and flat modes, demand caching, MEA epoch
//!   migration, saved-metadata-space caching, and all table/remap-cache
//!   bookkeeping.
//! * [`alloy`] — Alloy Cache (Qureshi & Loh, MICRO'12): direct-mapped,
//!   tag-and-data in one burst, perfect memory-access predictor.
//! * [`lohhill`] — Loh-Hill Cache (MICRO'11): 30-way within an 8 kB row,
//!   tags-in-row, perfect MissMap, RRIP replacement.
//! * [`mea`] — MemPod's Majority Element Algorithm counters.
//! * [`decay`] — pressure-driven metadata decay: cold remapped blocks
//!   migrate home and their table entries reclaim to identity format.
//! * [`fault`] — seeded deterministic fault injection (transient slow-tier
//!   read failures, metadata bit flips, stuck sets) driving the remap
//!   engine's recovery paths: bounded retry, scrub/rebuild, quarantine.
//! * [`prefetch`] — the portable software-prefetch shim behind the
//!   batched two-phase translate stage ([`Controller::access_block`] on
//!   the remap engine walks each batch ahead of execution and primes the
//!   metadata lines the probes will touch).
//!
//! All controllers implement [`Controller`]: the simulation engine feeds
//! them LLC-miss accesses in `(set, per-set index)` physical form and gets
//! back the demand latency; everything else (migration, metadata updates)
//! happens off the critical path but still occupies device banks.
//!
//! Panic audit (crate lint: `clippy::unwrap_used`): the controller hot
//! paths contain no production `unwrap`/`expect` at all — fallible
//! conditions either return typed errors at construction or are
//! `debug_assert`ed invariants the verify oracle re-checks.

pub mod alloy;
pub mod decay;
pub mod fault;
pub mod lohhill;
pub mod mea;
pub mod prefetch;
pub mod remap;
pub mod tagmatch;

use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

/// One controller-bound demand access in `(set, per-set index)` physical
/// form — the unit of the batched [`Controller::access_block`] entry point.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Access {
    pub set: u32,
    pub idx: u64,
    /// 64 B line offset within the migration block.
    pub line: u32,
    pub kind: AccessKind,
    /// Arrival cycle.
    pub now: Cycle,
}

/// A hybrid-memory controller under test.
pub trait Controller {
    /// One demand access (an LLC miss or LLC dirty writeback) to physical
    /// `(set, idx)`, 64 B line `line` within the block, arriving at cycle
    /// `now`. Returns the demand latency in cycles (metadata lookup + data
    /// access; fills/migrations excluded).
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle;

    /// Batched entry point: process `batch` in order, exactly as `N`
    /// single [`Controller::access`] calls would (stat-for-stat — the
    /// perf-harness tests lock this equivalence), returning the summed
    /// demand latency. The simulation engine routes posted LLC writebacks
    /// through this ([`crate::engine::Session::push_batch`]) to amortize
    /// dispatch; controllers with a monomorphic inner loop (e.g.
    /// [`remap::RemapController`]) override it so the per-access work is
    /// fully inlined.
    fn access_block(&mut self, batch: &[Access]) -> Cycle {
        let mut total = 0;
        for a in batch {
            total += self.access(a.set, a.idx, a.line, a.kind, a.now);
        }
        total
    }

    /// Snapshot end-of-run gauges (metadata size, donated slots) into stats.
    fn finalize(&mut self);

    /// Reset statistics (end of warmup). Structural state is kept.
    fn reset_stats(&mut self);

    fn stats(&self) -> &Stats;

    fn layout(&self) -> &SetLayout;

    /// Debug/verify introspection: the current physical->device translation
    /// for `(set, idx)`, with no stats or timing side effects. `None` means
    /// this controller has no remap table to introspect (the tag-matching
    /// baselines keep placement in cache tags instead); the verify oracle
    /// then skips remap-specific checks and runs only the generic ones.
    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        let _ = (set, idx);
        None
    }

    /// Deep self-check of one set's metadata/slot invariants (slot states
    /// vs. table entries, donated-slot accounting vs. iRT occupancy, free
    /// list coverage). Controllers without remap state accept by default.
    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        let _ = set;
        Ok(())
    }

    /// The remap table's own count of live non-identity entries in `set`
    /// (its internal occupancy bookkeeping). The verify oracle cross-checks
    /// this against the entries it can observe via [`Self::debug_translate`].
    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        let _ = set;
        None
    }
}

/// Boxed controllers forward every method to the boxed value, overrides
/// included, so `Box<SomeController>` (or a legacy `Box<dyn Controller>`)
/// is itself a [`Controller`]. The standard design points route through
/// the statically dispatched [`crate::engine::AnyController`] instead —
/// this impl exists for custom controllers and for the dispatch-overhead
/// comparison benches, which deliberately measure the dynamic path.
impl<T: Controller + ?Sized> Controller for Box<T> {
    #[inline]
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        (**self).access(set, idx, line, kind, now)
    }

    #[inline]
    fn access_block(&mut self, batch: &[Access]) -> Cycle {
        (**self).access_block(batch)
    }

    fn finalize(&mut self) {
        (**self).finalize()
    }

    fn reset_stats(&mut self) {
        (**self).reset_stats()
    }

    fn stats(&self) -> &Stats {
        (**self).stats()
    }

    fn layout(&self) -> &SetLayout {
        (**self).layout()
    }

    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        (**self).debug_translate(set, idx)
    }

    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        (**self).debug_check_set(set)
    }

    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        (**self).debug_nonidentity_entries(set)
    }
}

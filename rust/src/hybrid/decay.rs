//! Pressure-driven metadata decay ("trim the trimmer", DESIGN.md §11).
//!
//! Trimma's thesis is that identity mappings should cost no metadata; the
//! natural extension is that *cold* non-identity mappings shouldn't
//! either. [`DecayState`] tracks, per fast-tier slot, the last decay epoch
//! in which the slot's block was touched. At every epoch boundary the
//! controller (see `hybrid/remap.rs`) advances the set's epoch and — only
//! while the set's non-identity remap-table occupancy is above a
//! configurable pressure threshold — sweeps a budgeted window of slots,
//! evicting the cold remapped ones: a flat-mode swap is migrated back to
//! its home frame, a cached copy is written back if dirty, the table
//! entries are reclaimed to identity format, and the freed slot returns
//! to the DRAM-cache free stack.
//!
//! Epoch cadence: in flat mode the boundary piggybacks on the existing
//! MEA epoch (MemPod-style activity tracking already paces migration
//! there); in cache mode the decay state counts demand accesses itself
//! and fires every [`crate::config::DecayConfig::epoch_accesses`].
//!
//! Everything here is bookkeeping over preallocated arrays: the sweep
//! itself reuses the controller's eviction path, so the steady-state
//! translate path stays allocation-free (locked by `tests/alloc_free.rs`).

use crate::config::DecayConfig;

/// Per-controller decay bookkeeping: epoch counters, per-slot touch
/// stamps, and rotating sweep cursors, all sized at construction.
#[derive(Debug, Clone)]
pub struct DecayState {
    cfg: DecayConfig,
    /// Whether decay is active for this controller (config flag, and the
    /// controller is not the metadata-free Ideal oracle).
    enabled: bool,
    fast_per_set: u64,
    /// Per-set decay epoch (wraps; ages use `wrapping_sub`).
    epoch: Vec<u32>,
    /// Per-set demand-access counter toward the next epoch (cache mode).
    accesses: Vec<u32>,
    /// `set * fast_per_set + slot` -> epoch of the slot's last touch
    /// (fast-tier hit or fill).
    last_epoch: Vec<u32>,
    /// Per-set rotating cursor of the budgeted sweep.
    cursor: Vec<u32>,
}

impl DecayState {
    /// Build the bookkeeping for `num_sets x fast_per_set` slots. When
    /// `enabled` is false no arrays are allocated and every hot-path hook
    /// reduces to a single branch.
    pub fn new(cfg: DecayConfig, enabled: bool, num_sets: usize, fast_per_set: u64) -> Self {
        let (epoch, accesses, last_epoch, cursor) = if enabled {
            (
                vec![0u32; num_sets],
                vec![0u32; num_sets],
                vec![0u32; num_sets * fast_per_set as usize],
                vec![0u32; num_sets],
            )
        } else {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new())
        };
        DecayState { cfg, enabled, fast_per_set, epoch, accesses, last_epoch, cursor }
    }

    /// Whether the decay hooks are live for this controller.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Stamp `slot` of `set` as touched in the current epoch (fast-tier
    /// hit or fresh fill). Caller gates on [`Self::enabled`].
    #[inline]
    pub fn touch(&mut self, set: u32, slot: u64) {
        let at = set as usize * self.fast_per_set as usize + slot as usize;
        self.last_epoch[at] = self.epoch[set as usize];
    }

    /// Cache-mode cadence: count one demand access to `set`; returns
    /// `true` when the epoch boundary is reached (caller then runs the
    /// sweep). Flat mode skips this and piggybacks on the MEA boundary.
    #[inline]
    pub fn on_access(&mut self, set: u32) -> bool {
        let a = &mut self.accesses[set as usize];
        *a += 1;
        if *a >= self.cfg.epoch_accesses {
            *a = 0;
            true
        } else {
            false
        }
    }

    /// Advance `set`'s epoch at a boundary.
    #[inline]
    pub fn advance_epoch(&mut self, set: u32) {
        let e = &mut self.epoch[set as usize];
        *e = e.wrapping_add(1);
    }

    /// Pressure gate: sweep only while the set's non-identity occupancy
    /// (`occ`, in table *entries* — a cached block owns two, forward plus
    /// inverted) exceeds `2 * fast_per_set * pressure_milli / 1000`.
    /// `pressure_milli = 0` sweeps whenever any entry exists; `1000`
    /// never sweeps (occupancy cannot exceed two entries per slot).
    #[inline]
    pub fn over_pressure(&self, occ: u64) -> bool {
        occ > 2 * self.fast_per_set * self.cfg.pressure_milli as u64 / 1000
    }

    /// Slots to examine this epoch: the configured budget, clamped to one
    /// full rotation.
    #[inline]
    pub fn budget(&self) -> u64 {
        (self.cfg.sweep_budget as u64).min(self.fast_per_set)
    }

    /// Next slot under the set's rotating sweep cursor.
    #[inline]
    pub fn next_slot(&mut self, set: u32) -> u64 {
        let c = &mut self.cursor[set as usize];
        let s = *c as u64;
        *c = if s + 1 >= self.fast_per_set { 0 } else { *c + 1 };
        s
    }

    /// Whether `slot` of `set` has not been touched for more than
    /// `cold_epochs` epochs.
    #[inline]
    pub fn is_cold(&self, set: u32, slot: u64) -> bool {
        let at = set as usize * self.fast_per_set as usize + slot as usize;
        let age = self.epoch[set as usize].wrapping_sub(self.last_epoch[at]);
        age > self.cfg.cold_epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn on() -> DecayConfig {
        DecayConfig { enabled: true, ..DecayConfig::off() }
    }

    #[test]
    fn disabled_state_allocates_nothing() {
        let d = DecayState::new(DecayConfig::off(), false, 8, 64);
        assert!(!d.enabled());
        assert!(d.epoch.is_empty() && d.last_epoch.is_empty());
    }

    #[test]
    fn epoch_cadence_counts_accesses() {
        let mut cfg = on();
        cfg.epoch_accesses = 3;
        let mut d = DecayState::new(cfg, true, 1, 4);
        assert!(!d.on_access(0));
        assert!(!d.on_access(0));
        assert!(d.on_access(0));
        assert!(!d.on_access(0));
    }

    #[test]
    fn touch_resets_coldness() {
        let mut cfg = on();
        cfg.cold_epochs = 2;
        let mut d = DecayState::new(cfg, true, 1, 4);
        d.touch(0, 1);
        for _ in 0..3 {
            d.advance_epoch(0);
        }
        assert!(d.is_cold(0, 1), "3 epochs untouched > cold_epochs 2");
        d.touch(0, 1);
        assert!(!d.is_cold(0, 1));
        d.advance_epoch(0);
        assert!(!d.is_cold(0, 1), "age 1 <= cold_epochs 2");
    }

    #[test]
    fn coldness_survives_epoch_wraparound() {
        let mut cfg = on();
        cfg.cold_epochs = 1;
        let mut d = DecayState::new(cfg, true, 1, 2);
        d.epoch[0] = u32::MAX; // about to wrap
        d.touch(0, 0);
        d.advance_epoch(0); // epoch = 0
        assert_eq!(d.epoch[0], 0);
        assert!(!d.is_cold(0, 0), "age 1 across the wrap");
        d.advance_epoch(0);
        assert!(d.is_cold(0, 0), "age 2 across the wrap");
    }

    #[test]
    fn pressure_gate_brackets() {
        let mut cfg = on();
        cfg.pressure_milli = 500;
        let d = DecayState::new(cfg, true, 1, 64);
        // threshold = 2 * 64 * 500 / 1000 = 64 entries
        assert!(!d.over_pressure(64));
        assert!(d.over_pressure(65));
        let mut always = on();
        always.pressure_milli = 0;
        let d0 = DecayState::new(always, true, 1, 64);
        assert!(d0.over_pressure(1));
        assert!(!d0.over_pressure(0));
        let mut never = on();
        never.pressure_milli = 1000;
        let d1 = DecayState::new(never, true, 1, 64);
        assert!(!d1.over_pressure(2 * 64), "full occupancy still below the gate");
    }

    #[test]
    fn cursor_rotates_and_budget_clamps() {
        let mut cfg = on();
        cfg.sweep_budget = 100;
        let mut d = DecayState::new(cfg, true, 1, 3);
        assert_eq!(d.budget(), 3, "budget clamps to one rotation");
        assert_eq!(
            [d.next_slot(0), d.next_slot(0), d.next_slot(0), d.next_slot(0)],
            [0, 1, 2, 0]
        );
    }
}

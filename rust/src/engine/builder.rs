//! [`EngineBuilder`]: the one way to assemble a run.

use crate::config::presets::{self, DesignPoint};
use crate::config::{SystemConfig, TenantMixConfig};
use crate::engine::sharded::{self, ShardPlan, ShardedSession};
use crate::engine::{AnyController, EngineError, Session};
use crate::metadata::SetLayout;
use crate::sim::{tenants, ShardedSimulation, SimReport, Simulation, TenantReport};
use crate::workloads::{self, Workload};

/// Memory technology combination, mirroring the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryPreset {
    /// HBM3 fast tier + DDR5 slow tier (the paper's first combination).
    Hbm3Ddr5,
    /// DDR5 fast tier + Optane-like NVM slow tier (the second).
    Ddr5Nvm,
}

impl MemoryPreset {
    /// Every preset, in paper order.
    pub const ALL: &'static [MemoryPreset] = &[MemoryPreset::Hbm3Ddr5, MemoryPreset::Ddr5Nvm];

    /// The CLI spelling (`hbm3+ddr5` / `ddr5+nvm`).
    pub fn label(&self) -> &'static str {
        match self {
            MemoryPreset::Hbm3Ddr5 => "hbm3+ddr5",
            MemoryPreset::Ddr5Nvm => "ddr5+nvm",
        }
    }

    /// Parse the CLI spelling back; `None` for unknown names.
    pub fn parse(name: &str) -> Option<MemoryPreset> {
        MemoryPreset::ALL.iter().copied().find(|m| m.label() == name)
    }

    /// The ready-made [`SystemConfig`] for `design` on this technology.
    pub fn preset(&self, design: DesignPoint) -> SystemConfig {
        match self {
            MemoryPreset::Hbm3Ddr5 => presets::hbm3_ddr5(design),
            MemoryPreset::Ddr5Nvm => presets::ddr5_nvm(design),
        }
    }
}

/// Builder for simulation runs: one typed path from *design point +
/// memory preset + workload + toggles* to a config, a controller, a
/// streaming [`Session`], or a full trace-driven
/// [`Simulation`](crate::sim::Simulation).
///
/// Replaces the old `build_controller(cfg, ideal)` / `maybe_checked` /
/// `JobKind` triple-path. All `build_*` methods take `&self`, so one
/// builder can stamp out many identical runs (the coordinator builds one
/// per worker thread).
///
/// ```no_run
/// use trimma::config::presets::DesignPoint;
/// use trimma::engine::{EngineBuilder, MemoryPreset};
///
/// let report = EngineBuilder::new(DesignPoint::TrimmaFlat)
///     .memory(MemoryPreset::Ddr5Nvm)
///     .workload("ycsb_a")
///     .verify(true) // shadow the run with the differential remap oracle
///     .run()
///     .unwrap();
/// assert!(report.stats.mem_accesses > 0);
/// ```
///
/// Unknown workload names surface as a typed error instead of a panic:
///
/// ```
/// use trimma::config::presets::DesignPoint;
/// use trimma::engine::{EngineBuilder, EngineError};
///
/// let err = EngineBuilder::new(DesignPoint::TrimmaCache)
///     .workload("definitely_not_a_workload")
///     .run()
///     .unwrap_err();
/// assert!(matches!(err, EngineError::UnknownWorkload(_)));
/// assert!(err.to_string().contains("gap_pr")); // lists the valid names
/// ```
pub struct EngineBuilder {
    design: DesignPoint,
    memory: MemoryPreset,
    base: Option<SystemConfig>,
    workload: Option<String>,
    ideal: bool,
    verify: bool,
    decay: bool,
    faults: bool,
    prefetch: bool,
    tag_match: bool,
    shards: usize,
    pipeline: bool,
    tenant_mix: Option<TenantMixConfig>,
    trace: Option<std::path::PathBuf>,
    tweaks: Vec<Box<dyn Fn(&mut SystemConfig)>>,
}

impl EngineBuilder {
    /// A builder for `design` on the default HBM3+DDR5 technology.
    pub fn new(design: DesignPoint) -> Self {
        EngineBuilder {
            design,
            memory: MemoryPreset::Hbm3Ddr5,
            base: None,
            workload: None,
            ideal: false,
            verify: false,
            decay: false,
            faults: false,
            prefetch: false,
            tag_match: false,
            shards: 1,
            pipeline: false,
            tenant_mix: None,
            trace: None,
            tweaks: Vec::new(),
        }
    }

    /// Seed the builder from an explicit, already-assembled config (the
    /// CLI's flag-override path and the coordinator's per-job configs).
    /// Overrides whatever `design`/`memory` would have produced; `ideal`,
    /// `verify`, `tag_match`, and `configure` tweaks still apply on top.
    pub fn from_config(cfg: SystemConfig) -> Self {
        let mut b = EngineBuilder::new(DesignPoint::TrimmaCache);
        b.base = Some(cfg);
        b
    }

    /// Select the design point (ignored after [`EngineBuilder::from_config`]).
    pub fn design(mut self, design: DesignPoint) -> Self {
        self.design = design;
        self
    }

    /// Select the memory technology combination (ignored after
    /// [`EngineBuilder::from_config`]).
    pub fn memory(mut self, memory: MemoryPreset) -> Self {
        self.memory = memory;
        self
    }

    /// Name the workload to simulate (calibrated suite or `adv_*`
    /// adversarial scenario). Required for [`EngineBuilder::build`] /
    /// [`EngineBuilder::run`]; validated against
    /// [`workloads::all_names`](crate::workloads::all_names).
    pub fn workload(mut self, name: impl Into<String>) -> Self {
        self.workload = Some(name.into());
        self
    }

    /// Build the metadata-free Ideal oracle of Fig. 1 instead of the
    /// design point's controller (mutually exclusive with `tag_match`).
    pub fn ideal(mut self, ideal: bool) -> Self {
        self.ideal = ideal;
        self
    }

    /// Shadow the controller with the differential verify oracle
    /// ([`crate::verify`]); tests and debug runs pay the cost, sweeps
    /// don't.
    pub fn verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    /// Enable pressure-driven metadata decay ([`crate::hybrid::decay`],
    /// DESIGN.md §11): cold non-identity remap entries are periodically
    /// reclaimed to identity format and their fast-tier slots returned to
    /// the cache. Knob values come from the config's
    /// [`DecayConfig`](crate::config::DecayConfig) defaults unless
    /// overridden via [`EngineBuilder::configure`].
    pub fn decay(mut self, decay: bool) -> Self {
        self.decay = decay;
        self
    }

    /// Enable deterministic fault injection ([`crate::hybrid::fault`],
    /// DESIGN.md §14): seeded transient slow-tier read failures, metadata
    /// bit flips, and stuck sets drive the remap controller's recovery
    /// paths (bounded retry, scrub/rebuild, quarantine). Knob values come
    /// from the config's [`FaultConfig`](crate::config::FaultConfig)
    /// defaults unless overridden via [`EngineBuilder::configure`]. Inert
    /// on the Ideal oracle and the tag-matching baselines, which carry no
    /// remap metadata.
    pub fn faults(mut self, faults: bool) -> Self {
        self.faults = faults;
        self
    }

    /// Enable the batched two-phase translate stage
    /// ([`crate::hybrid::prefetch`], DESIGN.md §15): the remap engine's
    /// batched entry point walks each batch ahead of execution, issuing
    /// software prefetches for the metadata lines the upcoming probes
    /// will touch. Semantically invisible — canonical stats are
    /// byte-identical on/off modulo the `batch_prefetches` telemetry
    /// counter. The lookahead window comes from the config's
    /// [`BatchConfig`](crate::config::BatchConfig) defaults unless
    /// overridden via [`EngineBuilder::configure`]. Inert on the Ideal
    /// oracle and the tag-matching baselines, which carry no remap
    /// metadata.
    pub fn prefetch(mut self, prefetch: bool) -> Self {
        self.prefetch = prefetch;
        self
    }

    /// Build the generic a-way tag-matching baseline of Fig. 1 instead of
    /// the design point's controller (mutually exclusive with `ideal`).
    pub fn tag_match(mut self, tag_match: bool) -> Self {
        self.tag_match = tag_match;
        self
    }

    /// Worker-thread count for the sharded execution path
    /// ([`EngineBuilder::build_sharded`] / [`EngineBuilder::run_sharded`];
    /// clamped to the [`ShardPlan`]'s slice count at build time). Has no
    /// effect on the classic closed-loop [`EngineBuilder::run`] path.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Run the sharded path's front end **pipelined**: trace generation +
    /// cache filtering + translation on the calling thread, shard routing
    /// on a dedicated router stage (see [`crate::sim::ExecCore`]'s module
    /// docs for the stage split and the determinism argument). Merged
    /// canonical stats are byte-identical pipelined vs inline, locked by
    /// `rust/tests/pipeline_parity.rs`. Like [`EngineBuilder::shards`],
    /// this has no effect on the classic closed-loop
    /// [`EngineBuilder::run`] path (whose latency feedback cannot be
    /// pipelined).
    pub fn pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Run the **multi-tenant** front end ([`crate::sim::tenants`],
    /// DESIGN.md §12) with the given knobs: `mix.enabled` is forced on,
    /// everything else (tenant count, scenario, mix profile, histogram
    /// geometry) is taken from `mix`. The workload is the composite
    /// [`TenantMixWorkload`](crate::workloads::tenants::TenantMixWorkload)
    /// — [`EngineBuilder::workload`] is ignored on the tenant path.
    pub fn tenants(mut self, mix: TenantMixConfig) -> Self {
        self.tenant_mix = Some(mix);
        self
    }

    /// Drive the run from a recorded trace file instead of a synthetic
    /// generator ([`crate::trace::TraceWorkload`]; DESIGN.md §13): the
    /// trace replaces [`EngineBuilder::workload`] on the `build()` /
    /// `run()` / `run_sharded()` paths, `cfg.trace.enabled` is forced on,
    /// and the config's core count and access budgets must match the
    /// trace header (use [`EngineBuilder::configure`] or the `trimma
    /// replay` CLI, which adopts them from the header). Replay I/O knobs
    /// — chunking, buffered vs read-ahead, validate-on-open — come from
    /// [`TraceConfig`](crate::config::TraceConfig).
    pub fn trace(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Queue a raw config tweak, applied (in call order) after the preset
    /// is materialized — capacities, core counts, access budgets, remap
    /// cache geometry: anything the typed knobs don't cover.
    pub fn configure(mut self, f: impl Fn(&mut SystemConfig) + 'static) -> Self {
        self.tweaks.push(Box::new(f));
        self
    }

    /// Materialize and validate the [`SystemConfig`] this builder
    /// describes, without constructing a controller.
    pub fn build_config(&self) -> Result<SystemConfig, EngineError> {
        if self.ideal && self.tag_match {
            return Err(EngineError::InvalidConfig(
                "ideal and tag_match are mutually exclusive controller overrides".to_string(),
            ));
        }
        let mut cfg = match &self.base {
            Some(base) => base.clone(),
            None => self.memory.preset(self.design),
        };
        for tweak in &self.tweaks {
            tweak(&mut cfg);
        }
        cfg.hybrid.verify |= self.verify;
        cfg.hybrid.decay.enabled |= self.decay;
        cfg.hybrid.fault.enabled |= self.faults;
        cfg.hybrid.batch.prefetch |= self.prefetch;
        if let Some(mix) = self.tenant_mix {
            cfg.tenant_mix = mix;
            cfg.tenant_mix.enabled = true;
        }
        cfg.trace.enabled |= self.trace.is_some();
        cfg.validate().map_err(EngineError::InvalidConfig)?;
        Ok(cfg)
    }

    /// Build the enum-dispatched controller for this design point,
    /// honouring the `ideal` / `tag_match` / `verify` toggles.
    pub fn build_controller(&self) -> Result<AnyController, EngineError> {
        let cfg = self.build_config()?;
        Ok(self.controller_for(&cfg))
    }

    /// Controller routing against an already-materialized config.
    fn controller_for(&self, cfg: &SystemConfig) -> AnyController {
        if self.tag_match {
            AnyController::tag_match(cfg)
        } else {
            AnyController::from_config(cfg, self.ideal)
        }
    }

    /// Build a streaming [`Session`] over this builder's controller. The
    /// session label is the workload name when one is set, the config
    /// name otherwise.
    pub fn build_session(&self) -> Result<Session, EngineError> {
        let cfg = self.build_config()?;
        let ctrl = self.controller_for(&cfg);
        let label = self.workload.clone().unwrap_or_else(|| cfg.name.clone());
        Ok(Session::with_controller(label, ctrl))
    }

    /// Build a sharded session over this builder's configuration: one
    /// slice [`Session`] per [`ShardPlan`] slice, each running the
    /// [`sharded::slice_config`] sub-config (same per-set geometry,
    /// `1/num_slices` of the sets, capacities, and remap-cache SRAM),
    /// honouring the `ideal` / `tag_match` / `verify` toggles.
    pub fn build_sharded(&self) -> Result<ShardedSession, EngineError> {
        let cfg = self.build_config()?;
        // The layout must match what `controller_for` will build: tag
        // matching reserves no metadata region; `ideal` skips it too.
        let layout = SetLayout::for_config(&cfg.hybrid, self.tag_match || self.ideal);
        let plan = ShardPlan::new(&layout, self.shards);
        let mut sessions = Vec::with_capacity(plan.num_slices() as usize);
        for slice in 0..plan.num_slices() {
            let sub = sharded::slice_config(&cfg, &plan, slice);
            sub.validate().map_err(EngineError::InvalidConfig)?;
            let ctrl = self.controller_for(&sub);
            debug_assert_eq!(
                ctrl.layout().fast_per_set,
                layout.fast_per_set,
                "slice layout must keep the full config's per-set geometry"
            );
            let label = sub.name.clone();
            sessions.push(Session::with_controller(label, ctrl));
        }
        let label = self.workload.clone().unwrap_or_else(|| cfg.name.clone());
        Ok(ShardedSession::assemble(label, layout, plan, sessions))
    }

    /// Build and run the **sharded, open-loop** simulation of this
    /// builder's workload across [`EngineBuilder::shards`] worker threads
    /// (see [`sharded`](crate::engine::sharded) for the execution model
    /// and its determinism guarantee). Requires a workload.
    pub fn run_sharded(&self) -> Result<SimReport, EngineError> {
        let cfg = self.build_config()?;
        let wl = self.resolve_workload(&cfg)?;
        let session = self.build_sharded()?;
        Ok(ShardedSimulation::new(&cfg, wl, session).pipelined(self.pipeline).run())
    }

    /// The run's access-stream source: the attached trace file when
    /// [`EngineBuilder::trace`] was called (opened per `cfg.trace`'s
    /// replay knobs), the named synthetic workload otherwise.
    fn resolve_workload(&self, cfg: &SystemConfig) -> Result<Box<dyn Workload>, EngineError> {
        if let Some(path) = &self.trace {
            let wl = crate::trace::TraceWorkload::open(path, cfg)?;
            Ok(Box::new(wl))
        } else {
            let name = self.workload.as_deref().ok_or(EngineError::MissingWorkload)?;
            Ok(workloads::by_name(name, cfg)?)
        }
    }

    /// Run the **closed-loop** simulation of this builder's (synthetic)
    /// workload while recording every consumed access into a trace file
    /// at `path` ([`crate::trace::TraceRecorder`]; truncates an existing
    /// file). Returns the live run's report — replaying the trace
    /// reproduces its canonical stats byte-for-byte in every execution
    /// mode (`tests/trace_parity.rs`). Encoding knobs come from
    /// [`TraceConfig`](crate::config::TraceConfig).
    pub fn run_recorded(&self, path: impl AsRef<std::path::Path>) -> Result<SimReport, EngineError> {
        let name = self.workload.as_deref().ok_or(EngineError::MissingWorkload)?;
        let cfg = self.build_config()?;
        let wl = workloads::by_name(name, &cfg)?;
        let mut rec = crate::trace::TraceRecorder::create(
            path.as_ref(),
            &cfg,
            wl.name(),
            wl.footprint_bytes(),
        )?;
        let ctrl = self.controller_for(&cfg);
        let mut sim = Simulation::with_controller(&cfg, wl, ctrl);
        let rep = sim.run_tapped(&mut rec);
        rec.finish()?;
        Ok(rep)
    }

    /// Build and run the multi-tenant front end over this builder's
    /// configuration (requires [`EngineBuilder::tenants`] or a base
    /// config with `tenant_mix.enabled`). Execution model follows the
    /// builder's sharding knobs: `shards(0)` runs the **closed loop**
    /// (real controller latencies — meaningful per-tenant p50/p99,
    /// oracle-capable), any other shard count runs the **open-loop**
    /// sharded path (optionally pipelined), whose per-tenant stats are
    /// byte-identical across shard counts and front-end modes.
    pub fn run_tenant_mix(&self) -> Result<TenantReport, EngineError> {
        let cfg = self.build_config()?;
        if !cfg.tenant_mix.enabled {
            return Err(EngineError::InvalidConfig(
                "tenant mix not enabled: call EngineBuilder::tenants(..)".to_string(),
            ));
        }
        if self.shards == 0 {
            Ok(tenants::run_closed(&cfg)?)
        } else {
            let session = self.build_sharded()?;
            Ok(tenants::run_sharded(&cfg, session, self.pipeline)?)
        }
    }

    /// Build the full trace-driven simulation (requires a workload or an
    /// attached trace file).
    pub fn build(&self) -> Result<Simulation, EngineError> {
        let cfg = self.build_config()?;
        let wl = self.resolve_workload(&cfg)?;
        let ctrl = self.controller_for(&cfg);
        Ok(Simulation::with_controller(&cfg, wl, ctrl))
    }

    /// Build and run the simulation to completion.
    pub fn run(&self) -> Result<SimReport, EngineError> {
        Ok(self.build()?.run())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shrink(cfg: &mut SystemConfig) {
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 2;
        cfg.workload.accesses_per_core = 800;
        cfg.workload.warmup_per_core = 200;
    }

    #[test]
    fn builder_runs_a_tiny_simulation() {
        let rep = EngineBuilder::new(DesignPoint::TrimmaCache)
            .workload("adv_drift")
            .configure(shrink)
            .run()
            .unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert_eq!(rep.name, "adv_drift");
    }

    #[test]
    fn missing_workload_is_a_typed_error() {
        let err = EngineBuilder::new(DesignPoint::TrimmaCache).build().unwrap_err();
        assert_eq!(err, EngineError::MissingWorkload);
    }

    #[test]
    fn unknown_workload_lists_valid_names() {
        let err = EngineBuilder::new(DesignPoint::TrimmaCache)
            .workload("nope")
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("'nope'"), "{msg}");
        assert!(msg.contains("gap_pr") && msg.contains("adv_set_thrash"), "{msg}");
    }

    #[test]
    fn ideal_and_tag_match_conflict() {
        let err = EngineBuilder::new(DesignPoint::TrimmaCache)
            .ideal(true)
            .tag_match(true)
            .build_config()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)));
    }

    #[test]
    fn toggles_route_controllers() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache).configure(shrink);
        assert_eq!(b.build_controller().unwrap().kind(), "remap");
        let b = EngineBuilder::new(DesignPoint::AlloyCache)
            .configure(|cfg| cfg.hybrid.num_sets = (cfg.hybrid.fast_bytes / 256) as u32);
        assert_eq!(b.build_controller().unwrap().kind(), "alloy");
        assert_eq!(b.tag_match(true).build_controller().unwrap().kind(), "tag-match");
        let b = EngineBuilder::new(DesignPoint::TrimmaCache).configure(shrink).verify(true);
        assert_eq!(b.build_controller().unwrap().kind(), "checked");
    }

    #[test]
    fn from_config_keeps_explicit_overrides() {
        let mut cfg = MemoryPreset::Hbm3Ddr5.preset(DesignPoint::TrimmaFlat);
        shrink(&mut cfg);
        let session = EngineBuilder::from_config(cfg.clone()).build_session().unwrap();
        assert_eq!(session.layout().num_sets, 4);
        assert_eq!(session.label(), cfg.name);
    }

    #[test]
    fn build_sharded_slices_share_per_set_geometry() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache).configure(shrink).shards(2);
        let s = b.build_sharded().unwrap();
        assert_eq!(s.plan().num_sets(), 4);
        assert_eq!(s.plan().num_shards(), 2);
        assert_eq!(s.sessions().len(), s.plan().num_slices() as usize);
        for sess in s.sessions() {
            assert_eq!(sess.layout().fast_per_set, s.full_layout().fast_per_set);
            assert_eq!(sess.layout().num_sets, s.plan().sets_per_slice());
        }
    }

    #[test]
    fn run_sharded_runs_a_tiny_simulation() {
        let rep = EngineBuilder::new(DesignPoint::TrimmaCache)
            .workload("adv_drift")
            .configure(shrink)
            .shards(2)
            .run_sharded()
            .unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.instructions > 0);
        assert_eq!(rep.name, "adv_drift");
    }

    #[test]
    fn pipeline_toggle_runs_and_matches_inline() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache)
            .workload("adv_drift")
            .configure(shrink)
            .shards(2);
        let inline = b.run_sharded().unwrap();
        let piped = b.pipeline(true).run_sharded().unwrap();
        assert!(piped.stats.mem_accesses > 0);
        assert_eq!(inline.stats.canonical(), piped.stats.canonical());
    }

    #[test]
    fn decay_toggle_enables_the_knob_and_runs() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .configure(|cfg| cfg.hybrid.decay.epoch_accesses = 8)
            .decay(true);
        assert!(b.build_config().unwrap().hybrid.decay.enabled);
        let rep = b.workload("adv_drift").run().unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.decay_epochs > 0, "decay epochs should tick");
        // Off by default.
        let cfg = EngineBuilder::new(DesignPoint::TrimmaCache).build_config().unwrap();
        assert!(!cfg.hybrid.decay.enabled);
    }

    #[test]
    fn faults_toggle_enables_the_knob_and_runs() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .configure(|cfg| cfg.hybrid.fault.metadata_flip_milli = 200)
            .faults(true);
        assert!(b.build_config().unwrap().hybrid.fault.enabled);
        let rep = b.workload("adv_drift").verify(true).run().unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.fault_injected > 0, "faults should fire under the oracle");
        // Off by default.
        let cfg = EngineBuilder::new(DesignPoint::TrimmaCache).build_config().unwrap();
        assert!(!cfg.hybrid.fault.enabled);
    }

    #[test]
    fn prefetch_toggle_enables_the_knob_and_stays_invisible() {
        let on = EngineBuilder::new(DesignPoint::TrimmaCache).configure(shrink).prefetch(true);
        assert!(on.build_config().unwrap().hybrid.batch.prefetch);
        // The sharded path consumes everything through the batched entry
        // point, so the phase-1 walk really runs there.
        let rep_on = on.workload("adv_drift").run_sharded().unwrap();
        assert!(rep_on.stats.mem_accesses > 0);
        assert!(rep_on.stats.batch_prefetches > 0, "phase-1 walk never fired");
        let rep_off = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .workload("adv_drift")
            .run_sharded()
            .unwrap();
        assert_eq!(rep_off.stats.batch_prefetches, 0);
        // Semantically invisible: only the telemetry counter moves.
        let strip = |c: &str| {
            c.split(';')
                .filter(|p| !p.starts_with("batch_prefetches="))
                .collect::<Vec<_>>()
                .join(";")
        };
        assert_eq!(
            strip(&rep_on.stats.canonical()),
            strip(&rep_off.stats.canonical()),
            "prefetch changed an observable stat"
        );
        // Off by default.
        let cfg = EngineBuilder::new(DesignPoint::TrimmaCache).build_config().unwrap();
        assert!(!cfg.hybrid.batch.prefetch);
    }

    #[test]
    fn tenant_mix_runs_on_both_execution_models() {
        let mix = TenantMixConfig { tenants: 3, ..TenantMixConfig::off() };
        let closed = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .tenants(mix)
            .shards(0)
            .run_tenant_mix()
            .unwrap();
        assert_eq!(closed.tenants.len(), 3);
        assert!(closed.merged.stats.mem_accesses > 0);
        let sharded = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .tenants(mix)
            .shards(2)
            .run_tenant_mix()
            .unwrap();
        assert_eq!(sharded.tenants.len(), 3);
        // Without the toggle the tenant path is a typed error.
        let err = EngineBuilder::new(DesignPoint::TrimmaCache)
            .configure(shrink)
            .run_tenant_mix()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)));
    }

    #[test]
    fn record_then_replay_reproduces_the_run() {
        let path = std::env::temp_dir()
            .join(format!("trimma-builder-{}-roundtrip.trimtrace", std::process::id()));
        let b = EngineBuilder::new(DesignPoint::TrimmaCache).workload("adv_drift").configure(shrink);
        let live = b.run_recorded(&path).unwrap();
        assert!(live.stats.mem_accesses > 0);
        let replayed = EngineBuilder::new(DesignPoint::TrimmaCache)
            .trace(&path)
            .configure(shrink)
            .run()
            .unwrap();
        assert_eq!(replayed.name, "adv_drift", "replay reports the recorded label");
        assert_eq!(live.stats.canonical(), replayed.stats.canonical());
        // The trace toggle reaches the config; a bogus path is typed.
        let cfg = EngineBuilder::new(DesignPoint::TrimmaCache).trace(&path).build_config().unwrap();
        assert!(cfg.trace.enabled);
        std::fs::remove_file(&path).unwrap();
        let err = EngineBuilder::new(DesignPoint::TrimmaCache)
            .trace("/nonexistent/trimma.trimtrace")
            .run()
            .unwrap_err();
        assert!(matches!(err, EngineError::Trace(_)));
    }

    #[test]
    fn memory_preset_labels_round_trip() {
        for m in MemoryPreset::ALL {
            assert_eq!(MemoryPreset::parse(m.label()), Some(*m));
        }
        assert_eq!(MemoryPreset::parse("sram+tape"), None);
    }

    #[test]
    fn builder_session_and_sim_share_geometry() {
        let b = EngineBuilder::new(DesignPoint::TrimmaCache).workload("gap_pr").configure(shrink);
        let session = b.build_session().unwrap();
        let sim = b.build().unwrap();
        assert_eq!(session.layout().num_sets, sim.session().layout().num_sets);
        assert_eq!(session.controller().kind(), "remap");
    }
}

//! The public front door of the simulator: a builder-first, statically
//! dispatched session API.
//!
//! Historically a run was assembled through three overlapping mechanisms:
//! `hybrid::build_controller(cfg, ideal)` (an `ideal: bool` threaded
//! through every caller), `hybrid::maybe_checked` (manual verify-oracle
//! wrapping), and `coordinator::JobKind` (a third spelling of the same
//! choice for the sweep harness) — all of them meeting in a
//! `Box<dyn Controller>` whose virtual dispatch sat on the per-access hot
//! path. This module replaces that triple-path with one coherent, typed
//! API:
//!
//! * [`EngineBuilder`] — the single way to assemble a run: typed design
//!   point, memory preset, workload, and the `ideal` / `verify` /
//!   `tag_match` toggles, with `configure` closures for raw
//!   [`SystemConfig`](crate::config::SystemConfig) tweaks.
//! * [`AnyController`] — an enum over every controller implementation.
//!   `access` and `access_block` dispatch through a match, so once the
//!   simulation loop is monomorphized over `AnyController` the per-access
//!   call chain is fully devirtualized for **all** design points, not just
//!   the remap engine.
//! * [`Session`] — a streaming consumer of controller-level
//!   [`Access`](crate::hybrid::Access)es: `push_batch(&[Access]) ->
//!   Completion`, `finish() -> SimReport`. Trace generation is decoupled
//!   from simulation: the trace-driven [`Simulation`](crate::sim::Simulation)
//!   engine, the bench suite, the adversarial scenario drivers, and any
//!   future sharded/async driver all feed accesses through this one entry
//!   point.
//!
//! ```no_run
//! use trimma::config::presets::DesignPoint;
//! use trimma::engine::EngineBuilder;
//!
//! let report = EngineBuilder::new(DesignPoint::TrimmaCache)
//!     .workload("gap_pr")
//!     .run()
//!     .unwrap();
//! println!("IPC-proxy perf: {:.4}", report.performance());
//! ```
#![deny(missing_docs)]

mod builder;
mod controller;
mod session;

pub use builder::{EngineBuilder, MemoryPreset};
pub use controller::AnyController;
pub use session::{Completion, Session};

use crate::workloads::UnknownWorkload;

/// Everything that can go wrong while assembling or running an engine.
///
/// The CLI surfaces these with a non-zero exit code; library callers can
/// match on the variants (all payloads are plain data, so the error is
/// `Send + Sync` and travels across the coordinator's worker threads).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The requested workload name is not in the calibrated suite or the
    /// adversarial scenario set. The payload lists every valid name.
    UnknownWorkload(UnknownWorkload),
    /// A simulation was requested from a builder with no workload set.
    MissingWorkload,
    /// The assembled [`SystemConfig`](crate::config::SystemConfig) failed
    /// validation, or the builder toggles contradict each other.
    InvalidConfig(String),
    /// The requested figure id is not part of the evaluation
    /// (see `coordinator::figures::ALL_FIGURES`).
    UnknownFigure(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnknownWorkload(e) => write!(f, "{e}"),
            EngineError::MissingWorkload => {
                write!(f, "no workload set: call EngineBuilder::workload(..) before build()/run()")
            }
            EngineError::InvalidConfig(e) => write!(f, "invalid config: {e}"),
            EngineError::UnknownFigure(id) => write!(f, "unknown figure '{id}'"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<UnknownWorkload> for EngineError {
    fn from(e: UnknownWorkload) -> Self {
        EngineError::UnknownWorkload(e)
    }
}

//! The public front door of the simulator: a builder-first, statically
//! dispatched session API.
//!
//! Historically a run was assembled through three overlapping mechanisms:
//! `hybrid::build_controller(cfg, ideal)` (an `ideal: bool` threaded
//! through every caller), `hybrid::maybe_checked` (manual verify-oracle
//! wrapping), and `coordinator::JobKind` (a third spelling of the same
//! choice for the sweep harness) — all of them meeting in a
//! `Box<dyn Controller>` whose virtual dispatch sat on the per-access hot
//! path. This module replaces that triple-path with one coherent, typed
//! API:
//!
//! * [`EngineBuilder`] — the single way to assemble a run: typed design
//!   point, memory preset, workload, and the `ideal` / `verify` /
//!   `tag_match` toggles, with `configure` closures for raw
//!   [`SystemConfig`](crate::config::SystemConfig) tweaks.
//! * [`AnyController`] — an enum over every controller implementation.
//!   `access` and `access_block` dispatch through a match, so once the
//!   simulation loop is monomorphized over `AnyController` the per-access
//!   call chain is fully devirtualized for **all** design points, not just
//!   the remap engine.
//! * [`Session`] — a streaming consumer of controller-level
//!   [`Access`](crate::hybrid::Access)es: `push_batch(&[Access]) ->
//!   Completion`, `finish() -> SimReport`. Trace generation is decoupled
//!   from simulation: the trace-driven [`Simulation`](crate::sim::Simulation)
//!   engine, the bench suite, the adversarial scenario drivers, and the
//!   sharded driver all feed accesses through this one entry point.
//! * [`sharded`] — set-partitioned parallel execution of a **single**
//!   run: a [`ShardPlan`] cuts the set space into contiguous slices, a
//!   [`ShardedSession`] owns one `Session` per slice, and lock-free SPSC
//!   batch queues fan the front end's access stream out to worker
//!   threads, with a deterministic gauge-summing merge
//!   (`EngineBuilder::shards(n)` + `build_sharded`/`run_sharded`). The
//!   front end itself is the unified [`crate::sim::ExecCore`] loop and
//!   can be pipelined (`EngineBuilder::pipeline(true)`): shard routing
//!   moves to a dedicated stage, with byte-identical merged stats.
//!
//! ```no_run
//! use trimma::config::presets::DesignPoint;
//! use trimma::engine::EngineBuilder;
//!
//! let report = EngineBuilder::new(DesignPoint::TrimmaCache)
//!     .workload("gap_pr")
//!     .run()
//!     .unwrap();
//! println!("IPC-proxy perf: {:.4}", report.performance());
//! ```
#![deny(missing_docs)]

mod builder;
mod controller;
mod session;
pub mod sharded;

pub use builder::{EngineBuilder, MemoryPreset};
pub use controller::AnyController;
pub use session::{Completion, Session};
pub use sharded::{ShardFeeder, ShardPlan, ShardedSession};

use crate::workloads::UnknownWorkload;

/// Every failure of a `coordinator::run_jobs` sweep: `(job label, error)`
/// pairs in job order — all of them, not just the first, so one pass over
/// a long sweep reports every casualty. Defined here (next to
/// [`EngineError`], which carries it as [`EngineError::Jobs`]) so the
/// engine stays free of coordinator dependencies; the coordinator
/// re-exports it as `coordinator::JobFailures`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobFailures {
    /// `(label, error)` of each failing job, in job order.
    pub failures: Vec<(String, EngineError)>,
}

impl std::fmt::Display for JobFailures {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} job(s) failed:", self.failures.len())?;
        for (label, e) in &self.failures {
            write!(f, "\n  {label}: {e}")?;
        }
        Ok(())
    }
}

impl std::error::Error for JobFailures {}

/// Everything that can go wrong while assembling or running an engine.
///
/// The CLI surfaces these with a non-zero exit code; library callers can
/// match on the variants (all payloads are plain data, so the error is
/// `Send + Sync` and travels across the coordinator's worker threads).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The requested workload name is not in the calibrated suite or the
    /// adversarial scenario set. The payload lists every valid name.
    UnknownWorkload(UnknownWorkload),
    /// A simulation was requested from a builder with no workload set.
    MissingWorkload,
    /// The assembled [`SystemConfig`](crate::config::SystemConfig) failed
    /// validation, or the builder toggles contradict each other.
    InvalidConfig(String),
    /// The requested figure id is not part of the evaluation
    /// (see `coordinator::figures::ALL_FIGURES`).
    UnknownFigure(String),
    /// One or more jobs of a coordinator sweep failed; the payload lists
    /// every failing job's label and error (not just the first), so a
    /// long sweep reports all its casualties in one pass.
    Jobs(JobFailures),
    /// A trace record/replay failure: file corruption (typed per layer —
    /// header, index, chunk), I/O loss, or a header/config mismatch. See
    /// [`crate::trace::TraceError`].
    Trace(crate::trace::TraceError),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::UnknownWorkload(e) => write!(f, "{e}"),
            EngineError::MissingWorkload => {
                write!(f, "no workload set: call EngineBuilder::workload(..) before build()/run()")
            }
            EngineError::InvalidConfig(e) => write!(f, "invalid config: {e}"),
            EngineError::UnknownFigure(id) => write!(f, "unknown figure '{id}'"),
            EngineError::Jobs(e) => write!(f, "{e}"),
            EngineError::Trace(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<UnknownWorkload> for EngineError {
    fn from(e: UnknownWorkload) -> Self {
        EngineError::UnknownWorkload(e)
    }
}

impl From<JobFailures> for EngineError {
    fn from(e: JobFailures) -> Self {
        EngineError::Jobs(e)
    }
}

impl From<crate::trace::TraceError> for EngineError {
    fn from(e: crate::trace::TraceError) -> Self {
        EngineError::Trace(e)
    }
}

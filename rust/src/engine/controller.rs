//! [`AnyController`]: closed enum dispatch over every controller
//! implementation, replacing `Box<dyn Controller>` on the hot path.

use crate::config::{MetadataScheme, Mode, SystemConfig};
use crate::hybrid::alloy::AlloyController;
use crate::hybrid::lohhill::LohHillController;
use crate::hybrid::remap::RemapController;
use crate::hybrid::tagmatch::TagMatchController;
use crate::hybrid::{Access, Controller};
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};
use crate::verify::CheckedController;

/// Every hybrid-memory controller the engine can run, as one closed enum.
///
/// [`Controller`] is still the extension trait (custom controllers remain
/// pluggable through [`crate::sim::Simulation::with_controller`]), but the
/// standard design points all route through this enum so that a simulation
/// loop monomorphized over `AnyController` devirtualizes the per-access
/// call chain for every design point. The variant sizes differ wildly
/// (the remap engine owns every table and free stack; Alloy is a flat tag
/// array), but exactly one value exists per simulation and it is never
/// moved per access, so the enum is sized by its largest variant on
/// purpose rather than boxing the hot variants behind another pointer.
#[allow(clippy::large_enum_variant)]
pub enum AnyController {
    /// The general remap-table engine: Trimma-C/F, MemPod, the linear
    /// cache design, and the metadata-free Ideal oracle.
    Remap(RemapController),
    /// Alloy Cache (direct-mapped, tag+data in one burst).
    Alloy(AlloyController),
    /// Loh-Hill Cache (tags-in-row, perfect MissMap).
    LohHill(LohHillController),
    /// Generic a-way tag matching (the Fig. 1 "tag matching" series).
    TagMatch(TagMatchController),
    /// Any of the above shadowed by the differential verify oracle
    /// (boxed: the wrapper nests a full `AnyController` inside itself).
    Checked(Box<CheckedController<AnyController>>),
}

impl AnyController {
    /// Route a system configuration to its controller implementation —
    /// the single successor of the old `build_controller(cfg, ideal)` /
    /// `maybe_checked` pair. `ideal = true` builds the metadata-free
    /// oracle of Fig. 1 regardless of `cfg.hybrid.scheme`; with
    /// `cfg.hybrid.verify` the controller is shadowed by the
    /// [`CheckedController`] oracle.
    pub fn from_config(cfg: &SystemConfig, ideal: bool) -> AnyController {
        let inner = match (ideal, cfg.hybrid.scheme, cfg.hybrid.mode) {
            (true, _, _) => AnyController::Remap(RemapController::new(cfg, true)),
            (_, MetadataScheme::TagAlloy, Mode::Cache) => {
                AnyController::Alloy(AlloyController::new(cfg))
            }
            (_, MetadataScheme::TagLohHill, Mode::Cache) => {
                AnyController::LohHill(LohHillController::new(cfg))
            }
            _ => AnyController::Remap(RemapController::new(cfg, false)),
        };
        inner.maybe_checked(cfg)
    }

    /// The generic a-way tag-matching baseline (`cfg.hybrid.num_sets`
    /// encodes the associativity), verify-wrapped when the config asks.
    pub fn tag_match(cfg: &SystemConfig) -> AnyController {
        AnyController::TagMatch(TagMatchController::new(cfg)).maybe_checked(cfg)
    }

    /// Wrap `self` in the verify oracle when `cfg.hybrid.verify` is set
    /// (idempotent: an already-checked controller is returned unchanged).
    pub fn maybe_checked(self, cfg: &SystemConfig) -> AnyController {
        if cfg.hybrid.verify && !matches!(self, AnyController::Checked(_)) {
            AnyController::Checked(Box::new(CheckedController::new(self, cfg)))
        } else {
            self
        }
    }

    /// Short label of the active variant (diagnostics / bench labels).
    pub fn kind(&self) -> &'static str {
        match self {
            AnyController::Remap(_) => "remap",
            AnyController::Alloy(_) => "alloy",
            AnyController::LohHill(_) => "loh-hill",
            AnyController::TagMatch(_) => "tag-match",
            AnyController::Checked(_) => "checked",
        }
    }
}

impl Controller for AnyController {
    #[inline]
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        match self {
            AnyController::Remap(c) => c.access(set, idx, line, kind, now),
            AnyController::Alloy(c) => c.access(set, idx, line, kind, now),
            AnyController::LohHill(c) => c.access(set, idx, line, kind, now),
            AnyController::TagMatch(c) => c.access(set, idx, line, kind, now),
            AnyController::Checked(c) => c.access(set, idx, line, kind, now),
        }
    }

    #[inline]
    fn access_block(&mut self, batch: &[Access]) -> Cycle {
        match self {
            AnyController::Remap(c) => c.access_block(batch),
            AnyController::Alloy(c) => c.access_block(batch),
            AnyController::LohHill(c) => c.access_block(batch),
            AnyController::TagMatch(c) => c.access_block(batch),
            AnyController::Checked(c) => c.access_block(batch),
        }
    }

    fn finalize(&mut self) {
        match self {
            AnyController::Remap(c) => c.finalize(),
            AnyController::Alloy(c) => c.finalize(),
            AnyController::LohHill(c) => c.finalize(),
            AnyController::TagMatch(c) => c.finalize(),
            AnyController::Checked(c) => c.finalize(),
        }
    }

    fn reset_stats(&mut self) {
        match self {
            AnyController::Remap(c) => c.reset_stats(),
            AnyController::Alloy(c) => c.reset_stats(),
            AnyController::LohHill(c) => c.reset_stats(),
            AnyController::TagMatch(c) => c.reset_stats(),
            AnyController::Checked(c) => c.reset_stats(),
        }
    }

    fn stats(&self) -> &Stats {
        match self {
            AnyController::Remap(c) => c.stats(),
            AnyController::Alloy(c) => c.stats(),
            AnyController::LohHill(c) => c.stats(),
            AnyController::TagMatch(c) => c.stats(),
            AnyController::Checked(c) => c.stats(),
        }
    }

    fn layout(&self) -> &SetLayout {
        match self {
            AnyController::Remap(c) => c.layout(),
            AnyController::Alloy(c) => c.layout(),
            AnyController::LohHill(c) => c.layout(),
            AnyController::TagMatch(c) => c.layout(),
            AnyController::Checked(c) => c.layout(),
        }
    }

    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        match self {
            AnyController::Remap(c) => c.debug_translate(set, idx),
            AnyController::Alloy(c) => c.debug_translate(set, idx),
            AnyController::LohHill(c) => c.debug_translate(set, idx),
            AnyController::TagMatch(c) => c.debug_translate(set, idx),
            AnyController::Checked(c) => c.debug_translate(set, idx),
        }
    }

    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        match self {
            AnyController::Remap(c) => c.debug_check_set(set),
            AnyController::Alloy(c) => c.debug_check_set(set),
            AnyController::LohHill(c) => c.debug_check_set(set),
            AnyController::TagMatch(c) => c.debug_check_set(set),
            AnyController::Checked(c) => c.debug_check_set(set),
        }
    }

    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        match self {
            AnyController::Remap(c) => c.debug_nonidentity_entries(set),
            AnyController::Alloy(c) => c.debug_nonidentity_entries(set),
            AnyController::LohHill(c) => c.debug_nonidentity_entries(set),
            AnyController::TagMatch(c) => c.debug_nonidentity_entries(set),
            AnyController::Checked(c) => c.debug_nonidentity_entries(set),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    #[test]
    fn from_config_builds_every_preset() {
        for dp in DesignPoint::ALL {
            let cfg = presets::hbm3_ddr5(*dp);
            let ideal = *dp == DesignPoint::Ideal;
            let c = AnyController::from_config(&cfg, ideal);
            assert_eq!(c.stats().mem_accesses, 0);
            assert!(!matches!(c, AnyController::Checked(_)), "{dp:?}: verify off by default");
        }
    }

    #[test]
    fn variant_routing_matches_design_point() {
        let alloy = AnyController::from_config(&presets::hbm3_ddr5(DesignPoint::AlloyCache), false);
        assert_eq!(alloy.kind(), "alloy");
        let lh = AnyController::from_config(&presets::hbm3_ddr5(DesignPoint::LohHill), false);
        assert_eq!(lh.kind(), "loh-hill");
        for dp in [
            DesignPoint::TrimmaCache,
            DesignPoint::TrimmaFlat,
            DesignPoint::MemPod,
            DesignPoint::LinearCache,
        ] {
            let c = AnyController::from_config(&presets::hbm3_ddr5(dp), false);
            assert_eq!(c.kind(), "remap", "{dp:?}");
        }
        let tm = AnyController::tag_match(&presets::hbm3_ddr5(DesignPoint::AlloyCache));
        assert_eq!(tm.kind(), "tag-match");
    }

    #[test]
    fn verify_toggle_wraps_once() {
        let cfg = presets::with_verify(presets::hbm3_ddr5(DesignPoint::TrimmaCache));
        let c = AnyController::from_config(&cfg, false);
        assert_eq!(c.kind(), "checked");
        // Idempotent: re-wrapping an already-checked controller is a no-op.
        let c = c.maybe_checked(&cfg);
        match &c {
            AnyController::Checked(inner) => {
                assert_eq!(inner.inner().kind(), "remap", "exactly one oracle layer");
            }
            other => panic!("expected checked, got {}", other.kind()),
        }
    }
}

//! Sharded set-partitioned execution: one workload's post-LLC access
//! stream split across worker threads, with a deterministic merge.
//!
//! Trimma's remap state is set-local by construction — the iRT, the iRC,
//! and the remap caches are all indexed `set * k + idx` — so disjoint set
//! ranges of a single run can be simulated concurrently. This module
//! provides the machinery:
//!
//! * [`ShardPlan`] — contiguous set-range partitions derived from the
//!   run's [`SetLayout`]. The partition has two layers: **slices** (the
//!   unit of simulation state, fixed by the geometry alone) and
//!   **shards** (the unit of parallelism, a contiguous group of slices
//!   per worker thread).
//! * [`slice_config`] — the per-slice sub-config: set count, tier
//!   capacities, and remap-cache geometry all scaled by the slice's share
//!   of the set space, so metadata sizing, donated-slot accounting, and
//!   bank state stay set-local inside each slice.
//! * [`ShardedSession`] — owns one [`Session`]`<`[`AnyController`]`>` per
//!   slice and fans a single access stream out to them, either inline
//!   ([`ShardedSession::push_batch`]) or across worker threads over
//!   lock-free SPSC batch queues ([`ShardedSession::run_stream`]).
//!
//! ## Why the merge is deterministic
//!
//! The statistics of a sharded run are byte-identical for **every** shard
//! count (the `rust/tests/sharded_parity.rs` matrix locks this) because
//! nothing observable depends on the worker count:
//!
//! 1. the slice partition is derived from the geometry only — changing
//!    the shard count regroups slices onto threads but never changes
//!    which sets share simulation state;
//! 2. each access is routed to its slice's queue by the single-threaded
//!    front end, and each queue is FIFO, so every slice consumes exactly
//!    the serial order restricted to its own sets;
//! 3. slices share no state (each owns its controller, tables, remap
//!    caches, and device bank clocks via its sub-config), so cross-thread
//!    timing can only change wall-clock speed, never results;
//! 4. the merge ([`crate::stats::Stats::merge_shard`]) sums counters and
//!    storage gauges over the fixed slice order.
//!
//! The trade-off: the sharded driver is an **open-loop** throughput mode.
//! The front end charges a constant nominal memory latency per LLC miss
//! instead of feeding each access's simulated latency back into the core
//! clocks (that feedback would serialize the pipeline — the next access's
//! timestamp would depend on the previous access's result). Sharded runs
//! are therefore mutually comparable and deterministic, but their timing
//! stats are not comparable with the closed-loop
//! [`Simulation::run`](crate::sim::Simulation::run) path; see DESIGN.md
//! §9.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::{RemapCacheKind, SystemConfig};
use crate::engine::{AnyController, Completion, Session};
use crate::hybrid::Access;
use crate::metadata::SetLayout;
use crate::sim::SimReport;
use crate::stats::Stats;
use crate::types::Cycle;

/// Accesses buffered per slice before a batch message is enqueued.
const BATCH_ACCESSES: usize = 128;
/// SPSC queue capacity (messages) per shard.
const QUEUE_MSGS: usize = 512;

/// How a run's set space is partitioned for sharded execution.
///
/// Two independent layers:
///
/// * **Slices** — the unit of simulation state. The set space is cut into
///   `num_slices` contiguous equal ranges — the largest count within
///   [`ShardPlan::MAX_SLICES`] that tiles the set space exactly, i.e.
///   `min(num_sets, 64)` (a power of two) for every validated config —
///   each simulated by its own [`Session`] built from a [`slice_config`]
///   sub-config. The slice partition depends only on the geometry, never
///   on the requested worker count — that invariance is what makes the
///   merged statistics identical for every shard count.
/// * **Shards** — the unit of parallelism. The requested worker count is
///   clamped to `[1, num_slices]` and each shard drives a contiguous
///   group of slices (sizes differ by at most one) over one SPSC queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    num_sets: u32,
    num_slices: u32,
    sets_per_slice: u32,
    num_shards: u32,
}

impl ShardPlan {
    /// Upper bound on the slice count (and so on useful parallelism).
    pub const MAX_SLICES: u32 = 64;

    /// Plan for `layout`'s set space with (up to) `shards` workers.
    pub fn new(layout: &SetLayout, shards: usize) -> ShardPlan {
        let num_sets = layout.num_sets;
        // Largest slice count within MAX_SLICES that tiles the set space
        // exactly. Validated configs have power-of-two set counts, so
        // this is min(num_sets, MAX_SLICES) in one step; the walk-down
        // keeps the tiling invariant (and with it in-bounds routing) for
        // any layout a caller hands us.
        let mut num_slices = num_sets.min(Self::MAX_SLICES);
        while num_sets % num_slices != 0 {
            num_slices -= 1;
        }
        let num_shards = (shards.max(1) as u32).min(num_slices);
        ShardPlan {
            num_sets,
            num_slices,
            sets_per_slice: num_sets / num_slices,
            num_shards,
        }
    }

    /// Sets in the planned set space.
    pub fn num_sets(&self) -> u32 {
        self.num_sets
    }

    /// Number of slices (state partitions); a power of two.
    pub fn num_slices(&self) -> u32 {
        self.num_slices
    }

    /// Contiguous sets per slice; a power of two.
    pub fn sets_per_slice(&self) -> u32 {
        self.sets_per_slice
    }

    /// Worker threads the plan will use (requested count clamped to the
    /// slice count).
    pub fn num_shards(&self) -> u32 {
        self.num_shards
    }

    /// The slice owning global `set`.
    #[inline]
    pub fn slice_of(&self, set: u32) -> u32 {
        set / self.sets_per_slice
    }

    /// `set` relabelled into its slice's local set space.
    #[inline]
    pub fn local_set(&self, set: u32) -> u32 {
        set % self.sets_per_slice
    }

    /// The shard driving `slice` (slices group contiguously, sizes
    /// differing by at most one).
    #[inline]
    pub fn shard_of_slice(&self, slice: u32) -> u32 {
        ((slice as u64 * self.num_shards as u64) / self.num_slices as u64) as u32
    }

    /// The contiguous slice range shard `shard` drives.
    pub fn shard_slices(&self, shard: u32) -> Range<u32> {
        let lo = (shard as u64 * self.num_slices as u64).div_ceil(self.num_shards as u64);
        let hi = ((shard as u64 + 1) * self.num_slices as u64).div_ceil(self.num_shards as u64);
        lo as u32..hi as u32
    }

    /// The contiguous global set range shard `shard` drives.
    pub fn shard_sets(&self, shard: u32) -> Range<u32> {
        let s = self.shard_slices(shard);
        s.start * self.sets_per_slice..s.end * self.sets_per_slice
    }

    /// The contiguous global set range of `slice`.
    pub fn slice_sets(&self, slice: u32) -> Range<u32> {
        slice * self.sets_per_slice..(slice + 1) * self.sets_per_slice
    }

    /// Route a global set: `(owning slice, local set within it)`. Panics
    /// if `set` is outside the planned set space — sharding must never
    /// cross a set boundary.
    #[inline]
    pub fn route_set(&self, set: u32) -> (u32, u32) {
        assert!(
            set < self.num_sets,
            "access set {set} outside the planned set space ({} sets)",
            self.num_sets
        );
        (self.slice_of(set), self.local_set(set))
    }

    /// Route a global-set access: `(owning slice, access relabelled into
    /// the slice's local set space)`. Panics if `a.set` is outside the
    /// planned set space (see [`ShardPlan::route_set`]).
    #[inline]
    pub fn route(&self, a: Access) -> (u32, Access) {
        let (slice, local) = self.route_set(a.set);
        (slice, Access { set: local, ..a })
    }
}

/// The sub-config slice `slice` simulates: the full config with set
/// count, tier capacities, and remap-cache geometry divided by the slice
/// count (the per-set geometry — ways, metadata reservation, slow blocks
/// per set — is unchanged, so each slice sees exactly its sets' share of
/// the machine). Validity follows from the full config's: slice and set
/// counts are powers of two, so every division here is exact.
pub fn slice_config(cfg: &SystemConfig, plan: &ShardPlan, slice: u32) -> SystemConfig {
    let frac = plan.num_slices() as u64;
    let mut sub = cfg.clone();
    sub.name = format!("{}/slice{}", cfg.name, slice);
    sub.hybrid.num_sets = plan.sets_per_slice();
    sub.hybrid.fast_bytes = cfg.hybrid.fast_bytes / frac;
    sub.hybrid.slow_bytes = cfg.hybrid.slow_bytes / frac;
    sub.hybrid.remap_cache = scale_remap_cache(cfg.hybrid.remap_cache, frac);
    sub
}

/// Scale an SRAM remap-cache geometry down by `frac` (sets, not ways, so
/// associativity — and with it per-set conflict behaviour — is kept).
/// When the cache divides evenly (every preset does: 2048/256 sets vs at
/// most 64 slices), the slices' summed SRAM matches the full config's
/// budget exactly. A cache with fewer sets than there are slices clamps
/// at one set per slice — the sub-configs stay constructible, at the
/// cost of modelling proportionally more aggregate SRAM than configured
/// (shard-count parity is unaffected: every count uses the same slicing).
fn scale_remap_cache(kind: RemapCacheKind, frac: u64) -> RemapCacheKind {
    let scale = |sets: u32| ((sets as u64 / frac).max(1)) as u32;
    match kind {
        RemapCacheKind::None => RemapCacheKind::None,
        RemapCacheKind::Conventional { sets, ways } => {
            RemapCacheKind::Conventional { sets: scale(sets), ways }
        }
        RemapCacheKind::Irc { nonid_sets, nonid_ways, id_sets, id_ways, superblock_blocks } => {
            RemapCacheKind::Irc {
                nonid_sets: scale(nonid_sets),
                nonid_ways,
                id_sets: scale(id_sets),
                id_ways,
                superblock_blocks,
            }
        }
    }
}

// ---------------------------------------------------------------- SPSC

/// One message on a shard's queue.
enum ShardMsg {
    /// A batch of accesses for one slice, already relabelled to the
    /// slice's local set space.
    Batch { slice: u32, batch: Vec<Access> },
    /// End-of-warmup marker: reset the shard's slice statistics.
    ResetStats,
}

/// A bounded single-producer single-consumer ring. Lock-free: producer
/// and consumer each own one index; the only cross-thread communication
/// is an acquire/release pair per operation.
struct SpscInner<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Next slot to pop (written by the consumer only).
    head: AtomicUsize,
    /// Next slot to push (written by the producer only).
    tail: AtomicUsize,
    /// Set (release) when the producer disconnects. The consumer may only
    /// conclude "no more data is coming" after an acquire-load of this
    /// flag: that load synchronizes with the producer's final release, so
    /// every earlier slot write and tail store is visible before the
    /// consumer's last drain — a bare refcount probe would give no such
    /// happens-before edge and could drop queued batches on weakly
    /// ordered CPUs.
    closed: AtomicBool,
}

// Safety: the ring is shared between exactly one producer and one
// consumer (enforced by the non-Clone Producer/Consumer handles), and
// every slot is written before the release-store that publishes it.
unsafe impl<T: Send> Sync for SpscInner<T> {}

impl<T> Drop for SpscInner<T> {
    fn drop(&mut self) {
        let mut i = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        while i != tail {
            // Safety: slots in [head, tail) hold initialized values.
            unsafe { (*self.buf[i & self.mask].get()).assume_init_drop() };
            i = i.wrapping_add(1);
        }
    }
}

/// Producer half of [`spsc_channel`]. Crate-visible: the pipelined front
/// end (`sim::core`) reuses the ring for its front-stage hand-off.
pub(crate) struct Producer<T>(Arc<SpscInner<T>>);
/// Consumer half of [`spsc_channel`].
pub(crate) struct Consumer<T>(Arc<SpscInner<T>>);

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        // Publish the disconnect *after* every push (release pairs with
        // the consumer's acquire in `recv`).
        self.0.closed.store(true, Ordering::Release);
    }
}

/// A bounded lock-free single-producer single-consumer ring of `capacity`
/// (a power of two) messages.
pub(crate) fn spsc_channel<T>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    assert!(capacity.is_power_of_two());
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..capacity).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(SpscInner {
        buf,
        mask: capacity - 1,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
    });
    (Producer(Arc::clone(&inner)), Consumer(inner))
}

impl<T> Producer<T> {
    /// Non-blocking push; hands `v` back if the ring is full. Crate-visible
    /// for the trace replay I/O thread, which must never block on a full
    /// per-core ring (it round-robins the other cores instead).
    pub(crate) fn try_push(&mut self, v: T) -> Result<(), T> {
        let tail = self.0.tail.load(Ordering::Relaxed);
        let head = self.0.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.0.buf.len() {
            return Err(v);
        }
        // Safety: the slot at `tail` is unoccupied (checked above) and we
        // are the only producer.
        unsafe { (*self.0.buf[tail & self.0.mask].get()).write(v) };
        self.0.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Push, spinning (with yields) while the ring is full. Panics if the
    /// consumer vanished with the ring full (a worker died mid-run) —
    /// best-effort deadlock-into-panic conversion, not a data channel.
    pub(crate) fn send(&mut self, mut v: T) {
        loop {
            match self.try_push(v) {
                Ok(()) => return,
                Err(back) => {
                    v = back;
                    assert!(
                        Arc::strong_count(&self.0) > 1,
                        "sharded worker disappeared with its queue full"
                    );
                    std::thread::yield_now();
                }
            }
        }
    }
}

impl<T> Consumer<T> {
    /// Non-blocking pop; `None` if the ring is currently empty.
    /// Crate-visible for the trace replay I/O thread's recycle ring.
    pub(crate) fn try_pop(&mut self) -> Option<T> {
        let head = self.0.head.load(Ordering::Relaxed);
        let tail = self.0.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // Safety: the slot at `head` was published by the producer's
        // release-store and we are the only consumer.
        let v = unsafe { (*self.0.buf[head & self.0.mask].get()).assume_init_read() };
        self.0.head.store(head.wrapping_add(1), Ordering::Release);
        Some(v)
    }

    /// Pop, spinning while the ring is empty; `None` once the producer
    /// handle is dropped and the ring is drained.
    pub(crate) fn recv(&mut self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            if let Some(v) = self.try_pop() {
                return Some(v);
            }
            // Acquire pairs with the producer-drop release: after seeing
            // `closed`, every push that preceded the disconnect is
            // visible, so one more pop attempt cannot miss data (the
            // caller loops on `recv`, draining any remaining messages one
            // per call).
            if self.0.closed.load(Ordering::Acquire) {
                return self.try_pop();
            }
            spins += 1;
            if spins % 64 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

// ------------------------------------------------------------- session

/// The single-threaded feed handle passed to the closure of
/// [`ShardedSession::run_stream`]: the trace/cache front end pushes
/// global-set accesses here and they are routed, batched, and enqueued to
/// the owning shard's queue.
pub struct ShardFeeder {
    plan: ShardPlan,
    producers: Vec<Producer<ShardMsg>>,
    bufs: Vec<Vec<Access>>,
    pushed: u64,
}

impl ShardFeeder {
    fn new(plan: ShardPlan, producers: Vec<Producer<ShardMsg>>) -> ShardFeeder {
        ShardFeeder {
            plan,
            producers,
            bufs: (0..plan.num_slices()).map(|_| Vec::with_capacity(BATCH_ACCESSES)).collect(),
            pushed: 0,
        }
    }

    /// The set partition this feeder routes against.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Feed one access (global set space). Routed to its slice; panics if
    /// the set is outside the planned set space.
    #[inline]
    pub fn push(&mut self, a: Access) {
        let (slice, local) = self.plan.route(a);
        self.push_routed(slice, local);
    }

    /// Feed one already-routed access: `slice` owns it and `a.set` is the
    /// *local* set within that slice — the shard-aware mapper
    /// ([`AddrMapper::translate_sliced`](crate::sim::mapper::AddrMapper::translate_sliced))
    /// produces exactly these coordinates, saving a second routing
    /// division on the per-miss hot path.
    #[inline]
    pub fn push_routed(&mut self, slice: u32, a: Access) {
        debug_assert!(slice < self.plan.num_slices());
        debug_assert!(a.set < self.plan.sets_per_slice());
        self.pushed += 1;
        let buf = &mut self.bufs[slice as usize];
        buf.push(a);
        if buf.len() == BATCH_ACCESSES {
            self.flush_slice(slice);
        }
    }

    /// Feed a batch of already-routed `(slice, local access)` pairs, in
    /// order — exactly equivalent to `batch.len()`
    /// [`ShardFeeder::push_routed`] calls behind a single dispatch. The
    /// unified execution core's open-loop writeback path and the pipelined
    /// router stage both hand their per-step batches through this.
    #[inline]
    pub fn push_routed_batch(&mut self, batch: &[(u32, Access)]) {
        for (slice, a) in batch {
            self.push_routed(*slice, *a);
        }
    }

    /// End-of-warmup: flush all pending batches, then tell every shard to
    /// reset its slices' statistics. In-stream ordering is preserved per
    /// slice, so the reset point is deterministic.
    pub fn reset_stats(&mut self) {
        self.flush_all();
        for p in &mut self.producers {
            p.send(ShardMsg::ResetStats);
        }
    }

    fn flush_slice(&mut self, slice: u32) {
        if self.bufs[slice as usize].is_empty() {
            return;
        }
        let batch = std::mem::replace(
            &mut self.bufs[slice as usize],
            Vec::with_capacity(BATCH_ACCESSES),
        );
        let shard = self.plan.shard_of_slice(slice);
        self.producers[shard as usize].send(ShardMsg::Batch { slice, batch });
    }

    fn flush_all(&mut self) {
        for slice in 0..self.plan.num_slices() {
            self.flush_slice(slice);
        }
    }

    /// Flush everything and disconnect the queues (workers exit once
    /// drained). Returns the total accesses pushed.
    fn close(&mut self) -> u64 {
        self.flush_all();
        self.producers.clear();
        self.pushed
    }
}

/// A sharded simulation session: one [`Session`] per slice of the
/// [`ShardPlan`], fed by routing a single access stream over the set
/// space. Built through
/// [`EngineBuilder::build_sharded`](crate::engine::EngineBuilder::build_sharded).
///
/// Driving it inline ([`ShardedSession::push_batch`]) and across worker
/// threads ([`ShardedSession::run_stream`]) produce byte-identical merged
/// statistics; so does every shard count (see the module docs for why).
pub struct ShardedSession {
    plan: ShardPlan,
    full_layout: SetLayout,
    sessions: Vec<Session<AnyController>>,
    /// Per-slice regrouping scratch for the inline batched path
    /// ([`ShardedSession::push_batch`]): accesses are bucketed by slice so
    /// each slice session consumes a whole sub-batch through its batched
    /// entry point — the same per-slice batching the threaded FIFO path
    /// performs, which is what lets the two-phase prefetch walk
    /// (DESIGN.md §15) see real batches inline too. Pre-sized to
    /// [`BATCH_ACCESSES`], so steady-state pushes never allocate.
    bufs: Vec<Vec<Access>>,
    label: String,
    pushed: u64,
}

impl ShardedSession {
    pub(crate) fn assemble(
        label: String,
        full_layout: SetLayout,
        plan: ShardPlan,
        sessions: Vec<Session<AnyController>>,
    ) -> ShardedSession {
        assert_eq!(sessions.len(), plan.num_slices() as usize);
        let bufs =
            (0..plan.num_slices()).map(|_| Vec::with_capacity(BATCH_ACCESSES)).collect();
        ShardedSession { plan, full_layout, sessions, bufs, label, pushed: 0 }
    }

    /// The set partition this session runs under.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The full (unsliced) run geometry — what drivers build global-set
    /// accesses against.
    pub fn full_layout(&self) -> &SetLayout {
        &self.full_layout
    }

    /// The session label (workload name for trace-driven runs).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The per-slice sessions, in slice order (introspection/tests).
    pub fn sessions(&self) -> &[Session<AnyController>] {
        &self.sessions
    }

    /// Total accesses pushed since construction (warmup included).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Feed a batch of global-set accesses inline (no threads), routing
    /// each to its slice in order. The serial reference the threaded
    /// [`ShardedSession::run_stream`] path is locked against.
    ///
    /// Accesses are regrouped per slice and each slice consumes its
    /// sub-batch through [`Session::push_batch`] — one controller
    /// dispatch per slice and a real batch for the two-phase prefetch
    /// walk, exactly like the threaded workers' per-slice FIFO batches.
    /// Byte-parity with per-access routing holds by construction: slices
    /// share no state, the grouping preserves each slice's in-stream
    /// order, and the summed demand latency is order-independent (locked
    /// by `threaded_stream_matches_inline_routing` and the parity suites).
    pub fn push_batch(&mut self, batch: &[Access]) -> Completion {
        let mut latency: Cycle = 0;
        for a in batch {
            let (slice, local) = self.plan.route(*a);
            self.bufs[slice as usize].push(local);
        }
        for (slice, buf) in self.bufs.iter_mut().enumerate() {
            if !buf.is_empty() {
                latency += self.sessions[slice].push_batch(buf).latency;
                buf.clear();
            }
        }
        self.pushed += batch.len() as u64;
        Completion { accesses: batch.len() as u64, latency }
    }

    /// Reset every slice's statistics (end of warmup; inline driving).
    pub fn reset_stats(&mut self) {
        for s in &mut self.sessions {
            s.reset_stats();
        }
    }

    /// Drive the session with `feed` across `plan.num_shards()` worker
    /// threads: each shard owns a contiguous group of slices and consumes
    /// its own SPSC queue; `feed` runs on the calling thread and pushes
    /// the (single) access stream through the [`ShardFeeder`].
    ///
    /// Returns the combined [`Completion`] (accesses fed, summed demand
    /// latency), exactly what the equivalent [`ShardedSession::push_batch`]
    /// calls would return.
    // Panic audit: the worker `join()` expect is the intentional
    // survivor — a shard worker only panics if a controller panicked on
    // its thread, and re-raising that on the feeding thread (instead of
    // merging a partial run) is the correct behavior.
    #[allow(clippy::expect_used)]
    pub fn run_stream<F>(&mut self, feed: F) -> Completion
    where
        F: FnOnce(&mut ShardFeeder),
    {
        let plan = self.plan;
        // Hand each shard its contiguous group of slice sessions.
        let mut groups: Vec<Vec<Session<AnyController>>> = Vec::new();
        {
            let mut it = std::mem::take(&mut self.sessions).into_iter();
            for shard in 0..plan.num_shards() {
                let n = plan.shard_slices(shard).len();
                groups.push(it.by_ref().take(n).collect());
            }
        }
        let mut producers = Vec::with_capacity(plan.num_shards() as usize);
        let mut rigs = Vec::with_capacity(plan.num_shards() as usize);
        for (shard, group) in groups.into_iter().enumerate() {
            let (p, c) = spsc_channel::<ShardMsg>(QUEUE_MSGS);
            producers.push(p);
            rigs.push((c, plan.shard_slices(shard as u32).start, group));
        }

        let mut total = Completion { accesses: 0, latency: 0 };
        let mut returned: Vec<Vec<Session<AnyController>>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = rigs
                .into_iter()
                .map(|(c, first, group)| s.spawn(move || shard_worker(c, first, group)))
                .collect();
            let mut feeder = ShardFeeder::new(plan, producers);
            feed(&mut feeder);
            feeder.close();
            for h in handles {
                let (sessions, accesses, latency) = h.join().expect("shard worker panicked");
                returned.push(sessions);
                total.accesses += accesses;
                total.latency += latency;
            }
        });
        self.sessions = returned.into_iter().flatten().collect();
        self.pushed += total.accesses;
        total
    }

    /// Finalize every slice and merge their statistics (counters and
    /// storage gauges summed over the fixed slice order, per
    /// [`Stats::merge_shard`]) into one end-of-run report.
    pub fn finish(self) -> SimReport {
        let mut merged = Stats::default();
        for s in self.sessions {
            let rep = s.finish();
            merged.merge_shard(&rep.stats);
        }
        SimReport { name: self.label, stats: merged }
    }
}

/// One shard's worker loop: drain the queue, applying each batch to the
/// owning slice session, until the feeder disconnects.
fn shard_worker(
    mut rx: Consumer<ShardMsg>,
    first_slice: u32,
    mut sessions: Vec<Session<AnyController>>,
) -> (Vec<Session<AnyController>>, u64, Cycle) {
    let mut accesses = 0u64;
    let mut latency: Cycle = 0;
    while let Some(msg) = rx.recv() {
        match msg {
            ShardMsg::Batch { slice, batch } => {
                let done = sessions[(slice - first_slice) as usize].push_batch(&batch);
                accesses += done.accesses;
                latency += done.latency;
            }
            ShardMsg::ResetStats => {
                for s in &mut sessions {
                    s.reset_stats();
                }
            }
        }
    }
    (sessions, accesses, latency)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::engine::EngineBuilder;
    use crate::types::AccessKind;

    fn tiny_cfg(sets: u32) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = sets;
        cfg
    }

    fn layout_of(sets: u32) -> SetLayout {
        SetLayout::new(sets, 1 << 20, 32 << 20, 256, 0)
    }

    #[test]
    fn plan_covers_the_set_space_contiguously() {
        for (sets, shards) in [(4u32, 1usize), (4, 7), (16, 7), (64, 5), (4096, 9), (128, 128)] {
            let plan = ShardPlan::new(&layout_of(sets), shards);
            assert_eq!(plan.num_slices() * plan.sets_per_slice(), plan.num_sets());
            assert!(plan.num_slices() <= ShardPlan::MAX_SLICES);
            assert!(plan.num_slices().is_power_of_two());
            assert!(plan.num_shards() >= 1 && plan.num_shards() <= plan.num_slices());
            // Shards cover 0..num_slices contiguously and non-emptily.
            let mut next = 0u32;
            for shard in 0..plan.num_shards() {
                let r = plan.shard_slices(shard);
                assert_eq!(r.start, next, "{sets}/{shards}: gap before shard {shard}");
                assert!(!r.is_empty(), "{sets}/{shards}: empty shard {shard}");
                for slice in r.clone() {
                    assert_eq!(plan.shard_of_slice(slice), shard);
                }
                next = r.end;
            }
            assert_eq!(next, plan.num_slices());
            // Set routing round-trips.
            for set in 0..plan.num_sets() {
                let slice = plan.slice_of(set);
                assert!(plan.slice_sets(slice).contains(&set));
                assert_eq!(
                    slice * plan.sets_per_slice() + plan.local_set(set),
                    set,
                    "set {set}"
                );
            }
        }
    }

    #[test]
    fn plan_clamps_shards_to_slices() {
        let plan = ShardPlan::new(&layout_of(4), 7);
        assert_eq!(plan.num_slices(), 4);
        assert_eq!(plan.num_shards(), 4);
        let plan = ShardPlan::new(&layout_of(4096), 0);
        assert_eq!(plan.num_slices(), 64);
        assert_eq!(plan.num_shards(), 1);
    }

    #[test]
    fn slice_config_scales_geometry_not_per_set_shape() {
        let cfg = tiny_cfg(16);
        let plan = ShardPlan::new(&layout_of(16), 4);
        let full = SetLayout::for_config(&cfg.hybrid, false);
        for slice in 0..plan.num_slices() {
            let sub = slice_config(&cfg, &plan, slice);
            sub.validate().unwrap_or_else(|e| panic!("slice {slice}: {e}"));
            assert_eq!(sub.hybrid.num_sets, plan.sets_per_slice());
            let sl = SetLayout::for_config(&sub.hybrid, false);
            assert_eq!(sl.fast_per_set, full.fast_per_set, "slice {slice}");
            assert_eq!(sl.slow_per_set, full.slow_per_set, "slice {slice}");
            assert_eq!(sl.meta_per_set, full.meta_per_set, "slice {slice}");
        }
        // SRAM budget is divided across slices, associativity kept.
        let sub = slice_config(&cfg, &plan, 0);
        match (cfg.hybrid.remap_cache, sub.hybrid.remap_cache) {
            (
                RemapCacheKind::Irc { nonid_sets, nonid_ways, id_sets, .. },
                RemapCacheKind::Irc {
                    nonid_sets: sub_nonid,
                    nonid_ways: sub_ways,
                    id_sets: sub_id,
                    ..
                },
            ) => {
                assert_eq!(sub_nonid, nonid_sets / plan.num_slices());
                assert_eq!(sub_id, id_sets / plan.num_slices());
                assert_eq!(sub_ways, nonid_ways);
            }
            other => panic!("unexpected remap cache kinds: {other:?}"),
        }
    }

    #[test]
    fn spsc_round_trips_in_order_across_threads() {
        let (mut tx, mut rx) = spsc_channel::<u64>(8);
        std::thread::scope(|s| {
            s.spawn(move || {
                for i in 0..10_000u64 {
                    tx.send(i);
                }
            });
            let mut expect = 0u64;
            while let Some(v) = rx.recv() {
                assert_eq!(v, expect);
                expect += 1;
            }
            assert_eq!(expect, 10_000);
        });
    }

    #[test]
    fn spsc_drop_releases_undelivered_messages() {
        let payload = Arc::new(());
        let (mut tx, rx) = spsc_channel::<Arc<()>>(8);
        tx.try_push(Arc::clone(&payload)).unwrap();
        tx.try_push(Arc::clone(&payload)).unwrap();
        assert_eq!(Arc::strong_count(&payload), 3);
        drop(tx);
        drop(rx);
        assert_eq!(Arc::strong_count(&payload), 1);
    }

    fn stream(layout: &SetLayout, n: u64) -> Vec<Access> {
        (0..n)
            .map(|i| Access {
                set: (i % layout.num_sets as u64) as u32,
                idx: layout.fast_per_set + (i * 37) % layout.slow_per_set,
                line: 0,
                kind: if i % 3 == 0 { AccessKind::Write } else { AccessKind::Read },
                now: i * 450,
            })
            .collect()
    }

    #[test]
    fn threaded_stream_matches_inline_routing() {
        let cfg = tiny_cfg(16);
        let build = || {
            EngineBuilder::from_config(cfg.clone())
                .shards(3)
                .build_sharded()
                .expect("sharded session")
        };
        let mut inline = build();
        let accesses = stream(inline.full_layout(), 6000);
        let d1 = inline.push_batch(&accesses[..4000]);
        inline.reset_stats();
        let d2 = inline.push_batch(&accesses[4000..]);
        let rep_inline = inline.finish();

        let mut threaded = build();
        let run = threaded.run_stream(|feed| {
            for a in &accesses[..4000] {
                feed.push(*a);
            }
            feed.reset_stats();
            for a in &accesses[4000..] {
                feed.push(*a);
            }
        });
        assert_eq!(threaded.pushed(), 6000);
        let rep_threaded = threaded.finish();

        assert_eq!(d1.accesses + d2.accesses, run.accesses);
        assert_eq!(d1.latency + d2.latency, run.latency);
        assert_eq!(rep_inline.stats.canonical(), rep_threaded.stats.canonical());
    }

    #[test]
    #[should_panic(expected = "outside the planned set space")]
    fn routing_rejects_out_of_range_sets() {
        let plan = ShardPlan::new(&layout_of(4), 2);
        let _ = plan.route(Access { set: 4, ..Access::default() });
    }
}

//! [`Session`]: the streaming front door of the simulation — controllers
//! consume [`Access`] batches pushed by whatever driver generates them.

use crate::engine::AnyController;
use crate::hybrid::{Access, Controller};
use crate::metadata::SetLayout;
use crate::sim::SimReport;
use crate::stats::Stats;
use crate::types::Cycle;

/// Result of one [`Session::push_batch`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// Accesses consumed from the batch.
    pub accesses: u64,
    /// Summed demand latency of the batch, in cycles.
    pub latency: Cycle,
}

/// A streaming simulation session over one controller.
///
/// Decouples trace generation from simulation: the trace-driven
/// [`Simulation`](crate::sim::Simulation) engine, the bench suite, the
/// adversarial scenario drivers, and future sharded/async drivers all feed
/// controller-level [`Access`]es through `push` / `push_batch` and collect
/// the end-of-run [`SimReport`] from `finish`. The controller type is a
/// generic parameter (defaulting to the enum-dispatched
/// [`AnyController`]), so the per-access call chain monomorphizes — no
/// virtual dispatch on the hot path.
///
/// ```
/// use trimma::config::presets::DesignPoint;
/// use trimma::engine::EngineBuilder;
/// use trimma::hybrid::Access;
/// use trimma::types::AccessKind;
///
/// let mut session = EngineBuilder::new(DesignPoint::TrimmaCache)
///     .configure(|cfg| {
///         cfg.hybrid.fast_bytes = 1 << 20;
///         cfg.hybrid.slow_bytes = 32 << 20;
///         cfg.hybrid.num_sets = 4;
///     })
///     .build_session()
///     .unwrap();
/// let slow = session.layout().fast_per_set; // first slow-tier index
/// let batch: Vec<Access> = (0..64)
///     .map(|n| Access {
///         set: 0,
///         idx: slow + n,
///         line: 0,
///         kind: AccessKind::Read,
///         now: n * 700,
///     })
///     .collect();
/// let done = session.push_batch(&batch);
/// assert_eq!(done.accesses, 64);
/// assert!(done.latency > 0);
/// let report = session.finish();
/// assert_eq!(report.stats.mem_accesses, 64);
/// ```
pub struct Session<C: Controller = AnyController> {
    ctrl: C,
    label: String,
    pushed: u64,
}

impl<C: Controller> Session<C> {
    /// Wrap an explicit controller (the escape hatch mirroring
    /// [`Simulation::with_controller`](crate::sim::Simulation::with_controller)).
    /// Standard design points come from
    /// [`EngineBuilder::build_session`](crate::engine::EngineBuilder::build_session).
    pub fn with_controller(label: impl Into<String>, ctrl: C) -> Self {
        Session { ctrl, label: label.into(), pushed: 0 }
    }

    /// Feed one demand access; returns its demand latency in cycles.
    #[inline]
    pub fn push(&mut self, a: Access) -> Cycle {
        self.pushed += 1;
        self.ctrl.access(a.set, a.idx, a.line, a.kind, a.now)
    }

    /// Feed a batch of accesses in order, exactly as `batch.len()`
    /// [`Session::push`] calls would (stat-for-stat), through the
    /// controller's batched entry point — one dispatch for the whole
    /// batch.
    #[inline]
    pub fn push_batch(&mut self, batch: &[Access]) -> Completion {
        self.pushed += batch.len() as u64;
        Completion { accesses: batch.len() as u64, latency: self.ctrl.access_block(batch) }
    }

    /// Total accesses pushed since construction (warmup included).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// The session label (workload name for trace-driven runs).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Replace the session label.
    pub fn set_label(&mut self, label: impl Into<String>) {
        self.label = label.into();
    }

    /// The wrapped controller.
    pub fn controller(&self) -> &C {
        &self.ctrl
    }

    /// Mutable access to the wrapped controller (debug hooks, warmup).
    pub fn controller_mut(&mut self) -> &mut C {
        &mut self.ctrl
    }

    /// The controller's set layout (geometry for building accesses).
    pub fn layout(&self) -> &SetLayout {
        self.ctrl.layout()
    }

    /// Live statistics (finalized gauges only after [`Session::finish`]).
    pub fn stats(&self) -> &Stats {
        self.ctrl.stats()
    }

    /// Reset statistics at the end of warmup; structural state is kept.
    /// The [`Session::pushed`] counter keeps counting across the reset.
    pub fn reset_stats(&mut self) {
        self.ctrl.reset_stats();
    }

    /// Finalize in place and snapshot the report, keeping the session
    /// alive (used by drivers that add their own counters afterwards).
    /// Prefer [`Session::finish`] when the session is done.
    pub fn report(&mut self) -> SimReport {
        self.ctrl.finalize();
        SimReport { name: self.label.clone(), stats: self.ctrl.stats().clone() }
    }

    /// Finalize the controller (end-of-run gauges, verify sweeps) and
    /// return the end-of-run report.
    pub fn finish(mut self) -> SimReport {
        self.report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::engine::AnyController;
    use crate::types::AccessKind;

    fn tiny_cfg() -> crate::config::SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg
    }

    fn stream(layout: &SetLayout, n: u64) -> Vec<Access> {
        (0..n)
            .map(|i| Access {
                set: (i % 4) as u32,
                idx: layout.fast_per_set + (i * 37) % 3000,
                line: 0,
                kind: if i % 3 == 0 { AccessKind::Write } else { AccessKind::Read },
                now: i * 700,
            })
            .collect()
    }

    #[test]
    fn push_batch_matches_push_stat_for_stat() {
        let cfg = tiny_cfg();
        let layout = *AnyController::from_config(&cfg, false).layout();
        let accesses = stream(&layout, 4000);

        let mut single = Session::with_controller("s", AnyController::from_config(&cfg, false));
        let mut lat_single = 0;
        for a in &accesses {
            lat_single += single.push(*a);
        }
        let rep_single = single.finish();

        let mut batched = Session::with_controller("b", AnyController::from_config(&cfg, false));
        let mut lat_batched = 0;
        for chunk in accesses.chunks(7) {
            let done = batched.push_batch(chunk);
            assert_eq!(done.accesses, chunk.len() as u64);
            lat_batched += done.latency;
        }
        assert_eq!(batched.pushed(), 4000);
        let rep_batched = batched.finish();

        assert_eq!(lat_single, lat_batched);
        assert_eq!(rep_single.stats.canonical(), rep_batched.stats.canonical());
    }

    #[test]
    fn finish_carries_label_and_finalized_gauges() {
        let cfg = tiny_cfg();
        let mut s = Session::with_controller("adv_demo", AnyController::from_config(&cfg, false));
        let accesses = stream(s.layout(), 500);
        s.push_batch(&accesses);
        let rep = s.finish();
        assert_eq!(rep.name, "adv_demo");
        assert!(rep.stats.metadata_bytes_reserved > 0, "finalize must snapshot gauges");
    }

    #[test]
    fn reset_stats_keeps_pushed_counter() {
        let cfg = tiny_cfg();
        let mut s = Session::with_controller("w", AnyController::from_config(&cfg, false));
        let accesses = stream(s.layout(), 100);
        s.push_batch(&accesses);
        s.reset_stats();
        assert_eq!(s.stats().mem_accesses, 0);
        assert_eq!(s.pushed(), 100);
    }
}

//! # trimma — a reproduction of *Trimma: Trimming Metadata Storage and
//! Latency for Hybrid Memory Systems* (PACT '24).
//!
//! This crate is a full hybrid-memory-system simulation framework built
//! around the paper's two contributions:
//!
//! * [`metadata::irt`] — the **indirection-based remap table** (iRT): a
//!   hardware-managed, per-set radix tree that only stores remap entries for
//!   blocks that actually moved, and donates the saved fast-memory blocks as
//!   extra DRAM-cache capacity.
//! * [`metadata::irc`] — the **identity-mapping-aware remap cache** (iRC): an
//!   on-chip remap cache split into a conventional `NonIdCache` and a
//!   sector-cache-style `IdCache` holding 1-bit-per-block identity vectors.
//!
//! Around those we rebuild every substrate the paper's evaluation depends
//! on: DRAM/HBM/NVM device timing, a CPU cache hierarchy, cache-mode and
//! flat-mode hybrid memory controllers plus the Alloy-Cache, Loh-Hill-Cache,
//! and MemPod baselines ([`hybrid`]), calibrated synthetic workload
//! generators standing in for SPEC CPU 2017 / GAP / silo / memcached
//! ([`workloads`]), a 16-core trace-driven simulation engine ([`sim`]), and
//! an experiment coordinator that regenerates every figure in the paper
//! ([`coordinator`]).
//!
//! The public front door is [`engine`]: a typed [`engine::EngineBuilder`]
//! assembles every run (design point, memory preset, workload, and the
//! `ideal` / `verify` / `tag_match` toggles), and the enum-dispatched
//! [`engine::AnyController`] keeps virtual dispatch off the per-access hot
//! path for every design point. Streaming drivers feed accesses through
//! [`engine::Session`]; [`engine::sharded`] splits one run's set space
//! across worker threads (`EngineBuilder::shards(n)`) with a
//! deterministic, shard-count-invariant merge. Both execution models —
//! closed loop and sharded open loop — run on the **one** unified
//! [`sim::ExecCore`] scheduling loop, parameterized over a
//! [`sim::MissSink`]; the open loop's front end can additionally be
//! pipelined (`EngineBuilder::pipeline(true)`) with byte-identical
//! merged statistics. A multi-tenant front end ([`sim::tenants`],
//! `EngineBuilder::tenants(..)` + `run_tenant_mix()`) interleaves N
//! tenant sessions into one shared memory system with per-tenant stats
//! and contention scenarios (DESIGN.md §12).
//!
//! Runs are no longer generator-only: the [`trace`] subsystem records any
//! run's access stream into a compact binary trace file (CRC'd chunks,
//! optional delta/varint encoding) via an [`sim::AccessTap`], and replays
//! it as a streaming [`workloads::Workload`] ([`trace::TraceWorkload`];
//! `EngineBuilder::trace(path)`, the `trace:<path>` workload name, or the
//! `trimma record`/`replay` CLI pair) — buffered chunked reads by
//! default, or double-buffered read-ahead on a dedicated I/O thread, with
//! replayed stats byte-identical to the live run across every execution
//! mode (DESIGN.md §13).
//!
//! The AOT-compiled JAX/Pallas trace generator is loaded through
//! [`runtime`] (PJRT CPU client); Python never runs at simulation time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use trimma::prelude::*;
//!
//! let report = EngineBuilder::new(DesignPoint::TrimmaCache)
//!     .workload("gap_pr")
//!     .run()
//!     .unwrap();
//! println!("IPC-proxy perf: {:.4}", report.performance());
//! ```
//!
//! ## Panic policy
//!
//! Production code returns typed errors ([`engine::EngineError`],
//! [`trace::TraceError`]) for everything a caller could anticipate;
//! `unwrap`/`expect` are linted crate-wide (below) and each survivor
//! carries a targeted `#[allow]` with its infallibility argument — see
//! the panic-audit notes in the module docs of [`trace::format`],
//! [`trace::replay`], [`sim`], and [`engine::sharded`]. Test code is
//! exempt (the `cfg_attr` gate), as are the harness-style modules that
//! opt out at their own top with a stated reason.

// Fallible-by-construction `unwrap`/`expect` must not reach production
// paths: CI runs clippy with `-D warnings`, which turns these into hard
// errors everywhere an `#[allow]` doesn't argue otherwise.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

pub mod bench_util;
pub(crate) mod cachesim;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod hybrid;
pub(crate) mod mem;
pub mod metadata;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod types;
pub mod verify;
pub mod workloads;

pub use config::SystemConfig;
pub use engine::{AnyController, EngineBuilder, EngineError, Session};

/// One-stop imports for driving the simulator: the engine front door plus
/// the handful of types every driver touches.
pub mod prelude {
    pub use crate::config::presets::DesignPoint;
    pub use crate::config::SystemConfig;
    pub use crate::engine::{
        AnyController, Completion, EngineBuilder, EngineError, MemoryPreset, Session,
        ShardPlan, ShardedSession,
    };
    pub use crate::hybrid::{Access, Controller};
    pub use crate::config::{MixProfile, TenantMixConfig, TenantScenario};
    pub use crate::config::{TraceConfig, TraceReplayMode};
    pub use crate::sim::{ShardedSimulation, SimReport, Simulation, TenantReport, TenantStats};
    pub use crate::stats::Stats;
    pub use crate::trace::{TraceError, TraceSummary, TraceWorkload};
    pub use crate::types::AccessKind;
    pub use crate::workloads::Workload;
}

//! # trimma — a reproduction of *Trimma: Trimming Metadata Storage and
//! Latency for Hybrid Memory Systems* (PACT '24).
//!
//! This crate is a full hybrid-memory-system simulation framework built
//! around the paper's two contributions:
//!
//! * [`metadata::irt`] — the **indirection-based remap table** (iRT): a
//!   hardware-managed, per-set radix tree that only stores remap entries for
//!   blocks that actually moved, and donates the saved fast-memory blocks as
//!   extra DRAM-cache capacity.
//! * [`metadata::irc`] — the **identity-mapping-aware remap cache** (iRC): an
//!   on-chip remap cache split into a conventional `NonIdCache` and a
//!   sector-cache-style `IdCache` holding 1-bit-per-block identity vectors.
//!
//! Around those we rebuild every substrate the paper's evaluation depends
//! on: DRAM/HBM/NVM device timing ([`mem`]), a CPU cache hierarchy
//! ([`cachesim`]), cache-mode and flat-mode hybrid memory controllers plus
//! the Alloy-Cache, Loh-Hill-Cache, and MemPod baselines ([`hybrid`]),
//! calibrated synthetic workload generators standing in for SPEC CPU 2017 /
//! GAP / silo / memcached ([`workloads`]), a 16-core trace-driven simulation
//! engine ([`sim`]), and an experiment coordinator that regenerates every
//! figure in the paper ([`coordinator`]).
//!
//! The AOT-compiled JAX/Pallas trace generator is loaded through
//! [`runtime`] (PJRT CPU client); Python never runs at simulation time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use trimma::config::presets;
//! use trimma::sim::Simulation;
//!
//! let cfg = presets::hbm3_ddr5(presets::DesignPoint::TrimmaCache);
//! let mut sim = Simulation::new(&cfg, trimma::workloads::by_name("gap_pr", &cfg).unwrap());
//! let report = sim.run();
//! println!("IPC-proxy perf: {:.4}", report.performance());
//! ```

pub mod bench_util;
pub mod cachesim;
pub mod config;
pub mod coordinator;
pub mod hybrid;
pub mod mem;
pub mod metadata;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod types;
pub mod verify;
pub mod workloads;

pub use config::SystemConfig;

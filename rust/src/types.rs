//! Core shared types: addresses, block identifiers, cycles, access records.
//!
//! The simulator works in three address spaces, mirroring the paper's
//! terminology (§2.2):
//!
//! * **physical address** — what the OS/application sees and what arrives at
//!   the memory controller after virtual translation. In cache mode this
//!   covers only the slow tier; in flat mode it covers slow + the OS-visible
//!   part of the fast tier.
//! * **device address** — the actual location on a memory device after the
//!   hybrid-memory remap step. An *identity mapping* means
//!   `device == physical`.
//! * **block id** — a physical/device address divided by the migration block
//!   size (256 B by default).


/// A time stamp or duration in CPU cycles (3.2 GHz by default).
pub type Cycle = u64;

/// A physical byte address.
pub type PhysAddr = u64;

/// A block identifier: byte address >> log2(block size).
pub type BlockId = u64;

/// Sentinel for "no block".
pub const NO_BLOCK: BlockId = u64::MAX;

/// Memory tier selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// The fast tier (HBM3 or DDR5 depending on configuration).
    Fast,
    /// The slow tier (DDR5 or NVM depending on configuration).
    Slow,
}

/// Read or write.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum AccessKind {
    #[default]
    Read,
    Write,
}

impl AccessKind {
    #[inline]
    pub fn is_write(self) -> bool {
        matches!(self, AccessKind::Write)
    }
}

/// One memory access emitted by a workload generator (post-CPU, pre-cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    /// Physical byte address.
    pub addr: PhysAddr,
    pub kind: AccessKind,
    /// Number of non-memory instructions executed since the previous memory
    /// access on the same core (drives the core clock between accesses).
    pub gap_instrs: u32,
}

impl MemAccess {
    pub fn read(addr: PhysAddr, gap_instrs: u32) -> Self {
        MemAccess { addr, kind: AccessKind::Read, gap_instrs }
    }
    pub fn write(addr: PhysAddr, gap_instrs: u32) -> Self {
        MemAccess { addr, kind: AccessKind::Write, gap_instrs }
    }
}

/// Result of a device-address resolution (the metadata lookup of Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Remap {
    /// Device block id the physical block currently maps to.
    pub device_block: BlockId,
    /// Which tier the device block lives on.
    pub tier: Tier,
}

/// Simple deterministic 64-bit RNG (xorshift*), used everywhere a seeded
/// stream is needed so runs are bit-reproducible without external crates.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; splitmix the seed once.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        Rng64 { state: (z ^ (z >> 31)) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Integer log2 for powers of two, with a check in debug builds.
#[inline]
pub fn ilog2(x: u64) -> u32 {
    debug_assert!(x.is_power_of_two(), "{x} is not a power of two");
    x.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn rng_zero_seed_is_fine() {
        let mut r = Rng64::new(0);
        let v: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng64::new(7);
        for n in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..50 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Rng64::new(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn ilog2_powers() {
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(256), 8);
        assert_eq!(ilog2(1 << 33), 33);
    }
}

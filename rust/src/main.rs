//! `trimma` CLI — the Layer-3 leader entrypoint.
//!
//! ```text
//! trimma list                               available workloads / presets
//! trimma run --design trimma-c --workload gap_pr [--mem ddr5+nvm]
//!            [--accesses N] [--ideal] [--verify] [--decay] [--faults]
//!            [--prefetch]                  batched-translate software
//!                                          prefetch (DESIGN.md §15)
//!            [--ratio R] [--block B]
//!            [--shards N]                  N>0: open-loop sharded run
//!                                          across N worker threads
//!            [--pipeline]                  pipelined front end (needs
//!                                          --shards N with N>=1)
//! trimma sweep --figure fig7a [--quick] [--threads N]
//! trimma sweep --all [--quick]
//! trimma tenants [--tenants N] [--scenario steady|noisy_neighbor|churn|
//!                flash_crowd] [--mix serving|analytics|general]
//!                [--shards N] [--pipeline]  multi-tenant serving run with
//!                                           per-tenant stats (DESIGN.md §12)
//! trimma record --workload gap_pr [--out FILE.trimtrace] [--accesses N]
//!               [--warmup N] [--cores N]    record a closed-loop run into a
//!                                           compact binary trace (DESIGN.md §13)
//! trimma replay --trace FILE.trimtrace [--design trimma-c] [--readahead]
//!               [--shards N] [--pipeline]   replay a recorded trace (the
//!                                           header's run shape is adopted)
//! trimma bench [--quick] [--tag T] [--json BENCH_<tag>.json] [--shards N]
//!              [--pipeline] [--decay] [--faults] [--tenants] [--trace]
//!              [--prefetch]
//!                                           hot-path + sim-sweep perf
//!                                           report (EXPERIMENTS.md §Perf)
//! trimma bench-check --report bench.json [--require-labels L1,L2,...]
//!                                           validate a report's schema and
//!                                           required label coverage
//! trimma bench-compare --baseline B --new N [--warn-pct 10] [--fail-pct 30]
//!                                           CI regression gate
//! trimma bench-dispatch --report bench.json dyn-vs-enum dispatch delta
//! trimma analyze --workload gap_pr          hotness analysis via the AOT
//!                                           artifact (PJRT; no python)
//! trimma dump-config --design trimma-c [--mem hbm3+ddr5]
//! ```

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::coordinator::{bench::dispatch_deltas, figures, fmt, pct, run_job, Job};

const USAGE: &str = "\
trimma — Trimma (PACT'24) hybrid-memory metadata simulator

  trimma list                               workloads / designs / figures
  trimma run --design trimma-c --workload gap_pr [--mem ddr5+nvm]
             [--accesses N] [--cores N] [--ideal] [--verify] [--decay]
             [--faults]     deterministic fault injection + recovery
                            (scrub/rebuild/quarantine; DESIGN.md §14)
             [--prefetch]   batched-translate software prefetch: prime
                            metadata lines one batch walk ahead of
                            execution (DESIGN.md §15)
             [--ratio R] [--block B]
             [--shards N]   N>0: open-loop sharded run across N workers
             [--pipeline]   pipelined front end (needs --shards N, N>=1)
  trimma sweep --figure fig7a [--quick] [--threads N]
  trimma sweep --all [--quick]
  trimma compare --designs trimma-c,alloy --workload gap_pr
  trimma tenants [--design trimma-c] [--tenants N]
                 [--scenario steady|noisy_neighbor|churn|flash_crowd]
                 [--mix serving|analytics|general] [--phase-len P]
                 [--accesses N] [--verify]
                 [--shards N]   N>0: open-loop sharded run; 0 (default):
                                closed loop with real miss latencies
                 [--pipeline]   pipelined front end (needs --shards N, N>=1)
  trimma record --workload gap_pr [--design trimma-c] [--mem ddr5+nvm]
                [--accesses N] [--warmup N] [--cores N]
                [--out FILE.trimtrace]
                               record a closed-loop run into a compact
                               binary trace file (DESIGN.md §13)
  trimma replay --trace FILE.trimtrace [--design trimma-c] [--mem ddr5+nvm]
                [--readahead]  double-buffered read-ahead I/O thread
                               (default: buffered chunked reads)
                [--shards N] [--pipeline] [--verify] [--decay] [--faults]
                [--prefetch]   replay a recorded trace; cores/accesses/
                               warmup are adopted from the trace header
  trimma bench [--quick] [--tag T] [--json BENCH_<tag>.json] [--shards N] [--pipeline]
               [--decay] [--faults] [--tenants] [--trace] [--prefetch]
  trimma bench-check --report bench.json [--require-labels L1,L2,...]
  trimma bench-compare --baseline B.json --new N.json [--warn-pct 10] [--fail-pct 30]
  trimma bench-dispatch --report bench.json dyn-vs-enum dispatch delta
  trimma analyze --workload gap_pr          AOT hotness artifact via PJRT
  trimma dump-config --design trimma-c [--mem hbm3+ddr5]";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let has = |flag: &str| args.iter().any(|a| a == flag);

    match cmd {
        "list" => list(),
        "run" => run(&get, &has),
        "compare" => compare(&get),
        "sweep" => sweep(&get, &has),
        "tenants" => tenants(&get, &has),
        "record" => record(&get),
        "replay" => replay(&get, &has),
        "bench" => bench(&get, &has),
        "bench-check" => bench_check(&get),
        "bench-compare" => bench_compare(&get),
        "bench-dispatch" => bench_dispatch(&get),
        "analyze" => analyze(&get),
        "dump-config" => {
            let cfg = build_cfg(&get);
            println!("{}", cfg.describe());
        }
        _ => println!("{USAGE}"),
    }
}

fn design_of(name: &str) -> DesignPoint {
    match name {
        "alloy" => DesignPoint::AlloyCache,
        "loh-hill" => DesignPoint::LohHill,
        "trimma-c" => DesignPoint::TrimmaCache,
        "mempod" => DesignPoint::MemPod,
        "trimma-f" => DesignPoint::TrimmaFlat,
        "linear-c" => DesignPoint::LinearCache,
        "ideal" => DesignPoint::Ideal,
        other => {
            eprintln!("unknown design '{other}' (see `trimma list`)");
            std::process::exit(2);
        }
    }
}

fn build_cfg(get: &dyn Fn(&str) -> Option<String>) -> SystemConfig {
    let dp = design_of(&get("--design").unwrap_or_else(|| "trimma-c".into()));
    let mem = get("--mem").unwrap_or_else(|| "hbm3+ddr5".into());
    let mut cfg = match mem.as_str() {
        "hbm3+ddr5" => presets::hbm3_ddr5(dp),
        "ddr5+nvm" => presets::ddr5_nvm(dp),
        other => {
            eprintln!("unknown memory combo '{other}' (hbm3+ddr5 | ddr5+nvm)");
            std::process::exit(2);
        }
    };
    if let Some(r) = get("--ratio") {
        cfg = presets::with_capacity_ratio(cfg, r.parse().expect("--ratio"));
    }
    if let Some(b) = get("--block") {
        cfg = presets::with_block_bytes(cfg, b.parse().expect("--block"));
    }
    if let Some(n) = get("--accesses") {
        cfg.workload.accesses_per_core = n.parse().expect("--accesses");
    }
    if let Some(n) = get("--cores") {
        cfg.workload.cores = n.parse().expect("--cores");
    }
    if let Some(n) = get("--warmup") {
        cfg.workload.warmup_per_core = n.parse().expect("--warmup");
    }
    cfg.validate().unwrap_or_else(|e| {
        eprintln!("invalid config: {e}");
        std::process::exit(2);
    });
    cfg
}

fn list() {
    println!("designs:   alloy loh-hill trimma-c mempod trimma-f linear-c ideal");
    println!("memories:  hbm3+ddr5 ddr5+nvm");
    println!("figures:   {}", figures::ALL_FIGURES.join(" "));
    println!("workloads:");
    for w in trimma::workloads::all_names() {
        println!("  {w}");
    }
}

fn run(get: &dyn Fn(&str) -> Option<String>, has: &dyn Fn(&str) -> bool) {
    let mut cfg = build_cfg(get);
    cfg.hybrid.verify |= has("--verify");
    cfg.hybrid.decay.enabled |= has("--decay");
    cfg.hybrid.fault.enabled |= has("--faults");
    cfg.hybrid.batch.prefetch |= has("--prefetch");
    let wl = get("--workload").unwrap_or_else(|| "gap_pr".into());
    let mut job = Job::new(format!("{}:{}", cfg.name, wl), cfg, &wl);
    job.ideal = has("--ideal");
    if let Some(n) = get("--shards") {
        job.shards = n.parse().expect("--shards");
        if job.shards > 0 {
            println!(
                "(sharded open-loop mode: {} worker thread(s); timing stats are \
                 comparable between sharded runs, not with closed-loop runs)",
                job.shards
            );
        } else {
            println!("(--shards 0: classic closed-loop run)");
        }
    }
    if has("--pipeline") {
        if job.shards == 0 {
            eprintln!(
                "--pipeline needs --shards N (N >= 1): the pipelined front end is \
                 part of the open-loop sharded path (the closed loop's latency \
                 feedback cannot be pipelined)"
            );
            std::process::exit(2);
        }
        job.pipeline = true;
        println!(
            "(pipelined front end: shard routing on a dedicated stage, overlapping \
             trace generation + cache filtering; merged stats identical to inline)"
        );
    }
    let t0 = std::time::Instant::now();
    let rep = run_job(&job).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let dt = t0.elapsed();
    let s = &rep.stats;
    println!("== {} / {} ==", job.cfg.name, rep.name);
    println!("performance (IPC proxy):   {}", fmt(rep.performance()));
    println!("fast-mem serve rate:       {}", pct(s.fast_serve_rate()));
    println!("bandwidth bloat factor:    {}", fmt(s.bandwidth_bloat()));
    println!("remap cache hit rate:      {}", pct(s.rc_hit_rate()));
    let (m, f, sl) = s.amat_breakdown();
    println!("AMAT cycles (meta/fast/slow): {} / {} / {}", fmt(m), fmt(f), fmt(sl));
    println!("metadata bytes used:       {}", s.metadata_bytes_used);
    println!("metadata bytes reserved:   {}", s.metadata_bytes_reserved);
    println!("donated cache slots:       {}", s.donated_slots);
    println!("mem accesses:              {}", s.mem_accesses);
    let em = if get("--mem").as_deref() == Some("ddr5+nvm") {
        trimma::stats::energy::EnergyModel::ddr5_nvm()
    } else {
        trimma::stats::energy::EnergyModel::hbm3_ddr5()
    };
    let e = trimma::stats::energy::estimate(s, &em);
    println!(
        "energy (fast/slow/sram uJ): {:.1} / {:.1} / {:.1}  ({:.0} pJ/useful byte)",
        e.fast_uj, e.slow_uj, e.sram_uj, e.pj_per_useful_byte(s)
    );
    println!(
        "sim wall time: {:.2}s ({:.1} M instrs/s)",
        dt.as_secs_f64(),
        (s.instructions as f64 / 1e6) / dt.as_secs_f64().max(1e-9)
    );
}

/// `trimma tenants`: a multi-tenant serving run (DESIGN.md §12). Default
/// is the closed loop (`--shards 0`) with real per-access miss latencies
/// behind the p50/p99 columns; `--shards N` (N>0) switches to the
/// open-loop sharded path, whose constant nominal miss latency makes the
/// percentiles degenerate (attribution counts stay exact and
/// shard-invariant).
fn tenants(get: &dyn Fn(&str) -> Option<String>, has: &dyn Fn(&str) -> bool) {
    use trimma::config::{MixProfile, TenantMixConfig, TenantScenario};
    use trimma::engine::EngineBuilder;

    let dp = design_of(&get("--design").unwrap_or_else(|| "trimma-c".into()));
    let mut mix = TenantMixConfig::off();
    if let Some(n) = get("--tenants") {
        mix.tenants = n.parse().expect("--tenants");
    }
    if let Some(s) = get("--scenario") {
        mix.scenario = TenantScenario::parse(&s).unwrap_or_else(|| {
            eprintln!("unknown scenario '{s}' (steady | noisy_neighbor | churn | flash_crowd)");
            std::process::exit(2);
        });
    }
    if let Some(m) = get("--mix") {
        mix.mix = MixProfile::parse(&m).unwrap_or_else(|| {
            eprintln!("unknown mix '{m}' (serving | analytics | general)");
            std::process::exit(2);
        });
    }
    if let Some(p) = get("--phase-len") {
        mix.phase_len = p.parse().expect("--phase-len");
    }
    let shards: usize = get("--shards").map(|v| v.parse().expect("--shards")).unwrap_or(0);
    if has("--pipeline") && shards == 0 {
        eprintln!("--pipeline needs --shards N (N >= 1): the pipelined front end is part of the open-loop sharded path");
        std::process::exit(2);
    }
    let accesses: Option<u64> = get("--accesses").map(|n| n.parse().expect("--accesses"));
    let builder = EngineBuilder::new(dp)
        .tenants(mix)
        .shards(shards)
        .pipeline(has("--pipeline"))
        .verify(has("--verify"))
        .configure(move |cfg| {
            if let Some(n) = accesses {
                cfg.workload.accesses_per_core = n;
            }
        });
    let t0 = std::time::Instant::now();
    let rep = builder.run_tenant_mix().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let dt = t0.elapsed();
    println!("== {} ({}) ==", rep.merged.name, mix.scenario.label());
    println!(
        "{:<7} {:<16} {:>10} {:>8} {:>10} {:>8} {:>8} {:>10}",
        "tenant", "workload", "accesses", "hit%", "llc_miss", "p50", "p99", "fast_pg%"
    );
    for t in &rep.tenants {
        println!(
            "{:<7} {:<16} {:>10} {:>7.1}% {:>10} {:>8} {:>8} {:>9.1}%",
            t.tenant,
            t.workload,
            t.accesses,
            t.hit_rate_milli() as f64 / 10.0,
            t.llc_misses,
            t.p50_miss_lat(),
            t.p99_miss_lat(),
            t.fast_share_milli() as f64 / 10.0,
        );
    }
    if shards > 0 {
        println!("(open-loop run: p50/p99 reflect the constant nominal miss latency)");
    }
    let s = &rep.merged.stats;
    println!("merged performance (IPC proxy): {}", fmt(rep.merged.performance()));
    println!("merged fast-mem serve rate:     {}", pct(s.fast_serve_rate()));
    println!("merged mem accesses:            {}", s.mem_accesses);
    println!("sim wall time: {:.2}s", dt.as_secs_f64());
}

/// `trimma record`: run a workload through the closed loop and capture its
/// per-core access stream into a compact binary trace file (DESIGN.md
/// §13). The recording tap is allocation-free on the hot path; the file
/// carries the run shape in its header, so `trimma replay` needs no flags
/// beyond the path.
fn record(get: &dyn Fn(&str) -> Option<String>) {
    let cfg = build_cfg(get);
    let wl = get("--workload").unwrap_or_else(|| "gap_pr".into());
    let out = get("--out").unwrap_or_else(|| format!("{wl}.trimtrace"));
    let t0 = std::time::Instant::now();
    let rep = trimma::engine::EngineBuilder::from_config(cfg)
        .workload(&wl)
        .run_recorded(&out)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let dt = t0.elapsed();
    let summary = trimma::trace::validate(std::path::Path::new(&out)).unwrap_or_else(|e| {
        eprintln!("internal error: freshly recorded trace fails validation: {e}");
        std::process::exit(2);
    });
    println!("== recorded {wl} -> {out} ==");
    println!("records:        {} ({} cores)", summary.total_records, summary.meta.cores);
    println!("chunks:         {} x {} records", summary.chunk_count, summary.meta.chunk_records);
    println!(
        "file size:      {} KiB ({:.2} B/record, {} encoding)",
        summary.file_bytes >> 10,
        summary.file_bytes as f64 / summary.total_records.max(1) as f64,
        summary.meta.encoding.label()
    );
    println!("mem accesses:   {}", rep.stats.mem_accesses);
    println!("record wall time: {:.2}s", dt.as_secs_f64());
}

/// `trimma replay`: re-run a recorded trace through the simulator. The
/// header's run shape (cores, accesses, warmup) is adopted into the
/// config, so a trace recorded anywhere replays under any design point or
/// memory preset; `--readahead` moves chunk I/O onto a dedicated
/// read-ahead thread (`TraceReplayMode::ReadAhead`).
fn replay(get: &dyn Fn(&str) -> Option<String>, has: &dyn Fn(&str) -> bool) {
    use trimma::config::TraceReplayMode;

    let path = get("--trace").unwrap_or_else(|| {
        eprintln!("need --trace <file.trimtrace>");
        std::process::exit(2);
    });
    let summary = trimma::trace::validate(std::path::Path::new(&path)).unwrap_or_else(|e| {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    });
    let mut cfg = build_cfg(get);
    cfg.workload.cores = summary.meta.cores;
    cfg.workload.accesses_per_core = summary.meta.accesses_per_core;
    cfg.workload.warmup_per_core = summary.meta.warmup_per_core;
    cfg.hybrid.verify |= has("--verify");
    cfg.hybrid.decay.enabled |= has("--decay");
    cfg.hybrid.fault.enabled |= has("--faults");
    cfg.hybrid.batch.prefetch |= has("--prefetch");
    if has("--readahead") {
        cfg.trace.replay = TraceReplayMode::ReadAhead;
    }
    let shards: usize = get("--shards").map(|v| v.parse().expect("--shards")).unwrap_or(0);
    if has("--pipeline") && shards == 0 {
        eprintln!("--pipeline needs --shards N (N >= 1): the pipelined front end is part of the open-loop sharded path");
        std::process::exit(2);
    }
    let builder = trimma::engine::EngineBuilder::from_config(cfg)
        .trace(&path)
        .shards(shards)
        .pipeline(has("--pipeline"));
    let t0 = std::time::Instant::now();
    let result = if shards > 0 { builder.run_sharded() } else { builder.run() };
    let rep = result.unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let dt = t0.elapsed();
    let s = &rep.stats;
    println!(
        "== replayed {} ({} records, {} mode{}) ==",
        rep.name,
        summary.total_records,
        cfg_replay_label(has),
        if shards > 0 { format!(", {shards} shard(s)") } else { String::new() }
    );
    println!("performance (IPC proxy):   {}", fmt(rep.performance()));
    println!("fast-mem serve rate:       {}", pct(s.fast_serve_rate()));
    println!("remap cache hit rate:      {}", pct(s.rc_hit_rate()));
    println!("mem accesses:              {}", s.mem_accesses);
    println!(
        "replay wall time: {:.2}s ({:.1} M mem-steps/s)",
        dt.as_secs_f64(),
        (summary.total_records as f64 / 1e6) / dt.as_secs_f64().max(1e-9)
    );
}

/// The replay-mode label for `trimma replay`'s banner line.
fn cfg_replay_label(has: &dyn Fn(&str) -> bool) -> &'static str {
    if has("--readahead") { "readahead" } else { "buffered" }
}

/// `trimma bench`: run the hot-path + sim-sweep suite and (optionally)
/// write the schema-versioned JSON report. See EXPERIMENTS.md §Perf.
fn bench(get: &dyn Fn(&str) -> Option<String>, has: &dyn Fn(&str) -> bool) {
    let quick = has("--quick");
    let tag = get("--tag").unwrap_or_else(|| if quick { "quick".into() } else { "full".into() });
    let shards: usize = get("--shards").map(|v| v.parse().expect("--shards")).unwrap_or(2);
    let pipeline = has("--pipeline");
    let decay = has("--decay");
    let faults = has("--faults");
    let tenants = has("--tenants");
    let trace = has("--trace");
    let prefetch = has("--prefetch");
    let report = trimma::coordinator::bench::full_report(
        &tag, quick, shards, pipeline, decay, faults, tenants, trace, prefetch,
    );
    println!(
        "geomean sim throughput: {:.3} M mem-steps/s ({} records, tag '{}'{})",
        report.geomean_sim_msteps_per_s,
        report.records.len(),
        report.tag,
        if quick { ", quick" } else { "" }
    );
    print_dispatch_deltas(&report);
    if let Some(path) = get("--json") {
        report.validate().unwrap_or_else(|e| {
            eprintln!("internal error: generated report fails its own schema: {e}");
            std::process::exit(2);
        });
        std::fs::write(&path, report.to_json()).unwrap_or_else(|e| {
            eprintln!("cannot write {path}: {e}");
            std::process::exit(2);
        });
        println!("wrote {path}");
    }
}

/// Print the dyn-vs-enum dispatch comparison from a report's paired
/// `<base>/enum` + `<base>/dyn` hot-path records (positive delta = the
/// boxed `dyn Controller` path is slower than the enum-dispatched one).
fn print_dispatch_deltas(report: &trimma::bench_util::BenchReport) {
    let deltas = dispatch_deltas(report);
    if deltas.is_empty() {
        println!("dispatch delta: no enum/dyn record pairs in this report");
        return;
    }
    for d in deltas {
        println!(
            "dispatch delta {:<28} enum {:>8.1} ns  dyn {:>8.1} ns  ({:+.1}% for dyn)",
            d.base, d.enum_ns, d.dyn_ns, d.delta_pct
        );
    }
}

/// `trimma bench-dispatch`: re-read a bench report and print the
/// dyn-vs-enum dispatch delta (the CI bench-smoke job's summary step).
fn bench_dispatch(get: &dyn Fn(&str) -> Option<String>) {
    let path = get("--report").unwrap_or_else(|| {
        eprintln!("need --report <bench.json>");
        std::process::exit(2);
    });
    print_dispatch_deltas(&load_report(&path));
}

fn load_report(path: &str) -> trimma::bench_util::BenchReport {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("cannot read {path}: {e}");
        std::process::exit(2);
    });
    trimma::bench_util::BenchReport::from_json(&text).unwrap_or_else(|e| {
        eprintln!("{path}: malformed report: {e}");
        std::process::exit(2);
    })
}

/// `trimma bench-check`: parse + schema-validate a report (CI smoke job).
/// `--require-labels L1,L2,...` additionally asserts that every listed
/// label has a record — the single label-coverage gate that replaced CI's
/// per-label grep steps; all missing labels are listed in one error.
fn bench_check(get: &dyn Fn(&str) -> Option<String>) {
    let path = get("--report").unwrap_or_else(|| {
        eprintln!("need --report <bench.json>");
        std::process::exit(2);
    });
    let report = load_report(&path);
    report.validate().unwrap_or_else(|e| {
        eprintln!("{path}: schema violation: {e}");
        std::process::exit(2);
    });
    if let Some(required) = get("--require-labels") {
        let missing = trimma::bench_util::missing_labels(&report, &required);
        if !missing.is_empty() {
            eprintln!("{path}: missing required labels: {}", missing.join(", "));
            std::process::exit(2);
        }
        println!("{path}: all required labels present");
    }
    println!(
        "{path}: ok (schema v{}, {} records, geomean {:.3} M mem-steps/s)",
        report.schema_version,
        report.records.len(),
        report.geomean_sim_msteps_per_s
    );
}

/// `trimma bench-compare`: the CI perf-regression gate. Compares geomean
/// sim throughput of `--new` against `--baseline`; exits 0 on ok/warn
/// (regression <= fail threshold), 3 on a hard regression. A baseline
/// with no recorded sweep (the committed placeholder) skips the check.
fn bench_compare(get: &dyn Fn(&str) -> Option<String>) {
    let need = |flag: &str| {
        get(flag).unwrap_or_else(|| {
            eprintln!("need {flag} <report.json>");
            std::process::exit(2);
        })
    };
    let warn_pct: f64 = get("--warn-pct").map(|v| v.parse().expect("--warn-pct")).unwrap_or(10.0);
    let fail_pct: f64 = get("--fail-pct").map(|v| v.parse().expect("--fail-pct")).unwrap_or(30.0);
    let baseline = load_report(&need("--baseline"));
    let new = load_report(&need("--new"));
    match trimma::bench_util::throughput_ratio(&baseline, &new) {
        None if baseline.quick != new.quick => {
            println!(
                "baseline is a {} report but the new report is {}; skipping the \
                 comparison — refresh the baseline at matching scale \
                 (EXPERIMENTS.md §Perf)",
                if baseline.quick { "--quick" } else { "full-scale" },
                if new.quick { "--quick" } else { "full-scale" }
            );
        }
        None => {
            println!(
                "no recorded baseline geomean to compare against; skipping \
                 (refresh it per EXPERIMENTS.md §Perf)"
            );
        }
        Some(ratio) => {
            let delta_pct = (ratio - 1.0) * 100.0;
            println!(
                "geomean sim throughput: baseline {:.3} -> new {:.3} M mem-steps/s ({:+.1}%)",
                baseline.geomean_sim_msteps_per_s, new.geomean_sim_msteps_per_s, delta_pct
            );
            if delta_pct < -fail_pct {
                eprintln!("FAIL: regression exceeds {fail_pct}%");
                std::process::exit(3);
            } else if delta_pct < -warn_pct {
                println!("WARN: regression exceeds {warn_pct}% (soft gate; not failing)");
            } else {
                println!("ok");
            }
        }
    }
}

fn sweep(get: &dyn Fn(&str) -> Option<String>, has: &dyn Fn(&str) -> bool) {
    let scale = if has("--quick") { 0.1 } else { 1.0 };
    let threads: usize = get("--threads").map(|t| t.parse().expect("--threads")).unwrap_or(0);
    let figs: Vec<String> = if has("--all") {
        figures::ALL_FIGURES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![get("--figure").unwrap_or_else(|| {
            eprintln!("need --figure <id> or --all");
            std::process::exit(2);
        })]
    };
    for f in figs {
        let t0 = std::time::Instant::now();
        match figures::run_figure(&f, scale, threads) {
            Ok(tables) => {
                for t in tables {
                    println!("{}", t.markdown());
                }
                eprintln!("[{f}] done in {:.1}s (CSV under results/)", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("{e} (see `trimma list`)");
                std::process::exit(2);
            }
        }
    }
}

/// Side-by-side design comparison on one workload.
fn compare(get: &dyn Fn(&str) -> Option<String>) {
    let designs = get("--designs").unwrap_or_else(|| "alloy,trimma-c".into());
    let wl = get("--workload").unwrap_or_else(|| "gap_pr".into());
    let mut rows = Vec::new();
    for d in designs.split(',') {
        let mut cfg = build_cfg(&|f: &str| {
            if f == "--design" { Some(d.trim().to_string()) } else { get(f) }
        });
        if let Some(n) = get("--accesses") {
            cfg.workload.accesses_per_core = n.parse().expect("--accesses");
        }
        let mut job = Job::new(format!("{d}:{wl}"), cfg, &wl);
        job.ideal = d.trim() == "ideal";
        let rep = run_job(&job).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        rows.push((d.trim().to_string(), rep));
    }
    let base = rows[0].1.performance();
    println!(
        "{:<10} {:>9} {:>11} {:>9} {:>9} {:>12}",
        "design", "speedup", "serve_rate", "rc_hit", "bloat", "meta_bytes"
    );
    for (d, r) in &rows {
        let s = &r.stats;
        println!(
            "{:<10} {:>8.3}x {:>10.1}% {:>8.1}% {:>9.2} {:>12}",
            d,
            r.performance() / base,
            s.fast_serve_rate() * 100.0,
            s.rc_hit_rate() * 100.0,
            s.bandwidth_bloat(),
            s.metadata_bytes_used
        );
    }
}

/// Workload hotness analysis through the AOT `hotness` artifact — the
/// L2 analysis graph running via PJRT, no python involved. Requires the
/// `pjrt` cargo feature (the offline build image lacks the XLA crates).
#[cfg(not(feature = "pjrt"))]
fn analyze(_get: &dyn Fn(&str) -> Option<String>) {
    eprintln!(
        "`trimma analyze` needs the PJRT runtime: vendor the `xla` and \
         `anyhow` crates, add them to rust/Cargo.toml (see the [features] \
         note there), and rebuild with `--features pjrt`"
    );
    std::process::exit(2);
}

/// Workload hotness analysis through the AOT `hotness` artifact — the
/// L2 analysis graph running via PJRT, no python involved.
#[cfg(feature = "pjrt")]
fn analyze(get: &dyn Fn(&str) -> Option<String>) {
    use trimma::runtime::{artifacts_dir, Runtime, HOT_BUCKETS, STEPS};
    use trimma::workloads::suite;
    use trimma::workloads::synth::TraceGen;

    let wl = get("--workload").unwrap_or_else(|| "gap_pr".into());
    let profile = suite::profile(&wl).unwrap_or_else(|| {
        eprintln!("unknown workload '{wl}'");
        std::process::exit(2);
    });
    let rt = Runtime::cpu().expect("PJRT client");
    let hx = rt.hotness(&artifacts_dir()).expect("hotness artifact (make artifacts)");
    let gen = TraceGen::new(profile, 512 << 20, 16);
    let streams: Vec<u32> = (0..16).collect();
    let (tables, slice) = gen.to_region_tables(&streams);
    let mut hot = vec![0f32; HOT_BUCKETS];
    let (mut wf_acc, mut mg_acc) = (0.0, 0.0);
    let batches = 8u32;
    for k in 0..batches {
        let (h, wf, mg) = hx
            .run(&streams, k * STEPS as u32, &slice, &tables, &hot, 0.9)
            .expect("hotness batch");
        hot = h;
        wf_acc += wf as f64;
        mg_acc += mg as f64;
    }
    let mut sorted = hot.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f32 = hot.iter().sum();
    let top64: f32 = sorted.iter().take(64).sum();
    println!("== workload analysis: {wl} (AOT hotness artifact, {batches} batches) ==");
    println!("platform:            {}", rt.platform());
    println!("footprint:           {} MiB", gen.footprint() >> 20);
    println!("write fraction:      {}", pct(wf_acc / batches as f64));
    println!("mean gap (instrs):   {:.1}", mg_acc / batches as f64);
    println!("hotness concentration (top 64/1024 buckets): {}", pct((top64 / total) as f64));
}

//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the rust hot path. Python never runs at simulation time — the
//! artifacts under `artifacts/` are HLO *text* produced once by
//! `python/compile/aot.py` (see that file for why text, not protos).
//!
//! The wrapper owns a CPU PJRT client and one compiled executable per
//! artifact. `TraceGenExec` is the typed interface the workload layer
//! uses: feed stream/region tables, get back `(addr_line, is_write, gap)`
//! tiles.
//!
//! The PJRT client itself needs the `xla` and `anyhow` crates, which the
//! offline build image does not ship; everything touching them is gated
//! behind the `pjrt` cargo feature. The wire-format types
//! ([`RegionTables`], [`TraceTile`], the shape constants) stay available
//! unconditionally — the pure-rust twin ([`crate::workloads::synth`])
//! exports its geometry through them regardless of which backend runs.

// Panic audit: the feature-gated PJRT glue unwraps buffer-tuple arity
// that the AOT executable's fixed signature guarantees (STREAMS/STEPS
// shapes compiled in); a mismatch means the artifact on disk is not the
// one this build was compiled against, which must abort.
#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};

/// Fixed AOT shapes (must match python/compile/model.py).
pub const STREAMS: usize = 16;
pub const STEPS: usize = 4096;
pub const MAX_REGIONS: usize = 4;
pub const HOT_BUCKETS: usize = 1024;

/// Locate the artifacts directory: `$TRIMMA_ARTIFACTS`, `./artifacts`, or
/// the repo-relative default.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TRIMMA_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for cand in ["artifacts", "../artifacts", "/root/repo/artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("trace_gen.hlo.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// A PJRT CPU client plus compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            bail!("artifact {} not found — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).context("PJRT compile")
    }

    /// Load the trace-generator executable from `dir`.
    pub fn trace_gen(&self, dir: &Path) -> Result<TraceGenExec> {
        Ok(TraceGenExec { exe: self.load(&dir.join("trace_gen.hlo.txt"))? })
    }

    /// Load the hotness-analysis executable from `dir`.
    pub fn hotness(&self, dir: &Path) -> Result<HotnessExec> {
        Ok(HotnessExec { exe: self.load(&dir.join("hotness.hlo.txt"))? })
    }
}

/// Region tables in the artifact's wire format (padded to MAX_REGIONS).
#[derive(Debug, Clone, Default)]
pub struct RegionTables {
    pub cum_w: [f32; MAX_REGIONS],
    pub base_line: [u32; MAX_REGIONS],
    pub lines: [u32; MAX_REGIONS],
    pub runs: [u32; MAX_REGIONS],
    /// Working-set runs per epoch (phased reuse).
    pub wruns: [u32; MAX_REGIONS],
    pub alpha: [f32; MAX_REGIONS],
    pub seq: [u32; MAX_REGIONS],
    /// `[run_len, write_threshold, gap_mod, n_regions, epoch_runs, 0]`.
    pub params: [u32; 6],
}

/// One generated tile.
#[derive(Debug, Clone)]
pub struct TraceTile {
    /// Row-major `[STREAMS][STEPS]` address lines (64 B units).
    pub addr_line: Vec<u32>,
    pub is_write: Vec<u32>,
    pub gap: Vec<u32>,
}

#[cfg(feature = "pjrt")]
fn run_tuple3(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
    let elems = result.to_tuple()?;
    anyhow::ensure!(elems.len() == 3, "expected 3-tuple, got {}", elems.len());
    Ok(elems)
}

/// The compiled trace-generation executable.
#[cfg(feature = "pjrt")]
pub struct TraceGenExec {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl TraceGenExec {
    /// Run one batch: `streams`/`slice_base` are per-stream (len STREAMS),
    /// `step0` is the base step of the tile.
    pub fn run(
        &self,
        streams: &[u32],
        step0: u32,
        slice_base: &[u32],
        t: &RegionTables,
    ) -> Result<TraceTile> {
        anyhow::ensure!(streams.len() == STREAMS && slice_base.len() == STREAMS);
        let args = vec![
            xla::Literal::vec1(streams),
            xla::Literal::vec1(&[step0]),
            xla::Literal::vec1(slice_base),
            xla::Literal::vec1(&t.cum_w),
            xla::Literal::vec1(&t.base_line),
            xla::Literal::vec1(&t.lines),
            xla::Literal::vec1(&t.runs),
            xla::Literal::vec1(&t.wruns),
            xla::Literal::vec1(&t.alpha),
            xla::Literal::vec1(&t.seq),
            xla::Literal::vec1(&t.params),
        ];
        let mut it = run_tuple3(&self.exe, &args)?.into_iter();
        Ok(TraceTile {
            addr_line: it.next().unwrap().to_vec::<u32>()?,
            is_write: it.next().unwrap().to_vec::<u32>()?,
            gap: it.next().unwrap().to_vec::<u32>()?,
        })
    }
}

/// The compiled hotness-analysis executable.
#[cfg(feature = "pjrt")]
pub struct HotnessExec {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl HotnessExec {
    /// Fold one tile into the decayed histogram. Returns
    /// `(hot_out, write_frac, mean_gap)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        streams: &[u32],
        step0: u32,
        slice_base: &[u32],
        t: &RegionTables,
        hot_in: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, f32, f32)> {
        anyhow::ensure!(hot_in.len() == HOT_BUCKETS);
        let args = vec![
            xla::Literal::vec1(streams),
            xla::Literal::vec1(&[step0]),
            xla::Literal::vec1(slice_base),
            xla::Literal::vec1(&t.cum_w),
            xla::Literal::vec1(&t.base_line),
            xla::Literal::vec1(&t.lines),
            xla::Literal::vec1(&t.runs),
            xla::Literal::vec1(&t.wruns),
            xla::Literal::vec1(&t.alpha),
            xla::Literal::vec1(&t.seq),
            xla::Literal::vec1(&t.params),
            xla::Literal::vec1(hot_in),
            xla::Literal::vec1(&[decay]),
        ];
        let mut it = run_tuple3(&self.exe, &args)?.into_iter();
        let hot = it.next().unwrap().to_vec::<f32>()?;
        let wf = it.next().unwrap().to_vec::<f32>()?[0];
        let mg = it.next().unwrap().to_vec::<f32>()?[0];
        Ok((hot, wf, mg))
    }
}

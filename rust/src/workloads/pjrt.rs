//! PJRT-backed workload generation: the [`Workload`] adapter over the
//! AOT-compiled Pallas trace-generation artifact.
//!
//! This is the production data path of the three-layer architecture: the
//! Layer-1 kernel (lowered once, at build time, by `make artifacts`) runs
//! batched on the PJRT CPU client; the simulator pulls `(addr, write, gap)`
//! tuples out of the returned tiles. A small tile cache lets cores at
//! slightly different step counts share batches.
//!
//! `rust/tests/pjrt_crosscheck.rs` asserts this path agrees with the pure
//! rust twin ([`super::synth::TraceGen`]).

// Panic audit: `tile_for` expects a tile the immediately preceding
// generation call staged into the cache; a miss is a bug in this file's
// own cache keying, not a runtime condition.
#![allow(clippy::unwrap_used, clippy::expect_used)]

use anyhow::Result;

use super::synth::TraceGen;
use super::Workload;
use crate::runtime::{Runtime, TraceGenExec, TraceTile, RegionTables, STEPS, STREAMS};
use crate::types::{AccessKind, MemAccess};

/// Workload generating accesses via the AOT trace_gen artifact.
pub struct PjrtWorkload {
    exec: TraceGenExec,
    tables: RegionTables,
    streams: Vec<u32>,
    slice_base: Vec<u32>,
    name: String,
    footprint: u64,
    /// Per-core next step.
    steps: Vec<u32>,
    /// Small cache of generated tiles, keyed by `step / STEPS`.
    tiles: Vec<(u32, TraceTile)>,
    cores: usize,
}

impl PjrtWorkload {
    /// Wrap `gen`'s geometry behind the artifact in `runtime::artifacts_dir()`.
    pub fn from_trace_gen(gen: &TraceGen, name: &str, cores: u32, seed: u32) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let exec = rt.trace_gen(&crate::runtime::artifacts_dir())?;
        Self::with_exec(exec, gen, name, cores, seed)
    }

    pub fn with_exec(
        exec: TraceGenExec,
        gen: &TraceGen,
        name: &str,
        cores: u32,
        seed: u32,
    ) -> Result<Self> {
        anyhow::ensure!(
            cores as usize <= STREAMS,
            "artifact is compiled for {STREAMS} streams"
        );
        // Stream ids match SynthWorkload: core ^ seed; pad to STREAMS.
        let streams: Vec<u32> = (0..STREAMS as u32).map(|c| c ^ seed).collect();
        let (tables, slice_base) = gen.to_region_tables(&streams);
        Ok(PjrtWorkload {
            exec,
            tables,
            streams,
            slice_base,
            name: name.to_string(),
            footprint: gen.footprint(),
            steps: vec![0; cores as usize],
            tiles: Vec::new(),
            cores: cores as usize,
        })
    }

    fn tile_for(&mut self, k: u32) -> Result<usize> {
        if let Some(i) = self.tiles.iter().position(|(kk, _)| *kk == k) {
            return Ok(i);
        }
        let tile = self.exec.run(
            &self.streams,
            k * STEPS as u32,
            &self.slice_base,
            &self.tables,
        )?;
        if self.tiles.len() >= 2 {
            self.tiles.remove(0);
        }
        self.tiles.push((k, tile));
        Ok(self.tiles.len() - 1)
    }
}

impl Workload for PjrtWorkload {
    fn next(&mut self, core: usize) -> MemAccess {
        debug_assert!(core < self.cores);
        let step = self.steps[core];
        self.steps[core] = step.wrapping_add(1);
        let k = step / STEPS as u32;
        let off = (step % STEPS as u32) as usize;
        let i = self.tile_for(k).expect("PJRT trace generation failed");
        let (_, tile) = &self.tiles[i];
        let at = core * STEPS + off;
        let kind = if tile.is_write[at] != 0 {
            AccessKind::Write
        } else {
            AccessKind::Read
        };
        MemAccess {
            addr: tile.addr_line[at] as u64 * 64,
            kind,
            gap_instrs: tile.gap[at],
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn footprint_bytes(&self) -> u64 {
        self.footprint
    }
}

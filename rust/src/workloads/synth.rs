//! The counter-based synthetic trace generator — the rust twin of the
//! Pallas kernel in `python/compile/kernels/trace_gen.py`.
//!
//! Every access is a pure function of `(stream_seed, step, profile)`:
//!
//! ```text
//! run_id  = step / run_len            (spatial runs of run_len 64 B lines)
//! h1      = lowbias32(stream_key ^ lowbias32(run_id))
//! region  = cumulative-weight pick by h1
//! u       = uniform(h2) in [0,1)
//! line    = streaming sweep           (scan regions)
//!         | floor(R * u^(1/(1-theta))) (zipf/pareto hot-rank regions)
//! addr    = region_base + (line*run_len + pos) * 64   [+ private slice]
//! write?  = hash bit vs write_frac;   gap = hash % (2*avg_gap)
//! ```
//!
//! Statelessness makes the generator embarrassingly parallel (the Pallas
//! kernel evaluates a whole `(streams x steps)` tile at once) and makes the
//! rust and AOT-artifact paths directly comparable: integer-derived fields
//! (`is_write`, `gap`) match bit-exactly; the zipf line index may differ in
//! the last ULP of `powf` between libm and XLA, so address equality is
//! asserted statistically (see rust/tests/pjrt_crosscheck.rs).

use super::Workload;
use crate::types::{MemAccess, PhysAddr};

pub const LINE_BYTES: u64 = 64;

/// One address region of a profile.
#[derive(Debug, Clone, Copy)]
pub struct Region {
    /// Relative access weight (normalized over the profile's regions).
    pub weight: f32,
    /// Fraction of the footprint this region occupies.
    pub frac: f64,
    /// Zipf skew theta in [0, 1) for random regions. Ignored when `seq`.
    pub theta: f32,
    /// Fraction of the region's runs forming the per-epoch *working set*
    /// (phased reuse, e.g. PageRank iterations). 1.0 = classic IRM zipf
    /// over the whole region. Ignored when `seq`.
    pub working: f32,
    /// Streaming sweep (true) vs. zipf-skewed random runs (false).
    pub seq: bool,
}

/// Full workload profile (see [`super::suite`] for the calibrated set).
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: &'static str,
    /// Fraction of OS-visible memory the workload touches.
    pub footprint_frac: f64,
    /// SPEC rate mode: each core owns a private slice of the footprint.
    pub private_per_core: bool,
    /// Mean non-memory instructions between memory accesses.
    pub avg_gap_instrs: u32,
    /// Fraction of accesses that are writes.
    pub write_frac: f32,
    /// Spatial run length in 64 B lines.
    pub run_len: u32,
    pub regions: Vec<Region>,
}

/// The low-bias 32-bit integer hash (the same rounds as the Pallas kernel).
#[inline]
pub fn lowbias32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7feb_352d);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846c_a68b);
    x ^= x >> 16;
    x
}

/// Precomputed per-region geometry for a concrete footprint.
#[derive(Debug, Clone)]
struct RegionGeom {
    cum_weight: f32,
    base_line: u64,
    lines: u64,
    runs: u64,
    /// Working-set runs per epoch (phased reuse).
    wruns: u64,
    alpha: f32,
    seq: bool,
}

/// Stateless trace generator over a fixed footprint.
#[derive(Debug, Clone)]
pub struct TraceGen {
    profile: Profile,
    footprint: u64,
    /// Per-stream slice span (== footprint when shared).
    slice_bytes: u64,
    regions: Vec<RegionGeom>,
    run_len: u64,
    cores: u32,
    /// Runs per working-set epoch.
    epoch_runs: u32,
}

impl TraceGen {
    pub fn new(profile: Profile, os_capacity: u64, cores: u32) -> Self {
        let footprint =
            ((os_capacity as f64 * profile.footprint_frac) as u64).max(1 << 20) & !(LINE_BYTES - 1);
        let slice_bytes = if profile.private_per_core {
            (footprint / cores as u64) & !(LINE_BYTES - 1)
        } else {
            footprint
        };
        let slice_lines = slice_bytes / LINE_BYTES;
        let run_len = profile.run_len.max(1) as u64;

        let total_w: f32 = profile.regions.iter().map(|r| r.weight).sum();
        let mut regions = Vec::with_capacity(profile.regions.len());
        let mut cum_w = 0.0f32;
        let mut base = 0u64;
        let total_frac: f64 = profile.regions.iter().map(|r| r.frac).sum();
        for r in &profile.regions {
            cum_w += r.weight / total_w;
            let lines = ((slice_lines as f64 * r.frac / total_frac) as u64).max(run_len);
            let runs = (lines / run_len).max(1);
            let wruns = ((runs as f64 * r.working as f64) as u64).clamp(1, runs);
            regions.push(RegionGeom {
                cum_weight: cum_w,
                base_line: base,
                lines,
                runs,
                wruns,
                alpha: if r.theta < 1.0 { 1.0 / (1.0 - r.theta) } else { 64.0 },
                seq: r.seq,
            });
            base += lines;
        }

        // Epoch length: ~8x the largest working set, so each epoch's set
        // is re-referenced several times before it shifts.
        let max_w = regions.iter().filter(|g| !g.seq).map(|g| g.wruns).max().unwrap_or(1);
        let epoch_runs = (8 * max_w).max(1) as u32;

        TraceGen { profile, footprint, slice_bytes, regions, run_len, cores, epoch_runs }
    }

    pub fn footprint(&self) -> u64 {
        self.footprint
    }

    /// Export the precomputed geometry in the AOT artifact's wire format,
    /// plus per-stream slice bases (in 64 B lines) for `streams`.
    pub fn to_region_tables(
        &self,
        streams: &[u32],
    ) -> (crate::runtime::RegionTables, Vec<u32>) {
        use crate::runtime::{RegionTables, MAX_REGIONS};
        let mut t = RegionTables::default();
        // Pad unused slots with cum_w = 1.0 and 1-run dummy geometry.
        for i in 0..MAX_REGIONS {
            if let Some(g) = self.regions.get(i) {
                t.cum_w[i] = g.cum_weight;
                t.base_line[i] = g.base_line as u32;
                t.lines[i] = g.lines as u32;
                t.runs[i] = g.runs as u32;
                t.wruns[i] = g.wruns as u32;
                t.alpha[i] = g.alpha;
                t.seq[i] = g.seq as u32;
            } else {
                t.cum_w[i] = 1.0;
                t.lines[i] = self.run_len as u32;
                t.runs[i] = 1;
                t.wruns[i] = 1;
                t.alpha[i] = 1.0;
            }
        }
        t.params = [
            self.run_len as u32,
            (self.profile.write_frac * 65536.0) as u32,
            (2 * self.profile.avg_gap_instrs).max(1),
            self.regions.len() as u32,
            self.epoch_runs,
            0,
        ];
        let slice_lines = (self.slice_bytes / LINE_BYTES) as u32;
        let bases = streams
            .iter()
            .map(|&s| {
                if self.profile.private_per_core {
                    (s % self.cores) * slice_lines
                } else {
                    0
                }
            })
            .collect();
        (t, bases)
    }

    /// The pure function: access for `(stream, step)`. Mirrors the Pallas
    /// kernel exactly (integer ops + one `powf`).
    pub fn gen(&self, stream: u32, step: u32) -> MemAccess {
        let run_id = step / self.run_len as u32;
        let pos = (step % self.run_len as u32) as u64;
        let stream_key = lowbias32(stream.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        let h1 = lowbias32(stream_key ^ lowbias32(run_id));
        let h2 = lowbias32(h1 ^ 0x9E37_79B9);
        let h3 = lowbias32(h2 ^ 0x85EB_CA6B);

        // Region pick by cumulative weight.
        let u_r = h1 as f32 / 4294967296.0;
        let mut ri = self.regions.len() - 1;
        for (i, g) in self.regions.iter().enumerate() {
            if u_r < g.cum_weight {
                ri = i;
                break;
            }
        }
        let g = &self.regions[ri];

        let line = if g.seq {
            // Streaming sweep: consecutive runs are adjacent (per stream).
            ((run_id as u64).wrapping_mul(self.run_len).wrapping_add(pos)) % g.lines
        } else {
            // Zipf (continuous pareto) rank over the epoch's *working
            // set*, then a stateless hash scatter over the whole region.
            // The epoch salt shifts the working set periodically (phased
            // reuse, like graph-iteration sweeps); the hash spreads hot
            // runs across the address space (collisions merely merge
            // popularity mass and preserve the skew).
            let u = (h2 >> 8) as f32 / 16777216.0;
            let wrank = (g.wruns as f32 * u.powf(g.alpha)) as u32;
            let epoch = run_id / self.epoch_runs;
            let salt = lowbias32(epoch ^ (ri as u32).wrapping_mul(0x0100_0193) ^ 0x5EED_5EED);
            let scattered = lowbias32(wrank ^ salt) as u64 % g.runs;
            (scattered * self.run_len + pos) % g.lines
        };

        let slice_base = if self.profile.private_per_core {
            stream as u64 % self.cores as u64 * self.slice_bytes
        } else {
            0
        };
        let addr: PhysAddr = slice_base + (g.base_line + line) * LINE_BYTES;

        // Integer threshold (not an f32 compare) so the AOT kernel matches
        // bit-exactly.
        let is_write = (h3 & 0xFFFF) < (self.profile.write_frac * 65536.0) as u32;
        let gap_mod = (2 * self.profile.avg_gap_instrs).max(1);
        let gap = (h3 >> 16) % gap_mod;
        let kind = if is_write {
            crate::types::AccessKind::Write
        } else {
            crate::types::AccessKind::Read
        };
        MemAccess { addr, kind, gap_instrs: gap }
    }
}

/// [`Workload`] adapter: per-core step counters over a [`TraceGen`].
pub struct SynthWorkload {
    gen: TraceGen,
    steps: Vec<u32>,
    seed: u32,
}

impl SynthWorkload {
    pub fn new(gen: TraceGen, cores: u32, seed: u32) -> Self {
        SynthWorkload { gen, steps: vec![0; cores as usize], seed }
    }

    pub fn trace_gen(&self) -> &TraceGen {
        &self.gen
    }
}

impl Workload for SynthWorkload {
    fn next(&mut self, core: usize) -> MemAccess {
        let step = self.steps[core];
        self.steps[core] = step.wrapping_add(1);
        self.gen.gen(core as u32 ^ self.seed, step)
    }

    fn next_batch(&mut self, core: usize, out: &mut [MemAccess]) {
        // Monomorphic inner loop over the pure generator: one virtual
        // dispatch per batch, bit-identical to out.len() `next` calls.
        let stream = core as u32 ^ self.seed;
        let mut step = self.steps[core];
        for slot in out.iter_mut() {
            *slot = self.gen.gen(stream, step);
            step = step.wrapping_add(1);
        }
        self.steps[core] = step;
    }

    fn name(&self) -> &str {
        self.gen.profile.name
    }

    fn footprint_bytes(&self) -> u64 {
        self.gen.footprint
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> Profile {
        Profile {
            name: "test",
            footprint_frac: 0.5,
            private_per_core: false,
            avg_gap_instrs: 20,
            write_frac: 0.3,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.5, theta: 0.0, working: 1.0, seq: true },
                Region { weight: 1.0, frac: 0.5, theta: 0.9, working: 1.0, seq: false },
            ],
        }
    }

    #[test]
    fn deterministic_and_stateless() {
        let g = TraceGen::new(profile(), 64 << 20, 4);
        let a = g.gen(3, 100);
        let b = g.gen(3, 100);
        assert_eq!(a, b);
        assert_ne!(g.gen(3, 101), a);
        // Different streams diverge (shared seq regions may collide on the
        // address, but the hash-derived fields differ).
        let other = g.gen(4, 100);
        assert!(other != a || g.gen(4, 101) != g.gen(3, 101));
    }

    #[test]
    fn addresses_within_footprint() {
        let g = TraceGen::new(profile(), 64 << 20, 4);
        for s in 0..4 {
            for t in 0..5000 {
                let a = g.gen(s, t);
                assert!(a.addr < g.footprint());
                assert_eq!(a.addr % LINE_BYTES, 0);
            }
        }
    }

    #[test]
    fn write_fraction_approximates_profile() {
        let g = TraceGen::new(profile(), 64 << 20, 4);
        let n = 20_000;
        let writes = (0..n).filter(|&t| g.gen(0, t).kind.is_write()).count();
        let frac = writes as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.02, "write frac {frac}");
    }

    #[test]
    fn gap_mean_approximates_profile() {
        let g = TraceGen::new(profile(), 64 << 20, 4);
        let n = 20_000u32;
        let total: u64 = (0..n).map(|t| g.gen(0, t).gap_instrs as u64).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 20.0).abs() < 1.5, "gap mean {mean}");
    }

    #[test]
    fn zipf_region_is_skewed() {
        // The hash scatter spreads hot runs across the region, so head
        // concentration shows up in the *frequency distribution*: the most
        // popular 10% of distinct lines must absorb most accesses.
        let mut p = profile();
        p.regions = vec![Region { weight: 1.0, frac: 1.0, theta: 0.9, working: 1.0, seq: false }];
        let g = TraceGen::new(p, 16 << 20, 1);
        let n = 50_000u32;
        let mut counts = std::collections::HashMap::new();
        for t in 0..n {
            *counts.entry(g.gen(0, t).addr).or_insert(0u32) += 1;
        }
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let top: u64 = freqs.iter().take((freqs.len() / 10).max(1)).map(|&c| c as u64).sum();
        let frac = top as f64 / n as f64;
        assert!(frac > 0.5, "zipf 0.9 head too cold: {frac}");
    }

    #[test]
    fn working_set_shifts_across_epochs() {
        // With a small working set, addresses inside one epoch repeat
        // heavily; across epochs the sets differ.
        let mut p = profile();
        p.regions =
            vec![Region { weight: 1.0, frac: 1.0, theta: 0.5, working: 0.01, seq: false }];
        p.run_len = 1;
        let g = TraceGen::new(p, 64 << 20, 1);
        let epoch_steps = g.epoch_runs; // run_len = 1
        let set_a: std::collections::HashSet<u64> =
            (0..epoch_steps / 2).map(|t| g.gen(0, t).addr).collect();
        let set_b: std::collections::HashSet<u64> = (8 * epoch_steps..8 * epoch_steps + epoch_steps / 2)
            .map(|t| g.gen(0, t).addr)
            .collect();
        let inter = set_a.intersection(&set_b).count();
        assert!(
            (inter as f64) < 0.2 * set_a.len() as f64,
            "epochs should shift the working set: {inter} / {}",
            set_a.len()
        );
        // And within an epoch the set is small relative to the sample.
        assert!(set_a.len() < (epoch_steps / 2) as usize);
    }

    #[test]
    fn sequential_region_sweeps() {
        let mut p = profile();
        p.regions = vec![Region { weight: 1.0, frac: 1.0, theta: 0.0, working: 1.0, seq: true }];
        p.run_len = 1;
        let g = TraceGen::new(p, 16 << 20, 1);
        let a0 = g.gen(0, 0).addr;
        let a1 = g.gen(0, 1).addr;
        let a2 = g.gen(0, 2).addr;
        assert_eq!(a1 - a0, LINE_BYTES);
        assert_eq!(a2 - a1, LINE_BYTES);
    }

    #[test]
    fn private_slices_are_disjoint() {
        let mut p = profile();
        p.private_per_core = true;
        let g = TraceGen::new(p, 64 << 20, 4);
        let slice = g.slice_bytes;
        for s in 0..4u32 {
            for t in 0..2000 {
                let a = g.gen(s, t);
                assert_eq!(a.addr / slice, s as u64, "stream {s} leaked its slice");
            }
        }
    }

    #[test]
    fn lowbias32_reference_values() {
        // Pinned values so the Pallas kernel can assert the same constants.
        assert_eq!(lowbias32(0), 0);
        assert_eq!(lowbias32(1), 1753845952);
        assert_eq!(lowbias32(0xDEADBEEF), 3861431939);
    }
}

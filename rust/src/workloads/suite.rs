//! The calibrated workload suite: one [`Profile`] per paper workload.
//!
//! Parameters are calibrated to the qualitative properties the paper
//! reports or that are well known for these benchmarks:
//!
//! * **SPEC CPU 2017** (rate-16, private per-core slices): `mcf` is
//!   latency-bound pointer chasing with little spatial locality; `lbm`,
//!   `bwaves`, `roms` are streaming stencil codes with large write shares;
//!   `cactuBSSN` has very high spatial locality across a few big arrays
//!   (hence the paper's >75% iRT metadata saving); `omnetpp` is
//!   small-footprint, pointer-heavy; `xz` touches a big dictionary with
//!   moderate skew (the paper's high-footprint stress case).
//! * **GAP** (shared footprint, twitter-like skew): CSR scans (offsets /
//!   edges streamed sequentially) mixed with power-law-skewed random value
//!   accesses; `tc` is the most random, `pr` the most stream-heavy.
//! * **silo TPC-C / memcached YCSB**: B-tree/hash-bucket walks with
//!   zipf-0.99 key popularity; YCSB-A is 50% updates, YCSB-B 5%.

use super::synth::{Profile, Region, SynthWorkload, TraceGen};
use super::Workload;
use crate::config::{Mode, SystemConfig};
use crate::metadata::SetLayout;

/// Profile for `name`, or `None` if unknown.
pub fn profile(name: &str) -> Option<Profile> {
    let p = match name {
        // ---- SPEC CPU 2017 (rate-16) ----
        "503.bwaves_r" => Profile {
            name: "503.bwaves_r",
            footprint_frac: 0.55,
            private_per_core: true,
            avg_gap_instrs: 42,
            write_frac: 0.30,
            run_len: 16,
            regions: vec![
                Region { weight: 3.0, frac: 0.8, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 1.0, frac: 0.2, theta: 0.30, working: 0.200, seq: false },
            ],
        },
        "505.mcf_r" => Profile {
            name: "505.mcf_r",
            footprint_frac: 0.45,
            private_per_core: true,
            avg_gap_instrs: 15,
            write_frac: 0.22,
            run_len: 2,
            regions: vec![
                Region { weight: 4.0, frac: 0.9, theta: 0.30, working: 0.070, seq: false },
                Region { weight: 1.0, frac: 0.1, theta: 0.00, working: 1.000, seq: true },
            ],
        },
        "507.cactuBSSN_r" => Profile {
            name: "507.cactuBSSN_r",
            footprint_frac: 0.40,
            private_per_core: true,
            avg_gap_instrs: 33,
            write_frac: 0.35,
            run_len: 32,
            regions: vec![
                Region { weight: 5.0, frac: 0.9, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 1.0, frac: 0.1, theta: 0.30, working: 0.200, seq: false },
            ],
        },
        "519.lbm_r" => Profile {
            name: "519.lbm_r",
            footprint_frac: 0.30,
            private_per_core: true,
            avg_gap_instrs: 18,
            write_frac: 0.45,
            run_len: 16,
            regions: vec![
                Region { weight: 1.0, frac: 1.0, theta: 0.00, working: 1.000, seq: true },
            ],
        },
        "520.omnetpp_r" => Profile {
            name: "520.omnetpp_r",
            footprint_frac: 0.12,
            private_per_core: true,
            avg_gap_instrs: 24,
            write_frac: 0.25,
            run_len: 4,
            regions: vec![
                Region { weight: 3.0, frac: 0.7, theta: 0.30, working: 0.110, seq: false },
                Region { weight: 1.0, frac: 0.3, theta: 0.00, working: 1.000, seq: true },
            ],
        },
        "554.roms_r" => Profile {
            name: "554.roms_r",
            footprint_frac: 0.50,
            private_per_core: true,
            avg_gap_instrs: 36,
            write_frac: 0.32,
            run_len: 16,
            regions: vec![
                Region { weight: 2.0, frac: 0.75, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 1.0, frac: 0.25, theta: 0.30, working: 0.200, seq: false },
            ],
        },
        "557.xz_r" => Profile {
            name: "557.xz_r",
            footprint_frac: 0.70,
            private_per_core: true,
            avg_gap_instrs: 21,
            write_frac: 0.28,
            run_len: 4,
            regions: vec![
                Region { weight: 3.0, frac: 0.85, theta: 0.30, working: 0.069, seq: false },
                Region { weight: 1.0, frac: 0.15, theta: 0.00, working: 1.000, seq: true },
            ],
        },

        "549.fotonik3d_r" => Profile {
            name: "549.fotonik3d_r",
            footprint_frac: 0.45,
            private_per_core: true,
            avg_gap_instrs: 30,
            write_frac: 0.35,
            run_len: 16,
            regions: vec![
                Region { weight: 4.0, frac: 0.85, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 1.0, frac: 0.15, theta: 0.30, working: 0.200, seq: false },
            ],
        },
        "523.xalancbmk_r" => Profile {
            name: "523.xalancbmk_r",
            footprint_frac: 0.10,
            private_per_core: true,
            avg_gap_instrs: 20,
            write_frac: 0.20,
            run_len: 2,
            regions: vec![
                Region { weight: 4.0, frac: 0.8, theta: 0.40, working: 0.200, seq: false },
                Region { weight: 1.0, frac: 0.2, theta: 0.00, working: 1.000, seq: true },
            ],
        },

        // ---- GAP (shared, twitter-like) ----
        "gap_pr" => Profile {
            name: "gap_pr",
            footprint_frac: 0.85,
            private_per_core: false,
            avg_gap_instrs: 15,
            write_frac: 0.18,
            run_len: 4,
            regions: vec![
                Region { weight: 2.0, frac: 0.75, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 3.0, frac: 0.25, theta: 0.30, working: 0.139, seq: false },
            ],
        },
        "gap_bfs" => Profile {
            name: "gap_bfs",
            footprint_frac: 0.80,
            private_per_core: false,
            avg_gap_instrs: 18,
            write_frac: 0.15,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.6, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 2.0, frac: 0.4, theta: 0.30, working: 0.075, seq: false },
            ],
        },
        "gap_sssp" => Profile {
            name: "gap_sssp",
            footprint_frac: 0.90,
            private_per_core: false,
            avg_gap_instrs: 16,
            write_frac: 0.20,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.55, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 2.0, frac: 0.45, theta: 0.30, working: 0.068, seq: false },
            ],
        },
        "gap_cc" => Profile {
            name: "gap_cc",
            footprint_frac: 0.80,
            private_per_core: false,
            avg_gap_instrs: 18,
            write_frac: 0.25,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.5, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 2.0, frac: 0.5, theta: 0.30, working: 0.064, seq: false },
            ],
        },
        "gap_tc" => Profile {
            name: "gap_tc",
            footprint_frac: 0.75,
            private_per_core: false,
            avg_gap_instrs: 12,
            write_frac: 0.05,
            run_len: 2,
            regions: vec![
                Region { weight: 1.0, frac: 0.3, theta: 0.00, working: 1.000, seq: true },
                Region { weight: 4.0, frac: 0.7, theta: 0.30, working: 0.090, seq: false },
            ],
        },

        // ---- server workloads ----
        "silo_tpcc" => Profile {
            name: "silo_tpcc",
            footprint_frac: 0.65,
            private_per_core: false,
            avg_gap_instrs: 27,
            write_frac: 0.35,
            run_len: 4,
            regions: vec![
                Region { weight: 3.0, frac: 0.1, theta: 0.30, working: 0.180, seq: false },
                Region { weight: 2.0, frac: 0.8, theta: 0.30, working: 0.038, seq: false },
                Region { weight: 1.0, frac: 0.1, theta: 0.00, working: 1.000, seq: true },
            ],
        },
        "ycsb_a" => Profile {
            name: "ycsb_a",
            footprint_frac: 0.70,
            private_per_core: false,
            avg_gap_instrs: 22,
            write_frac: 0.50,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.05, theta: 0.40, working: 0.120, seq: false },
                Region { weight: 2.0, frac: 0.95, theta: 0.30, working: 0.044, seq: false },
            ],
        },
        "ycsb_b" => Profile {
            name: "ycsb_b",
            footprint_frac: 0.70,
            private_per_core: false,
            avg_gap_instrs: 22,
            write_frac: 0.05,
            run_len: 4,
            regions: vec![
                Region { weight: 1.0, frac: 0.05, theta: 0.40, working: 0.120, seq: false },
                Region { weight: 2.0, frac: 0.95, theta: 0.30, working: 0.044, seq: false },
            ],
        },
        _ => return None,
    };
    Some(p)
}

/// OS-visible capacity under a config (flat mode excludes the metadata
/// region; cache mode exposes only the slow tier).
pub fn os_capacity(cfg: &SystemConfig) -> u64 {
    let layout = SetLayout::for_config(&cfg.hybrid, false);
    match cfg.hybrid.mode {
        Mode::Cache => cfg.hybrid.slow_bytes,
        Mode::Flat => {
            (layout.data_ways * layout.num_sets as u64) * cfg.hybrid.block_bytes as u64
                + cfg.hybrid.slow_bytes
        }
    }
}

/// Build a suite workload for a configuration.
pub fn build(name: &str, cfg: &SystemConfig) -> Option<Box<dyn Workload>> {
    let p = profile(name)?;
    let cores = cfg.workload.cores;
    let gen = TraceGen::new(p, os_capacity(cfg), cores);
    Some(Box::new(SynthWorkload::new(gen, cores, cfg.workload.seed as u32)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    #[test]
    fn profiles_have_sane_parameters() {
        for name in super::super::SUITE {
            let p = profile(name).unwrap();
            assert!(p.footprint_frac > 0.0 && p.footprint_frac <= 1.0, "{name}");
            assert!(p.write_frac >= 0.0 && p.write_frac <= 1.0, "{name}");
            assert!(!p.regions.is_empty(), "{name}");
            for r in &p.regions {
                assert!(r.theta >= 0.0 && r.theta < 1.0, "{name}");
                assert!(r.frac > 0.0, "{name}");
            }
        }
    }

    #[test]
    fn spec_is_private_gap_is_shared() {
        assert!(profile("505.mcf_r").unwrap().private_per_core);
        assert!(!profile("gap_pr").unwrap().private_per_core);
        assert!(!profile("ycsb_a").unwrap().private_per_core);
    }

    #[test]
    fn flat_capacity_excludes_metadata_region() {
        let cache = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        let flat = presets::hbm3_ddr5(DesignPoint::TrimmaFlat);
        assert_eq!(os_capacity(&cache), cache.hybrid.slow_bytes);
        let flat_cap = os_capacity(&flat);
        assert!(flat_cap > flat.hybrid.slow_bytes);
        assert!(flat_cap < flat.hybrid.slow_bytes + flat.hybrid.fast_bytes);
    }

    #[test]
    fn ycsb_a_hotter_writes_than_b() {
        assert!(profile("ycsb_a").unwrap().write_frac > profile("ycsb_b").unwrap().write_frac);
    }
}

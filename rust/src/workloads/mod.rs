//! Workload generators: calibrated synthetic stand-ins for the paper's
//! evaluation suite (SPEC CPU 2017 memory-intensive rate-16, GAP, silo
//! TPC-C, memcached YCSB-A/B) — see DESIGN.md §4 for the substitution
//! rationale.
//!
//! Every workload is parameterized by a [`synth::Profile`]: memory
//! footprint, per-region access mix (streaming scans vs. zipf-skewed random
//! access), spatial run length, write fraction, and memory intensity.
//! The generator itself ([`synth::TraceGen`]) is *stateless per
//! `(stream, step)`* — a counter-based hash pipeline — which is exactly
//! what lets the same algorithm run as the AOT-compiled Pallas kernel
//! (python/compile/kernels/trace_gen.py) loaded through
//! [`crate::runtime`]; `pjrt::PjrtWorkload` (behind the `pjrt` feature)
//! wraps that artifact behind the same [`Workload`] trait.

pub mod adversarial;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod suite;
pub mod synth;
pub mod tenants;

use crate::types::MemAccess;

/// A multi-stream workload: one access stream per simulated core.
/// (Not `Send`: the PJRT-backed implementation holds client handles;
/// parallel sweeps construct workloads inside their worker threads.)
///
/// Streams are **per-core pure**: `core`'s sequence of accesses depends
/// only on how many accesses `core` has drawn so far, never on what other
/// cores drew in between. Every implementation in the crate satisfies
/// this by construction (counter-based generators), and the execution
/// core's batched, look-ahead trace generation relies on it.
pub trait Workload {
    /// Generate the next access of `core`'s stream.
    fn next(&mut self, core: usize) -> MemAccess;

    /// Generate the next `out.len()` accesses of `core`'s stream into
    /// `out` — semantically exactly `out.len()` successive
    /// [`Workload::next`] calls (the default implementation is that
    /// loop). Generators with a monomorphic inner loop (the synthetic
    /// suite, the adversarial scenarios) override it so the virtual
    /// dispatch is paid once per batch: this is the trace-generation
    /// stage of the pipelined front end
    /// ([`crate::sim::ExecCore`]).
    fn next_batch(&mut self, core: usize, out: &mut [MemAccess]) {
        for slot in out.iter_mut() {
            *slot = self.next(core);
        }
    }

    /// Human-readable name (matches the paper's workload labels).
    fn name(&self) -> &str;

    /// Bytes of OS-visible memory the workload touches.
    fn footprint_bytes(&self) -> u64;
}

/// All workload names in the evaluation suite, in the paper's order:
/// SPEC CPU 2017 (rate-16) first, then GAP, then the server workloads.
pub const SUITE: &[&str] = &[
    "503.bwaves_r",
    "505.mcf_r",
    "507.cactuBSSN_r",
    "519.lbm_r",
    "520.omnetpp_r",
    "523.xalancbmk_r",
    "549.fotonik3d_r",
    "554.roms_r",
    "557.xz_r",
    "gap_pr",
    "gap_bfs",
    "gap_sssp",
    "gap_cc",
    "gap_tc",
    "silo_tpcc",
    "ycsb_a",
    "ycsb_b",
];

/// Every buildable workload name: the calibrated suite ([`SUITE`]) first,
/// then the adversarial scenarios ([`adversarial::ADVERSARIAL`]).
pub fn all_names() -> impl Iterator<Item = &'static str> {
    SUITE.iter().chain(adversarial::ADVERSARIAL.iter()).copied()
}

/// The error returned by [`by_name`] for a name that is neither in the
/// calibrated suite nor an adversarial scenario. Its `Display` output
/// lists every valid name, so surfacing it verbatim (as the CLI does) is
/// self-documenting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownWorkload {
    /// The name that failed to resolve.
    pub name: String,
}

impl UnknownWorkload {
    /// Wrap the offending name.
    pub fn new(name: impl Into<String>) -> Self {
        UnknownWorkload { name: name.into() }
    }
}

impl std::fmt::Display for UnknownWorkload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown workload '{}'; valid names: {}, or trace:<file> to replay a recorded trace",
            self.name,
            all_names().collect::<Vec<_>>().join(", ")
        )
    }
}

impl std::error::Error for UnknownWorkload {}

/// Build a workload by name for a system configuration (footprints scale
/// with the configured capacities). Covers the calibrated suite, the
/// `adv_*` adversarial scenarios ([`adversarial::ADVERSARIAL`]), and
/// `trace:<file>` — a recorded trace replayed through
/// [`TraceWorkload`](crate::trace::TraceWorkload) (the config's core
/// count and access budgets must match the trace header; the `trimma
/// replay` subcommand adopts them automatically). Unknown names return an
/// [`UnknownWorkload`] error listing the valid ones; a failing trace open
/// embeds the typed [`TraceError`](crate::trace::TraceError)'s message in
/// the same error shape, so CLI surfacing stays uniform.
pub fn by_name(
    name: &str,
    cfg: &crate::config::SystemConfig,
) -> Result<Box<dyn Workload>, UnknownWorkload> {
    if let Some(path) = name.strip_prefix("trace:") {
        return match crate::trace::TraceWorkload::open(std::path::Path::new(path), cfg) {
            Ok(wl) => Ok(Box::new(wl)),
            Err(e) => Err(UnknownWorkload::new(format!("{name} ({e})"))),
        };
    }
    suite::build(name, cfg)
        .or_else(|| adversarial::build(name, cfg))
        .ok_or_else(|| UnknownWorkload::new(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    #[test]
    fn suite_is_complete() {
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        for name in SUITE {
            let wl = by_name(name, &cfg).unwrap_or_else(|e| panic!("missing {name}: {e}"));
            assert_eq!(wl.name(), *name);
            assert!(wl.footprint_bytes() > 0);
        }
        let err = by_name("nonexistent", &cfg).unwrap_err();
        assert_eq!(err.name, "nonexistent");
        let msg = err.to_string();
        for name in all_names() {
            assert!(msg.contains(name), "error must list '{name}'");
        }
    }

    #[test]
    fn registry_is_complete_for_every_cli_reachable_scenario() {
        // Every name a CLI flag can request — the calibrated suite AND the
        // adversarial scenarios (adv_metadata_bloat regressed out of an
        // earlier registry test's coverage; never again) — round-trips
        // through by_name, and the exit-2 error message lists all of them
        // plus the trace:<file> entry.
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        let names: Vec<&str> = all_names().collect();
        assert!(names.contains(&"adv_metadata_bloat"));
        assert_eq!(names.len(), SUITE.len() + adversarial::ADVERSARIAL.len());
        for name in &names {
            let wl = by_name(name, &cfg).unwrap_or_else(|e| panic!("missing {name}: {e}"));
            assert_eq!(wl.name(), *name, "by_name round-trip");
            assert!(wl.footprint_bytes() > 0, "{name}");
        }
        let msg = by_name("nonexistent", &cfg).unwrap_err().to_string();
        for name in &names {
            assert!(msg.contains(name), "error must list '{name}'");
        }
        assert!(msg.contains("trace:<file>"), "error must mention trace replay: {msg}");
    }

    #[test]
    fn trace_prefix_builds_a_replay_workload() {
        let path = std::env::temp_dir()
            .join(format!("trimma-registry-{}.trimtrace", std::process::id()));
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 2;
        cfg.workload.accesses_per_core = 600;
        cfg.workload.warmup_per_core = 200;
        crate::engine::EngineBuilder::from_config(cfg.clone())
            .workload("adv_drift")
            .run_recorded(&path)
            .unwrap();
        let spec = format!("trace:{}", path.display());
        let mut wl = by_name(&spec, &cfg).unwrap();
        assert_eq!(wl.name(), "adv_drift", "replay reports the recorded label");
        let a = wl.next(0);
        assert_eq!(a, by_name("adv_drift", &cfg).unwrap().next(0), "replays the stream");
        std::fs::remove_file(&path).unwrap();
        // A failing open keeps the typed detail in the registry error.
        let err = by_name(&spec, &cfg).unwrap_err();
        assert!(err.name.contains("trace I/O error"), "{err}");
    }

    #[test]
    fn next_batch_matches_per_access_generation() {
        // Batched and per-access generation must produce identical
        // streams, per core, across batch boundaries and regardless of
        // how cores interleave (the per-core-purity contract).
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        for name in ["gap_pr", "ycsb_a", "505.mcf_r", "adv_set_thrash", "adv_pointer_chase"] {
            let mut plain = by_name(name, &cfg).unwrap();
            let mut batched = by_name(name, &cfg).unwrap();
            for round in 0..4 {
                for core in [0usize, 2, 1] {
                    let mut batch = vec![MemAccess::read(0, 0); 37];
                    batched.next_batch(core, &mut batch);
                    for (i, got) in batch.iter().enumerate() {
                        assert_eq!(
                            plain.next(core),
                            *got,
                            "{name} core {core} round {round} i {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn accesses_stay_in_footprint() {
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        for name in ["505.mcf_r", "gap_pr", "ycsb_a"] {
            let mut wl = by_name(name, &cfg).unwrap();
            let fp = wl.footprint_bytes();
            for core in 0..4 {
                for _ in 0..500 {
                    let a = wl.next(core);
                    assert!(a.addr < fp, "{name}: {:#x} >= {fp:#x}", a.addr);
                }
            }
        }
    }
}

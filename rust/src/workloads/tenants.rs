//! The multi-tenant composite workload: N independent tenant sessions
//! interleaved into one access stream (DESIGN.md §12).
//!
//! Each tenant owns a private **address slab** — a contiguous,
//! page-aligned carve-out of the OS-visible space — and an independent
//! per-tenant workload drawn from a named mix distribution
//! ([`crate::config::MixProfile`]) with its own derived RNG seed. The
//! interleave schedule is a pure function of `(core, step, seed)`:
//! scenario weights are piecewise-constant over *phases*
//! (`phase = step / phase_len`), and each step hashes into the phase's
//! cumulative weight vector to pick the issuing tenant. Because both the
//! schedule and every per-tenant generator are counter-based, the
//! composite stream keeps the [`Workload`] per-core-purity contract and
//! inherits all of the execution core's sharding/pipelining determinism.

use super::synth::lowbias32;
use super::{by_name, UnknownWorkload, Workload};
use crate::config::{MixProfile, SystemConfig, TenantMixConfig, TenantScenario};
use crate::types::MemAccess;

/// Latency-sensitive serving mix.
const SERVING: &[&str] = &["ycsb_a", "ycsb_b", "silo_tpcc", "520.omnetpp_r"];
/// Scan/graph-heavy analytics mix.
const ANALYTICS: &[&str] = &["gap_pr", "gap_bfs", "gap_cc", "554.roms_r"];
/// Broad blend of both.
const GENERAL: &[&str] = &[
    "ycsb_a",
    "ycsb_b",
    "silo_tpcc",
    "gap_pr",
    "gap_bfs",
    "505.mcf_r",
    "520.omnetpp_r",
    "554.roms_r",
];

/// The workload-name table a mix profile draws from.
pub fn mix_table(mix: MixProfile) -> &'static [&'static str] {
    match mix {
        MixProfile::Serving => SERVING,
        MixProfile::Analytics => ANALYTICS,
        MixProfile::General => GENERAL,
    }
}

/// Per-tenant address slab size: the OS-visible capacity divided evenly
/// across tenants, rounded down to a 4 kB page multiple (so page-level
/// occupancy attribution is exact), at least one page.
pub fn slab_bytes(os_capacity: u64, tenants: u32) -> u64 {
    ((os_capacity / tenants.max(1) as u64) / 4096 * 4096).max(4096)
}

/// Owning tenant of an address under the slab carve-out (the inverse of
/// the composite stream's address fold; addresses past the last slab
/// belong to the last tenant).
#[inline]
pub fn tenant_of(addr: u64, slab: u64, tenants: u32) -> u32 {
    ((addr / slab) as u32).min(tenants.saturating_sub(1))
}

/// The workload name tenant `tenant` draws under `mix` (a pure hash of
/// the seed and tenant id). The noisy-neighbor scenario pins tenant 0 to
/// the `adv_set_thrash` adversary instead.
pub fn tenant_workload_name(
    mix: MixProfile,
    scenario: TenantScenario,
    seed: u32,
    tenant: u32,
) -> &'static str {
    if scenario == TenantScenario::NoisyNeighbor && tenant == 0 {
        return "adv_set_thrash";
    }
    let table = mix_table(mix);
    table[lowbias32(seed ^ lowbias32(tenant.wrapping_add(0x5EED))) as usize % table.len()]
}

/// Schedule weight of `tenant` during `phase` — pure in all arguments,
/// so churn and flash-crowd activity patterns replay identically every
/// run and on every shard count.
pub fn tenant_weight(
    scenario: TenantScenario,
    tenants: u32,
    tenant: u32,
    phase: u64,
    seed: u32,
) -> u32 {
    let ph = lowbias32((phase as u32) ^ ((phase >> 32) as u32).wrapping_add(seed));
    match scenario {
        TenantScenario::Steady => 1,
        // Tenant 0 gets as much weight as all victims combined (~50%).
        TenantScenario::NoisyNeighbor => {
            if tenant == 0 {
                (tenants - 1).max(1)
            } else {
                1
            }
        }
        // Tenant 0 is the always-active anchor; every other tenant is
        // present in ~3/4 of the phases (arrives/departs at boundaries).
        TenantScenario::Churn => {
            if tenant == 0 || lowbias32(ph ^ lowbias32(tenant)) % 4 < 3 {
                1
            } else {
                0
            }
        }
        // The crowd tenant spikes to 8x everyone else combined during a
        // periodic 2-of-8-phase window.
        TenantScenario::FlashCrowd => {
            if tenant == tenants - 1 && (3..5).contains(&(phase % 8)) {
                8 * (tenants - 1).max(1)
            } else {
                1
            }
        }
    }
}

/// One core's cached schedule state: its composite step counter plus the
/// cumulative weight vector of the phase it is currently in (recomputed
/// purely whenever the core crosses a phase boundary).
struct CoreSched {
    step: u64,
    phase: u64,
    cum: Vec<u32>,
    total: u32,
}

/// The composite multi-tenant workload (see the module docs).
///
/// Each drawn access comes from the scheduled tenant's own generator and
/// is folded into that tenant's slab
/// (`addr = tenant * slab + inner % slab`), so tenants never alias each
/// other's pages and any observer can attribute an address back to its
/// tenant with [`tenant_of`].
pub struct TenantMixWorkload {
    tenants: Vec<Box<dyn Workload>>,
    names: Vec<String>,
    label: String,
    slab: u64,
    scenario: TenantScenario,
    phase_len: u64,
    num_tenants: u32,
    seed: u32,
    sched: Vec<CoreSched>,
}

impl TenantMixWorkload {
    /// Build the composite for `cfg.tenant_mix` (which must be enabled
    /// and validated). Tenant `t`'s generator gets an independent seed
    /// derived from the base seed and `t`.
    pub fn new(cfg: &SystemConfig) -> Result<TenantMixWorkload, UnknownWorkload> {
        let t = cfg.tenant_mix;
        let os_cap = super::suite::os_capacity(cfg);
        let slab = slab_bytes(os_cap, t.tenants);
        let seed = cfg.workload.seed as u32;
        let mut tenants = Vec::with_capacity(t.tenants as usize);
        let mut names = Vec::with_capacity(t.tenants as usize);
        for i in 0..t.tenants {
            let name = tenant_workload_name(t.mix, t.scenario, seed, i);
            let mut sub = cfg.clone();
            sub.workload.seed =
                cfg.workload.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            tenants.push(by_name(name, &sub)?);
            names.push(name.to_string());
        }
        let sched = (0..cfg.workload.cores)
            .map(|_| CoreSched { step: 0, phase: u64::MAX, cum: vec![0; t.tenants as usize], total: 0 })
            .collect();
        Ok(TenantMixWorkload {
            tenants,
            names,
            label: format!("tenants/{}x{}/{}", t.tenants, t.mix.label(), t.scenario.label()),
            slab,
            scenario: t.scenario,
            phase_len: t.phase_len as u64,
            num_tenants: t.tenants,
            seed,
            sched,
        })
    }

    /// Per-tenant workload names, indexed by tenant id.
    pub fn tenant_names(&self) -> &[String] {
        &self.names
    }

    /// The per-tenant address slab size, bytes.
    pub fn slab(&self) -> u64 {
        self.slab
    }

    /// The tenant `core`'s next access will be drawn from — pure in
    /// `(core, step)`, shared with [`Workload::next`].
    fn pick(&mut self, core: usize, step: u64) -> u32 {
        let phase = step / self.phase_len;
        let (scenario, n, seed) = (self.scenario, self.num_tenants, self.seed);
        let s = &mut self.sched[core];
        if s.phase != phase {
            let mut total = 0u32;
            for t in 0..n {
                total += tenant_weight(scenario, n, t, phase, seed);
                s.cum[t as usize] = total;
            }
            s.phase = phase;
            s.total = total;
        }
        let h = lowbias32(
            (step as u32) ^ lowbias32((core as u32).wrapping_add(seed)) ^ ((step >> 32) as u32),
        );
        let r = h % s.total;
        let mut t = 0u32;
        while s.cum[t as usize] <= r {
            t += 1;
        }
        t
    }
}

impl Workload for TenantMixWorkload {
    fn next(&mut self, core: usize) -> MemAccess {
        let step = self.sched[core].step;
        self.sched[core].step += 1;
        let t = self.pick(core, step);
        let mut acc = self.tenants[t as usize].next(core);
        acc.addr = t as u64 * self.slab + acc.addr % self.slab;
        acc
    }

    fn name(&self) -> &str {
        &self.label
    }

    fn footprint_bytes(&self) -> u64 {
        self.slab * self.num_tenants as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn cfg(tenants: u32, scenario: TenantScenario) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.workload.cores = 3;
        cfg = presets::with_tenants(cfg, tenants, scenario);
        cfg.tenant_mix.phase_len = 64;
        cfg
    }

    #[test]
    fn composite_stays_in_slabs_and_attributes_back() {
        let cfg = cfg(4, TenantScenario::Steady);
        let mut wl = TenantMixWorkload::new(&cfg).unwrap();
        let slab = wl.slab();
        assert_eq!(slab % 4096, 0);
        assert!(wl.footprint_bytes() <= crate::workloads::suite::os_capacity(&cfg));
        for core in 0..3 {
            for _ in 0..1000 {
                let a = wl.next(core);
                assert!(a.addr < wl.footprint_bytes());
                let t = tenant_of(a.addr, slab, 4);
                assert!(t < 4);
            }
        }
    }

    #[test]
    fn composite_is_per_core_pure_and_deterministic() {
        let cfg = cfg(8, TenantScenario::Churn);
        let mut a = TenantMixWorkload::new(&cfg).unwrap();
        let mut b = TenantMixWorkload::new(&cfg).unwrap();
        // Different core interleavings must replay identical per-core
        // streams (batched generation relies on this).
        let mut got_a = vec![Vec::new(); 3];
        let mut got_b = vec![Vec::new(); 3];
        for _ in 0..500 {
            for core in [0usize, 1, 2] {
                got_a[core].push(a.next(core));
            }
        }
        for _ in 0..500 {
            for core in [2usize, 0, 1] {
                got_b[core].push(b.next(core));
            }
        }
        assert_eq!(got_a, got_b);
    }

    #[test]
    fn noisy_neighbor_pins_the_adversary_with_half_the_schedule() {
        let cfg = cfg(8, TenantScenario::NoisyNeighbor);
        let mut wl = TenantMixWorkload::new(&cfg).unwrap();
        assert_eq!(wl.tenant_names()[0], "adv_set_thrash");
        let slab = wl.slab();
        let mut hits = 0u64;
        let n = 20_000u64;
        for _ in 0..n {
            if tenant_of(wl.next(0).addr, slab, 8) == 0 {
                hits += 1;
            }
        }
        let share = hits as f64 / n as f64;
        assert!((0.40..0.60).contains(&share), "noisy share = {share}");
    }

    #[test]
    fn churn_idles_tenants_but_never_the_anchor() {
        let seed = 0xD1CE;
        let mut saw_idle = false;
        for phase in 0..64u64 {
            let mut active = 0;
            for t in 0..8 {
                let w = tenant_weight(TenantScenario::Churn, 8, t, phase, seed);
                if t == 0 {
                    assert_eq!(w, 1, "anchor must always be active");
                }
                if w == 0 {
                    saw_idle = true;
                } else {
                    active += 1;
                }
            }
            assert!(active >= 1);
        }
        assert!(saw_idle, "churn never idled any tenant across 64 phases");
    }

    #[test]
    fn flash_crowd_spikes_periodically() {
        let seed = 7;
        let w_quiet = tenant_weight(TenantScenario::FlashCrowd, 8, 7, 0, seed);
        let w_spike = tenant_weight(TenantScenario::FlashCrowd, 8, 7, 3, seed);
        assert_eq!(w_quiet, 1);
        assert_eq!(w_spike, 8 * 7);
        // Non-crowd tenants never spike.
        assert_eq!(tenant_weight(TenantScenario::FlashCrowd, 8, 2, 3, seed), 1);
    }

    #[test]
    fn mix_tables_only_name_buildable_workloads() {
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        for mix in MixProfile::ALL {
            for name in mix_table(*mix) {
                by_name(name, &cfg).unwrap_or_else(|e| panic!("{}: {e}", mix.label()));
            }
        }
    }
}

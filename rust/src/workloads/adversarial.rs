//! Adversarial scenario engine: deterministic, seedable access patterns
//! built to break the remap metadata path rather than to resemble any real
//! application. Each scenario targets one failure class of the iRT/iRC
//! machinery; all of them run green under every design point with the
//! [`crate::verify`] oracle enabled (rust/tests/verify_oracle.rs), and
//! their stat vectors are locked by the golden-snapshot harness
//! (rust/tests/golden.rs).
//!
//! | name                  | attack                                          |
//! |-----------------------|-------------------------------------------------|
//! | `adv_set_thrash`      | all accesses conflict on one hybrid set: more   |
//! |                       | distinct blocks than the set has ways, cycled,  |
//! |                       | so every fill evicts (and LLC sets alias too)   |
//! | `adv_migration_storm` | a hot region larger than the LLC is hammered,   |
//! |                       | then teleports every epoch — mass fills,        |
//! |                       | evictions, MEA swaps and swap restores          |
//! | `adv_identity_flip`   | two same-set block groups alternate phases, so  |
//! |                       | the same indices flip identity <-> non-identity |
//! |                       | continuously (iRT alloc/free churn, iRC         |
//! |                       | invalidation storms)                            |
//! | `adv_drift`           | a working-set window slides over the footprint, |
//! |                       | continuously retiring old mappings while        |
//! |                       | minting new ones                                |
//! | `adv_pointer_chase`   | dependent-chain hash walk over the whole        |
//! |                       | footprint: no spatial locality, maximal remap   |
//! |                       | cache pressure                                  |
//! | `adv_metadata_bloat`  | phase-changing hot regions that never return:   |
//! |                       | each phase mints fresh remap entries and        |
//! |                       | abandons the last phase's, so stale             |
//! |                       | non-identity mappings pile up (the decay        |
//! |                       | subsystem's target workload, DESIGN.md §11)     |
//! | `adv_fault_storm`     | a drifting hot region keeps every set full of   |
//! |                       | live remapped pairs while every 4th access      |
//! |                       | probes the whole footprint: maximal surface     |
//! |                       | for metadata-flip and transient-read injection  |
//! |                       | and the scrub/rebuild/quarantine recovery       |
//! |                       | paths (the fault subsystem's target workload,   |
//! |                       | DESIGN.md §14)                                  |
//!
//! Scenarios are pure functions of `(seed, core, step)` plus the config
//! geometry, so runs are bit-reproducible across thread counts and hosts.

use super::synth::lowbias32;
use super::Workload;
use crate::config::SystemConfig;
use crate::types::{AccessKind, MemAccess, PhysAddr};

/// 64 B cache-line size (the unit the CPU hierarchy works in).
const LINE: u64 = 64;

/// Scenario names, registration order.
pub const ADVERSARIAL: &[&str] = &[
    "adv_set_thrash",
    "adv_migration_storm",
    "adv_identity_flip",
    "adv_drift",
    "adv_pointer_chase",
    "adv_metadata_bloat",
    "adv_fault_storm",
];

/// Geometry every scenario derives its parameters from.
#[derive(Debug, Clone, Copy)]
struct Geom {
    /// Hybrid migration block size in bytes.
    block: u64,
    /// Stride (bytes) between consecutive blocks of one hybrid set.
    set_stride: u64,
    /// Fast-tier blocks per hybrid set (the associativity to overload).
    fast_per_set: u64,
    /// Total fast-tier blocks.
    fast_blocks: u64,
    /// OS-visible capacity in bytes.
    os_cap: u64,
    /// Shared LLC capacity in bytes (patterns must exceed it to reach the
    /// hybrid controller at all).
    llc_bytes: u64,
    seed: u32,
}

impl Geom {
    fn of(cfg: &SystemConfig) -> Geom {
        let h = &cfg.hybrid;
        Geom {
            block: h.block_bytes as u64,
            set_stride: h.num_sets as u64 * h.block_bytes as u64,
            fast_per_set: (h.fast_blocks() / h.num_sets as u64).max(1),
            fast_blocks: h.fast_blocks().max(1),
            os_cap: super::suite::os_capacity(cfg).max(1 << 20),
            llc_bytes: cfg.llc.size_bytes.max(1),
            seed: cfg.workload.seed as u32,
        }
    }
}

/// Per-access hash-derived read/write + core-gap fields, shared by all
/// scenarios so their mix knobs stay in one place.
#[inline]
fn mix(h: u32, write_milli: u32, gap_mod: u32) -> (AccessKind, u32) {
    let kind = if (h & 0x3FF) < write_milli { AccessKind::Write } else { AccessKind::Read };
    let gap = (h >> 10) % gap_mod.max(1);
    (kind, gap)
}

/// One scenario: a name, per-core step counters, and a pure address
/// function. Keeping the state down to counters is what makes scenarios
/// trivially deterministic.
struct Scenario {
    name: &'static str,
    geom: Geom,
    footprint: u64,
    steps: Vec<u32>,
    gen: fn(&Geom, u32, u32) -> u64,
    write_milli: u32,
    gap_mod: u32,
}

impl Scenario {
    /// The pure access function: scenario access for `(stream, step)`.
    #[inline]
    fn at(&self, stream: u32, step: u32) -> MemAccess {
        let addr: PhysAddr = (self.gen)(&self.geom, stream, step) % self.footprint;
        let h = lowbias32(lowbias32(stream.wrapping_mul(0x9E37_79B9) ^ step) ^ 0x5EED);
        let (kind, gap) = mix(h, self.write_milli, self.gap_mod);
        MemAccess { addr: addr & !(LINE - 1), kind, gap_instrs: gap }
    }
}

impl Workload for Scenario {
    fn next(&mut self, core: usize) -> MemAccess {
        let step = self.steps[core];
        self.steps[core] = step.wrapping_add(1);
        self.at((core as u32) ^ self.geom.seed, step)
    }

    fn next_batch(&mut self, core: usize, out: &mut [MemAccess]) {
        // Monomorphic inner loop over the pure access function: one
        // virtual dispatch per batch, identical to out.len() `next` calls.
        let stream = (core as u32) ^ self.geom.seed;
        let mut step = self.steps[core];
        for slot in out.iter_mut() {
            *slot = self.at(stream, step);
            step = step.wrapping_add(1);
        }
        self.steps[core] = step;
    }

    fn name(&self) -> &str {
        self.name
    }

    fn footprint_bytes(&self) -> u64 {
        self.footprint
    }
}

// ---------------- address functions ----------------

/// Set-conflict thrash: every address lands in hybrid set 0 (multiples of
/// `set_stride`), cycling over several times more distinct blocks than the
/// set has fast ways. Cores run phase-shifted over the same conflict ring.
fn thrash_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let ring = thrash_ring(g);
    let pos = (step as u64 + stream as u64 * 7) % ring;
    pos * g.set_stride
}

fn thrash_ring(g: &Geom) -> u64 {
    // Overload the set's associativity, stay inside the OS capacity, and
    // keep at least a few dozen blocks so even direct-mapped designs (one
    // fast block per set) see LLC-defeating reuse distances.
    (4 * g.fast_per_set).max(64).min((g.os_cap / g.set_stride).max(2))
}

/// Migration storm: sweep a hot region bigger than the LLC (so every
/// access reaches the controller) but comparable to the fast tier (so it
/// gets cached/migrated in), then teleport the region every epoch to turn
/// all of those mappings stale at once.
fn storm_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let hot_bytes = (2 * g.llc_bytes).max(g.fast_blocks * g.block / 2).min(g.os_cap / 2);
    let hot_lines = (hot_bytes / LINE).max(1);
    // Short epochs (per core) so even brief runs see several teleports;
    // within an epoch the sweep is sequential, so the 64 B lines of each
    // migration block coalesce into one fill + several fast hits.
    let epoch_len: u32 = 1024;
    let epoch = step / epoch_len;
    let base = (epoch as u64).wrapping_mul(hot_bytes + 17 * g.block) % g.os_cap;
    let off = ((step % epoch_len) as u64 + stream as u64 * 1031) % hot_lines;
    base + off * LINE
}

/// Identity-flip churn: two block groups, both aliasing hybrid set 0,
/// alternate as the active group. Each phase caches its own group
/// (identity -> non-identity) while pressure evicts the other
/// (non-identity -> identity), flipping the same iRT leaves and iRC bits
/// over and over.
fn flip_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let group = flip_group(g);
    // Phases much shorter than a full group sweep: the point is the
    // *flip rate* (iRT alloc/free churn, iRC invalidations), not coverage.
    let phase_len = ((group / 4) as u32).max(256);
    let phase = step / phase_len;
    let which = (phase & 1) as u64;
    let pos = (step as u64 + stream as u64 * 13) % group;
    (which * group + pos) * g.set_stride
}

fn flip_group(g: &Geom) -> u64 {
    (2 * g.fast_per_set).max(64).min((g.os_cap / (2 * g.set_stride)).max(2))
}

/// Working-set drift: a window about twice the fast tier slides forward an
/// eighth of its span every window's worth of accesses; accesses scatter
/// hash-uniformly inside the window.
fn drift_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let window_blocks = (2 * g.fast_blocks).max(256).min((g.os_cap / g.block).max(2));
    // Advance the window every 1/16th of a window's worth of accesses so
    // short runs still drift several times.
    let epoch = step / ((window_blocks / 16).max(64) as u32);
    let base_block = (epoch as u64).wrapping_mul(window_blocks / 8 + 1);
    let h = lowbias32(lowbias32(step ^ stream.wrapping_mul(0x0100_0193)) ^ 0xD81F);
    let block = base_block + (h as u64 % window_blocks);
    block * g.block
}

/// Pointer chase: a per-core dependent hash chain over the whole
/// footprint. Successive addresses share nothing — worst case for the
/// remap caches and for any spatial-locality assumption in the tables.
fn chase_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    // Stateless chain: position i is hash^(i)(seed), realized as a single
    // mix of (stream, step) — equivalent distribution, still deterministic.
    let h = lowbias32(step.wrapping_mul(0x9E37_79B9) ^ lowbias32(stream ^ 0xC4A5));
    let total_lines = (g.os_cap / LINE).max(1);
    (h as u64 % total_lines) * LINE
}

/// Metadata bloat: a hot region (bigger than the LLC, comparable to half
/// the fast tier) is hammered hash-uniformly for one phase, then the
/// region jumps to fresh address space and **never returns**. Every phase
/// mints a region's worth of non-identity remap entries whose blocks go
/// cold the moment the phase ends; without decay those stale mappings
/// only retire under replacement pressure, so non-identity occupancy
/// ratchets toward capacity.
fn bloat_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let region_blocks = ((2 * g.llc_bytes / g.block).max(g.fast_blocks / 2)).max(64);
    // Phases long enough to warm the region, short enough that a tiny run
    // still crosses several phase changes.
    let phase_len: u32 = 1024;
    let phase = step / phase_len;
    let base_block = (phase as u64).wrapping_mul(region_blocks);
    let h = lowbias32(lowbias32(step ^ stream.wrapping_mul(0x0100_0193)) ^ 0xB10A);
    let block = base_block + (h as u64 % region_blocks);
    block * g.block
}

/// Fault storm: three of every four accesses hammer a hot region about
/// the fast tier's size (and past the LLC) that drifts slowly, so every
/// hybrid set stays full of live non-identity pairs — targets for the
/// metadata-flip injector and work for scrub/rebuild. The fourth access
/// probes hash-uniformly over the whole footprint, keeping a steady
/// stream of slow-tier reads for the transient-fault injector to stall
/// and, at high rates, exhaust into quarantine.
fn fault_storm_addr(g: &Geom, stream: u32, step: u32) -> u64 {
    let h = lowbias32(lowbias32(step ^ stream.wrapping_mul(0x0100_0193)) ^ 0xFA17);
    if step & 3 == 3 {
        let total_blocks = (g.os_cap / g.block).max(1);
        (h as u64 % total_blocks) * g.block
    } else {
        let hot_blocks = ((2 * g.llc_bytes / g.block).max(g.fast_blocks)).max(64);
        let epoch = step / 4096;
        let base = (epoch as u64).wrapping_mul(hot_blocks / 4 + 1);
        (base + (h as u64 % hot_blocks)) * g.block
    }
}

/// Build a scenario by name, or `None` if the name is not adversarial.
pub fn build(name: &str, cfg: &SystemConfig) -> Option<Box<dyn Workload>> {
    let geom = Geom::of(cfg);
    let cores = cfg.workload.cores as usize;
    let (gen, footprint, write_milli, gap_mod): (fn(&Geom, u32, u32) -> u64, u64, u32, u32) =
        match name {
            "adv_set_thrash" => {
                let span = thrash_ring(&geom) * geom.set_stride;
                (thrash_addr, span, 307, 16)
            }
            "adv_migration_storm" => (storm_addr, geom.os_cap, 307, 24),
            "adv_identity_flip" => {
                let span = 2 * flip_group(&geom) * geom.set_stride;
                (flip_addr, span, 409, 16)
            }
            "adv_drift" => (drift_addr, geom.os_cap, 204, 20),
            "adv_pointer_chase" => (chase_addr, geom.os_cap, 51, 8),
            "adv_metadata_bloat" => (bloat_addr, geom.os_cap, 307, 16),
            "adv_fault_storm" => (fault_storm_addr, geom.os_cap, 153, 16),
            _ => return None,
        };
    Some(Box::new(Scenario {
        name: ADVERSARIAL.iter().copied().find(|n| *n == name)?,
        geom,
        footprint: footprint.max(LINE),
        steps: vec![0; cores],
        gen,
        write_milli,
        gap_mod,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn cfg() -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 4;
        cfg
    }

    #[test]
    fn all_scenarios_build_and_stay_in_footprint() {
        let cfg = cfg();
        for name in ADVERSARIAL {
            let mut wl = build(name, &cfg).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(wl.name(), *name);
            let fp = wl.footprint_bytes();
            assert!(fp > 0, "{name}");
            for core in 0..4 {
                for _ in 0..2000 {
                    let a = wl.next(core);
                    assert!(a.addr < fp, "{name}: {:#x} >= {fp:#x}", a.addr);
                    assert_eq!(a.addr % LINE, 0, "{name}: unaligned");
                }
            }
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("adv_nonexistent", &cfg()).is_none());
        assert!(build("gap_pr", &cfg()).is_none());
    }

    #[test]
    fn scenarios_are_deterministic() {
        let cfg = cfg();
        for name in ADVERSARIAL {
            let mut a = build(name, &cfg).unwrap();
            let mut b = build(name, &cfg).unwrap();
            for core in 0..2 {
                for _ in 0..500 {
                    assert_eq!(a.next(core), b.next(core), "{name} core {core}");
                }
            }
        }
    }

    #[test]
    fn seed_changes_the_stream() {
        let cfg_a = cfg();
        let mut cfg_b = cfg();
        cfg_b.workload.seed = 0xBEEF;
        let mut a = build("adv_pointer_chase", &cfg_a).unwrap();
        let mut b = build("adv_pointer_chase", &cfg_b).unwrap();
        let div = (0..200).any(|_| a.next(0) != b.next(0));
        assert!(div, "different seeds must diverge");
    }

    #[test]
    fn set_thrash_hits_one_hybrid_set() {
        let cfg = cfg();
        let layout = crate::metadata::SetLayout::for_config(&cfg.hybrid, false);
        let mut wl = build("adv_set_thrash", &cfg).unwrap();
        let mut mapper = crate::sim::mapper::AddrMapper::new(layout, cfg.hybrid.mode);
        let mut sets = std::collections::HashSet::new();
        for _ in 0..1000 {
            let a = wl.next(0);
            let (set, _) = mapper.translate(a.addr);
            sets.insert(set);
        }
        assert_eq!(sets.len(), 1, "thrash must alias one set: {sets:?}");
    }

    #[test]
    fn metadata_bloat_abandons_old_phases() {
        // Once a phase ends its region is never revisited: the minimum
        // address of each later phase's accesses keeps climbing (modulo
        // the footprint wrap, which a short run never reaches).
        let cfg = cfg();
        let mut wl = build("adv_metadata_bloat", &cfg).unwrap();
        let mut phase_min = [u64::MAX; 3];
        for step in 0..3 * 1024 {
            let a = wl.next(0);
            let p = (step / 1024) as usize;
            phase_min[p] = phase_min[p].min(a.addr);
        }
        assert!(
            phase_min[0] < phase_min[1] && phase_min[1] < phase_min[2],
            "phases must move to fresh address space: {phase_min:?}"
        );
    }

    #[test]
    fn identity_flip_alternates_groups() {
        let cfg = cfg();
        let mut wl = build("adv_identity_flip", &cfg).unwrap();
        let fp = wl.footprint_bytes();
        let half = fp / 2;
        // Drain one phase, then confirm the next phase visits the other half.
        let mut last_group = wl.next(0).addr >= half;
        let mut flips = 0;
        for _ in 0..40_000 {
            let g = wl.next(0).addr >= half;
            if g != last_group {
                flips += 1;
                last_group = g;
            }
        }
        assert!(flips >= 2, "phases must alternate between groups: {flips}");
    }
}

//! First-order energy accounting over the simulation's traffic counters.
//!
//! Constants are device-class estimates from the literature the paper
//! builds on (HBM ~3.9 pJ/bit, DDR5 ~15 pJ/bit access+IO, Optane-class
//! NVM ~100/500 pJ/bit read/write at the media, SRAM probes ~10 pJ) —
//! good enough to rank designs by *memory-system* energy, which is how we
//! use them (the `trimma run` report and the efficiency rows in
//! EXPERIMENTS.md). Absolute joules are not a claim.

use super::Stats;

/// Per-byte / per-probe energy coefficients (picojoules).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub fast_pj_per_byte: f64,
    pub slow_read_pj_per_byte: f64,
    pub slow_write_pj_per_byte: f64,
    pub sram_probe_pj: f64,
}

impl EnergyModel {
    /// HBM3 fast tier + DDR5 slow tier.
    pub fn hbm3_ddr5() -> Self {
        EnergyModel {
            fast_pj_per_byte: 31.0,       // ~3.9 pJ/bit
            slow_read_pj_per_byte: 120.0, // ~15 pJ/bit incl. IO
            slow_write_pj_per_byte: 120.0,
            sram_probe_pj: 10.0,
        }
    }

    /// DDR5 fast tier + Optane-class NVM slow tier.
    pub fn ddr5_nvm() -> Self {
        EnergyModel {
            fast_pj_per_byte: 120.0,
            slow_read_pj_per_byte: 800.0,  // ~100 pJ/bit media read
            slow_write_pj_per_byte: 4000.0, // ~500 pJ/bit media write
            sram_probe_pj: 10.0,
        }
    }
}

/// Energy breakdown in microjoules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    pub fast_uj: f64,
    pub slow_uj: f64,
    pub sram_uj: f64,
}

impl EnergyReport {
    pub fn total_uj(&self) -> f64 {
        self.fast_uj + self.slow_uj + self.sram_uj
    }

    /// Energy per useful byte delivered (pJ/B) — the efficiency metric.
    pub fn pj_per_useful_byte(&self, stats: &Stats) -> f64 {
        if stats.useful_bytes == 0 {
            return 0.0;
        }
        self.total_uj() * 1e6 / stats.useful_bytes as f64
    }
}

/// Estimate memory-system energy for a finished run.
pub fn estimate(stats: &Stats, m: &EnergyModel) -> EnergyReport {
    // Approximate the slow read/write split by the demand mix plus
    // migration (reads) and writebacks (writes).
    let slow_writes = stats.writeback_bytes;
    let slow_reads = stats.slow_traffic_bytes.saturating_sub(slow_writes);
    EnergyReport {
        fast_uj: stats.fast_traffic_bytes as f64 * m.fast_pj_per_byte / 1e6,
        slow_uj: (slow_reads as f64 * m.slow_read_pj_per_byte
            + slow_writes as f64 * m.slow_write_pj_per_byte)
            / 1e6,
        sram_uj: stats.rc_probes as f64 * m.sram_probe_pj / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> Stats {
        Stats {
            fast_traffic_bytes: 1_000_000,
            slow_traffic_bytes: 500_000,
            writeback_bytes: 100_000,
            rc_probes: 10_000,
            useful_bytes: 640_000,
            ..Default::default()
        }
    }

    #[test]
    fn breakdown_sums() {
        let r = estimate(&stats(), &EnergyModel::hbm3_ddr5());
        assert!(r.fast_uj > 0.0 && r.slow_uj > 0.0 && r.sram_uj > 0.0);
        assert!((r.total_uj() - (r.fast_uj + r.slow_uj + r.sram_uj)).abs() < 1e-12);
    }

    #[test]
    fn nvm_writes_dominate() {
        let r = estimate(&stats(), &EnergyModel::ddr5_nvm());
        // 100 kB of NVM writes at 4 nJ/B = 400 uJ > everything else.
        assert!(r.slow_uj > r.fast_uj);
        assert!(r.slow_uj > 0.4 * 1000.0 * 0.9);
    }

    #[test]
    fn efficiency_metric_scales_with_useful_bytes() {
        let m = EnergyModel::hbm3_ddr5();
        let a = estimate(&stats(), &m).pj_per_useful_byte(&stats());
        let mut s2 = stats();
        s2.useful_bytes *= 2;
        let b = estimate(&s2, &m).pj_per_useful_byte(&s2);
        assert!((a / b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_useful_bytes_is_safe() {
        let s = Stats::default();
        let r = estimate(&s, &EnergyModel::hbm3_ddr5());
        assert_eq!(r.pj_per_useful_byte(&s), 0.0);
    }
}

//! Metrics collection: every quantity the paper's evaluation reports.
//!
//! * Fig. 7 — performance (weighted-speedup proxy from per-core cycles);
//! * Fig. 8 — average memory access time split into metadata lookup, fast
//!   data access, and slow data access;
//! * Fig. 9 — metadata bytes resident in the fast tier at end of simulation;
//! * Fig. 10 — fast-memory serve rate and bandwidth bloat factor;
//! * Fig. 11 — remap cache hit rates (overall / identity / non-identity).
//!
//! [`energy`] adds first-order energy accounting on top of the traffic
//! counters.


pub mod energy;

/// Raw event counters accumulated during simulation. All plain integers so
/// merging and CSV export are trivial.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    // ---- demand stream ----
    /// Memory accesses that reached the hybrid memory controller (LLC misses).
    pub mem_accesses: u64,
    pub mem_reads: u64,
    pub mem_writes: u64,
    /// Accesses whose data was served by the fast tier.
    pub fast_served: u64,
    /// Accesses served by the slow tier.
    pub slow_served: u64,

    // ---- latency breakdown (cycles summed over demand accesses) ----
    /// Cycles spent resolving physical->device mappings on the critical path
    /// (SRAM remap cache probes + off-chip table walks).
    pub metadata_cycles: u64,
    /// Cycles spent on fast-tier data access (critical path).
    pub fast_data_cycles: u64,
    /// Cycles spent on slow-tier data access (critical path).
    pub slow_data_cycles: u64,

    // ---- remap cache ----
    pub rc_probes: u64,
    pub rc_hits_nonid: u64,
    pub rc_hits_id: u64,
    /// Probes that found an IdCache line but with bit = 0 (known non-identity
    /// or unknown): counted as misses.
    pub rc_sector_bit_miss: u64,
    /// Off-chip table walks (remap cache misses).
    pub table_walks: u64,
    /// Fast-memory accesses issued by table walks (iRT issues up to
    /// `levels`, in parallel; linear issues 1).
    pub table_walk_mem_accesses: u64,
    /// Probes whose resolved mapping was identity.
    pub lookups_identity: u64,
    /// Probes whose resolved mapping was non-identity.
    pub lookups_nonidentity: u64,

    // ---- traffic (bytes) ----
    /// Useful demand data delivered to the processor.
    pub useful_bytes: u64,
    /// Total fast-tier traffic: demand + fills + evictions + metadata.
    pub fast_traffic_bytes: u64,
    /// Total slow-tier traffic.
    pub slow_traffic_bytes: u64,
    /// Bytes moved by caching/migration (fills + evictions + swaps).
    pub migration_bytes: u64,
    /// Bytes written back from fast to slow (dirty evictions / swap-outs).
    pub writeback_bytes: u64,
    /// Metadata bytes read/written in fast memory (table walks + updates).
    pub metadata_traffic_bytes: u64,

    // ---- structural ----
    /// Blocks inserted into the fast tier (fills/migrations in).
    pub fills: u64,
    /// Data blocks evicted from the fast tier.
    pub evictions: u64,
    /// Data blocks evicted specifically because a metadata block needed the
    /// slot back (iRT allocation priority, §3.3).
    pub metadata_priority_evictions: u64,
    /// Fills that landed in donated (saved-metadata-space) slots.
    pub saved_slot_fills: u64,
    /// Sub-block line fetches into partially-present blocks (sub-blocking
    /// extension only).
    pub subblock_fetches: u64,
    /// Remap entries recycled through software deallocation hints (§3.5).
    pub dealloc_recycled: u64,

    // ---- metadata decay (DESIGN.md §11) ----
    /// Decay epoch boundaries observed across all sets.
    pub decay_epochs: u64,
    /// Fast-tier slots examined by the budgeted background sweep.
    pub decay_checked: u64,
    /// Cold remapped blocks migrated home and reclaimed to identity by the
    /// decay sweep.
    pub decay_reclaims: u64,

    // ---- fault injection & recovery (DESIGN.md §14) ----
    /// Faults injected by the deterministic injector (all three classes:
    /// transient reads, metadata flips, stuck-set corruption).
    pub fault_injected: u64,
    /// Transient-read retry attempts (each charged exponential backoff).
    pub fault_retried: u64,
    /// Scrub passes that detected and reacted to metadata corruption.
    pub fault_scrubbed: u64,
    /// Corrupted iRT entries rebuilt from the surviving inverse direction.
    pub fault_rebuilt: u64,
    /// Sets quarantined to degraded identity mapping (stuck metadata or
    /// retry exhaustion).
    pub fault_quarantined: u64,

    // ---- batched translate (DESIGN.md §15) ----
    /// Accesses the phase-1 batch walk issued software prefetches for
    /// (telemetry of the host-side prefetch stage; the only counter that
    /// legitimately differs between prefetch-on and prefetch-off runs —
    /// every other counter is locked byte-identical by
    /// `rust/tests/prefetch_parity.rs`).
    pub batch_prefetches: u64,

    // ---- metadata storage (sampled at end of run) ----
    /// Bytes of remap-table storage currently allocated in the fast tier.
    pub metadata_bytes_used: u64,
    /// Bytes of fast memory reserved for the metadata region (worst case).
    pub metadata_bytes_reserved: u64,
    /// Number of reserved metadata blocks currently donated as cache slots.
    pub donated_slots: u64,

    // ---- CPU side ----
    /// Total instructions retired across cores.
    pub instructions: u64,
    /// Maximum per-core cycle count (the run's wall clock).
    pub max_core_cycles: u64,
    /// Sum of per-core cycle counts.
    pub total_core_cycles: u64,
    /// Cache-hierarchy hits per level (L1, L2, LLC).
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub llc_hits: u64,
    pub cache_accesses: u64,
}

/// The **single source of truth** for the canonical stat vector: invokes
/// the given callback macro with every [`Stats`] field as a
/// `(name, class)` pair, in canonical order. `class` is a literal token
/// selecting the merge semantics:
///
/// * `sum` — event counter, added on every merge;
/// * `gauge` — end-of-run storage sample (`metadata_bytes_used`,
///   `metadata_bytes_reserved`, `donated_slots`): later sample wins in
///   [`Stats::merge`], partial sums are **added** across the disjoint set
///   ranges of [`Stats::merge_shard`];
/// * `max` — the wall clock (`max_core_cycles`), maxed everywhere.
///
/// [`Stats::merge`], [`Stats::merge_shard`], [`Stats::canonical`], and
/// [`NUM_STAT_COUNTERS`] are all generated from this one list — and
/// `canonical` destructures `Stats` exhaustively, so adding a field to
/// the struct without adding it here is a compile error, not a counter
/// silently dropped from merge (the PR 6 hazard: three hand-maintained
/// copies of the list).
macro_rules! with_stat_counters {
    ($cb:ident) => {
        $cb! {
            (mem_accesses, sum),
            (mem_reads, sum),
            (mem_writes, sum),
            (fast_served, sum),
            (slow_served, sum),
            (metadata_cycles, sum),
            (fast_data_cycles, sum),
            (slow_data_cycles, sum),
            (rc_probes, sum),
            (rc_hits_nonid, sum),
            (rc_hits_id, sum),
            (rc_sector_bit_miss, sum),
            (table_walks, sum),
            (table_walk_mem_accesses, sum),
            (lookups_identity, sum),
            (lookups_nonidentity, sum),
            (useful_bytes, sum),
            (fast_traffic_bytes, sum),
            (slow_traffic_bytes, sum),
            (migration_bytes, sum),
            (writeback_bytes, sum),
            (metadata_traffic_bytes, sum),
            (fills, sum),
            (evictions, sum),
            (metadata_priority_evictions, sum),
            (saved_slot_fills, sum),
            (subblock_fetches, sum),
            (dealloc_recycled, sum),
            (decay_epochs, sum),
            (decay_checked, sum),
            (decay_reclaims, sum),
            (fault_injected, sum),
            (fault_retried, sum),
            (fault_scrubbed, sum),
            (fault_rebuilt, sum),
            (fault_quarantined, sum),
            (batch_prefetches, sum),
            (metadata_bytes_used, gauge),
            (metadata_bytes_reserved, gauge),
            (donated_slots, gauge),
            (instructions, sum),
            (max_core_cycles, max),
            (total_core_cycles, sum),
            (l1_hits, sum),
            (l2_hits, sum),
            (llc_hits, sum),
            (cache_accesses, sum),
        }
    };
}

macro_rules! count_stat_counters {
    ($(($f:ident, $class:ident)),* $(,)?) => { [$(stringify!($f)),*].len() };
}

/// Number of counters in the canonical stat vector ([`Stats::canonical`]
/// emits exactly this many `name=value` pairs; generated from the same
/// list that drives the merges).
pub const NUM_STAT_COUNTERS: usize = with_stat_counters!(count_stat_counters);

impl Stats {
    pub fn merge(&mut self, o: &Stats) {
        // `sum` adds, `max` maxes, `gauge` is handled below: two samples
        // of the same run, the later storage snapshot wins.
        macro_rules! merge_field {
            ($s:expr, $o:expr, $f:ident, sum) => { $s.$f += $o.$f; };
            ($s:expr, $o:expr, $f:ident, max) => { $s.$f = $s.$f.max($o.$f); };
            ($s:expr, $o:expr, $f:ident, gauge) => {};
        }
        macro_rules! apply {
            ($(($f:ident, $class:ident)),* $(,)?) => {
                $( merge_field!(self, o, $f, $class); )*
            };
        }
        with_stat_counters!(apply);
        // storage gauges: take the other's (later) sample if set
        if o.metadata_bytes_used > 0 || o.metadata_bytes_reserved > 0 {
            self.metadata_bytes_used = o.metadata_bytes_used;
            self.metadata_bytes_reserved = o.metadata_bytes_reserved;
            self.donated_slots = o.donated_slots;
        }
    }

    /// Merge the stats of one shard/slice of a set-partitioned run
    /// ([`crate::engine::sharded`]). Unlike [`Stats::merge`] — which
    /// treats two samples of the *same* run and lets the later storage
    /// gauges win — shards own **disjoint set ranges**, so their storage
    /// gauges (`metadata_bytes_used`, `metadata_bytes_reserved`,
    /// `donated_slots`) are partial sums and must be **added**, exactly
    /// like the event counters. `max_core_cycles` still maxes: shards
    /// share the front end's wall clock.
    pub fn merge_shard(&mut self, o: &Stats) {
        macro_rules! merge_field {
            ($s:expr, $o:expr, $f:ident, sum) => { $s.$f += $o.$f; };
            ($s:expr, $o:expr, $f:ident, gauge) => { $s.$f += $o.$f; };
            ($s:expr, $o:expr, $f:ident, max) => { $s.$f = $s.$f.max($o.$f); };
        }
        macro_rules! apply {
            ($(($f:ident, $class:ident)),* $(,)?) => {
                $( merge_field!(self, o, $f, $class); )*
            };
        }
        with_stat_counters!(apply);
    }

    // ---- derived metrics ----

    /// Fraction of demand accesses served by the fast tier (Fig. 10a).
    pub fn fast_serve_rate(&self) -> f64 {
        ratio(self.fast_served, self.mem_accesses)
    }

    /// Fast-tier traffic divided by useful processor traffic (Fig. 10b,
    /// "bandwidth bloat factor" after BEAR).
    pub fn bandwidth_bloat(&self) -> f64 {
        if self.useful_bytes == 0 {
            return 0.0;
        }
        self.fast_traffic_bytes as f64 / self.useful_bytes as f64
    }

    /// Overall remap-cache hit rate (Fig. 11 lines).
    pub fn rc_hit_rate(&self) -> f64 {
        ratio(self.rc_hits_nonid + self.rc_hits_id, self.rc_probes)
    }

    /// Hit rate over probes that resolve to identity mappings.
    pub fn rc_id_hit_rate(&self) -> f64 {
        ratio(self.rc_hits_id, self.lookups_identity)
    }

    /// Hit rate over probes that resolve to non-identity mappings.
    pub fn rc_nonid_hit_rate(&self) -> f64 {
        ratio(self.rc_hits_nonid, self.lookups_nonidentity)
    }

    /// Average memory access time components, per demand access (Fig. 8).
    pub fn amat_breakdown(&self) -> (f64, f64, f64) {
        let n = self.mem_accesses.max(1) as f64;
        (
            self.metadata_cycles as f64 / n,
            self.fast_data_cycles as f64 / n,
            self.slow_data_cycles as f64 / n,
        )
    }

    /// Performance proxy: instructions per cycle over the slowest core
    /// (throughput of the rate-mode batch; ratios between designs form the
    /// paper's weighted-speedup comparisons).
    pub fn performance(&self) -> f64 {
        ratio(self.instructions, self.max_core_cycles)
    }

    /// Fraction of the reserved metadata region actually holding metadata
    /// at end of run (Fig. 9's "metadata size").
    pub fn metadata_occupancy(&self) -> f64 {
        ratio(self.metadata_bytes_used, self.metadata_bytes_reserved)
    }

    /// Canonical serialization of the full stat vector: every counter in a
    /// fixed order as `name=value` pairs joined by `;`. Two runs are
    /// byte-identical iff these strings are equal — the golden-snapshot
    /// harness (rust/tests/golden.rs) and the determinism matrix compare
    /// exactly this.
    pub fn canonical(&self) -> String {
        macro_rules! emit {
            ($(($f:ident, $class:ident)),* $(,)?) => {{
                // Exhaustive destructuring: a `Stats` field missing from
                // `with_stat_counters!` fails to compile here instead of
                // silently vanishing from merge and the golden snapshots.
                let Stats { $($f),* } = self;
                let pairs: [(&str, &u64); NUM_STAT_COUNTERS] =
                    [$((stringify!($f), $f)),*];
                let mut out = String::with_capacity(pairs.len() * 24);
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(';');
                    }
                    out.push_str(k);
                    out.push('=');
                    out.push_str(&v.to_string());
                }
                out
            }};
        }
        with_stat_counters!(emit)
    }
}

#[inline]
fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 { 0.0 } else { num as f64 / den as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics_handle_zero() {
        let s = Stats::default();
        assert_eq!(s.fast_serve_rate(), 0.0);
        assert_eq!(s.bandwidth_bloat(), 0.0);
        assert_eq!(s.rc_hit_rate(), 0.0);
        assert_eq!(s.performance(), 0.0);
    }

    #[test]
    fn canonical_serializes_the_full_vector() {
        // Every one of the 47 counters must appear — `cache_accesses` was
        // historically omitted, leaving golden snapshots blind to it.
        let s = Stats { cache_accesses: 7, ..Default::default() };
        let c = s.canonical();
        assert_eq!(c.matches('=').count(), 47);
        assert!(c.ends_with("cache_accesses=7"), "{c}");
    }

    #[test]
    fn counter_list_is_the_single_source_of_truth() {
        // canonical(), merge(), and merge_shard() are all generated from
        // `with_stat_counters!`; the pair count must track it exactly, so
        // a counter can never be in the struct but absent from a merge.
        let c = Stats::default().canonical();
        assert_eq!(c.matches('=').count(), NUM_STAT_COUNTERS);
        assert_eq!(c.split(';').count(), NUM_STAT_COUNTERS);
        assert_eq!(NUM_STAT_COUNTERS, 47);
    }

    #[test]
    fn merge_shard_sums_storage_gauges() {
        // Shards own disjoint set ranges: gauges are partial sums, not
        // later samples of the same whole.
        let mut a = Stats {
            mem_accesses: 10,
            max_core_cycles: 100,
            metadata_bytes_used: 64,
            metadata_bytes_reserved: 1024,
            donated_slots: 3,
            ..Default::default()
        };
        let b = Stats {
            mem_accesses: 5,
            max_core_cycles: 70,
            metadata_bytes_used: 32,
            metadata_bytes_reserved: 1024,
            donated_slots: 2,
            ..Default::default()
        };
        a.merge_shard(&b);
        assert_eq!(a.mem_accesses, 15);
        assert_eq!(a.max_core_cycles, 100);
        assert_eq!(a.metadata_bytes_used, 96);
        assert_eq!(a.metadata_bytes_reserved, 2048);
        assert_eq!(a.donated_slots, 5);
        // Contrast: plain merge lets the later gauge sample win.
        let mut c = Stats { metadata_bytes_reserved: 1024, ..Default::default() };
        c.merge(&b);
        assert_eq!(c.metadata_bytes_reserved, 1024);
    }

    #[test]
    fn merge_adds_counters_and_maxes_clock() {
        let mut a = Stats { mem_accesses: 10, max_core_cycles: 100, ..Default::default() };
        let b = Stats { mem_accesses: 5, max_core_cycles: 70, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.mem_accesses, 15);
        assert_eq!(a.max_core_cycles, 100);
    }

    #[test]
    fn serve_rate() {
        let s = Stats { mem_accesses: 100, fast_served: 80, ..Default::default() };
        assert!((s.fast_serve_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn amat_breakdown_sums() {
        let s = Stats {
            mem_accesses: 4,
            metadata_cycles: 8,
            fast_data_cycles: 40,
            slow_data_cycles: 100,
            ..Default::default()
        };
        let (m, f, sl) = s.amat_breakdown();
        assert_eq!((m, f, sl), (2.0, 10.0, 25.0));
    }
}

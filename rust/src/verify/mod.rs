//! Differential verification of the remap metadata path.
//!
//! Trimma's whole value proposition rests on the correctness of the
//! physical->device translation: the iRT must never lose or alias a block
//! while trimming identity entries, and the iRC must return the same
//! translation the off-chip tables would. This module provides the
//! ground-truth model and the wiring that lets any [`Controller`] be
//! shadowed by it:
//!
//! * [`ReferenceRemap`] — the oracle. It checks, after *every* access,
//!   that the translation is in range, involutive (every non-identity
//!   mapping is a 2-cycle `p -> s`, `s -> p` — the bidirectional-entry
//!   invariant of paper §3.3), tier-crossing (a moved block always pairs a
//!   fast slot with a slow home), consistent with which tier actually
//!   served the access, and consistent with the identity/non-identity
//!   classification counters. Periodically (and at finalize) it sweeps a
//!   whole set: involution over the full per-set index space (which
//!   implies bijectivity — no lost, no aliased blocks) plus a cross-check
//!   of the table's own occupancy bookkeeping against the entries the
//!   sweep observes.
//! * [`CheckedController`] — a transparent verifying wrapper, generic over
//!   the wrapped controller so the checked path stays statically
//!   dispatched. The engine wires it in as the `Checked` variant of
//!   [`crate::engine::AnyController`] whenever `cfg.hybrid.verify = true`
//!   (see [`crate::config::presets::with_verify`] and
//!   [`crate::engine::EngineBuilder::verify`]); tests and debug runs pay
//!   the cost, benches and figure sweeps do not.
//!
//! Controllers expose three debug hooks ([`Controller::debug_translate`],
//! [`Controller::debug_check_set`], [`Controller::debug_nonidentity_entries`]);
//! the tag-matching baselines (Alloy, Loh-Hill) keep placement in cache
//! tags rather than a remap table and use the default hooks, so for them
//! the oracle degrades to the generic conservation checks (every access
//! served exactly once, read/write partition, latency breakdown equals the
//! returned demand latency).
//!
//! Any violation panics with a description of the broken invariant, so a
//! seeded mutation in `hybrid/remap.rs` (e.g. skipping the inverse-entry
//! write on a swap) fails the scenario tests immediately.

// Panic audit: panicking *is* this module's contract — the oracle's one
// job is to halt the run the instant an invariant breaks, and its two
// `expect`s guard introspection hooks whose availability it itself
// probed at construction.
#![allow(clippy::unwrap_used, clippy::expect_used)]

use crate::engine::AnyController;
use crate::hybrid::Controller;
use crate::metadata::SetLayout;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};

/// How many accesses between incremental full-set sweeps.
const SWEEP_EVERY: u64 = 2048;

/// Small snapshot of the counters the per-access checks need.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct Snap {
    mem: u64,
    reads: u64,
    writes: u64,
    fast: u64,
    slow: u64,
    id: u64,
    nonid: u64,
    meta_cyc: u64,
    fast_cyc: u64,
    slow_cyc: u64,
}

impl Snap {
    fn of(s: &Stats) -> Snap {
        Snap {
            mem: s.mem_accesses,
            reads: s.mem_reads,
            writes: s.mem_writes,
            fast: s.fast_served,
            slow: s.slow_served,
            id: s.lookups_identity,
            nonid: s.lookups_nonidentity,
            meta_cyc: s.metadata_cycles,
            fast_cyc: s.fast_data_cycles,
            slow_cyc: s.slow_data_cycles,
        }
    }
}

/// The ground-truth remap model: a dead-simple view of what a correct
/// logical->physical map must look like, checked against whatever the
/// controller reports through its debug hooks. All checks are generic
/// over the controller type (`?Sized`, so `&dyn Controller` works too).
#[derive(Debug, Clone)]
pub struct ReferenceRemap {
    layout: SetLayout,
    subblock: bool,
}

impl ReferenceRemap {
    pub fn new(layout: SetLayout, subblock: bool) -> Self {
        ReferenceRemap { layout, subblock }
    }

    /// Check one observed mapping `idx -> device` of `set`.
    fn check_mapping<C: Controller + ?Sized>(
        &self,
        ctrl: &C,
        set: u32,
        idx: u64,
        device: u64,
        when: &str,
    ) {
        let k = self.layout.indices_per_set();
        if device >= k {
            panic!(
                "verify oracle [{when}]: set {set} idx {idx} maps out of range \
                 ({device} >= {k})"
            );
        }
        let back = ctrl
            .debug_translate(set, device)
            .expect("controller with translation must stay introspectable");
        if back != idx {
            panic!(
                "verify oracle [{when}]: set {set} mapping is not involutive: \
                 {idx} -> {device} but {device} -> {back} (lost or aliased block)"
            );
        }
        if device != idx && self.layout.is_fast_idx(device) == self.layout.is_fast_idx(idx) {
            panic!(
                "verify oracle [{when}]: set {set} non-identity mapping {idx} -> {device} \
                 does not cross tiers"
            );
        }
    }

    /// Per-access differential check. `pre_dev` is the translation sampled
    /// immediately before the access (what the lookup must have resolved);
    /// `pre`/`post` are the stats snapshots around it.
    #[allow(clippy::too_many_arguments)]
    fn check_access<C: Controller + ?Sized>(
        &self,
        ctrl: &C,
        set: u32,
        idx: u64,
        kind: AccessKind,
        lat: Cycle,
        pre_dev: Option<u64>,
        pre: Snap,
        post: Snap,
    ) {
        // Generic conservation laws (hold for every design point).
        if post.mem != pre.mem + 1 {
            panic!("verify oracle: access did not count exactly once (set {set} idx {idx})");
        }
        let (dr, dw) = (post.reads - pre.reads, post.writes - pre.writes);
        if (dr + dw) != 1 || (kind.is_write() && dw != 1) || (!kind.is_write() && dr != 1) {
            panic!("verify oracle: read/write partition broken (set {set} idx {idx})");
        }
        let served_fast = post.fast == pre.fast + 1;
        let served_slow = post.slow == pre.slow + 1;
        if served_fast == served_slow {
            panic!(
                "verify oracle: access must be served by exactly one tier \
                 (set {set} idx {idx}: fast {served_fast}, slow {served_slow})"
            );
        }
        let breakdown = (post.meta_cyc - pre.meta_cyc)
            + (post.fast_cyc - pre.fast_cyc)
            + (post.slow_cyc - pre.slow_cyc);
        if breakdown != lat {
            panic!(
                "verify oracle: latency breakdown {breakdown} != demand latency {lat} \
                 (set {set} idx {idx})"
            );
        }

        // Remap-specific checks (controllers with a translation hook).
        let Some(d0) = pre_dev else { return };
        // Fast/slow placement: the serving tier must match the translation
        // resolved by the lookup. Sub-blocking may legitimately serve a
        // fast-mapped block from the slow tier (sub-block miss), never the
        // reverse.
        if self.subblock {
            if served_fast && !self.layout.is_fast_idx(d0) {
                panic!(
                    "verify oracle: set {set} idx {idx} -> {d0} (slow) but served fast"
                );
            }
        } else if served_fast != self.layout.is_fast_idx(d0) {
            panic!(
                "verify oracle: set {set} idx {idx} -> {d0} placement disagrees with \
                 serving tier (served_fast = {served_fast})"
            );
        }
        // Identity classification: when the lookup classified this access,
        // its verdict must match the pre-access translation.
        let class_delta = (post.id + post.nonid) - (pre.id + pre.nonid);
        if class_delta == 1 {
            let claimed_nonid = post.nonid == pre.nonid + 1;
            if claimed_nonid != (d0 != idx) {
                panic!(
                    "verify oracle: set {set} idx {idx} -> {d0} classified as \
                     {} mapping",
                    if claimed_nonid { "non-identity" } else { "identity" }
                );
            }
        }
        // The mapping pair must be consistent after the access settles
        // (fills/migrations/evictions included).
        let d1 = ctrl
            .debug_translate(set, idx)
            .expect("controller with translation must stay introspectable");
        self.check_mapping(ctrl, set, idx, d1, "after access");
    }

    /// Full sweep of one set: involution over the entire per-set index
    /// space (=> the mapping is a bijection; no block is lost or aliased),
    /// tier-crossing for every non-identity entry, and agreement between
    /// the table's occupancy bookkeeping and the observed entries.
    pub fn sweep_set<C: Controller + ?Sized>(&self, ctrl: &C, set: u32) {
        let k = self.layout.indices_per_set();
        if ctrl.debug_translate(set, 0).is_none() {
            return; // tag-matching baseline: nothing to sweep
        }
        let mut nonid = 0u64;
        for i in 0..k {
            let d = ctrl.debug_translate(set, i).unwrap();
            self.check_mapping(ctrl, set, i, d, "sweep");
            if d != i {
                nonid += 1;
            }
        }
        if let Some(counted) = ctrl.debug_nonidentity_entries(set) {
            if counted != nonid {
                panic!(
                    "verify oracle [sweep]: set {set} table occupancy bookkeeping says \
                     {counted} non-identity entries, sweep observed {nonid}"
                );
            }
        }
        if let Err(e) = ctrl.debug_check_set(set) {
            panic!("verify oracle [deep check]: {e}");
        }
    }
}

/// Transparent verifying wrapper around any controller. See module docs.
///
/// Generic over the wrapped controller (default: the enum-dispatched
/// [`AnyController`], which nests it as its `Checked` variant), so even
/// the verified path involves no `Box<dyn Controller>`. Custom mutant
/// controllers plug in directly in tests: `CheckedController::new(mutant,
/// &cfg)`.
pub struct CheckedController<C: Controller = AnyController> {
    inner: C,
    oracle: ReferenceRemap,
    layout: SetLayout,
    accesses: u64,
    sweep_cursor: u32,
}

impl<C: Controller> CheckedController<C> {
    pub fn new(inner: C, cfg: &crate::config::SystemConfig) -> Self {
        let layout = *inner.layout();
        CheckedController {
            oracle: ReferenceRemap::new(layout, cfg.hybrid.subblock),
            inner,
            layout,
            accesses: 0,
            sweep_cursor: 0,
        }
    }

    /// The wrapped controller.
    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// Run the full verification (every set) immediately.
    pub fn verify_all_sets(&self) {
        for set in 0..self.layout.num_sets {
            self.oracle.sweep_set(&self.inner, set);
        }
    }
}

impl<C: Controller> Controller for CheckedController<C> {
    fn access(&mut self, set: u32, idx: u64, line: u32, kind: AccessKind, now: Cycle) -> Cycle {
        let pre = Snap::of(self.inner.stats());
        let pre_dev = self.inner.debug_translate(set, idx);
        if let Some(d0) = pre_dev {
            self.oracle.check_mapping(&self.inner, set, idx, d0, "before access");
        }
        let lat = self.inner.access(set, idx, line, kind, now);
        let post = Snap::of(self.inner.stats());
        self.oracle.check_access(&self.inner, set, idx, kind, lat, pre_dev, pre, post);

        self.accesses += 1;
        if self.accesses % SWEEP_EVERY == 0 {
            let s = self.sweep_cursor;
            self.sweep_cursor = (self.sweep_cursor + 1) % self.layout.num_sets;
            self.oracle.sweep_set(&self.inner, s);
        }
        lat
    }

    fn finalize(&mut self) {
        self.verify_all_sets();
        self.inner.finalize();
    }

    fn reset_stats(&mut self) {
        self.inner.reset_stats();
    }

    fn stats(&self) -> &Stats {
        self.inner.stats()
    }

    fn layout(&self) -> &SetLayout {
        self.inner.layout()
    }

    fn debug_translate(&self, set: u32, idx: u64) -> Option<u64> {
        self.inner.debug_translate(set, idx)
    }

    fn debug_check_set(&self, set: u32) -> Result<(), String> {
        self.inner.debug_check_set(set)
    }

    fn debug_nonidentity_entries(&self, set: u32) -> Option<u64> {
        self.inner.debug_nonidentity_entries(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::engine::AnyController;

    fn small(dp: DesignPoint) -> crate::config::SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.hybrid.verify = true;
        cfg
    }

    #[test]
    fn checked_controller_is_transparent() {
        // Same accesses, same latencies and stats as the bare controller.
        let mut cfg = small(DesignPoint::TrimmaCache);
        let mut checked = AnyController::from_config(&cfg, false);
        cfg.hybrid.verify = false;
        let mut bare = AnyController::from_config(&cfg, false);
        let f = bare.layout().fast_per_set;
        let mut t = 0;
        for n in 0..500u64 {
            let idx = f + (n * 37) % 2000;
            let a = checked.access(0, idx, 0, AccessKind::Read, t);
            let b = bare.access(0, idx, 0, AccessKind::Read, t);
            assert_eq!(a, b, "access {n}");
            t += 900;
        }
        checked.finalize();
        bare.finalize();
        assert_eq!(checked.stats().fast_served, bare.stats().fast_served);
        assert_eq!(checked.stats().metadata_bytes_used, bare.stats().metadata_bytes_used);
    }

    #[test]
    fn oracle_accepts_correct_controller_storm() {
        let cfg = small(DesignPoint::TrimmaCache);
        let mut c = AnyController::from_config(&cfg, false);
        let f = c.layout().fast_per_set;
        let mut rng = crate::types::Rng64::new(0xFEED);
        let mut t = 0;
        for _ in 0..6000 {
            let set = rng.next_below(4) as u32;
            let idx = f + rng.next_below(3000);
            let kind = if rng.chance(0.3) { AccessKind::Write } else { AccessKind::Read };
            c.access(set, idx, 0, kind, t);
            t += 700;
        }
        c.finalize(); // full sweep of every set must pass
    }

    #[test]
    fn oracle_sweeps_flat_mode_swaps() {
        let cfg = small(DesignPoint::TrimmaFlat);
        let mut c = AnyController::from_config(&cfg, false);
        let f = c.layout().fast_per_set;
        let mut t = 0;
        // Hammer a few slow blocks across MEA epochs to force swaps, then
        // drift to force restores.
        for round in 0..8u64 {
            for n in 0..400u64 {
                let idx = f + round * 64 + n % 48;
                c.access(0, idx, 0, AccessKind::Read, t);
                t += 600;
            }
        }
        c.finalize();
    }
}

//! Benchmark harness (criterion is unavailable in this offline
//! environment). `cargo bench` targets and the `trimma bench` subcommand
//! use [`Bench`] to get warmup + two-pass calibration + repeated timed
//! iterations, criterion-style stdout output, **and** a machine-readable
//! result stream: every `iter`/`once` call appends a [`Record`]
//! `{label, ns_per_iter, reps, throughput}`, and [`BenchReport`]
//! serializes the whole run as schema-versioned JSON (hand-rolled,
//! dependency-free — see EXPERIMENTS.md §Perf for the schema and the CI
//! regression gates built on it).
//!
//! ```text
//! irt_lookup_hit          ... 12.3 ns/iter (4096 reps)
//! ```

// Panic audit: measurement harness, not a production path — its
// `unwrap`s are on UTF-8 slices it just built and on JSON it just
// serialized; aborting a bench run loudly is the desired failure mode.
#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Instant;

/// Version of the JSON report schema emitted by [`BenchReport::to_json`].
/// Bump on any breaking change to field names or semantics; the CI
/// `bench-check` step rejects reports whose version it does not know.
pub const SCHEMA_VERSION: u32 = 1;

/// One benchmark measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub label: String,
    /// Mean wall time per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// Timed repetitions behind the mean (1 for `once` measurements).
    pub reps: u64,
    /// Caller-defined throughput (e.g. M mem-steps/s for simulation runs);
    /// attached via [`Bench::attach_throughput`].
    pub throughput: Option<f64>,
}

/// One benchmark group: prints results to stdout and records them.
pub struct Bench {
    name: &'static str,
    /// Measurement budget per `iter` label, nanoseconds (default 200 ms;
    /// `--quick` runs shrink it).
    target_ns: f64,
    records: Vec<Record>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        Self::with_target(name, 200e6)
    }

    /// A group with an explicit per-label measurement budget in
    /// nanoseconds (smoke runs use ~50 ms to keep CI fast).
    pub fn with_target(name: &'static str, target_ns: f64) -> Self {
        println!("== bench: {name} ==");
        Bench { name, target_ns, records: Vec::new() }
    }

    /// Time `f` (which should perform one logical iteration) and report
    /// ns/iter.
    ///
    /// Calibration is two-pass: the warmup loop polls the clock between
    /// iterations to know when ~50 ms have passed, so its per-iteration
    /// time includes `Instant::now()` overhead — enough to skew rep counts
    /// badly for sub-10ns labels. The second pass re-runs the same
    /// iteration count with no clock reads inside the loop and calibrates
    /// on that clean timing.
    pub fn iter<R>(&mut self, label: &str, mut f: impl FnMut() -> R) -> f64 {
        // Pass 1: warmup; only sizes the calibration pass.
        let t0 = Instant::now();
        let mut warm = 0u64;
        while t0.elapsed().as_millis() < 50 {
            std::hint::black_box(f());
            warm += 1;
        }
        // Pass 2: clean calibration (no clock reads inside the loop).
        let t1 = Instant::now();
        for _ in 0..warm {
            std::hint::black_box(f());
        }
        let per = t1.elapsed().as_nanos() as f64 / warm as f64;
        let reps = ((self.target_ns / per.max(0.1)) as u64).clamp(3, 5_000_000);

        let t2 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        let mut total_ns = t2.elapsed().as_nanos() as f64;
        if total_ns < 1.0 {
            // A label cheap enough (or a clock coarse enough) that the
            // whole timed pass rounds to zero nanoseconds would report
            // ns_per_iter 0, and any throughput derived from it divides
            // by zero — `bench-check` must never see inf/NaN in a report.
            eprintln!(
                "warning: bench label '{label}' measured <1 ns over {reps} reps; \
                 clamping duration to 1 ns"
            );
            total_ns = 1.0;
        }
        let ns = total_ns / reps as f64;
        println!("{:<40} ... {:>12.1} ns/iter ({} reps)", label, ns, reps);
        self.records.push(Record {
            label: label.to_string(),
            ns_per_iter: ns,
            reps,
            throughput: None,
        });
        ns
    }

    /// Time one long-running operation (e.g., a whole simulation) once and
    /// report seconds plus the elapsed time; attach a throughput metric
    /// with [`Self::attach_throughput`].
    pub fn once<R>(&mut self, label: &str, f: impl FnOnce() -> R) -> (R, f64) {
        let t0 = Instant::now();
        let r = f();
        let mut dt = t0.elapsed().as_secs_f64();
        if dt < 1e-9 {
            // Same zero-duration hazard as in `iter`: a degenerate run
            // (e.g. an empty workload under `--quick`) must not produce a
            // zero dt that callers turn into infinite throughput.
            eprintln!("warning: bench label '{label}' completed in <1 ns; clamping to 1 ns");
            dt = 1e-9;
        }
        println!("{:<40} ... {:>10.3} s", label, dt);
        self.records.push(Record {
            label: label.to_string(),
            ns_per_iter: dt * 1e9,
            reps: 1,
            throughput: None,
        });
        (r, dt)
    }

    /// Attach a caller-computed throughput (units/second) to the most
    /// recent measurement. Non-finite or non-positive values are dropped
    /// (with a warning) rather than recorded: [`BenchReport::validate`]
    /// rejects them, and a division by a zero duration upstream must not
    /// poison an otherwise valid report.
    pub fn attach_throughput(&mut self, units_per_sec: f64) {
        if let Some(r) = self.records.last_mut() {
            if units_per_sec.is_finite() && units_per_sec > 0.0 {
                r.throughput = Some(units_per_sec);
            } else {
                eprintln!(
                    "warning: dropping bad throughput {units_per_sec} for bench label '{}'",
                    r.label
                );
            }
        }
    }

    pub fn records(&self) -> &[Record] {
        &self.records
    }

    pub fn into_records(self) -> Vec<Record> {
        self.records
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// A complete, schema-versioned benchmark report — what `trimma bench
/// --json` writes and the CI regression gate reads back.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub schema_version: u32,
    /// Free-form run tag (e.g. "pr2", "ci").
    pub tag: String,
    /// True for `--quick` (smoke-scale) runs; quick and full reports are
    /// never compared against each other by the CI gate.
    pub quick: bool,
    /// Geometric mean over the end-to-end simulation sweep's throughputs,
    /// in M mem-steps/s — the headline number the perf gate tracks.
    pub geomean_sim_msteps_per_s: f64,
    pub records: Vec<Record>,
}

impl BenchReport {
    /// Serialize as pretty-printed JSON (stable field order).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.records.len() * 96);
        out.push_str("{\n");
        out.push_str(&format!("  \"schema_version\": {},\n", self.schema_version));
        out.push_str(&format!("  \"tag\": \"{}\",\n", esc(&self.tag)));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str(&format!(
            "  \"geomean_sim_msteps_per_s\": {},\n",
            json_num(self.geomean_sim_msteps_per_s)
        ));
        out.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"ns_per_iter\": {}, \"reps\": {}, \"throughput\": {}}}",
                esc(&r.label),
                json_num(r.ns_per_iter),
                r.reps,
                match r.throughput {
                    Some(t) => json_num(t),
                    None => "null".to_string(),
                }
            ));
            out.push_str(if i + 1 < self.records.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a report back from JSON (round-trip inverse of
    /// [`Self::to_json`]; also accepts any field order / whitespace).
    pub fn from_json(text: &str) -> Result<BenchReport, String> {
        let v = Json::parse(text)?;
        let top = v.as_obj("top-level")?;
        let schema_version = get(top, "schema_version")?.as_f64("schema_version")? as u32;
        let tag = get(top, "tag")?.as_str("tag")?.to_string();
        let quick = get(top, "quick")?.as_bool("quick")?;
        let geomean_sim_msteps_per_s =
            get(top, "geomean_sim_msteps_per_s")?.as_f64("geomean_sim_msteps_per_s")?;
        let mut records = Vec::new();
        for (i, rv) in get(top, "records")?.as_arr("records")?.iter().enumerate() {
            let ro = rv.as_obj(&format!("records[{i}]"))?;
            let throughput = match get(ro, "throughput")? {
                Json::Null => None,
                other => Some(other.as_f64("throughput")?),
            };
            records.push(Record {
                label: get(ro, "label")?.as_str("label")?.to_string(),
                ns_per_iter: get(ro, "ns_per_iter")?.as_f64("ns_per_iter")?,
                reps: get(ro, "reps")?.as_f64("reps")? as u64,
                throughput,
            });
        }
        Ok(BenchReport { schema_version, tag, quick, geomean_sim_msteps_per_s, records })
    }

    /// Schema validation (`trimma bench-check` / the CI smoke job): a
    /// report that parses but carries nonsense must still be rejected.
    pub fn validate(&self) -> Result<(), String> {
        if self.schema_version != SCHEMA_VERSION {
            return Err(format!(
                "unsupported schema_version {} (this build knows {SCHEMA_VERSION})",
                self.schema_version
            ));
        }
        if self.tag.is_empty() {
            return Err("empty tag".into());
        }
        if !self.geomean_sim_msteps_per_s.is_finite() || self.geomean_sim_msteps_per_s < 0.0 {
            return Err(format!(
                "geomean_sim_msteps_per_s {} is not a finite non-negative number",
                self.geomean_sim_msteps_per_s
            ));
        }
        for r in &self.records {
            if r.label.is_empty() {
                return Err("record with empty label".into());
            }
            if !r.ns_per_iter.is_finite() || r.ns_per_iter < 0.0 {
                return Err(format!("record '{}': bad ns_per_iter {}", r.label, r.ns_per_iter));
            }
            if r.reps == 0 {
                return Err(format!("record '{}': zero reps", r.label));
            }
            if let Some(t) = r.throughput {
                if !t.is_finite() || t <= 0.0 {
                    return Err(format!("record '{}': bad throughput {t}", r.label));
                }
            }
        }
        Ok(())
    }
}

/// Ratio `new / baseline` of geomean sim throughput. `None` when the two
/// reports are not comparable: either side recorded no sim sweep (geomean
/// 0 — e.g. the placeholder baseline committed before the first reference
/// run), or the `quick` flags differ (quick and full sweeps run at
/// different scales, so their per-step throughputs differ systematically).
/// The CI gate skips the comparison in both cases instead of failing.
pub fn throughput_ratio(baseline: &BenchReport, new: &BenchReport) -> Option<f64> {
    if baseline.quick == new.quick
        && baseline.geomean_sim_msteps_per_s > 0.0
        && new.geomean_sim_msteps_per_s > 0.0
    {
        Some(new.geomean_sim_msteps_per_s / baseline.geomean_sim_msteps_per_s)
    } else {
        None
    }
}

/// Labels from `required` (a comma-separated list, entries trimmed, empty
/// entries ignored) that have **no** record in `report` — the CI
/// `bench-check --require-labels` gate. Order follows `required`, so the
/// error message reads in the same order the gate was configured.
pub fn missing_labels(report: &BenchReport, required: &str) -> Vec<String> {
    required
        .split(',')
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .filter(|l| !report.records.iter().any(|r| r.label == *l))
        .map(str::to_string)
        .collect()
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON number formatting: Rust's `{:?}` for floats is the shortest string
/// that round-trips exactly, and is always valid JSON for finite values.
fn json_num(v: f64) -> String {
    debug_assert!(v.is_finite(), "non-finite value in bench report");
    if v.is_finite() { format!("{v:?}") } else { "0.0".to_string() }
}

// ---------------- minimal JSON parser ----------------
// Just enough JSON (objects, arrays, strings with standard escapes,
// numbers, booleans, null) to read reports back. No external crates in
// this offline build, so the parser lives here, next to its only schema.

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    fn as_obj(&self, what: &str) -> Result<&[(String, Json)], String> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(format!("{what}: expected object")),
        }
    }

    fn as_arr(&self, what: &str) -> Result<&[Json], String> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(format!("{what}: expected array")),
        }
    }

    fn as_str(&self, what: &str) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(format!("{what}: expected string")),
        }
    }

    fn as_f64(&self, what: &str) -> Result<f64, String> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(format!("{what}: expected number")),
        }
    }

    fn as_bool(&self, what: &str) -> Result<bool, String> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(format!("{what}: expected boolean")),
        }
    }
}

fn get<'a>(obj: &'a [(String, Json)], key: &str) -> Result<&'a Json, String> {
    obj.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing field '{key}'"))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            // `self.i` points at the 'u'. Non-BMP chars
                            // (e.g. emoji) arrive as UTF-16 surrogate
                            // pairs from standard serializers.
                            let hi = self.hex4(self.i + 1)?;
                            self.i += 5;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err("unpaired high surrogate in \\u escape".into());
                                }
                                let lo = self.hex4(self.i + 2)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("unpaired high surrogate in \\u escape".into());
                                }
                                self.i += 6;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| "invalid \\u escape".to_string())?,
                            );
                            continue;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so byte
                    // boundaries are valid).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    /// Four hex digits starting at byte `at` (for `\u` escapes).
    fn hex4(&self, at: usize) -> Result<u32, String> {
        if at + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        std::str::from_utf8(&self.b[at..at + 4])
            .ok()
            .and_then(|h| u32::from_str_radix(h, 16).ok())
            .ok_or_else(|| "bad \\u escape".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_reports_positive_ns_and_records() {
        let mut b = Bench::with_target("self-test", 1e6);
        let ns = b.iter("noop-ish", || std::hint::black_box(1 + 1));
        assert!(ns > 0.0);
        assert_eq!(b.records().len(), 1);
        assert_eq!(b.records()[0].label, "noop-ish");
        assert!(b.records()[0].reps >= 3);
        assert!(b.records()[0].throughput.is_none());
    }

    #[test]
    fn once_returns_value_and_attaches_throughput() {
        let mut b = Bench::new("self-test");
        let (v, dt) = b.once("compute", || 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
        b.attach_throughput(123.5);
        assert_eq!(b.records()[0].reps, 1);
        assert_eq!(b.records()[0].throughput, Some(123.5));
    }

    #[test]
    fn zero_duration_labels_round_trip_through_a_valid_report() {
        // A no-op body is the worst case for the zero-ns hazard: even if
        // the whole timed pass rounds to zero on a coarse clock, the
        // clamp guarantees a strictly positive duration, the derived
        // throughput stays finite, and the serialized report passes the
        // same validation `bench-check` applies.
        let mut b = Bench::with_target("self-test", 1e5);
        let ns = b.iter("noop", || ());
        assert!(ns > 0.0, "clamp must keep ns/iter strictly positive");
        let (_, dt) = b.once("instant", || ());
        assert!(dt >= 1e-9, "clamp must keep dt at >= 1 ns");
        b.attach_throughput(1.0 / dt);
        let report = BenchReport {
            schema_version: SCHEMA_VERSION,
            tag: "zero-ns".into(),
            quick: true,
            geomean_sim_msteps_per_s: 0.0,
            records: b.into_records(),
        };
        for r in &report.records {
            assert!(r.ns_per_iter.is_finite() && r.ns_per_iter > 0.0, "{}", r.label);
        }
        let parsed = BenchReport::from_json(&report.to_json()).unwrap();
        assert_eq!(parsed, report);
        parsed.validate().unwrap();
    }

    #[test]
    fn attach_throughput_drops_non_finite_and_non_positive_values() {
        let mut b = Bench::new("self-test");
        b.once("compute", || 42);
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN, 0.0, -3.5] {
            b.attach_throughput(bad);
            assert_eq!(b.records()[0].throughput, None, "must drop {bad}");
        }
        b.attach_throughput(2.5);
        assert_eq!(b.records()[0].throughput, Some(2.5));
    }

    fn sample_report() -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            tag: "unit \"quoted\"\\tag".to_string(),
            quick: true,
            geomean_sim_msteps_per_s: 3.25,
            records: vec![
                Record {
                    label: "irt_lookup".into(),
                    ns_per_iter: 12.25,
                    reps: 4096,
                    throughput: None,
                },
                Record {
                    label: "sim/trimma-c/gap_pr".into(),
                    ns_per_iter: 1.5e9,
                    reps: 1,
                    throughput: Some(4.75),
                },
            ],
        }
    }

    #[test]
    fn report_json_round_trips_exactly() {
        let r = sample_report();
        let parsed = BenchReport::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed, r);
        parsed.validate().unwrap();
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(BenchReport::from_json("").is_err());
        assert!(BenchReport::from_json("{").is_err());
        assert!(BenchReport::from_json("{\"tag\": \"x\"}").is_err()); // missing fields
        assert!(BenchReport::from_json("[1, 2]").is_err());
        let trailing = sample_report().to_json() + "garbage";
        assert!(BenchReport::from_json(&trailing).is_err());
    }

    #[test]
    fn validate_rejects_schema_and_value_errors() {
        let mut r = sample_report();
        r.schema_version += 1;
        assert!(r.validate().is_err());

        let mut r = sample_report();
        r.records[0].reps = 0;
        assert!(r.validate().is_err());

        let mut r = sample_report();
        r.records[1].throughput = Some(-1.0);
        assert!(r.validate().is_err());

        let mut r = sample_report();
        r.geomean_sim_msteps_per_s = f64::NAN;
        assert!(r.validate().is_err());
    }

    #[test]
    fn throughput_ratio_skips_unrecorded_baseline() {
        let mut base = sample_report();
        let new = sample_report();
        assert_eq!(throughput_ratio(&base, &new), Some(1.0));
        base.geomean_sim_msteps_per_s = 0.0;
        assert_eq!(throughput_ratio(&base, &new), None);
    }

    #[test]
    fn throughput_ratio_refuses_quick_vs_full() {
        // Quick and full sweeps run at different scales; comparing them
        // would make the CI gate fire on scale, not on regressions.
        let base = sample_report(); // quick: true
        let mut new = sample_report();
        new.quick = false;
        assert_eq!(throughput_ratio(&base, &new), None);
    }

    #[test]
    fn missing_labels_reports_only_absent_ones_in_order() {
        let r = sample_report(); // has irt_lookup and sim/trimma-c/gap_pr
        assert!(missing_labels(&r, "irt_lookup").is_empty());
        assert!(missing_labels(&r, "").is_empty());
        assert_eq!(
            missing_labels(&r, "tenant_mix/8, irt_lookup, tenant_mix/1,"),
            vec!["tenant_mix/8".to_string(), "tenant_mix/1".to_string()]
        );
        // Whitespace around entries is tolerated; substrings don't count.
        assert!(missing_labels(&r, " sim/trimma-c/gap_pr ").is_empty());
        assert_eq!(missing_labels(&r, "sim/trimma-c"), vec!["sim/trimma-c".to_string()]);
    }

    #[test]
    fn parser_handles_surrogate_pair_escapes() {
        // Standard serializers escape non-BMP characters as UTF-16
        // surrogate pairs; a spec-valid report must parse.
        let mut r = sample_report();
        r.tag = "😀-tagged".to_string();
        let escaped = r.to_json().replace("😀", "\\ud83d\\ude00");
        let parsed = BenchReport::from_json(&escaped).unwrap();
        assert_eq!(parsed, r);
        // Unpaired surrogates are malformed, not silently mangled.
        assert!(BenchReport::from_json(&r.to_json().replace("😀", "\\ud83d")).is_err());
        assert!(BenchReport::from_json(&r.to_json().replace("😀", "\\ude00")).is_err());
    }
}

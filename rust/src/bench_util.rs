//! Minimal benchmark harness (criterion is unavailable in this offline
//! environment). `cargo bench` targets use [`Bench`] to get
//! warmup + repeated timed iterations and criterion-style output:
//!
//! ```text
//! irt_lookup_hit          ... 12.3 ns/iter (4096 iters x 64 reps)
//! ```

use std::time::Instant;

/// One benchmark group; prints results to stdout.
pub struct Bench {
    name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("== bench: {name} ==");
        Bench { name }
    }

    /// Time `f` (which should perform one logical iteration) and report
    /// ns/iter. Runs a warmup, then enough reps to cover ~200 ms.
    pub fn iter<R>(&self, label: &str, mut f: impl FnMut() -> R) -> f64 {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut calib = 0u64;
        while t0.elapsed().as_millis() < 50 {
            std::hint::black_box(f());
            calib += 1;
        }
        let per = t0.elapsed().as_nanos() as f64 / calib as f64;
        let reps = ((200e6 / per.max(1.0)) as u64).clamp(3, 5_000_000);

        let t1 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        let ns = t1.elapsed().as_nanos() as f64 / reps as f64;
        println!("{:<40} ... {:>12.1} ns/iter ({} reps)", label, ns, reps);
        ns
    }

    /// Time one long-running operation (e.g., a whole simulation) once and
    /// report seconds plus a caller-computed throughput metric.
    pub fn once<R>(&self, label: &str, f: impl FnOnce() -> R) -> (R, f64) {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<40} ... {:>10.3} s", label, dt);
        (r, dt)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_reports_positive_ns() {
        let b = Bench::new("self-test");
        let ns = b.iter("noop-ish", || std::hint::black_box(1 + 1));
        assert!(ns > 0.0);
    }

    #[test]
    fn once_returns_value() {
        let b = Bench::new("self-test");
        let (v, dt) = b.once("compute", || 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}

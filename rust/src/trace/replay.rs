//! Streaming trace replay: [`TraceWorkload`] turns a recorded trace file
//! back into a [`Workload`], byte-identical to the live run it captured
//! (DESIGN.md §13).
//!
//! ## Streaming, not loading
//!
//! The whole file is never resident: each core owns one decoded chunk
//! buffer (`trace.chunk_records` records) plus whatever the read-ahead
//! rings hold, so a multi-billion-access trace replays in a few MiB of
//! memory. Two I/O strategies, selected by `cfg.trace.replay`:
//!
//! * **Buffered** (portable default): the simulation thread seeks and
//!   reads the next chunk of a core's stream on demand, decoding into
//!   that core's reused buffer. No threads, no rings.
//! * **ReadAhead**: chunk I/O + CRC + decode move to one dedicated I/O
//!   thread (the PR 5 router-thread pattern), which stages decoded
//!   buffers into per-core SPSC rings (`read_ahead_chunks` deep; 2 =
//!   double-buffered). Consumed buffers return through a recycle ring,
//!   so the buffer pool — `cores * (read_ahead_chunks + 2)` buffers,
//!   preallocated at open — circulates with **zero steady-state
//!   allocations** (locked by `tests/alloc_free.rs`). The I/O thread
//!   never blocks: a full per-core ring just means it serves the other
//!   cores, so cross-core schedule skew (which differs between closed,
//!   sharded, and pipelined runs) can never deadlock it. An mmap path
//!   is future work — this container has no mmap crate, and read-ahead
//!   already overlaps disk latency with simulation.
//!
//! The per-core chunk index at the end of the file is what makes both
//! modes schedule-proof: every core has an independent cursor into its
//! own chunk chain, so nothing about replay depends on how the recording
//! run interleaved cores.
//!
//! ## Determinism and the filler contract
//!
//! A trace stores exactly `warmup_per_core + accesses_per_core` records
//! per core — the consumed stream — and every execution mode consumes
//! exactly that many, so replayed stats are byte-identical to the live
//! run across shard counts and the pipelined/inline front end
//! (`tests/trace_parity.rs`). The generation stage, however, *prefetches*
//! past consumption: [`ExecCore`](crate::sim::ExecCore) double-buffers
//! `2 * GEN_BATCH` accesses per core. Draws past end-of-trace therefore
//! return an inert filler access (`read 0, gap 0`) — provably never
//! consumed, merely buffered and dropped.
//!
//! ## Panic audit (crate lint: `clippy::unwrap_used`)
//!
//! All *anticipatable* failures — corruption, truncation, config
//! mismatch, thread-spawn failure — surface as typed [`TraceError`]s at
//! [`TraceWorkload::open`]. The deliberate panics in [`refill`] are the
//! one survivor class: a chunk read failing *mid-run*, after open-time
//! validation passed, means the file changed or the disk failed under
//! us; `Workload::next` has no error channel (by design — the hot path
//! returns accesses, not `Result`s), and no caller could meaningfully
//! continue a half-replayed deterministic run anyway.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::{SystemConfig, TraceReplayMode};
use crate::engine::sharded::{spsc_channel, Consumer, Producer};
use crate::types::MemAccess;
use crate::workloads::Workload;

use super::format::{TraceError, TraceMeta, TraceReader};

/// The inert access served past end-of-trace (see the module docs).
#[inline]
fn filler() -> MemAccess {
    MemAccess::read(0, 0)
}

/// One core's replay position: the currently decoded chunk, the draw
/// offset within it, how many chunks were consumed, and how many records
/// the trace still owes this core.
struct Cursor {
    buf: Vec<MemAccess>,
    pos: usize,
    chunks_taken: usize,
    remaining: u64,
}

/// Where refills come from — the replay I/O strategy.
enum Source {
    /// Inline reads on the simulation thread.
    Buffered(TraceReader),
    /// Dedicated I/O thread behind per-core rings.
    ReadAhead(ReadAhead),
}

/// The read-ahead machinery owned by the consumer side: per-core data
/// rings, the recycle ring back to the I/O thread, and the thread handle.
struct ReadAhead {
    rings: Vec<Consumer<Vec<MemAccess>>>,
    recycle: Producer<Vec<MemAccess>>,
    stop: Arc<AtomicBool>,
    failure: Arc<Mutex<Option<String>>>,
    handle: Option<JoinHandle<()>>,
}

fn take_failure(failure: &Mutex<Option<String>>) -> Option<String> {
    match failure.lock() {
        Ok(mut g) => g.take(),
        Err(p) => p.into_inner().take(),
    }
}

impl ReadAhead {
    /// Move `reader` onto a spawned I/O thread and wire up the rings.
    /// `depth` is `read_ahead_chunks` (ring depth per core). Thread
    /// creation can fail under resource exhaustion, so this surfaces
    /// [`TraceError::Io`] instead of panicking.
    fn spawn(
        mut reader: TraceReader,
        cores: usize,
        depth: usize,
        chunk_records: usize,
    ) -> Result<Self, TraceError> {
        let ring_cap = depth.next_power_of_two();
        let mut data_tx = Vec::with_capacity(cores);
        let mut rings = Vec::with_capacity(cores);
        for _ in 0..cores {
            let (tx, rx) = spsc_channel::<Vec<MemAccess>>(ring_cap);
            data_tx.push(tx);
            rings.push(rx);
        }
        // Pool sizing: each core can hold at most `depth` buffers in its
        // ring plus one staged on the I/O thread — the consumer's held
        // buffer is allocated with the cursors. The recycle ring is sized
        // to hold every pool buffer at once, so returning one never spins.
        let pool = cores * (depth + 1);
        let (recycle, mut recycle_rx) =
            spsc_channel::<Vec<MemAccess>>((pool + cores).next_power_of_two());
        let mut free: Vec<Vec<MemAccess>> =
            (0..pool).map(|_| Vec::with_capacity(chunk_records)).collect();
        let stop = Arc::new(AtomicBool::new(false));
        let failure = Arc::new(Mutex::new(None));
        let chunks: Vec<usize> = (0..cores).map(|c| reader.chunks_for(c)).collect();

        let stop2 = Arc::clone(&stop);
        let failure2 = Arc::clone(&failure);
        let handle = std::thread::Builder::new()
            .name("trace-readahead".into())
            .spawn(move || {
                let mut next_chunk = vec![0usize; cores];
                let mut staged: Vec<Option<Vec<MemAccess>>> = (0..cores).map(|_| None).collect();
                'io: loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    // Harvest returned buffers without blocking.
                    while let Some(buf) = recycle_rx.try_pop() {
                        free.push(buf);
                    }
                    let mut progress = false;
                    let mut done = true;
                    for core in 0..cores {
                        // Decode ahead into a free buffer, if one exists.
                        if staged[core].is_none() && next_chunk[core] < chunks[core] {
                            if let Some(mut buf) = free.pop() {
                                match reader.read_core_chunk(core, next_chunk[core], &mut buf) {
                                    Ok(()) => {
                                        next_chunk[core] += 1;
                                        staged[core] = Some(buf);
                                        progress = true;
                                    }
                                    Err(e) => {
                                        if let Ok(mut g) = failure2.lock() {
                                            *g = Some(e.to_string());
                                        }
                                        break 'io;
                                    }
                                }
                            }
                        }
                        // Hand the staged buffer over — never blocking: a
                        // full ring means the consumer is behind on this
                        // core, so serve the others and retry later.
                        if let Some(buf) = staged[core].take() {
                            match data_tx[core].try_push(buf) {
                                Ok(()) => progress = true,
                                Err(back) => staged[core] = Some(back),
                            }
                        }
                        if staged[core].is_some() || next_chunk[core] < chunks[core] {
                            done = false;
                        }
                    }
                    if done {
                        break;
                    }
                    if !progress {
                        std::thread::yield_now();
                    }
                }
                // Dropping `data_tx` here closes every ring: consumers see
                // `None` after draining whatever was staged.
            })
            .map_err(|e| {
                TraceError::Io(format!("failed to spawn the trace read-ahead thread: {e}"))
            })?;
        Ok(ReadAhead { rings, recycle, stop, failure, handle: Some(handle) })
    }
}

impl Drop for ReadAhead {
    fn drop(&mut self) {
        // The I/O thread never blocks, so it observes the stop flag
        // promptly, drops its producers, and the drain below terminates.
        self.stop.store(true, Ordering::Relaxed);
        for ring in &mut self.rings {
            while ring.recv().is_some() {}
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Refill `c` with `core`'s next chunk. Only called while the trace still
/// owes this core records, so a closed ring / read failure here is an
/// unrecoverable mid-run I/O loss — surfaced as a panic with the typed
/// error's message (all *anticipatable* failures — corruption, config
/// mismatch — were already returned as [`TraceError`]s at open).
fn refill(source: &mut Source, c: &mut Cursor, core: usize) {
    match source {
        Source::Buffered(reader) => {
            if let Err(e) = reader.read_core_chunk(core, c.chunks_taken, &mut c.buf) {
                panic!("trace replay failed mid-run: {e}");
            }
        }
        Source::ReadAhead(ra) => {
            // Return the drained buffer to the pool first (the recycle
            // ring holds the whole pool, so this never spins), then wait
            // for the staged refill.
            let old = std::mem::take(&mut c.buf);
            ra.recycle.send(old);
            match ra.rings[core].recv() {
                Some(buf) => c.buf = buf,
                None => {
                    let msg = take_failure(&ra.failure)
                        .unwrap_or_else(|| "read-ahead thread ended early".to_string());
                    panic!("trace replay failed mid-run: {msg}");
                }
            }
        }
    }
    c.chunks_taken += 1;
    c.pos = 0;
}

/// A recorded trace replayed as a [`Workload`] — open with
/// [`TraceWorkload::open`] (or `EngineBuilder::trace(path)`, or the
/// `trace:<path>` workload name). `name()` reports the *recorded
/// workload's* label, so live and replayed reports line up.
pub struct TraceWorkload {
    meta: TraceMeta,
    cursors: Vec<Cursor>,
    source: Source,
}

impl TraceWorkload {
    /// Open `path` for replay under `cfg`. Fails with a typed
    /// [`TraceError`] on corruption (header/index/chunk CRCs — the full
    /// chunk walk runs when `cfg.trace.validate_on_open` is set) or when
    /// the config's core count / access budgets disagree with the header
    /// (`cfg.workload.{cores,accesses_per_core,warmup_per_core}` must
    /// match; the `trimma replay` CLI adopts them from the header
    /// automatically). Geometry may differ freely — replaying one
    /// recording against many designs is the point.
    pub fn open(path: &Path, cfg: &SystemConfig) -> Result<TraceWorkload, TraceError> {
        let mut reader = TraceReader::open(path)?;
        let meta = reader.meta().clone();
        let w = &cfg.workload;
        if meta.cores != w.cores {
            return Err(TraceError::ConfigMismatch(format!(
                "trace was recorded with {} cores, config wants {}",
                meta.cores, w.cores
            )));
        }
        if meta.accesses_per_core != w.accesses_per_core
            || meta.warmup_per_core != w.warmup_per_core
        {
            return Err(TraceError::ConfigMismatch(format!(
                "trace carries {}+{} (warmup+measured) accesses per core, config wants {}+{}",
                meta.warmup_per_core,
                meta.accesses_per_core,
                w.warmup_per_core,
                w.accesses_per_core
            )));
        }
        if cfg.trace.validate_on_open {
            reader.validate_chunks()?;
        }
        let cores = meta.cores as usize;
        let chunk_records = meta.chunk_records as usize;
        let per_core = meta.records_per_core();
        let cursors = (0..cores)
            .map(|_| Cursor {
                buf: Vec::with_capacity(chunk_records),
                pos: 0,
                chunks_taken: 0,
                remaining: per_core,
            })
            .collect();
        let source = match cfg.trace.replay {
            TraceReplayMode::Buffered => Source::Buffered(reader),
            TraceReplayMode::ReadAhead => Source::ReadAhead(ReadAhead::spawn(
                reader,
                cores,
                cfg.trace.read_ahead_chunks.max(1) as usize,
                chunk_records,
            )?),
        };
        Ok(TraceWorkload { meta, cursors, source })
    }

    /// The trace header's recording-time identity.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }
}

impl Workload for TraceWorkload {
    fn next(&mut self, core: usize) -> MemAccess {
        let c = &mut self.cursors[core];
        if c.remaining == 0 {
            return filler();
        }
        if c.pos == c.buf.len() {
            refill(&mut self.source, c, core);
        }
        let a = c.buf[c.pos];
        c.pos += 1;
        c.remaining -= 1;
        a
    }

    /// Monomorphic bulk path: memcpy out of the decoded chunk across
    /// chunk boundaries, then filler past end-of-trace. Zero allocations
    /// in steady state (`tests/alloc_free.rs`).
    fn next_batch(&mut self, core: usize, out: &mut [MemAccess]) {
        let mut filled = 0;
        while filled < out.len() {
            let c = &mut self.cursors[core];
            if c.remaining == 0 {
                out[filled..].fill(filler());
                return;
            }
            if c.pos == c.buf.len() {
                refill(&mut self.source, c, core);
            }
            let c = &mut self.cursors[core];
            let want = out.len() - filled;
            let take = (c.buf.len() - c.pos).min(want).min(c.remaining as usize);
            out[filled..filled + take].copy_from_slice(&c.buf[c.pos..c.pos + take]);
            c.pos += take;
            c.remaining -= take as u64;
            filled += take;
        }
    }

    fn name(&self) -> &str {
        &self.meta.name
    }

    fn footprint_bytes(&self) -> u64 {
        self.meta.footprint_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::trace::format::{Encoding, TraceMeta, TraceWriter};
    use std::sync::atomic::AtomicU32;

    const CORES: u32 = 3;
    const WARMUP: u64 = 250;
    const ACCESSES: u64 = 1000;

    fn tmp(tag: &str) -> std::path::PathBuf {
        static N: AtomicU32 = AtomicU32::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("trimma-replay-{}-{tag}-{n}.trimtrace", std::process::id()))
    }

    fn reference(core: u64, i: u64) -> MemAccess {
        let addr = (core * 7_654_321 + i * 173) % (1 << 30);
        if (core + i) % 4 == 0 {
            MemAccess::write(addr, (i % 9) as u32)
        } else {
            MemAccess::read(addr, (i % 13) as u32)
        }
    }

    fn write_trace(path: &std::path::Path, chunk_records: u32) {
        let meta = TraceMeta {
            cores: CORES,
            accesses_per_core: ACCESSES,
            warmup_per_core: WARMUP,
            seed: 1,
            footprint_bytes: 1 << 30,
            fingerprint: 0,
            chunk_records,
            encoding: Encoding::Delta,
            name: "replay-unit".to_string(),
        };
        let mut w = TraceWriter::create(path, meta).unwrap();
        for i in 0..WARMUP + ACCESSES {
            for core in 0..CORES as usize {
                w.push(core, reference(core as u64, i)).unwrap();
            }
        }
        w.finish().unwrap();
    }

    fn cfg(mode: TraceReplayMode) -> crate::config::SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.workload.cores = CORES;
        cfg.workload.accesses_per_core = ACCESSES;
        cfg.workload.warmup_per_core = WARMUP;
        cfg.trace.replay = mode;
        cfg
    }

    #[test]
    fn replays_the_exact_stream_in_both_modes() {
        let path = tmp("stream");
        write_trace(&path, 128); // several chunks per core
        for mode in [TraceReplayMode::Buffered, TraceReplayMode::ReadAhead] {
            let mut wl = TraceWorkload::open(&path, &cfg(mode)).unwrap();
            assert_eq!(wl.name(), "replay-unit");
            assert_eq!(wl.footprint_bytes(), 1 << 30);
            // Mixed next/next_batch draws, cores interleaved out of order
            // and at different rates — the per-core purity contract.
            let mut drawn = vec![0u64; CORES as usize];
            let mut batch = vec![filler(); 37];
            // 2x the rounds a core needs, so even the lagging core (which
            // skips every other round) fully drains into filler territory.
            for round in 0..2 * ((WARMUP + ACCESSES) / 37 + 2) {
                for &core in &[2usize, 0, 1] {
                    if core == 1 && round % 2 == 0 {
                        continue; // core 1 lags behind
                    }
                    wl.next_batch(core, &mut batch);
                    for (k, got) in batch.iter().enumerate() {
                        let i = drawn[core] + k as u64;
                        let want = if i < WARMUP + ACCESSES {
                            reference(core as u64, i)
                        } else {
                            filler()
                        };
                        assert_eq!(*got, want, "{mode:?} core {core} record {i}");
                    }
                    drawn[core] += batch.len() as u64;
                }
            }
            // Every core must be fully drained and into filler territory.
            for core in 0..CORES as usize {
                assert!(drawn[core] >= WARMUP + ACCESSES, "core {core} under-drawn");
                assert_eq!(wl.next(core), filler());
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn next_and_next_batch_agree() {
        let path = tmp("agree");
        write_trace(&path, 64);
        let mut a = TraceWorkload::open(&path, &cfg(TraceReplayMode::Buffered)).unwrap();
        let mut b = TraceWorkload::open(&path, &cfg(TraceReplayMode::ReadAhead)).unwrap();
        let mut batch = vec![filler(); 50];
        for core in 0..CORES as usize {
            for _ in 0..30 {
                b.next_batch(core, &mut batch);
                for got in &batch {
                    assert_eq!(a.next(core), *got);
                }
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_rejects_mismatched_run_shape() {
        let path = tmp("shape");
        write_trace(&path, 64);
        let mut bad = cfg(TraceReplayMode::Buffered);
        bad.workload.cores = CORES + 1;
        assert!(matches!(
            TraceWorkload::open(&path, &bad).unwrap_err(),
            TraceError::ConfigMismatch(_)
        ));
        let mut bad = cfg(TraceReplayMode::Buffered);
        bad.workload.accesses_per_core += 1;
        assert!(matches!(
            TraceWorkload::open(&path, &bad).unwrap_err(),
            TraceError::ConfigMismatch(_)
        ));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dropping_a_partially_consumed_readahead_replay_is_clean() {
        let path = tmp("drop");
        write_trace(&path, 32);
        let mut wl = TraceWorkload::open(&path, &cfg(TraceReplayMode::ReadAhead)).unwrap();
        wl.next(0); // touch one core only, then drop mid-stream
        drop(wl);
        let wl = TraceWorkload::open(&path, &cfg(TraceReplayMode::ReadAhead)).unwrap();
        drop(wl); // never touched at all
        std::fs::remove_file(&path).unwrap();
    }
}

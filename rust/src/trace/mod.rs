//! Trace subsystem: record any run's access stream into a compact binary
//! trace file and replay it later as a [`Workload`](crate::workloads::Workload),
//! byte-identical to the live run (DESIGN.md §13).
//!
//! Three layers:
//!
//! * [`format`] — the versioned, little-endian container: CRC'd header,
//!   per-core chunks (raw or delta/varint encoded), and an end-of-file
//!   chunk index that gives every core an independent cursor. The
//!   [`validate`] entry point walks the whole file and returns a
//!   [`TraceSummary`], mirroring `bench_util`'s validate-the-JSON
//!   discipline for the binary format.
//! * [`record`] — [`TraceRecorder`], an
//!   [`AccessTap`](crate::sim::AccessTap) that taps `ExecCore`'s issue
//!   point, so recording works for any synthetic or tenant run with zero
//!   cost when unused (the `NoTap` path monomorphizes away).
//! * [`replay`] — [`TraceWorkload`], a streaming `Workload` over the
//!   chunked reader: an inline buffered mode (portable default) and a
//!   read-ahead mode that moves chunk I/O + decode onto a dedicated
//!   thread behind per-core SPSC rings with a recycled buffer pool
//!   (the PR 5 router-thread pattern). An mmap path is future work —
//!   this container has no `libc`/mmap crate, and buffered reads with
//!   read-ahead already overlap I/O with simulation.
//!
//! Determinism contract: the recorder captures each core's *consumed*
//! stream (warmup included), and per-core consumption is identical in
//! every execution mode (closed loop, any shard count, pipelined or
//! inline) — so one recording replays byte-identically everywhere.
//! `tests/trace_parity.rs` locks this across the adversarial suite.

pub mod format;
pub mod record;
pub mod replay;

pub use format::{validate, Encoding, TraceError, TraceMeta, TraceSummary};
pub use record::TraceRecorder;
pub use replay::TraceWorkload;

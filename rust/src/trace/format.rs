//! The on-disk trace format: a versioned, little-endian container of
//! per-core access streams, written and parsed by hand (this environment
//! is offline — no serde, no compression crates).
//!
//! ## Layout
//!
//! ```text
//! file    := header chunk* index
//! header  := magic[8] version:u32 cores:u32 fingerprint:u64
//!            total_records:u64 accesses_per_core:u64 warmup_per_core:u64
//!            seed:u64 footprint_bytes:u64 chunk_records:u32 encoding:u32
//!            index_offset:u64 chunk_count:u32 name_len:u32
//!            name[name_len] header_crc:u32
//! chunk   := core:u32 record_count:u32 payload_len:u32
//!            payload[payload_len] chunk_crc:u32
//! index   := { core:u32 record_count:u32 payload_len:u32 offset:u64 }
//!            * chunk_count, then index_crc:u32
//! ```
//!
//! Every multi-byte field is little-endian. `total_records`,
//! `index_offset`, and `chunk_count` are patched into the header when the
//! writer finishes; an `index_offset` of zero therefore marks a file whose
//! writer never finished. The header CRC covers every header byte before
//! it, a chunk CRC covers the chunk's 12-byte header plus payload, and
//! the index CRC covers the serialized entries — so corruption anywhere
//! surfaces as a typed [`TraceError`], never a garbled replay.
//!
//! ## Records
//!
//! One record is `{addr, is_write, gap_instrs}`. The issue sketch said
//! `{core, addr, is_write}`; two deliberate deviations: the core id is
//! hoisted into the chunk header (chunks are per-core, so repeating it
//! per record buys nothing), and `gap_instrs` is recorded because the
//! execution core's clocks — and therefore every timing-derived stat —
//! depend on it; without the gap a replay could not be byte-identical.
//!
//! * [`Encoding::Raw`]: 12 bytes per record — `addr` with the write bit
//!   packed into bit 63 (`u64`), then `gap_instrs` (`u32`).
//! * [`Encoding::Delta`]: per record, `varint(zigzag(addr - prev_addr))`
//!   then `varint(gap_instrs << 1 | is_write)`; `prev_addr` resets to 0 at
//!   each chunk boundary so chunks stay independently decodable.
//!
//! The end-of-file index (one entry per chunk, in file order) is what
//! makes replay streaming-friendly: each core's chunk chain can be read
//! on its own cursor without scanning other cores' interleaved chunks,
//! so a replayed core can run arbitrarily far ahead of another without
//! the reader buffering the gap.
//!
//! ## Panic audit (crate lint: `clippy::unwrap_used`)
//!
//! Every fallible parse in this module returns a typed [`TraceError`].
//! The surviving `unwrap()`s — marked `#[allow(clippy::unwrap_used)]` on
//! their functions — are all `try_into()` conversions of fixed-width
//! subslices whose bounds are compile-visible constants (`rec[0..8]`,
//! `fixed[off..off + 4]`, …); they cannot fail without an arithmetic bug
//! in this file itself, which the round-trip and corruption tests below
//! would catch.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::config::SystemConfig;
use crate::types::{AccessKind, MemAccess};

/// File magic, first 8 bytes of every trace.
pub const MAGIC: [u8; 8] = *b"TRIMTRC1";
/// Schema version this build writes and reads.
pub const TRACE_VERSION: u32 = 1;
/// Longest accepted workload label in a header.
const MAX_NAME_LEN: u32 = 1024;
/// Fixed byte length of the header before the name and CRC.
const HEADER_FIXED: usize = 88;
/// Serialized byte length of one index entry.
const INDEX_ENTRY: usize = 20;
/// Byte length of a chunk header (core, record_count, payload_len).
const CHUNK_HEADER: usize = 12;

/// Everything that can go wrong while writing, opening, validating, or
/// streaming a trace file. All payloads are plain data so the error is
/// `Clone + Eq` and can ride inside
/// [`EngineError`](crate::engine::EngineError).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceError {
    /// An I/O error outside the structured corruption cases.
    Io(String),
    /// The file does not start with the trace magic.
    BadMagic,
    /// The header's schema version is not one this build reads.
    UnsupportedVersion(u32),
    /// The header is structurally invalid or fails its CRC.
    CorruptHeader(String),
    /// The end-of-file chunk index is missing, truncated, inconsistent,
    /// or fails its CRC (a truncated file usually surfaces here: the
    /// index lives at the tail).
    CorruptIndex(String),
    /// A chunk read hit end-of-file before `payload_len` bytes arrived.
    TruncatedChunk {
        /// File-order chunk number.
        chunk: u32,
    },
    /// A chunk's stored CRC does not match its bytes.
    ChunkCrcMismatch {
        /// File-order chunk number.
        chunk: u32,
    },
    /// A chunk's payload does not decode to `record_count` records.
    MalformedChunk {
        /// File-order chunk number.
        chunk: u32,
        /// What failed to decode.
        reason: String,
    },
    /// The trace cannot drive the requested run (core count or access
    /// budget disagree between the header and the config).
    ConfigMismatch(String),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace I/O error: {e}"),
            TraceError::BadMagic => write!(f, "not a trimma trace (bad magic)"),
            TraceError::UnsupportedVersion(v) => {
                write!(f, "unsupported trace version {v} (this build reads {TRACE_VERSION})")
            }
            TraceError::CorruptHeader(e) => write!(f, "corrupt trace header: {e}"),
            TraceError::CorruptIndex(e) => write!(f, "corrupt trace index: {e}"),
            TraceError::TruncatedChunk { chunk } => {
                write!(f, "trace chunk {chunk} is truncated")
            }
            TraceError::ChunkCrcMismatch { chunk } => {
                write!(f, "trace chunk {chunk} failed its CRC check")
            }
            TraceError::MalformedChunk { chunk, reason } => {
                write!(f, "trace chunk {chunk} is malformed: {reason}")
            }
            TraceError::ConfigMismatch(e) => write!(f, "trace/config mismatch: {e}"),
        }
    }
}

impl std::error::Error for TraceError {}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> Self {
        TraceError::Io(e.to_string())
    }
}

/// Per-chunk payload encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// Fixed 12-byte records: packed `addr`+write-bit (`u64`) then
    /// `gap_instrs` (`u32`).
    Raw,
    /// Per-record zigzag address delta + gap/kind varints (typically
    /// 2-6 bytes per record on real streams).
    Delta,
}

impl Encoding {
    fn code(self) -> u32 {
        match self {
            Encoding::Raw => 0,
            Encoding::Delta => 1,
        }
    }

    fn from_code(code: u32) -> Option<Encoding> {
        match code {
            0 => Some(Encoding::Raw),
            1 => Some(Encoding::Delta),
            _ => None,
        }
    }

    /// Stable label (`raw` / `delta`) for summaries and the CLI.
    pub fn label(&self) -> &'static str {
        match self {
            Encoding::Raw => "raw",
            Encoding::Delta => "delta",
        }
    }
}

/// The recording-time identity of a trace: everything the header stores
/// besides the patched totals. [`TraceWriter::create`] takes it;
/// [`TraceReader`] hands it back.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceMeta {
    /// Core (stream) count the trace was recorded with.
    pub cores: u32,
    /// Measured accesses per core in the recorded run.
    pub accesses_per_core: u64,
    /// Warmup accesses per core (recorded too — replay needs them).
    pub warmup_per_core: u64,
    /// RNG seed of the recorded run (provenance).
    pub seed: u64,
    /// Footprint of the recorded workload, bytes.
    pub footprint_bytes: u64,
    /// FNV-1a fingerprint of the recording geometry + workload knobs
    /// ([`fingerprint`]). Provenance only: replay under a *different*
    /// design is the point of a trace, so a mismatch is not an error.
    pub fingerprint: u64,
    /// Records per full chunk.
    pub chunk_records: u32,
    /// Payload encoding of every chunk.
    pub encoding: Encoding,
    /// Label of the recorded workload.
    pub name: String,
}

impl TraceMeta {
    /// Records each core must carry: warmup + measured accesses.
    pub fn records_per_core(&self) -> u64 {
        self.warmup_per_core + self.accesses_per_core
    }
}

/// One chunk's location and shape, as stored in the end-of-file index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct ChunkRef {
    pub core: u32,
    pub record_count: u32,
    pub payload_len: u32,
    pub offset: u64,
}

/// What [`validate`] reports about a structurally sound trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSummary {
    /// The header's recording-time identity.
    pub meta: TraceMeta,
    /// Total records across all cores.
    pub total_records: u64,
    /// Number of chunks in the file.
    pub chunk_count: u32,
    /// Total file size in bytes.
    pub file_bytes: u64,
}

// ------------------------------------------------------------------ crc

/// CRC32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) lookup table,
/// built at compile time — the container bakes in no checksum crates.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// FNV-1a (64-bit) fingerprint of the recording geometry and workload
/// knobs: workload label, core count, seed, access budgets, tier
/// capacities, block size, and LLC capacity. Stored in the header as
/// provenance; see [`TraceMeta::fingerprint`].
pub fn fingerprint(cfg: &SystemConfig, workload: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    eat(workload.as_bytes());
    eat(&cfg.workload.cores.to_le_bytes());
    eat(&cfg.workload.seed.to_le_bytes());
    eat(&cfg.workload.accesses_per_core.to_le_bytes());
    eat(&cfg.workload.warmup_per_core.to_le_bytes());
    eat(&cfg.hybrid.fast_bytes.to_le_bytes());
    eat(&cfg.hybrid.slow_bytes.to_le_bytes());
    eat(&cfg.hybrid.block_bytes.to_le_bytes());
    eat(&cfg.llc.size_bytes.to_le_bytes());
    h
}

// --------------------------------------------------------------- varint

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos)?;
        *pos += 1;
        if shift > 63 {
            return None;
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

// ----------------------------------------------------- record en/decode

const WRITE_BIT: u64 = 1 << 63;

/// Encode `recs` as one chunk payload into `out` (cleared first; steady
/// state reuses the allocation).
pub(crate) fn encode_chunk(encoding: Encoding, recs: &[MemAccess], out: &mut Vec<u8>) {
    out.clear();
    match encoding {
        Encoding::Raw => {
            for r in recs {
                debug_assert!(r.addr < WRITE_BIT, "address overflows the packed write bit");
                let packed =
                    r.addr | if r.kind == AccessKind::Write { WRITE_BIT } else { 0 };
                out.extend_from_slice(&packed.to_le_bytes());
                out.extend_from_slice(&r.gap_instrs.to_le_bytes());
            }
        }
        Encoding::Delta => {
            let mut prev = 0i64;
            for r in recs {
                let addr = r.addr as i64;
                put_varint(out, zigzag(addr.wrapping_sub(prev)));
                prev = addr;
                let kind_bit = (r.kind == AccessKind::Write) as u64;
                put_varint(out, ((r.gap_instrs as u64) << 1) | kind_bit);
            }
        }
    }
}

fn record(addr: u64, write: bool, gap: u32) -> MemAccess {
    if write {
        MemAccess::write(addr, gap)
    } else {
        MemAccess::read(addr, gap)
    }
}

/// Decode one chunk payload of `count` records into `out` (cleared
/// first). Returns a human-readable reason on malformed input; the caller
/// wraps it into [`TraceError::MalformedChunk`].
// Fixed-width subslice conversions only (see the module's panic audit).
#[allow(clippy::unwrap_used)]
pub(crate) fn decode_chunk(
    encoding: Encoding,
    payload: &[u8],
    count: usize,
    out: &mut Vec<MemAccess>,
) -> Result<(), String> {
    out.clear();
    match encoding {
        Encoding::Raw => {
            if payload.len() != count * 12 {
                return Err(format!(
                    "raw payload is {} bytes, want {} for {count} records",
                    payload.len(),
                    count * 12
                ));
            }
            for rec in payload.chunks_exact(12) {
                let packed = u64::from_le_bytes(rec[0..8].try_into().unwrap());
                let gap = u32::from_le_bytes(rec[8..12].try_into().unwrap());
                out.push(record(packed & !WRITE_BIT, packed & WRITE_BIT != 0, gap));
            }
        }
        Encoding::Delta => {
            let mut pos = 0usize;
            let mut prev = 0i64;
            for i in 0..count {
                let delta = get_varint(payload, &mut pos)
                    .ok_or_else(|| format!("record {i}: truncated address varint"))?;
                let addr = prev.wrapping_add(unzigzag(delta));
                if addr < 0 {
                    return Err(format!("record {i}: negative decoded address"));
                }
                prev = addr;
                let gk = get_varint(payload, &mut pos)
                    .ok_or_else(|| format!("record {i}: truncated gap varint"))?;
                let gap = gk >> 1;
                if gap > u32::MAX as u64 {
                    return Err(format!("record {i}: gap {gap} overflows u32"));
                }
                out.push(record(addr as u64, gk & 1 != 0, gap as u32));
            }
            if pos != payload.len() {
                return Err(format!("{} trailing payload bytes", payload.len() - pos));
            }
        }
    }
    Ok(())
}

// --------------------------------------------------------------- header

fn header_bytes(meta: &TraceMeta, total_records: u64, index_offset: u64, chunk_count: u32) -> Vec<u8> {
    let name = meta.name.as_bytes();
    let mut b = Vec::with_capacity(HEADER_FIXED + name.len() + 4);
    b.extend_from_slice(&MAGIC);
    b.extend_from_slice(&TRACE_VERSION.to_le_bytes());
    b.extend_from_slice(&meta.cores.to_le_bytes());
    b.extend_from_slice(&meta.fingerprint.to_le_bytes());
    b.extend_from_slice(&total_records.to_le_bytes());
    b.extend_from_slice(&meta.accesses_per_core.to_le_bytes());
    b.extend_from_slice(&meta.warmup_per_core.to_le_bytes());
    b.extend_from_slice(&meta.seed.to_le_bytes());
    b.extend_from_slice(&meta.footprint_bytes.to_le_bytes());
    b.extend_from_slice(&meta.chunk_records.to_le_bytes());
    b.extend_from_slice(&meta.encoding.code().to_le_bytes());
    b.extend_from_slice(&index_offset.to_le_bytes());
    b.extend_from_slice(&chunk_count.to_le_bytes());
    b.extend_from_slice(&(name.len() as u32).to_le_bytes());
    debug_assert_eq!(b.len(), HEADER_FIXED);
    b.extend_from_slice(name);
    let crc = crc32(&b);
    b.extend_from_slice(&crc.to_le_bytes());
    b
}

struct ParsedHeader {
    meta: TraceMeta,
    total_records: u64,
    index_offset: u64,
    chunk_count: u32,
    header_len: u64,
}

// Fixed-width subslice conversions only (see the module's panic audit).
#[allow(clippy::unwrap_used)]
fn read_header(file: &mut File) -> Result<ParsedHeader, TraceError> {
    let mut fixed = [0u8; HEADER_FIXED];
    file.read_exact(&mut fixed)
        .map_err(|_| TraceError::CorruptHeader("file shorter than the fixed header".into()))?;
    if fixed[0..8] != MAGIC {
        return Err(TraceError::BadMagic);
    }
    let u32_at = |off: usize| u32::from_le_bytes(fixed[off..off + 4].try_into().unwrap());
    let u64_at = |off: usize| u64::from_le_bytes(fixed[off..off + 8].try_into().unwrap());
    let version = u32_at(8);
    if version != TRACE_VERSION {
        return Err(TraceError::UnsupportedVersion(version));
    }
    let name_len = u32_at(84);
    if name_len > MAX_NAME_LEN {
        return Err(TraceError::CorruptHeader(format!("name_len {name_len} out of range")));
    }
    let mut tail = vec![0u8; name_len as usize + 4];
    file.read_exact(&mut tail)
        .map_err(|_| TraceError::CorruptHeader("file shorter than the header name".into()))?;
    let (name_bytes, crc_bytes) = tail.split_at(name_len as usize);
    let stored_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let mut covered = fixed.to_vec();
    covered.extend_from_slice(name_bytes);
    if crc32(&covered) != stored_crc {
        return Err(TraceError::CorruptHeader("header CRC mismatch".into()));
    }
    let name = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| TraceError::CorruptHeader("workload name is not UTF-8".into()))?;
    let cores = u32_at(12);
    if cores == 0 {
        return Err(TraceError::CorruptHeader("zero cores".into()));
    }
    let chunk_records = u32_at(64);
    if chunk_records == 0 {
        return Err(TraceError::CorruptHeader("zero chunk_records".into()));
    }
    let encoding = Encoding::from_code(u32_at(68))
        .ok_or_else(|| TraceError::CorruptHeader(format!("unknown encoding {}", u32_at(68))))?;
    let index_offset = u64_at(72);
    if index_offset == 0 {
        return Err(TraceError::CorruptHeader(
            "index offset is zero: the writer never finished this trace".into(),
        ));
    }
    Ok(ParsedHeader {
        meta: TraceMeta {
            cores,
            accesses_per_core: u64_at(32),
            warmup_per_core: u64_at(40),
            seed: u64_at(48),
            footprint_bytes: u64_at(56),
            fingerprint: u64_at(16),
            chunk_records,
            encoding,
            name,
        },
        total_records: u64_at(24),
        index_offset,
        chunk_count: u32_at(80),
        header_len: HEADER_FIXED as u64 + name_len as u64 + 4,
    })
}

// --------------------------------------------------------------- writer

/// Streaming trace writer: records accumulate in per-core staging buffers
/// and hit the disk one encoded, CRC'd chunk at a time (buffered chunked
/// writes — the file handle is raw, the buffering is the chunk itself).
/// Call [`TraceWriter::finish`] to emit partial chunks, the index, and
/// the patched header; a file whose writer never finished is rejected by
/// [`TraceReader::open`].
pub struct TraceWriter {
    file: File,
    meta: TraceMeta,
    pending: Vec<Vec<MemAccess>>,
    payload_buf: Vec<u8>,
    chunk_buf: Vec<u8>,
    index: Vec<ChunkRef>,
    pos: u64,
    per_core_records: Vec<u64>,
}

impl TraceWriter {
    /// Create `path` (truncating any existing file) and write the
    /// placeholder header. `meta.chunk_records` must be non-zero and the
    /// workload label at most 1024 bytes.
    pub fn create(path: &Path, meta: TraceMeta) -> Result<TraceWriter, TraceError> {
        if meta.chunk_records == 0 {
            return Err(TraceError::ConfigMismatch("trace.chunk_records must be > 0".into()));
        }
        if meta.cores == 0 {
            return Err(TraceError::ConfigMismatch("trace needs at least one core".into()));
        }
        if meta.name.len() > MAX_NAME_LEN as usize {
            return Err(TraceError::ConfigMismatch(format!(
                "workload label longer than {MAX_NAME_LEN} bytes"
            )));
        }
        let mut file = File::create(path)?;
        let header = header_bytes(&meta, 0, 0, 0);
        file.write_all(&header)?;
        let chunk = meta.chunk_records as usize;
        Ok(TraceWriter {
            pending: (0..meta.cores).map(|_| Vec::with_capacity(chunk)).collect(),
            payload_buf: Vec::with_capacity(chunk * 12),
            chunk_buf: Vec::with_capacity(chunk * 12 + CHUNK_HEADER + 4),
            index: Vec::new(),
            pos: header.len() as u64,
            per_core_records: vec![0; meta.cores as usize],
            file,
            meta,
        })
    }

    /// Append one access to `core`'s stream; flushes a chunk when the
    /// staging buffer fills.
    pub fn push(&mut self, core: usize, acc: MemAccess) -> Result<(), TraceError> {
        self.pending[core].push(acc);
        self.per_core_records[core] += 1;
        if self.pending[core].len() == self.meta.chunk_records as usize {
            self.flush_core(core)?;
        }
        Ok(())
    }

    fn flush_core(&mut self, core: usize) -> Result<(), TraceError> {
        if self.pending[core].is_empty() {
            return Ok(());
        }
        encode_chunk(self.meta.encoding, &self.pending[core], &mut self.payload_buf);
        let chunk = ChunkRef {
            core: core as u32,
            record_count: self.pending[core].len() as u32,
            payload_len: self.payload_buf.len() as u32,
            offset: self.pos,
        };
        self.chunk_buf.clear();
        self.chunk_buf.extend_from_slice(&chunk.core.to_le_bytes());
        self.chunk_buf.extend_from_slice(&chunk.record_count.to_le_bytes());
        self.chunk_buf.extend_from_slice(&chunk.payload_len.to_le_bytes());
        self.chunk_buf.extend_from_slice(&self.payload_buf);
        let crc = crc32(&self.chunk_buf);
        self.chunk_buf.extend_from_slice(&crc.to_le_bytes());
        self.file.write_all(&self.chunk_buf)?;
        self.pos += self.chunk_buf.len() as u64;
        self.index.push(chunk);
        self.pending[core].clear();
        Ok(())
    }

    /// Records written so far, across all cores.
    pub fn records(&self) -> u64 {
        self.per_core_records.iter().sum()
    }

    /// Flush partial chunks, write the index, patch the header, and
    /// return a summary of the finished file.
    pub fn finish(mut self) -> Result<TraceSummary, TraceError> {
        for core in 0..self.meta.cores as usize {
            self.flush_core(core)?;
        }
        let index_offset = self.pos;
        let mut bytes = Vec::with_capacity(self.index.len() * INDEX_ENTRY + 4);
        for c in &self.index {
            bytes.extend_from_slice(&c.core.to_le_bytes());
            bytes.extend_from_slice(&c.record_count.to_le_bytes());
            bytes.extend_from_slice(&c.payload_len.to_le_bytes());
            bytes.extend_from_slice(&c.offset.to_le_bytes());
        }
        let crc = crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        self.file.write_all(&bytes)?;
        let file_bytes = index_offset + bytes.len() as u64;

        let total_records = self.records();
        let header =
            header_bytes(&self.meta, total_records, index_offset, self.index.len() as u32);
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&header)?;
        self.file.flush()?;
        Ok(TraceSummary {
            meta: self.meta,
            total_records,
            chunk_count: self.index.len() as u32,
            file_bytes,
        })
    }
}

// --------------------------------------------------------------- reader

/// Random-access chunk reader over a finished trace file: parses the
/// header and the end-of-file index at open, then serves any core's
/// chunks in stream order through a reused payload buffer (steady-state
/// reads allocate nothing). Every chunk read re-verifies the chunk CRC;
/// [`TraceReader::validate_chunks`] walks the whole file up front.
pub struct TraceReader {
    file: File,
    meta: TraceMeta,
    total_records: u64,
    chunks: Vec<ChunkRef>,
    per_core: Vec<Vec<u32>>,
    payload_buf: Vec<u8>,
    file_bytes: u64,
}

impl TraceReader {
    /// Open and structurally check `path`: header parse + CRC, index
    /// parse + CRC, chunk bounds, and per-core record totals. Does not
    /// touch chunk payloads — pair with [`TraceReader::validate_chunks`]
    /// for a full walk.
    // Fixed-width subslice conversions only (see the module's panic audit).
    #[allow(clippy::unwrap_used)]
    pub fn open(path: &Path) -> Result<TraceReader, TraceError> {
        let mut file = File::open(path)?;
        let h = read_header(&mut file)?;
        let file_bytes = file.metadata()?.len();

        let index_len = h.chunk_count as u64 * INDEX_ENTRY as u64 + 4;
        if h.index_offset < h.header_len || h.index_offset + index_len > file_bytes {
            return Err(TraceError::CorruptIndex(format!(
                "index [{}, {}) outside file of {} bytes (truncated?)",
                h.index_offset,
                h.index_offset + index_len,
                file_bytes
            )));
        }
        file.seek(SeekFrom::Start(h.index_offset))?;
        let mut bytes = vec![0u8; index_len as usize];
        file.read_exact(&mut bytes)
            .map_err(|_| TraceError::CorruptIndex("index read hit end-of-file".into()))?;
        let (entries, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if crc32(entries) != stored_crc {
            return Err(TraceError::CorruptIndex("index CRC mismatch".into()));
        }

        let mut chunks = Vec::with_capacity(h.chunk_count as usize);
        let mut per_core: Vec<Vec<u32>> = vec![Vec::new(); h.meta.cores as usize];
        let mut per_core_records = vec![0u64; h.meta.cores as usize];
        let mut max_payload = 0usize;
        for (i, e) in entries.chunks_exact(INDEX_ENTRY).enumerate() {
            let chunk = ChunkRef {
                core: u32::from_le_bytes(e[0..4].try_into().unwrap()),
                record_count: u32::from_le_bytes(e[4..8].try_into().unwrap()),
                payload_len: u32::from_le_bytes(e[8..12].try_into().unwrap()),
                offset: u64::from_le_bytes(e[12..20].try_into().unwrap()),
            };
            if chunk.core >= h.meta.cores {
                return Err(TraceError::CorruptIndex(format!(
                    "chunk {i} claims core {} of {}",
                    chunk.core, h.meta.cores
                )));
            }
            if chunk.record_count == 0 || chunk.record_count > h.meta.chunk_records {
                return Err(TraceError::CorruptIndex(format!(
                    "chunk {i} claims {} records (chunk_records = {})",
                    chunk.record_count, h.meta.chunk_records
                )));
            }
            let end = chunk.offset + CHUNK_HEADER as u64 + chunk.payload_len as u64 + 4;
            if chunk.offset < h.header_len || end > h.index_offset {
                return Err(TraceError::CorruptIndex(format!(
                    "chunk {i} spans [{}, {end}) outside the chunk region",
                    chunk.offset
                )));
            }
            per_core[chunk.core as usize].push(i as u32);
            per_core_records[chunk.core as usize] += chunk.record_count as u64;
            max_payload = max_payload.max(chunk.payload_len as usize);
            chunks.push(chunk);
        }
        let expect = h.meta.records_per_core();
        for (core, &n) in per_core_records.iter().enumerate() {
            if n != expect {
                return Err(TraceError::CorruptIndex(format!(
                    "core {core} carries {n} records, header promises {expect}"
                )));
            }
        }
        if per_core_records.iter().sum::<u64>() != h.total_records {
            return Err(TraceError::CorruptIndex("per-core records do not sum to total".into()));
        }
        Ok(TraceReader {
            file,
            meta: h.meta,
            total_records: h.total_records,
            chunks,
            per_core,
            payload_buf: vec![0u8; max_payload.max(1)],
            file_bytes,
        })
    }

    /// The header's recording-time identity.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// Total records across all cores.
    pub fn total_records(&self) -> u64 {
        self.total_records
    }

    /// Number of chunks `core` carries.
    pub(crate) fn chunks_for(&self, core: usize) -> usize {
        self.per_core[core].len()
    }

    /// Read and decode `core`'s `i`-th chunk (stream order) into `out`
    /// (cleared first; steady state reuses its allocation). Verifies the
    /// chunk header against the index and the chunk CRC against its
    /// bytes.
    pub(crate) fn read_core_chunk(
        &mut self,
        core: usize,
        i: usize,
        out: &mut Vec<MemAccess>,
    ) -> Result<(), TraceError> {
        let chunk_no = self.per_core[core][i];
        self.read_chunk(chunk_no, out)
    }

    // Fixed-width subslice conversions only (see the module's panic audit).
    #[allow(clippy::unwrap_used)]
    fn read_chunk(&mut self, chunk_no: u32, out: &mut Vec<MemAccess>) -> Result<(), TraceError> {
        let c = self.chunks[chunk_no as usize];
        let total = CHUNK_HEADER + c.payload_len as usize + 4;
        if self.payload_buf.len() < total {
            self.payload_buf.resize(total, 0);
        }
        self.file.seek(SeekFrom::Start(c.offset))?;
        let buf = &mut self.payload_buf[..total];
        self.file
            .read_exact(buf)
            .map_err(|e| match e.kind() {
                std::io::ErrorKind::UnexpectedEof => TraceError::TruncatedChunk { chunk: chunk_no },
                _ => TraceError::Io(e.to_string()),
            })?;
        let core = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        let count = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        let plen = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if core != c.core || count != c.record_count || plen != c.payload_len {
            return Err(TraceError::MalformedChunk {
                chunk: chunk_no,
                reason: "chunk header disagrees with the index".into(),
            });
        }
        let body = total - 4;
        let stored_crc = u32::from_le_bytes(buf[body..total].try_into().unwrap());
        if crc32(&buf[..body]) != stored_crc {
            return Err(TraceError::ChunkCrcMismatch { chunk: chunk_no });
        }
        decode_chunk(
            self.meta.encoding,
            &self.payload_buf[CHUNK_HEADER..body],
            c.record_count as usize,
            out,
        )
        .map_err(|reason| TraceError::MalformedChunk { chunk: chunk_no, reason })
    }

    /// Read and CRC-check every chunk in the file (decoding included), so
    /// corruption anywhere surfaces before a replay starts.
    pub fn validate_chunks(&mut self) -> Result<(), TraceError> {
        let mut out = Vec::with_capacity(self.meta.chunk_records as usize);
        for chunk_no in 0..self.chunks.len() as u32 {
            self.read_chunk(chunk_no, &mut out)?;
        }
        Ok(())
    }

    /// Summarize the open trace (sizes from the header and index).
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            meta: self.meta.clone(),
            total_records: self.total_records,
            chunk_count: self.chunks.len() as u32,
            file_bytes: self.file_bytes,
        }
    }
}

/// Fully validate the trace at `path` — header, index, and every chunk's
/// CRC and decode — and return its summary. This is the `trimma
/// trace-check` entry point, mirroring `bench_util`'s validate-the-JSON
/// discipline for the binary format.
pub fn validate(path: &Path) -> Result<TraceSummary, TraceError> {
    let mut r = TraceReader::open(path)?;
    r.validate_chunks()?;
    Ok(r.summary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn tmp(tag: &str) -> std::path::PathBuf {
        static N: AtomicU32 = AtomicU32::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("trimma-fmt-{}-{tag}-{n}.trimtrace", std::process::id()))
    }

    fn meta(cores: u32, per_core: u64, chunk: u32, encoding: Encoding) -> TraceMeta {
        TraceMeta {
            cores,
            accesses_per_core: per_core,
            warmup_per_core: 0,
            seed: 7,
            footprint_bytes: 1 << 20,
            fingerprint: 0xABCD,
            chunk_records: chunk,
            encoding,
            name: "unit".to_string(),
        }
    }

    fn stream(core: u64, i: u64) -> MemAccess {
        // Mildly adversarial: big forward/backward address swings and both
        // kinds, still per-core pure.
        let addr = (core * 1_000_003 + i * 97 + (i % 7) * 65536) % (1 << 40);
        if i % 3 == 0 {
            MemAccess::write(addr, (i % 11) as u32)
        } else {
            MemAccess::read(addr, (i % 5) as u32)
        }
    }

    fn write_trace(path: &std::path::Path, m: &TraceMeta) -> TraceSummary {
        let mut w = TraceWriter::create(path, m.clone()).unwrap();
        for i in 0..m.records_per_core() {
            for core in 0..m.cores as usize {
                w.push(core, stream(core as u64, i)).unwrap();
            }
        }
        w.finish().unwrap()
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn varint_and_zigzag_round_trip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX / 2, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len());
        }
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(get_varint(&[0x80], &mut 0), None, "dangling continuation");
    }

    #[test]
    fn both_encodings_round_trip_and_delta_is_smaller() {
        let recs: Vec<MemAccess> = (0..500).map(|i| stream(3, i)).collect();
        let mut raw = Vec::new();
        let mut delta = Vec::new();
        encode_chunk(Encoding::Raw, &recs, &mut raw);
        encode_chunk(Encoding::Delta, &recs, &mut delta);
        assert_eq!(raw.len(), recs.len() * 12);
        assert!(delta.len() < raw.len(), "delta ({}) >= raw ({})", delta.len(), raw.len());
        for (enc, buf) in [(Encoding::Raw, &raw), (Encoding::Delta, &delta)] {
            let mut out = Vec::new();
            decode_chunk(enc, buf, recs.len(), &mut out).unwrap();
            assert_eq!(out, recs, "{enc:?}");
        }
    }

    #[test]
    fn write_read_round_trip_both_encodings() {
        for encoding in [Encoding::Raw, Encoding::Delta] {
            let m = meta(3, 1000, 64, encoding);
            let path = tmp(encoding.label());
            let summary = write_trace(&path, &m);
            assert_eq!(summary.total_records, 3000);
            assert_eq!(summary.meta, m);

            let checked = validate(&path).unwrap();
            assert_eq!(checked, summary);

            let mut r = TraceReader::open(&path).unwrap();
            assert_eq!(r.meta(), &m);
            let mut out = Vec::new();
            for core in 0..3usize {
                let mut i = 0u64;
                for c in 0..r.chunks_for(core) {
                    r.read_core_chunk(core, c, &mut out).unwrap();
                    for got in &out {
                        assert_eq!(*got, stream(core as u64, i), "core {core} record {i}");
                        i += 1;
                    }
                }
                assert_eq!(i, m.records_per_core(), "core {core} record total");
            }
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn corruption_is_typed_not_a_panic() {
        let m = meta(2, 300, 64, Encoding::Delta);
        let path = tmp("corrupt");
        write_trace(&path, &m);
        let good = std::fs::read(&path).unwrap();

        let check = |bytes: &[u8]| {
            let p = tmp("mutant");
            std::fs::write(&p, bytes).unwrap();
            let r = validate(&p);
            std::fs::remove_file(&p).unwrap();
            r.unwrap_err()
        };

        // Bad magic.
        let mut b = good.clone();
        b[0] ^= 0xFF;
        assert_eq!(check(&b), TraceError::BadMagic);

        // Future version.
        let mut b = good.clone();
        b[8..12].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(check(&b), TraceError::UnsupportedVersion(99));

        // Header byte flip (cores field) breaks the header CRC.
        let mut b = good.clone();
        b[12] ^= 0x01;
        assert!(matches!(check(&b), TraceError::CorruptHeader(_)));

        // Truncation clips the tail index.
        assert!(matches!(check(&good[..good.len() - 9]), TraceError::CorruptIndex(_)));

        // A payload byte flip fails that chunk's CRC.
        let mut b = good.clone();
        let first_payload = HEADER_FIXED + m.name.len() + 4 + CHUNK_HEADER;
        b[first_payload] ^= 0x40;
        assert!(matches!(check(&b), TraceError::ChunkCrcMismatch { .. }));

        // An unfinished file (placeholder header) is rejected.
        let w = TraceWriter::create(&path, m).unwrap();
        drop(w);
        assert!(matches!(
            TraceReader::open(&path).unwrap_err(),
            TraceError::CorruptHeader(_)
        ));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fingerprint_tracks_workload_and_geometry() {
        let cfg = crate::config::presets::hbm3_ddr5(crate::config::presets::DesignPoint::TrimmaCache);
        let a = fingerprint(&cfg, "gap_pr");
        assert_eq!(a, fingerprint(&cfg, "gap_pr"), "deterministic");
        assert_ne!(a, fingerprint(&cfg, "ycsb_a"), "workload-sensitive");
        let mut small = cfg.clone();
        small.hybrid.fast_bytes /= 2;
        assert_ne!(a, fingerprint(&small, "gap_pr"), "geometry-sensitive");
    }
}

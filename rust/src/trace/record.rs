//! The trace recorder: an [`AccessTap`] that captures a run's per-core
//! access streams into a trace file (DESIGN.md §13).
//!
//! The tap fires at `ExecCore`'s issue point — once per *consumed*
//! access — so the recording is exactly the stream the run executed:
//! `warmup_per_core + accesses_per_core` records per core, independent of
//! the generator's double-buffered prefill overdraw. Crucially,
//! [`AccessTap::reset`] is a **no-op** here: warmup accesses are part of
//! the trace, because a replay must re-execute them to reproduce the
//! post-warmup cache, table, and migration state byte-for-byte.
//!
//! Because per-core consumption is identical in every execution mode, a
//! closed-loop recording replays byte-identically under any shard count
//! and under the pipelined or inline front end — so recording is only
//! wired through the closed loop
//! ([`EngineBuilder::run_recorded`](crate::engine::EngineBuilder::run_recorded)),
//! which is also the execution model whose stats the parity tests pin.

use std::path::Path;

use crate::config::SystemConfig;
use crate::sim::AccessTap;
use crate::types::{Cycle, MemAccess};

use super::format::{fingerprint, Encoding, TraceError, TraceMeta, TraceSummary, TraceWriter};

/// An [`AccessTap`] that streams every consumed access into a
/// [`TraceWriter`]. Create it, run the simulation with the tap attached,
/// then call [`TraceRecorder::finish`] to seal the file.
///
/// Disk I/O happens one encoded chunk at a time (`cfg.trace.chunk_records`
/// records per chunk), so the tap's per-access cost is a bounds-checked
/// push onto a staging buffer. Writer errors are deferred: the tap
/// signature cannot return them, so the first failure is remembered and
/// surfaced by `finish()` as a typed [`TraceError`].
pub struct TraceRecorder {
    writer: TraceWriter,
    failed: Option<TraceError>,
}

impl TraceRecorder {
    /// Create a recorder writing to `path` for a run of `cfg` driving
    /// `workload` (its registered label and footprint go into the
    /// header). Truncates any existing file at `path`.
    pub fn create(
        path: &Path,
        cfg: &SystemConfig,
        workload: &str,
        footprint_bytes: u64,
    ) -> Result<TraceRecorder, TraceError> {
        let meta = TraceMeta {
            cores: cfg.workload.cores,
            accesses_per_core: cfg.workload.accesses_per_core,
            warmup_per_core: cfg.workload.warmup_per_core,
            seed: cfg.workload.seed,
            footprint_bytes,
            fingerprint: fingerprint(cfg, workload),
            chunk_records: cfg.trace.chunk_records,
            encoding: if cfg.trace.delta { Encoding::Delta } else { Encoding::Raw },
            name: workload.to_string(),
        };
        Ok(TraceRecorder { writer: TraceWriter::create(path, meta)?, failed: None })
    }

    /// Seal the trace: flush partial chunks, write the index, patch the
    /// header, and return the file summary. Surfaces any write error that
    /// occurred mid-run.
    pub fn finish(self) -> Result<TraceSummary, TraceError> {
        if let Some(e) = self.failed {
            return Err(e);
        }
        self.writer.finish()
    }
}

impl AccessTap for TraceRecorder {
    #[inline]
    fn record(&mut self, core: usize, acc: &MemAccess, _llc_miss: bool, _miss_lat: Cycle) {
        if self.failed.is_none() {
            if let Err(e) = self.writer.push(core, *acc) {
                self.failed = Some(e);
            }
        }
    }

    /// End-of-warmup is **not** a recording boundary: replay needs the
    /// warmup stream to rebuild state, so the recorder keeps writing.
    fn reset(&mut self) {}
}

//! The `trimma bench` suite: hot-path micro-benchmarks plus an end-to-end
//! simulation sweep, shared between the `hot_paths` cargo-bench target and
//! the `trimma bench [--quick] --json` CLI subcommand (EXPERIMENTS.md
//! §Perf).
//!
//! The micro half times every structure on the per-access critical path
//! (iRT lookup/update, remap-cache and iRC probes, DRAM timing, the CPU
//! cache hierarchy, trace generation, and the full controller access —
//! single and batched). The end-to-end half runs
//! [`SIM_DESIGNS`] x [`SIM_WORKLOADS`] (three design points, three
//! workloads including one adversarial scenario) and reports throughput in
//! **M mem-steps/s** — simulated per-core memory steps (warmup included;
//! they are simulated all the same) per wall-clock second. The geometric
//! mean over the sweep is the headline number CI's soft perf gate tracks
//! against `BENCH_baseline.json`.

use crate::bench_util::{Bench, BenchReport, SCHEMA_VERSION};
use crate::cachesim::Hierarchy;
use crate::config::presets::{self, DesignPoint};
use crate::config::{TenantMixConfig, TenantScenario, TraceReplayMode};
use crate::coordinator::geomean;
use crate::engine::EngineBuilder;
use crate::hybrid::{Access, Controller};
use crate::mem::MemDevice;
use crate::metadata::irc::Irc;
use crate::metadata::irt::IrtTable;
use crate::metadata::remap_cache::RemapCache;
use crate::metadata::SetLayout;
use crate::sim::{ShardedSimulation, Simulation};
use crate::trace::TraceWorkload;
use crate::types::{AccessKind, Rng64};
use crate::workloads::synth::TraceGen;
use crate::workloads::{by_name, suite};

/// Design points of the end-to-end sweep: both Trimma modes plus the
/// linear-table baseline (the walk-heavy worst case).
pub const SIM_DESIGNS: &[DesignPoint] =
    &[DesignPoint::TrimmaCache, DesignPoint::TrimmaFlat, DesignPoint::LinearCache];

/// Workloads of the end-to-end sweep: streaming-graph, key-value, and one
/// adversarial scenario (set-conflict thrash — the eviction-heavy path).
pub const SIM_WORKLOADS: &[&str] = &["gap_pr", "ycsb_a", "adv_set_thrash"];

/// The hot-path micro suite. Every label lands in `b`'s record stream.
pub fn run_hot_paths(b: &mut Bench) {
    // ---- metadata structures ----
    let layout = SetLayout::new(4, 16 << 20, 512 << 20, 256, 33000);
    let mut irt = IrtTable::new(&layout, 2);
    let mut ev = Vec::new();
    let k = layout.indices_per_set();
    let mut rng = Rng64::new(7);
    for _ in 0..10_000 {
        irt.set_mapping(0, rng.next_below(k), rng.next_below(k), &mut ev);
        ev.clear();
    }
    let mut i = 0u64;
    b.iter("irt_lookup", || {
        i = (i + 9973) % k;
        irt.lookup(0, i)
    });
    b.iter("irt_is_identity", || {
        i = (i + 9973) % k;
        irt.is_identity(0, i)
    });
    b.iter("irt_update_cycle", || {
        i = (i + 9973) % k;
        irt.set_mapping(0, i, (i + 5) % k, &mut ev);
        irt.clear_mapping(0, i, &mut ev);
        ev.clear();
    });

    let mut rc = RemapCache::new(2048, 8);
    for j in 0..16384u64 {
        rc.insert(j, j as u32);
    }
    b.iter("remap_cache_probe", || {
        i = i.wrapping_add(977);
        rc.probe(i % 40000)
    });

    let mut irc = Irc::new(2048, 6, 256, 16, 32);
    for j in 0..8192u64 {
        irc.fill_nonid(j * 3, j as u32);
        irc.fill_id_vector(j, 0xAAAA_5555);
    }
    b.iter("irc_probe", || {
        i = i.wrapping_add(977);
        irc.probe(i % 300_000)
    });

    // ---- devices / caches ----
    let mut dev = MemDevice::new(presets::hbm3());
    let mut t = 0u64;
    b.iter("dram_access", || {
        i = i.wrapping_add(0x40_0001);
        t += 30;
        dev.access(i % (16 << 20), 64, AccessKind::Read, t)
    });

    let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    let mut h = Hierarchy::new(16, &cfg.l1d, &cfg.l2, &cfg.llc);
    b.iter("cache_hierarchy_access", || {
        i = i.wrapping_add(4093 * 64);
        h.access((i % 16) as usize, i % (256 << 20), AccessKind::Read)
    });

    // ---- trace generation ----
    let gen = TraceGen::new(suite::profile("gap_pr").unwrap(), 512 << 20, 16);
    let mut step = 0u32;
    b.iter("trace_gen_access", || {
        step = step.wrapping_add(1);
        gen.gen(3, step)
    });

    // ---- full controller access: single and batched, enum vs dyn ----
    // The same Trimma-C controller driven two ways: through the
    // enum-dispatched engine session (what the simulation loop
    // monomorphizes over) and through a boxed `dyn Controller` (the
    // pre-engine seed path). The paired `<base>/enum` + `<base>/dyn`
    // labels feed [`dispatch_deltas`] and `trimma bench-dispatch`.
    let builder = EngineBuilder::new(DesignPoint::TrimmaCache);
    let mut session = builder.build_session().expect("trimma-c preset");
    let f = session.layout().fast_per_set;
    let span = session.layout().slow_per_set;
    let mut now = 0u64;
    b.iter("controller_access/enum", || {
        i = i.wrapping_add(104729);
        now += 40;
        session.push(Access {
            set: (i % 16) as u32,
            idx: f + i % span,
            line: 0,
            kind: AccessKind::Read,
            now,
        })
    });
    let mut batch = [Access::default(); 8];
    b.iter("controller_access_block_x8/enum", || {
        for slot in batch.iter_mut() {
            i = i.wrapping_add(104729);
            now += 40;
            *slot = Access {
                set: (i % 16) as u32,
                idx: f + i % span,
                line: 0,
                kind: AccessKind::Read,
                now,
            };
        }
        session.push_batch(&batch).latency
    });

    let mut dyn_ctrl: Box<dyn Controller> =
        Box::new(builder.build_controller().expect("trimma-c preset"));
    b.iter("controller_access/dyn", || {
        i = i.wrapping_add(104729);
        now += 40;
        dyn_ctrl.access((i % 16) as u32, f + i % span, 0, AccessKind::Read, now)
    });
    b.iter("controller_access_block_x8/dyn", || {
        for slot in batch.iter_mut() {
            i = i.wrapping_add(104729);
            now += 40;
            *slot = Access {
                set: (i % 16) as u32,
                idx: f + i % span,
                line: 0,
                kind: AccessKind::Read,
                now,
            };
        }
        dyn_ctrl.access_block(&batch)
    });
}

/// One `<base>/enum` vs `<base>/dyn` hot-path record pair, compared.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchDelta {
    /// Base label (e.g. `controller_access`).
    pub base: String,
    /// ns/iter through the enum-dispatched engine session.
    pub enum_ns: f64,
    /// ns/iter through a boxed `dyn Controller` (the seed path).
    pub dyn_ns: f64,
    /// `(dyn / enum - 1) * 100`: positive = dynamic dispatch is slower.
    pub delta_pct: f64,
}

/// Pair up the `<base>/enum` + `<base>/dyn` records of `report` (the
/// dispatch-overhead comparison the CI bench-smoke job prints).
pub fn dispatch_deltas(report: &BenchReport) -> Vec<DispatchDelta> {
    let mut out = Vec::new();
    for r in &report.records {
        let Some(base) = r.label.strip_suffix("/enum") else { continue };
        let dyn_label = format!("{base}/dyn");
        if let Some(d) = report.records.iter().find(|r| r.label == dyn_label) {
            out.push(DispatchDelta {
                base: base.to_string(),
                enum_ns: r.ns_per_iter,
                dyn_ns: d.ns_per_iter,
                delta_pct: (d.ns_per_iter / r.ns_per_iter.max(1e-9) - 1.0) * 100.0,
            });
        }
    }
    out
}

/// The end-to-end simulation sweep. Each run is recorded on `b` (label
/// `sim/<design>/<workload>`) with its throughput attached; the returned
/// vector holds the per-run throughputs in M mem-steps/s, sweep order.
pub fn run_sim_sweep(b: &mut Bench, quick: bool) -> Vec<f64> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let mut tputs = Vec::new();
    for dp in SIM_DESIGNS {
        for wl in SIM_WORKLOADS {
            let builder = EngineBuilder::new(*dp).configure(move |cfg| {
                cfg.workload.accesses_per_core = accesses;
                cfg.workload.warmup_per_core = warmup;
            });
            let cfg = builder.build_config().expect("sweep preset");
            // Workload generation stays outside the timed region (as it
            // always has); controller + hierarchy construction and the
            // run itself are what the throughput metric measures.
            let w = by_name(wl, &cfg).unwrap_or_else(|e| panic!("{e}"));
            let steps = cfg.workload.cores as f64 * (accesses + warmup) as f64;
            let label = format!("sim/{}/{}", dp.label(), wl);
            let (_rep, dt) = b.once(&label, || Simulation::new(&cfg, w).run());
            let msteps_per_s = steps / 1e6 / dt.max(1e-9);
            b.attach_throughput(msteps_per_s);
            println!("  -> {msteps_per_s:.2} M mem-steps/s");
            tputs.push(msteps_per_s);
        }
    }
    tputs
}

/// Shard counts the full sharded-session sweep measures.
pub const SHARD_COUNTS: &[usize] = &[1, 2, 4, 8];

/// Shard counts to measure for a run: `--quick` keeps it to
/// `{1, max(2, shards)}` so CI smoke stays fast; full runs measure
/// [`SHARD_COUNTS`] plus the explicitly requested count.
pub fn shard_counts(quick: bool, shards: usize) -> Vec<usize> {
    let mut counts: Vec<usize> = if quick {
        vec![1, shards.max(2)]
    } else {
        let mut v = SHARD_COUNTS.to_vec();
        if shards > 1 {
            v.push(shards);
        }
        v
    };
    counts.sort_unstable();
    counts.dedup();
    counts
}

/// The sharded end-to-end sweep: the same [`SIM_DESIGNS`] x
/// [`SIM_WORKLOADS`] matrix as [`run_sim_sweep`], driven through the
/// open-loop sharded path (`engine::sharded`) at each count in `counts`.
/// Records one `sharded_session/<n>` label per count with the aggregate
/// throughput attached, prints the session-throughput speedup over the
/// first count (conventionally 1 shard), and returns the
/// `(count, M mem-steps/s)` pairs.
///
/// Unlike the closed-loop sweep, *all* construction (workloads, slice
/// sessions, front ends) happens outside the timed region: slice
/// construction is single-threaded and identical for every count, so
/// timing it would add a constant serial term that deflates the measured
/// N-shard speedup — the number the scaling claim is read off.
pub fn run_sharded_sweep(b: &mut Bench, quick: bool, counts: &[usize]) -> Vec<(usize, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let mut out = Vec::new();
    for &n in counts {
        let mut sims: Vec<ShardedSimulation> = Vec::new();
        let mut steps = 0.0;
        for dp in SIM_DESIGNS {
            for wl in SIM_WORKLOADS {
                let builder = EngineBuilder::new(*dp)
                    .workload(*wl)
                    .shards(n)
                    .configure(move |cfg| {
                        cfg.workload.accesses_per_core = accesses;
                        cfg.workload.warmup_per_core = warmup;
                    });
                let cfg = builder.build_config().expect("sweep preset");
                steps += cfg.workload.cores as f64 * (accesses + warmup) as f64;
                let workload = by_name(wl, &cfg).unwrap_or_else(|e| panic!("{e}"));
                let session = builder.build_sharded().expect("sharded session");
                sims.push(ShardedSimulation::new(&cfg, workload, session));
            }
        }
        let label = format!("sharded_session/{n}");
        let (_done, dt) = b.once(&label, move || {
            for sim in sims {
                sim.run();
            }
        });
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((n, msteps));
    }
    if let Some(&(base_n, base)) = out.first() {
        for &(n, t) in out.iter().skip(1) {
            println!(
                "  sharded session throughput at {n} shards: {:.2}x over {base_n}",
                t / base.max(1e-12)
            );
        }
    }
    out
}

/// The pipelined-front-end comparison sweep: the same [`SIM_DESIGNS`] x
/// [`SIM_WORKLOADS`] matrix as [`run_sharded_sweep`], at one worker count
/// (`shards`, min 2 so the routing stage has real consumers), front end
/// inline vs pipelined. Records one label per mode —
/// `frontend_pipeline/off` and `frontend_pipeline/on` — with the
/// aggregate matrix throughput attached (M mem-steps/s), prints the
/// pipelined speedup over inline, and returns the `(pipelined, msteps)`
/// pairs. Construction stays outside the timed region for the same
/// reason as in [`run_sharded_sweep`].
pub fn run_pipeline_sweep(b: &mut Bench, quick: bool, shards: usize) -> Vec<(bool, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let n = shards.max(2);
    let mut out = Vec::new();
    for pipeline in [false, true] {
        let mut sims: Vec<ShardedSimulation> = Vec::new();
        let mut steps = 0.0;
        for dp in SIM_DESIGNS {
            for wl in SIM_WORKLOADS {
                let builder = EngineBuilder::new(*dp)
                    .workload(*wl)
                    .shards(n)
                    .configure(move |cfg| {
                        cfg.workload.accesses_per_core = accesses;
                        cfg.workload.warmup_per_core = warmup;
                    });
                let cfg = builder.build_config().expect("sweep preset");
                steps += cfg.workload.cores as f64 * (accesses + warmup) as f64;
                let workload = by_name(wl, &cfg).unwrap_or_else(|e| panic!("{e}"));
                let session = builder.build_sharded().expect("sharded session");
                sims.push(ShardedSimulation::new(&cfg, workload, session).pipelined(pipeline));
            }
        }
        let label = format!("frontend_pipeline/{}", if pipeline { "on" } else { "off" });
        let (_done, dt) = b.once(&label, move || {
            for sim in sims {
                sim.run();
            }
        });
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((pipeline, msteps));
    }
    if let [(_, off), (_, on)] = out[..] {
        println!(
            "  pipelined front end at {n} shards: {:.2}x over inline",
            on / off.max(1e-12)
        );
    }
    out
}

/// The metadata-decay comparison sweep: [`SIM_DESIGNS`] x the decay
/// subsystem's target scenario (`adv_metadata_bloat` — stale remaps pile
/// up phase after phase), sharded at `shards` workers, decay off vs on.
/// Records one label per mode — `metadata_decay/off` and
/// `metadata_decay/on` — with the aggregate throughput attached
/// (M mem-steps/s), prints the decay-on throughput ratio over off, and
/// returns the `(decay, msteps)` pairs. Construction stays outside the
/// timed region for the same reason as in [`run_sharded_sweep`].
pub fn run_decay_sweep(b: &mut Bench, quick: bool, shards: usize) -> Vec<(bool, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let n = shards.max(1);
    let mut out = Vec::new();
    for decay in [false, true] {
        let mut sims: Vec<ShardedSimulation> = Vec::new();
        let mut steps = 0.0;
        for dp in SIM_DESIGNS {
            let builder = EngineBuilder::new(*dp)
                .workload("adv_metadata_bloat")
                .shards(n)
                .decay(decay)
                .configure(move |cfg| {
                    cfg.workload.accesses_per_core = accesses;
                    cfg.workload.warmup_per_core = warmup;
                });
            let cfg = builder.build_config().expect("sweep preset");
            steps += cfg.workload.cores as f64 * (accesses + warmup) as f64;
            let workload = by_name("adv_metadata_bloat", &cfg).unwrap_or_else(|e| panic!("{e}"));
            let session = builder.build_sharded().expect("sharded session");
            sims.push(ShardedSimulation::new(&cfg, workload, session));
        }
        let label = format!("metadata_decay/{}", if decay { "on" } else { "off" });
        let (_done, dt) = b.once(&label, move || {
            for sim in sims {
                sim.run();
            }
        });
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((decay, msteps));
    }
    if let [(_, off), (_, on)] = out[..] {
        println!("  metadata decay on: {:.2}x throughput over off", on / off.max(1e-12));
    }
    out
}

/// The fault-injection comparison sweep: [`SIM_DESIGNS`] x the fault
/// subsystem's target scenario (`adv_fault_storm` — a hot working set
/// hammered under wide sweeps, so flips land on live remapped pairs and
/// slow-tier reads keep rolling the transient fault), sharded at `shards`
/// workers, faults off vs on. Records one label per mode —
/// `fault_injection/off` and `fault_injection/on` — with the aggregate
/// throughput attached (M mem-steps/s), prints the faults-on throughput
/// ratio over off (the cost of injection + scrub/rebuild/quarantine
/// recovery), and returns the `(faults, msteps)` pairs. Construction stays
/// outside the timed region for the same reason as in
/// [`run_sharded_sweep`].
pub fn run_fault_sweep(b: &mut Bench, quick: bool, shards: usize) -> Vec<(bool, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let n = shards.max(1);
    let mut out = Vec::new();
    for faults in [false, true] {
        let mut sims: Vec<ShardedSimulation> = Vec::new();
        let mut steps = 0.0;
        for dp in SIM_DESIGNS {
            let builder = EngineBuilder::new(*dp)
                .workload("adv_fault_storm")
                .shards(n)
                .faults(faults)
                .configure(move |cfg| {
                    cfg.workload.accesses_per_core = accesses;
                    cfg.workload.warmup_per_core = warmup;
                });
            let cfg = builder.build_config().expect("sweep preset");
            steps += cfg.workload.cores as f64 * (accesses + warmup) as f64;
            let workload = by_name("adv_fault_storm", &cfg).unwrap_or_else(|e| panic!("{e}"));
            let session = builder.build_sharded().expect("sharded session");
            sims.push(ShardedSimulation::new(&cfg, workload, session));
        }
        let label = format!("fault_injection/{}", if faults { "on" } else { "off" });
        let (_done, dt) = b.once(&label, move || {
            for sim in sims {
                sim.run();
            }
        });
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((faults, msteps));
    }
    if let [(_, off), (_, on)] = out[..] {
        println!("  fault injection on: {:.2}x throughput over off", on / off.max(1e-12));
    }
    out
}

/// The trace-replay comparison sweep: record one closed-loop Trimma-C /
/// `gap_pr` run into a temporary trace file (recording happens **outside**
/// the timed region — construction discipline as in [`run_sharded_sweep`]),
/// then replay it through both I/O modes of
/// [`TraceWorkload`](crate::trace::TraceWorkload). Records one label per
/// mode — `trace_replay/buffered` and `trace_replay/readahead` — with the
/// replay throughput attached (M mem-steps/s), prints the read-ahead
/// throughput ratio over buffered, and returns the `(mode, msteps)` pairs.
/// The temporary trace is removed afterwards.
pub fn run_trace_sweep(b: &mut Bench, quick: bool) -> Vec<(TraceReplayMode, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let path =
        std::env::temp_dir().join(format!("trimma-bench-{}.trimtrace", std::process::id()));
    let builder = EngineBuilder::new(DesignPoint::TrimmaCache).workload("gap_pr").configure(
        move |cfg| {
            cfg.workload.accesses_per_core = accesses;
            cfg.workload.warmup_per_core = warmup;
        },
    );
    builder.run_recorded(&path).unwrap_or_else(|e| panic!("trace recording: {e}"));
    let mut out = Vec::new();
    for mode in [TraceReplayMode::Buffered, TraceReplayMode::ReadAhead] {
        let mut cfg = builder.build_config().expect("sweep preset");
        cfg.trace.replay = mode;
        let steps = cfg.workload.cores as f64 * (accesses + warmup) as f64;
        let workload =
            TraceWorkload::open(&path, &cfg).unwrap_or_else(|e| panic!("trace open: {e}"));
        let mut sim = Simulation::new(&cfg, Box::new(workload));
        let label = format!("trace_replay/{}", mode.label());
        let (_rep, dt) = b.once(&label, move || sim.run());
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((mode, msteps));
    }
    std::fs::remove_file(&path).ok();
    if let [(_, buffered), (_, readahead)] = out[..] {
        println!(
            "  trace replay read-ahead: {:.2}x throughput over buffered",
            readahead / buffered.max(1e-12)
        );
    }
    out
}

/// Tenant counts the multi-tenant sweep measures: `--quick` keeps it to
/// `{1, 8}` so CI smoke stays fast; full runs add the 64-tenant point.
pub fn tenant_counts(quick: bool) -> Vec<u32> {
    if quick { vec![1, 8] } else { vec![1, 8, 64] }
}

/// The multi-tenant serving sweep: the Trimma-C design point under the
/// noisy-neighbor scenario (`sim::tenants`), sharded at `shards` workers,
/// at each tenant count in [`tenant_counts`]. Records one
/// `tenant_mix/<n>` label per count with the throughput attached
/// (M mem-steps/s), prints the per-count throughput ratio over the
/// single-tenant baseline, and returns the `(tenants, msteps)` pairs.
///
/// Unlike the sharded sweeps above, the timed region here is the public
/// end-to-end path ([`EngineBuilder::run_tenant_mix`]), so it includes
/// workload and front-end construction; that cost is identical in shape
/// across counts, and the interesting number is the relative cost of
/// interleaving more tenants.
pub fn run_tenant_sweep(b: &mut Bench, quick: bool, shards: usize) -> Vec<(u32, f64)> {
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let mut out = Vec::new();
    for n in tenant_counts(quick) {
        let builder = EngineBuilder::new(DesignPoint::TrimmaCache)
            .tenants(TenantMixConfig {
                tenants: n,
                scenario: TenantScenario::NoisyNeighbor,
                ..TenantMixConfig::off()
            })
            .shards(shards.max(1))
            .configure(move |cfg| {
                cfg.workload.accesses_per_core = accesses;
                cfg.workload.warmup_per_core = warmup;
            });
        let cfg = builder.build_config().expect("tenant sweep preset");
        let steps = cfg.workload.cores as f64 * (accesses + warmup) as f64;
        let label = format!("tenant_mix/{n}");
        let (rep, dt) = b.once(&label, move || builder.run_tenant_mix());
        let rep = rep.expect("tenant sweep run");
        assert_eq!(rep.tenants.len(), n as usize, "one stats row per tenant");
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((n, msteps));
    }
    if let Some(&(base_n, base)) = out.first() {
        for &(n, t) in out.iter().skip(1) {
            println!(
                "  tenant mix throughput at {n} tenants: {:.2}x over {base_n}",
                t / base.max(1e-12)
            );
        }
    }
    out
}

/// The batched-prefetch comparison sweep, in two halves.
///
/// **Micro:** a 64-access batch pushed through the monomorphic engine
/// session for each of [`SIM_DESIGNS`] — a remap-cache-heavy Trimma-C, a
/// flat-iRT Trimma-F, and the linear-table baseline — with the two-phase
/// prefetched walk off and on (`batched_probe_x64/<design>/{off,on}`).
/// This isolates the translate stage itself: the only difference between
/// the paired labels is the phase-1 `prefetch_targets` walk in
/// [`RemapController::access_block`](crate::hybrid::remap::RemapController).
///
/// **Macro:** the full [`SIM_DESIGNS`] x [`SIM_WORKLOADS`] simulation
/// sweep, sharded at `shards` workers, prefetch off vs on. Records one
/// label per mode — `batched_probe/off` and `batched_probe/on` (the pair
/// CI's `bench-check --require-labels` gates on) — with the aggregate
/// throughput attached (M mem-steps/s), prints the prefetch-on throughput
/// ratio over off, and returns the `(prefetch, msteps)` pairs.
/// Construction stays outside the timed region for the same reason as in
/// [`run_sharded_sweep`].
pub fn run_prefetch_sweep(b: &mut Bench, quick: bool, shards: usize) -> Vec<(bool, f64)> {
    // ---- micro: 64-access batched translate, prefetch off vs on ----
    let mut i = 0u64;
    let mut now = 0u64;
    for dp in SIM_DESIGNS {
        for prefetch in [false, true] {
            let builder = EngineBuilder::new(*dp).prefetch(prefetch);
            let mut session = builder.build_session().expect("sweep preset");
            let f = session.layout().fast_per_set;
            let span = session.layout().slow_per_set;
            let mut batch = [Access::default(); 64];
            let label = format!(
                "batched_probe_x64/{}/{}",
                dp.label(),
                if prefetch { "on" } else { "off" }
            );
            b.iter(&label, || {
                for slot in batch.iter_mut() {
                    i = i.wrapping_add(104729);
                    now += 40;
                    *slot = Access {
                        set: (i % 16) as u32,
                        idx: f + i % span,
                        line: 0,
                        kind: AccessKind::Read,
                        now,
                    };
                }
                session.push_batch(&batch).latency
            });
        }
    }

    // ---- macro: the full sim sweep, prefetch off vs on ----
    let (accesses, warmup) = if quick { (8_000u64, 1_000u64) } else { (40_000, 5_000) };
    let n = shards.max(1);
    let mut out = Vec::new();
    for prefetch in [false, true] {
        let mut sims: Vec<ShardedSimulation> = Vec::new();
        let mut steps = 0.0;
        for dp in SIM_DESIGNS {
            for wl in SIM_WORKLOADS {
                let builder = EngineBuilder::new(*dp)
                    .workload(*wl)
                    .shards(n)
                    .prefetch(prefetch)
                    .configure(move |cfg| {
                        cfg.workload.accesses_per_core = accesses;
                        cfg.workload.warmup_per_core = warmup;
                    });
                let cfg = builder.build_config().expect("sweep preset");
                steps += cfg.workload.cores as f64 * (accesses + warmup) as f64;
                let workload = by_name(wl, &cfg).unwrap_or_else(|e| panic!("{e}"));
                let session = builder.build_sharded().expect("sharded session");
                sims.push(ShardedSimulation::new(&cfg, workload, session));
            }
        }
        let label = format!("batched_probe/{}", if prefetch { "on" } else { "off" });
        let (_done, dt) = b.once(&label, move || {
            for sim in sims {
                sim.run();
            }
        });
        let msteps = steps / 1e6 / dt.max(1e-9);
        b.attach_throughput(msteps);
        println!("  -> {msteps:.2} M mem-steps/s");
        out.push((prefetch, msteps));
    }
    if let [(_, off), (_, on)] = out[..] {
        println!("  batched prefetch on: {:.2}x throughput over off", on / off.max(1e-12));
    }
    out
}

/// Run the whole suite and package it as a schema-versioned report.
/// `shards` feeds [`shard_counts`] for the sharded-session sweep;
/// `pipeline` additionally runs [`run_pipeline_sweep`] (the
/// `frontend_pipeline/{off,on}` labels — `trimma bench --pipeline`, and
/// what CI's bench-smoke asserts); `decay` additionally runs
/// [`run_decay_sweep`] (the `metadata_decay/{off,on}` labels —
/// `trimma bench --decay`, also asserted by CI's bench-smoke).
/// `faults` additionally runs [`run_fault_sweep`] (the
/// `fault_injection/{off,on}` labels — `trimma bench --faults`, also
/// asserted by CI's bench-smoke). `tenants` additionally runs
/// [`run_tenant_sweep`] (the `tenant_mix/<n>` labels — `trimma bench
/// --tenants`, gated by CI's `bench-check --require-labels` pass).
/// `trace` additionally runs [`run_trace_sweep`] (the
/// `trace_replay/{buffered,readahead}` labels — `trimma bench --trace`,
/// also gated by the same label pass). `prefetch` additionally runs
/// [`run_prefetch_sweep`] (the `batched_probe/{off,on}` labels plus the
/// per-design `batched_probe_x64/*` micros — `trimma bench --prefetch`,
/// also gated by the same label pass).
#[allow(clippy::fn_params_excessive_bools, clippy::too_many_arguments)]
pub fn full_report(
    tag: &str,
    quick: bool,
    shards: usize,
    pipeline: bool,
    decay: bool,
    faults: bool,
    tenants: bool,
    trace: bool,
    prefetch: bool,
) -> BenchReport {
    let mut b = if quick {
        // Smoke scale: ~50 ms measurement budget per micro label.
        Bench::with_target("trimma-bench", 50e6)
    } else {
        Bench::new("trimma-bench")
    };
    run_hot_paths(&mut b);
    let tputs = run_sim_sweep(&mut b, quick);
    run_sharded_sweep(&mut b, quick, &shard_counts(quick, shards));
    if pipeline {
        run_pipeline_sweep(&mut b, quick, shards);
    }
    if decay {
        run_decay_sweep(&mut b, quick, shards);
    }
    if faults {
        run_fault_sweep(&mut b, quick, shards);
    }
    if tenants {
        run_tenant_sweep(&mut b, quick, shards);
    }
    if trace {
        run_trace_sweep(&mut b, quick);
    }
    if prefetch {
        run_prefetch_sweep(&mut b, quick, shards);
    }
    BenchReport {
        schema_version: SCHEMA_VERSION,
        tag: tag.to_string(),
        quick,
        geomean_sim_msteps_per_s: geomean(&tputs),
        records: b.into_records(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_util::Record;
    use crate::workloads::by_name;

    #[test]
    fn sweep_matrix_is_three_by_three_with_adversarial() {
        assert_eq!(SIM_DESIGNS.len(), 3);
        assert_eq!(SIM_WORKLOADS.len(), 3);
        assert!(SIM_WORKLOADS.iter().any(|w| w.starts_with("adv_")));
        // Every sweep cell must resolve to a real workload under every
        // swept design point's preset.
        for dp in SIM_DESIGNS {
            let cfg = presets::hbm3_ddr5(*dp);
            for wl in SIM_WORKLOADS {
                assert!(by_name(wl, &cfg).is_ok(), "{}/{wl}", dp.label());
            }
        }
    }

    #[test]
    fn shard_counts_cover_quick_and_full() {
        assert_eq!(shard_counts(true, 2), vec![1, 2]);
        assert_eq!(shard_counts(true, 1), vec![1, 2]);
        assert_eq!(shard_counts(true, 8), vec![1, 8]);
        assert_eq!(shard_counts(false, 1), vec![1, 2, 4, 8]);
        assert_eq!(shard_counts(false, 6), vec![1, 2, 4, 6, 8]);
        assert_eq!(shard_counts(false, 4), vec![1, 2, 4, 8]);
    }

    #[test]
    fn tenant_counts_cover_quick_and_full() {
        assert_eq!(tenant_counts(true), vec![1, 8]);
        assert_eq!(tenant_counts(false), vec![1, 8, 64]);
    }

    #[test]
    fn dispatch_deltas_pairs_enum_and_dyn_records() {
        let rec = |label: &str, ns: f64| Record {
            label: label.to_string(),
            ns_per_iter: ns,
            reps: 100,
            throughput: None,
        };
        let report = BenchReport {
            schema_version: SCHEMA_VERSION,
            tag: "t".into(),
            quick: true,
            geomean_sim_msteps_per_s: 0.0,
            records: vec![
                rec("irt_lookup", 3.0),
                rec("controller_access/enum", 40.0),
                rec("controller_access/dyn", 50.0),
                rec("controller_access_block_x8/enum", 300.0),
                // no matching /dyn for the block label: must be skipped
            ],
        };
        let deltas = dispatch_deltas(&report);
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].base, "controller_access");
        assert!((deltas[0].delta_pct - 25.0).abs() < 1e-9);
    }
}

//! Experiment coordinator: parallel simulation jobs, result tables, and
//! the per-figure sweeps that regenerate the paper's evaluation
//! ([`figures`]).
//!
//! Jobs fan out over `std::thread` workers (one simulation per job; each
//! worker assembles its own run through [`crate::engine::EngineBuilder`],
//! so nothing non-`Send` crosses threads). Results come back as
//! [`crate::sim::SimReport`]s and are formatted into [`Table`]s (markdown
//! to stdout, CSV under `results/`). Failures (e.g. an unknown workload
//! name) come back as typed [`EngineError`]s instead of panicking the
//! worker — all of them, aggregated per sweep in a [`JobFailures`].

// Panic audit: the coordinator (and its bench/figures submodules) is the
// top-level experiment harness — its `expect`s are on conditions the
// harness itself established moments earlier (presets it constructed,
// workers it spawned, job slots it assigned), and aborting the sweep
// with the condition named is exactly what a harness should do.
#![allow(clippy::unwrap_used, clippy::expect_used)]

pub mod bench;
pub mod figures;

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::SystemConfig;
use crate::engine::{EngineBuilder, EngineError};
use crate::sim::SimReport;

/// One simulation to run: an explicit config, a workload name, and the
/// engine's controller-override toggles (the old three-valued `JobKind`
/// is now the `ideal` / `tag_match` pair, mirroring
/// [`EngineBuilder::ideal`] and [`EngineBuilder::tag_match`]).
#[derive(Clone)]
pub struct Job {
    pub label: String,
    pub cfg: SystemConfig,
    pub workload: String,
    /// Run the metadata-free oracle (Fig. 1 "Ideal") instead of the
    /// configured design point.
    pub ideal: bool,
    /// Run generic a-way tag matching (Fig. 1 "tag matching") instead of
    /// the configured design point.
    pub tag_match: bool,
    /// `0` (the default) runs the classic closed-loop simulation;
    /// `n >= 1` runs the open-loop sharded path with `n` worker threads
    /// ([`EngineBuilder::run_sharded`](crate::engine::EngineBuilder::run_sharded)).
    /// The two execution models' timing stats are not comparable — see
    /// DESIGN.md §9.
    pub shards: usize,
    /// Run the sharded path's front end pipelined (shard routing on a
    /// dedicated stage, overlapping trace generation + cache filtering —
    /// [`EngineBuilder::pipeline`](crate::engine::EngineBuilder::pipeline)).
    /// Only meaningful with `shards >= 1`; merged stats are byte-identical
    /// either way.
    pub pipeline: bool,
}

impl Job {
    /// A job for the configured design point.
    pub fn new(label: impl Into<String>, cfg: SystemConfig, workload: &str) -> Self {
        Job {
            label: label.into(),
            cfg,
            workload: workload.to_string(),
            ideal: false,
            tag_match: false,
            shards: 0,
            pipeline: false,
        }
    }

    /// A job for the metadata-free Ideal oracle.
    pub fn ideal(label: impl Into<String>, cfg: SystemConfig, workload: &str) -> Self {
        Job { ideal: true, ..Job::new(label, cfg, workload) }
    }

    /// A job for the generic tag-matching baseline.
    pub fn tag_match(label: impl Into<String>, cfg: SystemConfig, workload: &str) -> Self {
        Job { tag_match: true, ..Job::new(label, cfg, workload) }
    }

    /// Run this job through the open-loop sharded path with `shards`
    /// worker threads instead of the classic closed-loop simulation.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Run this job's sharded front end pipelined (requires
    /// [`Job::with_shards`] with `shards >= 1` to take effect).
    pub fn with_pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// The builder describing this job's run.
    pub fn builder(&self) -> EngineBuilder {
        EngineBuilder::from_config(self.cfg.clone())
            .workload(self.workload.as_str())
            .ideal(self.ideal)
            .tag_match(self.tag_match)
            .shards(self.shards.max(1))
            .pipeline(self.pipeline)
    }
}

/// Run one job to completion (sharded when [`Job::shards`] asks for it).
pub fn run_job(job: &Job) -> Result<SimReport, EngineError> {
    if job.shards > 0 {
        job.builder().run_sharded()
    } else {
        job.builder().run()
    }
}

pub use crate::engine::JobFailures;

/// Run jobs in parallel across up to `threads` workers (0 = all cores).
/// Results are returned in job order. Every failing job is reported (the
/// remaining jobs still run to completion): errors come back as one
/// [`JobFailures`] listing each failing label, wrapped in
/// [`EngineError::Jobs`].
///
/// Result collection is contention-free: each worker pulls job indices
/// off one shared atomic counter and collects `(index, result)` pairs
/// into its own buffer; the buffers are merged after the workers join,
/// so no lock is touched while simulations run.
pub fn run_jobs(jobs: &[Job], threads: usize) -> Result<Vec<SimReport>, EngineError> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(jobs.len().max(1));

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<Result<SimReport, EngineError>>> = Vec::new();
    slots.resize_with(jobs.len(), || None);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        out.push((i, run_job(&jobs[i])));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("job worker panicked") {
                slots[i] = Some(r);
            }
        }
    });

    let mut reports = Vec::with_capacity(jobs.len());
    let mut failures = Vec::new();
    for (job, slot) in jobs.iter().zip(slots) {
        match slot.expect("every job index was claimed by a worker") {
            Ok(rep) => reports.push(rep),
            Err(e) => failures.push((job.label.clone(), e)),
        }
    }
    if failures.is_empty() {
        Ok(reports)
    } else {
        Err(JobFailures { failures }.into())
    }
}

/// A result table: markdown for the terminal, CSV for `results/`.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "{}", self.title);
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out += &format!("| {} |\n", self.columns.join(" | "));
        out += &format!("|{}\n", "---|".repeat(self.columns.len()));
        for r in &self.rows {
            out += &format!("| {} |\n", r.join(" | "));
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut out = self.columns.join(",") + "\n";
        for r in &self.rows {
            out += &(r.join(",") + "\n");
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (directory created if needed).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }
}

/// Geometric mean of the **positive** values in `vals`. Zero, negative,
/// and non-finite entries are skipped (`ln(0) = -inf` would otherwise
/// poison the whole mean into `0` or `NaN`); if nothing positive remains,
/// the result is `0.0`. Callers averaging throughputs thus degrade
/// gracefully when one cell of a sweep records nothing.
pub fn geomean(vals: &[f64]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0u32;
    for &v in vals {
        if v > 0.0 && v.is_finite() {
            sum += v.ln();
            n += 1;
        }
    }
    if n == 0 {
        return 0.0;
    }
    (sum / n as f64).exp()
}

/// Format helpers used across figures.
pub fn fmt(v: f64) -> String {
    format!("{v:.3}")
}
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn tiny(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 4;
        cfg.workload.accesses_per_core = 1500;
        cfg.workload.warmup_per_core = 500;
        cfg
    }

    #[test]
    fn parallel_matches_serial() {
        let jobs: Vec<Job> = ["gap_pr", "ycsb_b"]
            .iter()
            .map(|w| Job::new(*w, tiny(DesignPoint::TrimmaCache), w))
            .collect();
        let par = run_jobs(&jobs, 2).unwrap();
        let ser: Vec<_> = jobs.iter().map(|j| run_job(j).unwrap()).collect();
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.stats.max_core_cycles, s.stats.max_core_cycles);
        }
    }

    #[test]
    fn unknown_workload_surfaces_as_error_not_panic() {
        let job = Job::new("bad", tiny(DesignPoint::TrimmaCache), "no_such_workload");
        let err = run_job(&job).unwrap_err();
        assert!(matches!(err, crate::engine::EngineError::UnknownWorkload(_)));
        let jobs = [Job::new("ok", tiny(DesignPoint::TrimmaCache), "gap_pr"), job];
        assert!(run_jobs(&jobs, 2).is_err());
    }

    #[test]
    fn run_jobs_reports_every_failure_with_labels() {
        let jobs = [
            Job::new("bad-one", tiny(DesignPoint::TrimmaCache), "nope_1"),
            Job::new("fine", tiny(DesignPoint::TrimmaCache), "gap_pr"),
            Job::new("bad-two", tiny(DesignPoint::TrimmaCache), "nope_2"),
        ];
        let err = run_jobs(&jobs, 2).unwrap_err();
        let crate::engine::EngineError::Jobs(fails) = &err else {
            panic!("expected EngineError::Jobs, got {err}");
        };
        assert_eq!(fails.failures.len(), 2);
        assert_eq!(fails.failures[0].0, "bad-one");
        assert_eq!(fails.failures[1].0, "bad-two");
        let msg = err.to_string();
        assert!(msg.contains("bad-one") && msg.contains("bad-two"), "{msg}");
        assert!(msg.contains("2 job(s) failed"), "{msg}");
    }

    #[test]
    fn sharded_job_runs_open_loop() {
        let job =
            Job::new("sharded", tiny(DesignPoint::TrimmaCache), "adv_drift").with_shards(2);
        let rep = run_job(&job).unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.instructions > 0);
    }

    #[test]
    fn pipelined_job_matches_inline_job() {
        let mk = |pipeline| {
            Job::new("piped", tiny(DesignPoint::TrimmaCache), "adv_drift")
                .with_shards(2)
                .with_pipeline(pipeline)
        };
        let inline = run_job(&mk(false)).unwrap();
        let piped = run_job(&mk(true)).unwrap();
        assert!(piped.stats.mem_accesses > 0);
        assert_eq!(inline.stats.canonical(), piped.stats.canonical());
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_skips_zero_and_negative_inputs() {
        // ln(0) = -inf used to poison the mean to 0; ln of a negative is
        // NaN and poisoned it to NaN. Both are now skipped.
        assert!((geomean(&[2.0, 8.0, 0.0]) - 4.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0, -3.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[0.0]), 0.0);
        assert_eq!(geomean(&[-1.0, 0.0]), 0.0);
        assert!((geomean(&[f64::NAN, 5.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert!(t.markdown().contains("| 1 | 2 |"));
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    fn tag_match_job_runs() {
        let mut cfg = tiny(DesignPoint::AlloyCache);
        cfg.hybrid.num_sets = ((cfg.hybrid.fast_bytes / 256) / 64) as u32; // 64-way
        let job = Job::tag_match("tag64", cfg, "gap_pr");
        let rep = run_job(&job).unwrap();
        assert!(rep.stats.metadata_cycles > 0);
    }

    #[test]
    fn ideal_job_runs_oracle() {
        let job = Job::ideal("ideal", tiny(DesignPoint::Ideal), "gap_pr");
        let rep = run_job(&job).unwrap();
        assert!(rep.stats.mem_accesses > 0);
        assert_eq!(rep.stats.metadata_cycles, 0, "the oracle's lookups are free");
    }
}

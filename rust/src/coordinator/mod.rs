//! Experiment coordinator: parallel simulation jobs, result tables, and
//! the per-figure sweeps that regenerate the paper's evaluation
//! ([`figures`]).
//!
//! Jobs fan out over `std::thread` workers (one simulation per job; each
//! worker constructs its own workload/controller, so nothing non-`Send`
//! crosses threads). Results come back as [`crate::sim::SimReport`]s and
//! are formatted into [`Table`]s (markdown to stdout, CSV under
//! `results/`).

pub mod bench;
pub mod figures;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::SystemConfig;
use crate::hybrid::{build_controller, maybe_checked, tagmatch::TagMatchController, Controller};
use crate::sim::{SimReport, Simulation};
use crate::workloads;

/// Which controller a job uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// The configured design point.
    Normal,
    /// The metadata-free oracle (Fig. 1 "Ideal").
    Ideal,
    /// Generic a-way tag matching (Fig. 1 "tag matching").
    TagMatch,
}

/// One simulation to run.
#[derive(Clone)]
pub struct Job {
    pub label: String,
    pub cfg: SystemConfig,
    pub workload: String,
    pub kind: JobKind,
}

impl Job {
    pub fn new(label: impl Into<String>, cfg: SystemConfig, workload: &str) -> Self {
        Job { label: label.into(), cfg, workload: workload.to_string(), kind: JobKind::Normal }
    }
}

/// Run one job to completion.
pub fn run_job(job: &Job) -> SimReport {
    let wl = workloads::by_name(&job.workload, &job.cfg)
        .unwrap_or_else(|| panic!("unknown workload {}", job.workload));
    let ctrl: Box<dyn Controller> = match job.kind {
        JobKind::Normal => build_controller(&job.cfg, false),
        JobKind::Ideal => build_controller(&job.cfg, true),
        JobKind::TagMatch => {
            maybe_checked(Box::new(TagMatchController::new(&job.cfg)), &job.cfg)
        }
    };
    let mut sim = Simulation::with_controller(&job.cfg, wl, ctrl);
    sim.run()
}

/// Run jobs in parallel across up to `threads` workers (0 = all cores).
/// Results are returned in job order.
pub fn run_jobs(jobs: &[Job], threads: usize) -> Vec<SimReport> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(jobs.len().max(1));

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<SimReport>>> = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let rep = run_job(&jobs[i]);
                results.lock().unwrap()[i] = Some(rep);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

/// A result table: markdown for the terminal, CSV for `results/`.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "{}", self.title);
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out += &format!("| {} |\n", self.columns.join(" | "));
        out += &format!("|{}\n", "---|".repeat(self.columns.len()));
        for r in &self.rows {
            out += &format!("| {} |\n", r.join(" | "));
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut out = self.columns.join(",") + "\n";
        for r in &self.rows {
            out += &(r.join(",") + "\n");
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (directory created if needed).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }
}

/// Geometric mean of positive values.
pub fn geomean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len() as f64).exp()
}

/// Format helpers used across figures.
pub fn fmt(v: f64) -> String {
    format!("{v:.3}")
}
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn tiny(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 4;
        cfg.workload.accesses_per_core = 1500;
        cfg.workload.warmup_per_core = 500;
        cfg
    }

    #[test]
    fn parallel_matches_serial() {
        let jobs: Vec<Job> = ["gap_pr", "ycsb_b"]
            .iter()
            .map(|w| Job::new(*w, tiny(DesignPoint::TrimmaCache), w))
            .collect();
        let par = run_jobs(&jobs, 2);
        let ser: Vec<_> = jobs.iter().map(run_job).collect();
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.stats.max_core_cycles, s.stats.max_core_cycles);
        }
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert!(t.markdown().contains("| 1 | 2 |"));
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    fn tagmatch_job_kind_runs() {
        let mut cfg = tiny(DesignPoint::AlloyCache);
        cfg.hybrid.num_sets = ((cfg.hybrid.fast_bytes / 256) / 64) as u32; // 64-way
        let job = Job {
            label: "tag64".into(),
            cfg,
            workload: "gap_pr".into(),
            kind: JobKind::TagMatch,
        };
        let rep = run_job(&job);
        assert!(rep.stats.metadata_cycles > 0);
    }
}

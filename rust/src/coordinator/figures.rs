//! Per-figure sweeps: each function regenerates one figure/table of the
//! paper's evaluation (see DESIGN.md §3 for the index) and returns result
//! [`Table`]s whose rows mirror the series the paper plots.
//!
//! `scale` multiplies the simulated access counts (1.0 = full runs,
//! 0.1 = the CLI's `--quick`).

use super::{fmt, geomean, pct, run_jobs, Job, Table};
use crate::config::presets::{self, DesignPoint};
use crate::config::{RemapCacheKind, SystemConfig};
use crate::engine::EngineError;
use crate::sim::SimReport;
use crate::workloads::SUITE;

/// Representative subset for the sensitivity sweeps (Figs. 12-13), chosen
/// to span streaming (lbm), pointer-chasing (mcf), big-footprint (xz),
/// graph (pr, tc), and key-value (ycsb_a) behaviour.
pub const SENSITIVITY_SUBSET: &[&str] =
    &["505.mcf_r", "519.lbm_r", "557.xz_r", "gap_pr", "gap_tc", "ycsb_a"];

/// All figure ids, in paper order.
pub const ALL_FIGURES: &[&str] = &[
    "fig1", "fig7a", "fig7b", "fig8", "fig9", "fig10", "fig11", "fig12a",
    "fig12b", "fig13a", "fig13b",
];

fn scaled(mut cfg: SystemConfig, scale: f64) -> SystemConfig {
    cfg.workload.accesses_per_core =
        ((cfg.workload.accesses_per_core as f64 * scale) as u64).max(2_000);
    cfg.workload.warmup_per_core =
        ((cfg.workload.warmup_per_core as f64 * scale) as u64).max(500);
    cfg
}

/// Memory technology combination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tech {
    Hbm3Ddr5,
    Ddr5Nvm,
}

fn preset(tech: Tech, dp: DesignPoint) -> SystemConfig {
    match tech {
        Tech::Hbm3Ddr5 => presets::hbm3_ddr5(dp),
        Tech::Ddr5Nvm => presets::ddr5_nvm(dp),
    }
}

/// Position of `dp` in a figure's design-point list. A report whose design
/// point is missing from the list is a wiring bug in the sweep definition;
/// surface it as [`EngineError::InvalidConfig`] naming the stray label
/// instead of panicking mid-report.
fn design_index(dps: &[DesignPoint], dp: DesignPoint) -> Result<usize, EngineError> {
    dps.iter().position(|x| *x == dp).ok_or_else(|| {
        EngineError::InvalidConfig(format!(
            "design point '{}' is not in this figure's design list",
            dp.label()
        ))
    })
}

/// Run one figure by id. Returns its tables (already saved as CSV);
/// unknown ids surface as [`EngineError::UnknownFigure`].
pub fn run_figure(id: &str, scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let tables = match id {
        "fig1" => fig1(scale, threads)?,
        "fig7a" => fig7(Tech::Hbm3Ddr5, "fig7a", scale, threads)?,
        "fig7b" => fig7(Tech::Ddr5Nvm, "fig7b", scale, threads)?,
        "fig8" => fig8(scale, threads)?,
        "fig9" => fig9(scale, threads)?,
        "fig10" => fig10(scale, threads)?,
        "fig11" => fig11(scale, threads)?,
        "fig12a" => fig12a(scale, threads)?,
        "fig12b" => fig12b(scale, threads)?,
        "fig13a" => fig13a(scale, threads)?,
        "fig13b" => fig13b(scale, threads)?,
        _ => return Err(EngineError::UnknownFigure(id.to_string())),
    };
    for t in &tables {
        let name = t
            .title
            .split_whitespace()
            .next()
            .unwrap_or("table")
            .trim_end_matches(':')
            .to_lowercase();
        let _ = t.save_csv(&name);
    }
    Ok(tables)
}

// ---------------------------------------------------------------- fig 1

/// Fig. 1: PageRank performance vs. associativity for Ideal, tag matching,
/// linear table, and Trimma — normalized to Ideal at associativity 1.
pub fn fig1(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let assocs = [1u64, 4, 16, 64, 256, 1024];
    let wl = "gap_pr";
    let mut jobs = Vec::new();
    for &a in &assocs {
        for (series, dp, ideal, tag_match) in [
            ("ideal", DesignPoint::Ideal, true, false),
            ("tag", DesignPoint::AlloyCache, false, true),
            ("linear", DesignPoint::LinearCache, false, false),
            ("trimma", DesignPoint::TrimmaCache, false, false),
        ] {
            let mut cfg = scaled(preset(Tech::Hbm3Ddr5, dp), scale);
            let fast_blocks = cfg.hybrid.fast_blocks();
            cfg.hybrid.num_sets = (fast_blocks / a).max(1) as u32;
            jobs.push(Job {
                label: format!("{series}@{a}"),
                cfg,
                workload: wl.into(),
                ideal,
                tag_match,
                shards: 0,
                pipeline: false,
            });
        }
    }
    let reps = run_jobs(&jobs, threads)?;
    let base = reps[0].performance(); // ideal @ assoc 1
    let mut t = Table::new(
        "fig1: PageRank speedup vs associativity (norm. ideal@1)",
        &["assoc", "ideal", "tag_matching", "linear_table", "trimma"],
    );
    for (i, &a) in assocs.iter().enumerate() {
        let r = &reps[i * 4..(i + 1) * 4];
        t.row(vec![
            a.to_string(),
            fmt(r[0].performance() / base),
            fmt(r[1].performance() / base),
            fmt(r[2].performance() / base),
            fmt(r[3].performance() / base),
        ]);
    }
    Ok(vec![t])
}

// ---------------------------------------------------------------- fig 7

fn suite_jobs(tech: Tech, dps: &[DesignPoint], scale: f64) -> Vec<Job> {
    let mut jobs = Vec::new();
    for wl in SUITE {
        for &dp in dps {
            jobs.push(Job::new(
                format!("{}:{}", dp.label(), wl),
                scaled(preset(tech, dp), scale),
                wl,
            ));
        }
    }
    jobs
}

/// Fig. 7: overall performance, all workloads. Cache designs normalized to
/// Alloy; flat designs normalized to MemPod.
pub fn fig7(tech: Tech, name: &str, scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let dps = [
        DesignPoint::AlloyCache,
        DesignPoint::LohHill,
        DesignPoint::TrimmaCache,
        DesignPoint::MemPod,
        DesignPoint::TrimmaFlat,
    ];
    let jobs = suite_jobs(tech, &dps, scale);
    let reps = run_jobs(&jobs, threads)?;
    let mut t = Table::new(
        format!("{name}: speedups ({})", match tech {
            Tech::Hbm3Ddr5 => "HBM3+DDR5",
            Tech::Ddr5Nvm => "DDR5+NVM",
        }),
        &["workload", "alloy", "loh-hill", "trimma-c", "mempod", "trimma-f"],
    );
    let (mut sc_l, mut sc_t, mut sf_t) = (vec![], vec![], vec![]);
    for (w, chunk) in SUITE.iter().zip(reps.chunks(dps.len())) {
        let alloy = chunk[0].performance();
        let mempod = chunk[3].performance();
        let lh = chunk[1].performance() / alloy;
        let tc = chunk[2].performance() / alloy;
        let tf = chunk[4].performance() / mempod;
        sc_l.push(lh);
        sc_t.push(tc);
        sf_t.push(tf);
        t.row(vec![
            w.to_string(),
            "1.000".into(),
            fmt(lh),
            fmt(tc),
            "1.000".into(),
            fmt(tf),
        ]);
    }
    t.row(vec![
        "geomean".into(),
        "1.000".into(),
        fmt(geomean(&sc_l)),
        fmt(geomean(&sc_t)),
        "1.000".into(),
        fmt(geomean(&sf_t)),
    ]);
    Ok(vec![t])
}

// ---------------------------------------------------------------- fig 8

/// Fig. 8: memory access latency breakdown (metadata / fast / slow), per
/// design, averaged over the suite, on HBM3+DDR5.
pub fn fig8(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let dps = [
        DesignPoint::AlloyCache,
        DesignPoint::LohHill,
        DesignPoint::TrimmaCache,
        DesignPoint::MemPod,
        DesignPoint::TrimmaFlat,
    ];
    let jobs = suite_jobs(Tech::Hbm3Ddr5, &dps, scale);
    let reps = run_jobs(&jobs, threads)?;
    let mut t = Table::new(
        "fig8: AMAT breakdown, cycles/access (HBM3+DDR5)",
        &["workload", "design", "metadata", "fast_data", "slow_data"],
    );
    let mut sums = vec![(0.0, 0.0, 0.0); dps.len()];
    for (w, chunk) in SUITE.iter().zip(reps.chunks(dps.len())) {
        for (d, rep) in dps.iter().zip(chunk) {
            let (m, f, s) = rep.stats.amat_breakdown();
            let e = &mut sums[design_index(&dps, *d)?];
            e.0 += m;
            e.1 += f;
            e.2 += s;
            t.row(vec![w.to_string(), d.label().into(), fmt(m), fmt(f), fmt(s)]);
        }
    }
    let n = SUITE.len() as f64;
    for (d, (m, f, s)) in dps.iter().zip(&sums) {
        t.row(vec![
            "MEAN".into(),
            d.label().into(),
            fmt(m / n),
            fmt(f / n),
            fmt(s / n),
        ]);
    }
    Ok(vec![t])
}

// ------------------------------------------------------------ figs 9/10

fn flat_pair(scale: f64, threads: usize) -> Result<(Vec<SimReport>, Vec<SimReport>), EngineError> {
    let jobs_m = suite_jobs(Tech::Hbm3Ddr5, &[DesignPoint::MemPod], scale);
    let jobs_t = suite_jobs(Tech::Hbm3Ddr5, &[DesignPoint::TrimmaFlat], scale);
    let all: Vec<Job> = jobs_m.into_iter().chain(jobs_t).collect();
    let mut reps = run_jobs(&all, threads)?;
    let t = reps.split_off(SUITE.len());
    Ok((reps, t))
}

/// Fig. 9: metadata size at end of run — Trimma iRT vs MemPod linear table,
/// as a fraction of the fast tier.
pub fn fig9(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let (mempod, trimma) = flat_pair(scale, threads)?;
    let mut t = Table::new(
        "fig9: metadata size (fraction of fast memory)",
        &["workload", "linear(mempod)", "irt(trimma)", "saving"],
    );
    let mut savings = vec![];
    for ((w, m), tr) in SUITE.iter().zip(&mempod).zip(&trimma) {
        let fast = 16.0 * 1024.0 * 1024.0;
        let lin = m.stats.metadata_bytes_used as f64 / fast;
        let irt = tr.stats.metadata_bytes_used as f64 / fast;
        let saving = 1.0 - irt / lin.max(1e-12);
        savings.push(saving);
        t.row(vec![w.to_string(), pct(lin), pct(irt), pct(saving)]);
    }
    t.row(vec![
        "MEAN".into(),
        "-".into(),
        "-".into(),
        pct(savings.iter().sum::<f64>() / savings.len() as f64),
    ]);
    Ok(vec![t])
}

/// Fig. 10: fast-memory serve rate (a) and bandwidth bloat factor (b).
pub fn fig10(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let (mempod, trimma) = flat_pair(scale, threads)?;
    let mut a = Table::new(
        "fig10a: fast memory serve rate",
        &["workload", "mempod", "trimma-f", "delta"],
    );
    let mut b = Table::new(
        "fig10b: bandwidth bloat factor (lower is better)",
        &["workload", "mempod", "trimma-f"],
    );
    let (mut dsum, mut n) = (0.0, 0);
    for ((w, m), tr) in SUITE.iter().zip(&mempod).zip(&trimma) {
        let sm = m.stats.fast_serve_rate();
        let st = tr.stats.fast_serve_rate();
        dsum += st - sm;
        n += 1;
        a.row(vec![w.to_string(), pct(sm), pct(st), pct(st - sm)]);
        b.row(vec![
            w.to_string(),
            fmt(m.stats.bandwidth_bloat()),
            fmt(tr.stats.bandwidth_bloat()),
        ]);
    }
    a.row(vec!["MEAN".into(), "-".into(), "-".into(), pct(dsum / n as f64)]);
    Ok(vec![a, b])
}

// ---------------------------------------------------------------- fig 11

/// Fig. 11: conventional remap cache vs iRC on Trimma-F — performance and
/// remap-cache hit rates.
pub fn fig11(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let mk = |rc: RemapCacheKind, tag: &str, wl: &&str| {
        let mut cfg = scaled(preset(Tech::Hbm3Ddr5, DesignPoint::TrimmaFlat), scale);
        cfg.hybrid.remap_cache = rc;
        Job::new(format!("{tag}:{wl}"), cfg, wl)
    };
    let mut jobs = Vec::new();
    for wl in SUITE {
        jobs.push(mk(presets::conventional_rc(), "conv", wl));
        jobs.push(mk(presets::irc_rc(), "irc", wl));
    }
    let reps = run_jobs(&jobs, threads)?;
    let mut t = Table::new(
        "fig11: conventional RC vs iRC (Trimma-F, HBM3+DDR5)",
        &["workload", "speedup", "conv_hit", "irc_hit", "conv_id_hit", "irc_id_hit"],
    );
    let (mut sp, mut ch, mut ih) = (vec![], vec![], vec![]);
    for (w, pair) in SUITE.iter().zip(reps.chunks(2)) {
        let (c, i) = (&pair[0], &pair[1]);
        let s = i.performance() / c.performance();
        sp.push(s);
        ch.push(c.stats.rc_hit_rate());
        ih.push(i.stats.rc_hit_rate());
        t.row(vec![
            w.to_string(),
            fmt(s),
            pct(c.stats.rc_hit_rate()),
            pct(i.stats.rc_hit_rate()),
            pct(c.stats.rc_id_hit_rate()),
            pct(i.stats.rc_id_hit_rate()),
        ]);
    }
    t.row(vec![
        "MEAN".into(),
        fmt(geomean(&sp)),
        pct(ch.iter().sum::<f64>() / ch.len() as f64),
        pct(ih.iter().sum::<f64>() / ih.len() as f64),
        "-".into(),
        "-".into(),
    ]);
    Ok(vec![t])
}

// --------------------------------------------------------------- fig 12

/// Fig. 12a: Trimma speedup vs slow-to-fast capacity ratio.
pub fn fig12a(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let ratios = [8u64, 16, 32, 64];
    let mut jobs = Vec::new();
    for &r in &ratios {
        for wl in SENSITIVITY_SUBSET {
            for dp in [
                DesignPoint::MemPod,
                DesignPoint::TrimmaFlat,
                DesignPoint::LinearCache,
                DesignPoint::TrimmaCache,
            ] {
                let cfg = presets::with_capacity_ratio(
                    scaled(preset(Tech::Hbm3Ddr5, dp), scale),
                    r,
                );
                jobs.push(Job::new(format!("{}@{r}:{wl}", dp.label()), cfg, wl));
            }
        }
    }
    let reps = run_jobs(&jobs, threads)?;
    let mut t = Table::new(
        "fig12a: Trimma speedup vs capacity ratio (geomean)",
        &["ratio", "trimma-f_vs_mempod", "trimma-c_vs_linear"],
    );
    let per_ratio = SENSITIVITY_SUBSET.len() * 4;
    for (i, &r) in ratios.iter().enumerate() {
        let chunk = &reps[i * per_ratio..(i + 1) * per_ratio];
        let mut flat = vec![];
        let mut cache = vec![];
        for q in chunk.chunks(4) {
            flat.push(q[1].performance() / q[0].performance());
            cache.push(q[3].performance() / q[2].performance());
        }
        t.row(vec![
            format!("{r}:1"),
            fmt(geomean(&flat)),
            fmt(geomean(&cache)),
        ]);
    }
    Ok(vec![t])
}

/// Fig. 12b: performance vs migration block size, normalized to 256 B.
pub fn fig12b(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let blocks = [64u32, 256, 1024, 4096];
    let mut jobs = Vec::new();
    for &b in &blocks {
        for wl in SENSITIVITY_SUBSET {
            let cfg = presets::with_block_bytes(
                scaled(preset(Tech::Hbm3Ddr5, DesignPoint::TrimmaCache), scale),
                b,
            );
            jobs.push(Job::new(format!("b{b}:{wl}"), cfg, wl));
        }
    }
    let reps = run_jobs(&jobs, threads)?;
    let n = SENSITIVITY_SUBSET.len();
    let perf: Vec<f64> = blocks
        .iter()
        .enumerate()
        .map(|(i, _)| {
            geomean(
                &reps[i * n..(i + 1) * n]
                    .iter()
                    .map(|r| r.performance())
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let base = perf[1]; // 256 B
    let mut t = Table::new(
        "fig12b: performance vs block size (norm. 256B, geomean)",
        &["block_bytes", "relative_perf"],
    );
    for (b, p) in blocks.iter().zip(&perf) {
        t.row(vec![b.to_string(), fmt(p / base)]);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- fig 13

/// Fig. 13a: iRT level count ablation (1 = linear, 2 = Trimma, 4 = Tag
/// Tables-like), normalized to 2-level.
pub fn fig13a(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let levels = [1u32, 2, 4];
    let mut jobs = Vec::new();
    for &lv in &levels {
        for wl in SENSITIVITY_SUBSET {
            let mut cfg = scaled(preset(Tech::Hbm3Ddr5, DesignPoint::TrimmaCache), scale);
            cfg.hybrid.scheme = crate::config::MetadataScheme::Irt { levels: lv };
            jobs.push(Job::new(format!("irt{lv}:{wl}"), cfg, wl));
        }
    }
    let reps = run_jobs(&jobs, threads)?;
    let n = SENSITIVITY_SUBSET.len();
    let perf: Vec<f64> = levels
        .iter()
        .enumerate()
        .map(|(i, _)| {
            geomean(
                &reps[i * n..(i + 1) * n]
                    .iter()
                    .map(|r| r.performance())
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let mut t = Table::new(
        "fig13a: iRT level ablation (norm. 2-level, geomean)",
        &["levels", "relative_perf"],
    );
    for (lv, p) in levels.iter().zip(&perf) {
        t.row(vec![lv.to_string(), fmt(p / perf[1])]);
    }
    Ok(vec![t])
}

/// iRC partition for a given fraction of SRAM spent on the IdCache,
/// holding total capacity at the conventional 2048x8 budget.
pub fn irc_partition(id_frac: f64) -> RemapCacheKind {
    if id_frac <= 0.0 {
        return presets::conventional_rc();
    }
    // 16384 entries total; IdCache lines cost one entry's SRAM each.
    let id_lines = (16384.0 * id_frac) as u32;
    let id_ways = 16u32;
    let id_sets = (id_lines / id_ways).next_power_of_two().max(1) / 2 * 2;
    let id_sets = id_sets.max(1);
    let nonid_ways = (((16384.0 * (1.0 - id_frac)) as u32) / 2048).max(1);
    RemapCacheKind::Irc {
        nonid_sets: 2048,
        nonid_ways,
        id_sets,
        id_ways,
        superblock_blocks: 32,
    }
}

/// Fig. 13b: iRC capacity split between NonIdCache and IdCache.
pub fn fig13b(scale: f64, threads: usize) -> Result<Vec<Table>, EngineError> {
    let fracs = [0.0, 0.125, 0.25, 0.5, 0.75];
    let mut jobs = Vec::new();
    for &f in &fracs {
        for wl in SENSITIVITY_SUBSET {
            let mut cfg = scaled(preset(Tech::Hbm3Ddr5, DesignPoint::TrimmaFlat), scale);
            cfg.hybrid.remap_cache = irc_partition(f);
            jobs.push(Job::new(format!("id{f}:{wl}"), cfg, wl));
        }
    }
    let reps = run_jobs(&jobs, threads)?;
    let n = SENSITIVITY_SUBSET.len();
    let mut t = Table::new(
        "fig13b: iRC IdCache capacity fraction (norm. 25%, geomean)",
        &["id_frac", "relative_perf", "rc_hit_rate"],
    );
    let perf: Vec<f64> = fracs
        .iter()
        .enumerate()
        .map(|(i, _)| {
            geomean(
                &reps[i * n..(i + 1) * n]
                    .iter()
                    .map(|r| r.performance())
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let base = perf[2]; // 25%
    for (i, &f) in fracs.iter().enumerate() {
        let hits: f64 = reps[i * n..(i + 1) * n]
            .iter()
            .map(|r| r.stats.rc_hit_rate())
            .sum::<f64>()
            / n as f64;
        t.row(vec![pct(f), fmt(perf[i] / base), pct(hits)]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_known() {
        for id in ALL_FIGURES {
            // Existence check only (scale tiny smoke runs live in
            // rust/tests/end_to_end.rs; running all figures here would be
            // too slow for unit tests).
            assert!(matches!(
                *id,
                "fig1" | "fig7a" | "fig7b" | "fig8" | "fig9" | "fig10" | "fig11"
                    | "fig12a" | "fig12b" | "fig13a" | "fig13b"
            ));
        }
        assert!(matches!(
            run_figure("nope", 1.0, 1),
            Err(EngineError::UnknownFigure(id)) if id == "nope"
        ));
    }

    #[test]
    fn design_index_reports_stray_labels() {
        let dps = [DesignPoint::AlloyCache, DesignPoint::TrimmaCache];
        assert_eq!(design_index(&dps, DesignPoint::TrimmaCache).unwrap(), 1);
        // A design point outside the figure's list must surface as an
        // error naming the label, not an unwrap panic (fig8 regression).
        match design_index(&dps, DesignPoint::MemPod) {
            Err(EngineError::InvalidConfig(msg)) => {
                assert!(msg.contains(DesignPoint::MemPod.label()), "msg: {msg}")
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn irc_partition_budget() {
        assert_eq!(irc_partition(0.0), presets::conventional_rc());
        if let RemapCacheKind::Irc { nonid_ways, id_sets, id_ways, .. } = irc_partition(0.25) {
            assert_eq!(nonid_ways, 6);
            assert!(id_sets * id_ways <= 4096 + 2048);
        } else {
            panic!("expected irc");
        }
    }
}

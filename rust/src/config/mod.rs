//! Configuration system: every knob of the simulated machine and of the
//! hybrid-memory design under test. Experiments are driven by the presets
//! plus CLI overrides (this environment is offline, so no serde: configs
//! are code-defined and dumped via `Debug`/the CLI's `dump-config`).
//!
//! [`presets`] contains ready-made configurations matching the paper's
//! Table 1 (HBM3 + DDR5 and DDR5 + NVM, 32:1 capacity ratio) for each of the
//! five evaluated design points.

pub mod presets;


use crate::types::ilog2;

/// Use mode of the fast memory tier (§2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fast tier is an OS-invisible cache of the slow tier.
    Cache,
    /// Both tiers are OS-visible; blocks are migrated (swapped) between them.
    Flat,
}

/// The metadata structure that maps physical block ids to device block ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetadataScheme {
    /// Conventional linear remap table: one 4 B entry per block over *both*
    /// tiers, stored in the fast memory (MemPod, SILC-FM, Sim et al.).
    Linear,
    /// Trimma's indirection-based remap table (§3.2). `levels = 1` degrades
    /// to the linear table; `levels = 4` mimics Tag Tables' deep slicing.
    Irt { levels: u32 },
    /// Cache-style tag matching with tags embedded alongside data
    /// (Alloy Cache: direct-mapped, tag+data in one burst).
    TagAlloy,
    /// Cache-style tag matching with tags at the head of each DRAM row
    /// (Loh-Hill Cache: 30-way within an 8 kB row, tag access = row hit).
    TagLohHill,
}

/// On-chip SRAM remap-cache organization (§3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemapCacheKind {
    /// No remap cache: every access walks the off-chip table.
    None,
    /// Conventional remap cache storing full entries (identity or not).
    Conventional { sets: u32, ways: u32 },
    /// Trimma's identity-mapping-aware remap cache: NonIdCache +
    /// sector-style IdCache with one bit per block over a super-block of
    /// `superblock_blocks` blocks.
    Irc {
        nonid_sets: u32,
        nonid_ways: u32,
        id_sets: u32,
        id_ways: u32,
        superblock_blocks: u32,
    },
}

/// Data replacement policy within a set (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplacementPolicy {
    /// FIFO over the set's slots, skipping slots currently used as metadata
    /// (Trimma's default, with prefetched index bits).
    Fifo,
    /// Random candidate with resampling on metadata slots.
    Random,
    /// Full LRU (expensive at high associativity; for ablations).
    Lru,
    /// RRIP (used for the Loh-Hill baseline, +2.1% over LRU in the paper).
    Rrip,
    /// CLOCK (second chance): reference bits with a rotating hand — the
    /// classic low-cost LRU approximation the paper lists as applicable.
    Clock,
    /// MemPod's Majority Element Algorithm: epoch-based counters pick the
    /// hottest slow blocks to migrate in.
    Mea,
}

/// One level of the CPU cache hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    pub size_bytes: u64,
    pub ways: u32,
    pub line_bytes: u32,
    /// Access latency in CPU cycles (charged on hit; lookup cost on miss).
    pub latency: u64,
}

impl CacheConfig {
    pub fn num_sets(&self) -> u64 {
        self.size_bytes / (self.ways as u64 * self.line_bytes as u64)
    }
}

/// Timing model for one memory device (a tier).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemTech {
    /// Banked DRAM with row buffers (covers HBM3 and DDR5).
    /// Timing parameters are in CPU cycles.
    Dram {
        channels: u32,
        banks_per_channel: u32,
        /// Row-to-column delay (activate), CPU cycles.
        t_rcd: u64,
        /// Column access (CAS), CPU cycles.
        t_cas: u64,
        /// Precharge, CPU cycles.
        t_rp: u64,
        /// Row buffer size in bytes (8 kB typical).
        row_bytes: u32,
        /// Data bus throughput per channel, bytes per CPU cycle.
        bytes_per_cycle: f64,
    },
    /// Constant-latency, bandwidth-limited NVM (Optane-like).
    Nvm {
        channels: u32,
        banks_per_channel: u32,
        /// Read latency, CPU cycles.
        read_lat: u64,
        /// Write latency, CPU cycles.
        write_lat: u64,
        /// Data bus throughput per channel, bytes per CPU cycle.
        bytes_per_cycle: f64,
    },
}

/// Pressure-driven metadata decay ("trim the trimmer"): cold non-identity
/// mappings are migrated back to their home frames and their iRT entries
/// reclaimed to identity format, returning both the freed fast-memory slot
/// and the (eventually empty) metadata leaf to the set. Epochs piggyback on
/// the existing MEA epoch cadence in flat mode and on a per-set access
/// counter in cache mode; the sweep is incremental (at most `sweep_budget`
/// slots per epoch) and only runs while non-identity iRT occupancy exceeds
/// the pressure threshold. See DESIGN.md §11.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecayConfig {
    /// Master switch; all presets default to `false` (decay off).
    pub enabled: bool,
    /// Cache mode: per-set accesses between decay epochs. Flat mode
    /// ignores this and fires at the MEA epoch boundary instead.
    pub epoch_accesses: u32,
    /// Pressure threshold in thousandths of the set's fast capacity: the
    /// sweep only runs while `nonidentity_entries(set) >
    /// 2 * fast_per_set * pressure_milli / 1000` (a remapped block owns up
    /// to two iRT entries — forward and inverse — so `2 * fast_per_set` is
    /// the occupancy ceiling). `0` sweeps whenever any non-identity entry
    /// exists; `1000` effectively disables the sweep (occupancy can never
    /// exceed the ceiling).
    pub pressure_milli: u32,
    /// Maximum fast slots examined per set per epoch (the incremental
    /// sweep budget K; the cursor rotates across epochs).
    pub sweep_budget: u32,
    /// Whole epochs without a touch before a resident block counts as
    /// cold and is eligible for reclamation.
    pub cold_epochs: u32,
}

impl DecayConfig {
    /// Decay disabled, with moderate knob defaults so flipping `enabled`
    /// alone yields a sane policy (epoch every 256 per-set accesses — the
    /// MEA cadence — pressure gate at 50% occupancy, 64-slot budget, cold
    /// after 4 untouched epochs).
    pub const fn off() -> Self {
        DecayConfig {
            enabled: false,
            epoch_accesses: 256,
            pressure_milli: 500,
            sweep_budget: 64,
            cold_epochs: 4,
        }
    }
}

impl Default for DecayConfig {
    fn default() -> Self {
        DecayConfig::off()
    }
}

/// Deterministic fault injection + degraded-mode recovery knobs (DESIGN.md
/// §14). When enabled, the remap controller's [`FaultInjector`]
/// (`hybrid::fault`) injects three fault classes — transient slow-tier
/// read failures (recovered by bounded retry with exponential backoff
/// charged as extra latency), metadata corruption (a bit flip in a sampled
/// iRT entry, detected by the involution audit and rebuilt from the
/// surviving inverse direction), and stuck sets (persistent faults that
/// defeat rebuilding and force the set into identity-mapped quarantine).
/// Every decision is a pure hash of `(seed, set, per-set event counter)`,
/// so fault streams are set-stream-local and byte-identical across shard
/// counts and pipelined/inline execution, exactly like decay.
///
/// [`FaultInjector`]: crate::hybrid::fault::FaultInjector
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultConfig {
    /// Master switch; all presets default to `false` (faults off).
    pub enabled: bool,
    /// Seed of the fault stream (independent of the workload seed so the
    /// same traffic can be replayed under different fault universes).
    pub seed: u64,
    /// Per-mille of slow-tier demand reads that fail transiently and must
    /// be retried (each retry re-rolls independently).
    pub transient_read_milli: u32,
    /// Per-mille of demand accesses that flip a bit in one of the set's
    /// live iRT entries (forward direction; the inverse survives).
    pub metadata_flip_milli: u32,
    /// Per-mille of sets whose metadata cells are stuck: corruption there
    /// returns after every rebuild, so the scrub quarantines the set
    /// instead (sampled once per set from the fault seed).
    pub stuck_set_milli: u32,
    /// Bounded retry budget for transient read faults (must be >= 1 when
    /// faults are enabled; exhaustion quarantines the set).
    pub max_retries: u32,
    /// Backoff latency of the first retry, CPU cycles; doubles per attempt
    /// (`backoff_base << attempt`), charged to the access's slow-tier
    /// latency.
    pub backoff_base: u64,
}

impl FaultConfig {
    /// Faults disabled, with moderate knob defaults so flipping `enabled`
    /// alone yields a sane policy: ~2% transient read faults, ~0.5%
    /// metadata flips, ~0.1% stuck sets, 4 retries from a 64-cycle
    /// backoff.
    pub const fn off() -> Self {
        FaultConfig {
            enabled: false,
            seed: 0xFA17,
            transient_read_milli: 20,
            metadata_flip_milli: 5,
            stuck_set_milli: 1,
            max_retries: 4,
            backoff_base: 64,
        }
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig::off()
    }
}

/// Knobs of the batched two-phase translate stage (DESIGN.md §15): with
/// `prefetch` on, the remap engine's batched entry point walks each batch
/// ahead of execution, issuing software prefetches for the remap-cache
/// lanes and table words the upcoming probes will touch, keeping the walk
/// `distance` accesses ahead of the executing access. Prefetching is
/// semantically invisible — canonical stats are byte-identical on/off
/// except for the `batch_prefetches` telemetry counter (locked by
/// `rust/tests/prefetch_parity.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Master switch; all presets default to `false` (prefetch off).
    pub prefetch: bool,
    /// Lookahead window of the phase-1 walk, in accesses: how far ahead of
    /// the executing access the prefetch walk runs (must be >= 1 when
    /// `prefetch` is enabled; a value >= the batch length degenerates to
    /// prefetching the whole batch before the first access executes).
    pub distance: u32,
}

impl BatchConfig {
    /// Prefetch disabled, with a sane default window so flipping
    /// `prefetch` alone yields a reasonable policy: 8 accesses of
    /// lookahead (a quarter of the 64-access generation batch — far
    /// enough to cover metadata-line miss latency, near enough that the
    /// primed lines are still resident when their access executes).
    pub const fn off() -> Self {
        BatchConfig { prefetch: false, distance: 8 }
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig::off()
    }
}

/// Contention scenario shaping the per-phase tenant schedule of a
/// multi-tenant run (see [`TenantMixConfig`] and DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantScenario {
    /// Every tenant gets equal weight for the whole run.
    Steady,
    /// Tenant 0 runs the `adv_set_thrash` adversary with ~50% of the total
    /// schedule weight; the victims share the rest.
    NoisyNeighbor,
    /// Tenants arrive and depart at phase boundaries: tenant 0 is an
    /// always-active anchor, every other tenant is active in ~3/4 of the
    /// phases (a pure hash of tenant id x phase decides).
    Churn,
    /// A periodic traffic spike: in a window of phases the crowd tenant
    /// (the highest-numbered one) gets 8x every other tenant's combined
    /// weight, then recedes.
    FlashCrowd,
}

impl TenantScenario {
    /// All scenarios (CLI enumeration order).
    pub const ALL: &'static [TenantScenario] = &[
        TenantScenario::Steady,
        TenantScenario::NoisyNeighbor,
        TenantScenario::Churn,
        TenantScenario::FlashCrowd,
    ];

    /// Stable CLI / label name.
    pub fn label(&self) -> &'static str {
        match self {
            TenantScenario::Steady => "steady",
            TenantScenario::NoisyNeighbor => "noisy_neighbor",
            TenantScenario::Churn => "churn",
            TenantScenario::FlashCrowd => "flash_crowd",
        }
    }

    /// Parse a CLI name produced by [`TenantScenario::label`].
    pub fn parse(s: &str) -> Option<TenantScenario> {
        TenantScenario::ALL.iter().copied().find(|t| t.label() == s)
    }
}

/// Named distribution the per-tenant workloads are drawn from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixProfile {
    /// Latency-sensitive serving: YCSB A/B, Silo TPC-C, omnetpp.
    Serving,
    /// Scan/graph heavy: GAP pr/bfs/cc, roms.
    Analytics,
    /// A broad 8-workload blend of both.
    General,
}

impl MixProfile {
    /// All profiles (CLI enumeration order).
    pub const ALL: &'static [MixProfile] =
        &[MixProfile::Serving, MixProfile::Analytics, MixProfile::General];

    /// Stable CLI / label name.
    pub fn label(&self) -> &'static str {
        match self {
            MixProfile::Serving => "serving",
            MixProfile::Analytics => "analytics",
            MixProfile::General => "general",
        }
    }

    /// Parse a CLI name produced by [`MixProfile::label`].
    pub fn parse(s: &str) -> Option<MixProfile> {
        MixProfile::ALL.iter().copied().find(|m| m.label() == s)
    }
}

/// Multi-tenant serving simulation knobs (the `TenantMix` front end,
/// DESIGN.md §12): N independent tenant sessions, each a workload drawn
/// from a named mix distribution with its own deterministic RNG stream and
/// address-space slab, interleaved into one shared hybrid memory system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantMixConfig {
    /// Master switch; all presets default to `false` (single-tenant).
    pub enabled: bool,
    /// Number of tenant sessions interleaved into the shared system.
    pub tenants: u32,
    /// Contention scenario shaping the per-phase schedule.
    pub scenario: TenantScenario,
    /// Distribution the per-tenant workloads are drawn from.
    pub mix: MixProfile,
    /// Per-core accesses per schedule phase (scenario weights are
    /// piecewise-constant over phases; churn/flash-crowd re-roll here).
    pub phase_len: u32,
    /// Width of one miss-latency histogram bucket, CPU cycles.
    pub hist_cycles_per_bucket: u32,
    /// Number of histogram buckets (the last bucket absorbs overflow).
    pub hist_buckets: u32,
}

impl TenantMixConfig {
    /// Multi-tenancy disabled, with sane knob defaults so flipping
    /// `enabled` alone yields a usable policy: 8 tenants, steady schedule,
    /// general mix, 4096-access phases, 64-cycle buckets x 256 buckets
    /// (16k-cycle range before overflow).
    pub const fn off() -> Self {
        TenantMixConfig {
            enabled: false,
            tenants: 8,
            scenario: TenantScenario::Steady,
            mix: MixProfile::General,
            phase_len: 4096,
            hist_cycles_per_bucket: 64,
            hist_buckets: 256,
        }
    }
}

impl Default for TenantMixConfig {
    fn default() -> Self {
        TenantMixConfig::off()
    }
}

/// How [`TraceWorkload`](crate::trace::TraceWorkload) turns trace chunks
/// back into an access stream (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceReplayMode {
    /// Inline chunked reads on the simulation thread — the portable
    /// default: one file handle, seek + read + decode on demand.
    Buffered,
    /// Chunk I/O + decode move to a dedicated read-ahead thread behind
    /// per-core SPSC rings with a recycled buffer pool, overlapping disk
    /// latency with simulation.
    ReadAhead,
}

impl TraceReplayMode {
    /// Stable CLI/bench label (`buffered` / `readahead`).
    pub fn label(&self) -> &'static str {
        match self {
            TraceReplayMode::Buffered => "buffered",
            TraceReplayMode::ReadAhead => "readahead",
        }
    }

    /// Parse a CLI name produced by [`TraceReplayMode::label`].
    pub fn parse(s: &str) -> Option<TraceReplayMode> {
        match s {
            "buffered" => Some(TraceReplayMode::Buffered),
            "readahead" => Some(TraceReplayMode::ReadAhead),
            _ => None,
        }
    }
}

/// Trace record/replay knobs (the `trace` subsystem, DESIGN.md §13).
/// The trace file *path* is not configuration — it flows through
/// [`EngineBuilder::trace`](crate::engine::EngineBuilder::trace) and the
/// `trimma record`/`replay` CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Master switch; set by the engine when a trace path is attached.
    pub enabled: bool,
    /// Records per chunk — the unit of encoding, CRC, and buffered I/O.
    pub chunk_records: u32,
    /// Write chunks delta/varint-encoded (roughly 3-5x smaller than the
    /// fixed 12-byte records on real streams); `false` writes raw.
    pub delta: bool,
    /// Replay I/O strategy (see [`TraceReplayMode`]).
    pub replay: TraceReplayMode,
    /// Chunks of read-ahead per core ring (>= 1; 2 = double-buffered).
    pub read_ahead_chunks: u32,
    /// Walk every chunk's CRC when opening a trace for replay, so
    /// corruption surfaces as a typed error before the run starts.
    pub validate_on_open: bool,
}

impl TraceConfig {
    /// Tracing disabled, with sane knob defaults so attaching a path
    /// alone yields a usable policy: 4096-record delta chunks, buffered
    /// replay, double-buffered read-ahead, validate on open.
    pub const fn off() -> Self {
        TraceConfig {
            enabled: false,
            chunk_records: 4096,
            delta: true,
            replay: TraceReplayMode::Buffered,
            read_ahead_chunks: 2,
            validate_on_open: true,
        }
    }
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig::off()
    }
}

/// Configuration of the hybrid memory system (both tiers + metadata design).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridConfig {
    pub mode: Mode,
    pub scheme: MetadataScheme,
    pub remap_cache: RemapCacheKind,
    pub replacement: ReplacementPolicy,
    /// Migration/caching granularity in bytes (256 B default).
    pub block_bytes: u32,
    /// Number of disjoint sets the two tiers are partitioned into.
    /// Associativity = fast blocks per set. MemPod/Trimma-F use 4.
    pub num_sets: u32,
    /// Fast tier capacity in bytes (data + metadata region).
    pub fast_bytes: u64,
    /// Slow tier capacity in bytes.
    pub slow_bytes: u64,
    /// Whether freed metadata blocks are donated as extra cache slots
    /// (Trimma's §3.3; off for the plain-iRT ablation).
    pub use_saved_space: bool,
    /// SRAM remap-cache latency, CPU cycles (CACTI-derived in the paper).
    pub remap_cache_latency: u64,
    /// In flat mode, fraction of OS-visible space placed in the fast tier
    /// by first-touch allocation (the rest of fast capacity may be cache).
    pub flat_fast_fraction: f64,
    /// Sub-blocked fills (SILC-FM/Hybrid2/Baryon-style): fetch only the
    /// demanded 64 B sub-blocks of a cached block instead of the whole
    /// block, trading fill bandwidth for extra sub-block misses.
    pub subblock: bool,
    /// Shadow every controller with the [`crate::verify`] oracle: after
    /// each access the translation, fast/slow placement, and
    /// identity/non-identity classification are checked against the
    /// ground-truth model, and the remap tables are periodically swept for
    /// bijectivity, lost blocks, and donated-slot accounting. Costs a
    /// constant factor per access — on for tests and debug runs, off for
    /// benches and figure sweeps (all presets default to `false`).
    pub verify: bool,
    /// Pressure-driven metadata decay knobs (see [`DecayConfig`]).
    pub decay: DecayConfig,
    /// Deterministic fault injection knobs (see [`FaultConfig`]).
    pub fault: FaultConfig,
    /// Batched-translate prefetch knobs (see [`BatchConfig`]).
    pub batch: BatchConfig,
}

impl HybridConfig {
    pub fn fast_blocks(&self) -> u64 {
        self.fast_bytes / self.block_bytes as u64
    }
    pub fn slow_blocks(&self) -> u64 {
        self.slow_bytes / self.block_bytes as u64
    }
    pub fn block_offset_bits(&self) -> u32 {
        ilog2(self.block_bytes as u64)
    }
    /// Slow-to-fast capacity ratio.
    pub fn capacity_ratio(&self) -> u64 {
        self.slow_bytes / self.fast_bytes
    }
}

/// Workload sizing/scaling knobs shared by all generators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadConfig {
    /// Number of cores / streams (paper: 16).
    pub cores: u32,
    /// Memory accesses simulated per core (post-warmup).
    pub accesses_per_core: u64,
    /// Warmup accesses per core (stats reset afterwards).
    pub warmup_per_core: u64,
    /// RNG seed base.
    pub seed: u64,
}

/// Top-level system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// Human-readable label, e.g. "trimma-c/hbm3+ddr5".
    pub name: String,
    pub cpu_freq_ghz: f64,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
    pub fast_mem: MemTech,
    pub slow_mem: MemTech,
    pub hybrid: HybridConfig,
    pub workload: WorkloadConfig,
    /// Multi-tenant serving knobs (see [`TenantMixConfig`]; off by default).
    pub tenant_mix: TenantMixConfig,
    /// Trace record/replay knobs (see [`TraceConfig`]; off by default).
    pub trace: TraceConfig,
}

impl SystemConfig {
    /// Convert nanoseconds to CPU cycles under this config's clock.
    pub fn ns_to_cycles(&self, ns: f64) -> u64 {
        (ns * self.cpu_freq_ghz).round() as u64
    }

    /// Validate internal consistency; returns a description of the first
    /// violated invariant.
    pub fn validate(&self) -> Result<(), String> {
        let h = &self.hybrid;
        if !h.block_bytes.is_power_of_two() {
            return Err(format!("block_bytes {} not a power of two", h.block_bytes));
        }
        if h.fast_bytes % h.block_bytes as u64 != 0 || h.slow_bytes % h.block_bytes as u64 != 0 {
            return Err("tier capacities must be block-aligned".into());
        }
        if h.slow_bytes < h.fast_bytes {
            return Err("slow tier smaller than fast tier".into());
        }
        if !h.num_sets.is_power_of_two() {
            return Err(format!("num_sets {} not a power of two", h.num_sets));
        }
        if h.fast_blocks() % h.num_sets as u64 != 0 || h.slow_blocks() % h.num_sets as u64 != 0 {
            return Err("blocks must divide evenly across sets".into());
        }
        if let MetadataScheme::Irt { levels } = h.scheme {
            if !(1..=4).contains(&levels) {
                return Err(format!("iRT levels {levels} out of range 1..=4"));
            }
        }
        if matches!(h.scheme, MetadataScheme::TagAlloy) && h.mode != Mode::Cache {
            return Err("Alloy tag matching only supports cache mode".into());
        }
        if matches!(h.scheme, MetadataScheme::TagLohHill) && h.mode != Mode::Cache {
            return Err("Loh-Hill tag matching only supports cache mode".into());
        }
        if h.decay.enabled {
            if h.decay.epoch_accesses == 0 {
                return Err("decay.epoch_accesses must be > 0".into());
            }
            if h.decay.pressure_milli > 1000 {
                return Err(format!(
                    "decay.pressure_milli {} out of range 0..=1000",
                    h.decay.pressure_milli
                ));
            }
            if h.decay.sweep_budget == 0 {
                return Err("decay.sweep_budget must be > 0".into());
            }
            if matches!(h.scheme, MetadataScheme::TagAlloy | MetadataScheme::TagLohHill) {
                return Err("metadata decay requires a remap table scheme".into());
            }
        }
        if h.fault.enabled {
            for (milli, knob) in [
                (h.fault.transient_read_milli, "fault.transient_read_milli"),
                (h.fault.metadata_flip_milli, "fault.metadata_flip_milli"),
                (h.fault.stuck_set_milli, "fault.stuck_set_milli"),
            ] {
                if milli > 1000 {
                    return Err(format!("{knob} {milli} out of range 0..=1000"));
                }
            }
            if h.fault.max_retries == 0 {
                return Err(
                    "fault.max_retries must be >= 1 (a zero budget cannot recover any \
                     transient fault)"
                        .into(),
                );
            }
        }
        if h.batch.prefetch && h.batch.distance == 0 {
            return Err(
                "batch.distance must be >= 1 when batch.prefetch is enabled (a zero \
                 lookahead window never issues a prefetch)"
                    .into(),
            );
        }
        let t = &self.tenant_mix;
        if t.enabled {
            if t.tenants == 0 {
                return Err("tenant_mix.tenants must be >= 1".into());
            }
            if t.phase_len == 0 {
                return Err("tenant_mix.phase_len must be > 0".into());
            }
            if t.hist_cycles_per_bucket == 0 {
                return Err("tenant_mix.hist_cycles_per_bucket must be > 0".into());
            }
            if t.hist_buckets == 0 {
                return Err("tenant_mix.hist_buckets must be > 0".into());
            }
        }
        let tr = &self.trace;
        if tr.enabled {
            if tr.chunk_records == 0 {
                return Err("trace.chunk_records must be > 0".into());
            }
            if tr.read_ahead_chunks == 0 {
                return Err("trace.read_ahead_chunks must be >= 1".into());
            }
        }
        Ok(())
    }

    /// Human-readable multi-line dump (the CLI's `dump-config`).
    pub fn describe(&self) -> String {
        format!("{self:#?}")
    }
}

#[cfg(test)]
mod tests {
    use super::presets::{self, DesignPoint};
    use super::*;

    #[test]
    fn presets_validate() {
        for dp in DesignPoint::ALL {
            let cfg = presets::hbm3_ddr5(*dp);
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
            let cfg = presets::ddr5_nvm(*dp);
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn describe_mentions_key_fields() {
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        let s = cfg.describe();
        assert!(s.contains("Irt"));
        assert!(s.contains("fast_bytes"));
    }

    #[test]
    fn capacity_ratio_default_is_32() {
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaFlat);
        assert_eq!(cfg.hybrid.capacity_ratio(), 32);
    }

    #[test]
    fn validate_rejects_bad_block() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.block_bytes = 300;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_rejects_alloy_flat() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.mode = Mode::Flat;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn decay_knobs_validate() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.decay.enabled = true;
        cfg.validate().unwrap();
        cfg.hybrid.decay.epoch_accesses = 0;
        assert!(cfg.validate().is_err());
        cfg.hybrid.decay.epoch_accesses = 256;
        cfg.hybrid.decay.pressure_milli = 1001;
        assert!(cfg.validate().is_err());
        cfg.hybrid.decay.pressure_milli = 0;
        cfg.hybrid.decay.sweep_budget = 0;
        assert!(cfg.validate().is_err());
        // Tag-matching designs have no remap table to decay.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.decay.enabled = true;
        assert!(cfg.validate().is_err());
        // Disabled decay never blocks validation, whatever the knobs say.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.decay.sweep_budget = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn fault_knobs_validate() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fault.enabled = true;
        cfg.validate().unwrap();
        cfg.hybrid.fault.transient_read_milli = 1001;
        assert!(cfg.validate().is_err());
        cfg.hybrid.fault.transient_read_milli = 1000;
        cfg.hybrid.fault.metadata_flip_milli = 1001;
        assert!(cfg.validate().is_err());
        cfg.hybrid.fault.metadata_flip_milli = 0;
        cfg.hybrid.fault.stuck_set_milli = 2000;
        assert!(cfg.validate().is_err());
        cfg.hybrid.fault.stuck_set_milli = 0;
        cfg.hybrid.fault.max_retries = 0;
        assert!(cfg.validate().is_err());
        // Tag baselines carry no remap metadata; faults are allowed but the
        // injector is inert there (DESIGN.md §14), so validation passes.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        cfg.hybrid.fault.enabled = true;
        cfg.validate().unwrap();
        // Disabled faults never block validation, whatever the knobs say.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fault.max_retries = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn batch_knobs_validate() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.batch.prefetch = true;
        cfg.validate().unwrap();
        cfg.hybrid.batch.distance = 0;
        assert!(cfg.validate().is_err());
        cfg.hybrid.batch.distance = 1;
        cfg.validate().unwrap();
        // Prefetch is purely a host-side hint: every design point accepts
        // it (tag baselines and Ideal just never issue any).
        for dp in DesignPoint::ALL {
            let mut cfg = presets::hbm3_ddr5(*dp);
            cfg.hybrid.batch.prefetch = true;
            cfg.validate().unwrap_or_else(|e| panic!("{dp:?}: {e}"));
        }
        // Disabled prefetch never blocks validation, whatever the knobs say.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.batch.distance = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn tenant_knobs_validate() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.tenant_mix.enabled = true;
        cfg.validate().unwrap();
        cfg.tenant_mix.tenants = 0;
        assert!(cfg.validate().is_err());
        cfg.tenant_mix.tenants = 8;
        cfg.tenant_mix.phase_len = 0;
        assert!(cfg.validate().is_err());
        cfg.tenant_mix.phase_len = 4096;
        cfg.tenant_mix.hist_cycles_per_bucket = 0;
        assert!(cfg.validate().is_err());
        cfg.tenant_mix.hist_cycles_per_bucket = 64;
        cfg.tenant_mix.hist_buckets = 0;
        assert!(cfg.validate().is_err());
        // Disabled tenancy never blocks validation, whatever the knobs say.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.tenant_mix.tenants = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn trace_knobs_validate() {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.trace.enabled = true;
        cfg.validate().unwrap();
        cfg.trace.chunk_records = 0;
        assert!(cfg.validate().is_err());
        cfg.trace.chunk_records = 4096;
        cfg.trace.read_ahead_chunks = 0;
        assert!(cfg.validate().is_err());
        // Disabled tracing never blocks validation, whatever the knobs say.
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.trace.chunk_records = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn trace_replay_mode_round_trips() {
        for m in [TraceReplayMode::Buffered, TraceReplayMode::ReadAhead] {
            assert_eq!(TraceReplayMode::parse(m.label()), Some(m));
        }
        assert_eq!(TraceReplayMode::parse("nope"), None);
    }

    #[test]
    fn tenant_enums_round_trip() {
        for s in TenantScenario::ALL {
            assert_eq!(TenantScenario::parse(s.label()), Some(*s));
        }
        for m in MixProfile::ALL {
            assert_eq!(MixProfile::parse(m.label()), Some(*m));
        }
        assert_eq!(TenantScenario::parse("nope"), None);
        assert_eq!(MixProfile::parse("nope"), None);
    }

    #[test]
    fn ns_conversion() {
        let cfg = presets::ddr5_nvm(DesignPoint::TrimmaCache);
        assert_eq!(cfg.ns_to_cycles(77.0), 246); // NVM read at 3.2 GHz
    }
}

//! Ready-made system configurations mirroring the paper's Table 1.
//!
//! Capacities are scaled down ~1000x from the paper's 20 GB / 640 MB
//! (see DESIGN.md §4): the default fast tier is 16 MiB and the slow tier
//! 512 MiB, preserving the 32:1 slow-to-fast ratio that drives all of the
//! metadata-overhead arithmetic (a linear table still costs
//! `(32+1) * 4/256 = 52%` of the fast tier). Workload footprints are scaled
//! by the same factor so they fill the same fraction of memory.

use super::*;

/// The design points evaluated in the paper (Fig. 7) plus the auxiliary
/// points needed by Fig. 1 and the ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignPoint {
    /// Alloy Cache: direct-mapped DRAM cache, tag+data in one burst,
    /// perfect memory-access predictor (cache-mode baseline #1).
    AlloyCache,
    /// Loh-Hill Cache: 30-way within an 8 kB row, tags-in-row, perfect
    /// MissMap, RRIP replacement (cache-mode baseline #2).
    LohHill,
    /// Trimma in cache mode: iRT (2-level) + iRC + saved-space caching.
    TrimmaCache,
    /// MemPod: flat mode, 4 pods, linear remap table + conventional remap
    /// cache, MEA epoch migration (flat-mode baseline).
    MemPod,
    /// Trimma in flat mode: iRT (2-level) + iRC + saved-space caching.
    TrimmaFlat,
    /// Cache-mode design with a linear remap table + conventional remap
    /// cache (the "linear table" series of Fig. 1).
    LinearCache,
    /// Metadata-free oracle: lookups cost nothing and no fast-memory
    /// capacity is spent on tables (the "Ideal" series of Fig. 1).
    Ideal,
}

impl DesignPoint {
    pub const ALL: &'static [DesignPoint] = &[
        DesignPoint::AlloyCache,
        DesignPoint::LohHill,
        DesignPoint::TrimmaCache,
        DesignPoint::MemPod,
        DesignPoint::TrimmaFlat,
        DesignPoint::LinearCache,
        DesignPoint::Ideal,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            DesignPoint::AlloyCache => "alloy",
            DesignPoint::LohHill => "loh-hill",
            DesignPoint::TrimmaCache => "trimma-c",
            DesignPoint::MemPod => "mempod",
            DesignPoint::TrimmaFlat => "trimma-f",
            DesignPoint::LinearCache => "linear-c",
            DesignPoint::Ideal => "ideal",
        }
    }

    pub fn mode(&self) -> Mode {
        match self {
            DesignPoint::MemPod | DesignPoint::TrimmaFlat => Mode::Flat,
            _ => Mode::Cache,
        }
    }
}

/// Default scaled fast-tier capacity (16 MiB).
pub const FAST_BYTES: u64 = 16 << 20;
/// Default scaled slow-tier capacity (512 MiB), ratio 32:1.
pub const SLOW_BYTES: u64 = 512 << 20;
/// Default migration block size (256 B, paper default).
pub const BLOCK_BYTES: u32 = 256;

/// The conventional remap cache of Table 1: 2048 sets x 8 ways, 3 cycles.
pub fn conventional_rc() -> RemapCacheKind {
    RemapCacheKind::Conventional { sets: 2048, ways: 8 }
}

/// Trimma's iRC of Table 1: NonIdCache 2048x6 + IdCache 256x16 over 32-block
/// (8 kB) super-blocks; same total SRAM as the conventional 2048x8 cache.
pub fn irc_rc() -> RemapCacheKind {
    RemapCacheKind::Irc {
        nonid_sets: 2048,
        nonid_ways: 6,
        id_sets: 256,
        id_ways: 16,
        superblock_blocks: 32,
    }
}

/// CPU cache hierarchy, scaled down with the memory capacities (DESIGN.md
/// §4): the paper's 32 MB LLC is ~0.16% of its 20 GB footprint; with the
/// slow tier scaled to 512 MiB we keep the same proportion (1 MiB LLC,
/// 128 KiB L2, 16 KiB L1D) so the hybrid memory sees the same *kind* of
/// post-LLC traffic. Latencies stay at Table 1's cycle counts.
fn caches() -> (CacheConfig, CacheConfig, CacheConfig) {
    let l1d = CacheConfig { size_bytes: 16 << 10, ways: 8, line_bytes: 64, latency: 4 };
    let l2 = CacheConfig { size_bytes: 128 << 10, ways: 8, line_bytes: 64, latency: 14 };
    let llc = CacheConfig { size_bytes: 1 << 20, ways: 16, line_bytes: 64, latency: 60 };
    (l1d, l2, llc)
}

/// HBM3: 1600 MHz, 16 channels, RCD-CAS-RP 48-48-48 (CPU cycles @3.2 GHz).
pub fn hbm3() -> MemTech {
    MemTech::Dram {
        channels: 16,
        banks_per_channel: 16,
        t_rcd: 48,
        t_cas: 48,
        t_rp: 48,
        row_bytes: 8192,
        bytes_per_cycle: 16.0, // ~51 GB/s per channel at 3.2 GHz
    }
}

/// DDR5-4800, RCD-CAS-RP 40-40-40 (CPU cycles @3.2 GHz).
pub fn ddr5(channels: u32) -> MemTech {
    MemTech::Dram {
        channels,
        banks_per_channel: 32, // 2 ranks x 16 banks
        t_rcd: 40,
        t_cas: 40,
        t_rp: 40,
        row_bytes: 8192,
        bytes_per_cycle: 12.0, // ~38 GB/s per channel at 3.2 GHz
    }
}

/// Optane-like NVM: RD 77 ns, WR 231 ns, 2 channels x 8 banks.
pub fn nvm() -> MemTech {
    MemTech::Nvm {
        channels: 2,
        banks_per_channel: 8,
        read_lat: 246,  // 77 ns @ 3.2 GHz
        write_lat: 739, // 231 ns @ 3.2 GHz
        bytes_per_cycle: 2.0, // ~6.4 GB/s per channel
    }
}

fn hybrid_for(dp: DesignPoint, fast_bytes: u64, slow_bytes: u64, block: u32) -> HybridConfig {
    let fast_blocks = fast_bytes / block as u64;
    let (scheme, remap_cache, replacement, num_sets, use_saved_space) = match dp {
        DesignPoint::AlloyCache => (
            MetadataScheme::TagAlloy,
            RemapCacheKind::None,
            ReplacementPolicy::Fifo, // direct-mapped: replacement is trivial
            // direct-mapped: one fast block per set
            fast_blocks as u32,
            false,
        ),
        DesignPoint::LohHill => (
            MetadataScheme::TagLohHill,
            RemapCacheKind::None,
            ReplacementPolicy::Rrip,
            // one set per 8 kB row (30 data + 2 tag blocks at 256 B)
            (fast_bytes / 8192) as u32,
            false,
        ),
        DesignPoint::TrimmaCache => (
            MetadataScheme::Irt { levels: 2 },
            irc_rc(),
            ReplacementPolicy::Fifo,
            // high associativity: 1024 data ways per set
            (fast_blocks / 1024).max(1) as u32,
            true,
        ),
        DesignPoint::MemPod => (
            MetadataScheme::Linear,
            conventional_rc(),
            ReplacementPolicy::Mea,
            4, // 4 pods
            false,
        ),
        DesignPoint::TrimmaFlat => (
            MetadataScheme::Irt { levels: 2 },
            irc_rc(),
            ReplacementPolicy::Fifo,
            4, // match MemPod's pod count for apples-to-apples
            true,
        ),
        DesignPoint::LinearCache => (
            MetadataScheme::Linear,
            conventional_rc(),
            ReplacementPolicy::Fifo,
            (fast_blocks / 1024).max(1) as u32,
            false,
        ),
        DesignPoint::Ideal => (
            MetadataScheme::Linear, // unused: lookups are free
            RemapCacheKind::None,
            ReplacementPolicy::Fifo,
            (fast_blocks / 1024).max(1) as u32,
            false,
        ),
    };
    HybridConfig {
        mode: dp.mode(),
        scheme,
        remap_cache,
        replacement,
        block_bytes: block,
        num_sets,
        fast_bytes,
        slow_bytes,
        use_saved_space,
        remap_cache_latency: 3,
        flat_fast_fraction: 1.0,
        subblock: false,
        verify: false,
        decay: DecayConfig::off(),
        fault: FaultConfig::off(),
        batch: BatchConfig::off(),
    }
}

/// Enable the [`crate::verify`] oracle (tests / debug runs).
pub fn with_verify(mut cfg: SystemConfig) -> SystemConfig {
    cfg.hybrid.verify = true;
    cfg
}

/// Enable pressure-driven metadata decay with the default policy knobs
/// ([`DecayConfig::off`]'s values with `enabled = true`): epoch every 256
/// per-set accesses (cache mode; flat mode rides the MEA cadence),
/// pressure gate at 50% of per-set fast capacity, 64-slot sweep budget,
/// cold after 4 untouched epochs.
pub fn with_decay(mut cfg: SystemConfig) -> SystemConfig {
    cfg.hybrid.decay.enabled = true;
    cfg
}

/// Enable deterministic fault injection with the default fault profile
/// ([`FaultConfig::off`]'s values with `enabled = true`): ~2% transient
/// slow reads, ~0.5% metadata flips, ~0.1% stuck sets, 4 retries from a
/// 64-cycle backoff.
pub fn with_faults(mut cfg: SystemConfig) -> SystemConfig {
    cfg.hybrid.fault.enabled = true;
    cfg
}

/// Enable batched-translate software prefetch with the default window
/// ([`BatchConfig::off`]'s values with `prefetch = true`): the phase-1
/// walk runs 8 accesses ahead of execution. Semantically invisible —
/// canonical stats are unchanged except the `batch_prefetches` counter.
pub fn with_prefetch(mut cfg: SystemConfig) -> SystemConfig {
    cfg.hybrid.batch.prefetch = true;
    cfg
}

fn base(name: String, fast_mem: MemTech, slow_mem: MemTech, hybrid: HybridConfig) -> SystemConfig {
    let (l1d, l2, llc) = caches();
    SystemConfig {
        name,
        cpu_freq_ghz: 3.2,
        l1d,
        l2,
        llc,
        fast_mem,
        slow_mem,
        hybrid,
        workload: WorkloadConfig {
            cores: 16,
            accesses_per_core: 1_500_000,
            warmup_per_core: 300_000,
            seed: 0xD1CE,
        },
        tenant_mix: TenantMixConfig::off(),
        trace: TraceConfig::off(),
    }
}

/// Enable the multi-tenant front end with `tenants` sessions under the
/// given scenario ([`TenantMixConfig::off`]'s remaining knob defaults:
/// general mix, 4096-access phases, 64-cycle x 256-bucket histograms).
pub fn with_tenants(mut cfg: SystemConfig, tenants: u32, scenario: TenantScenario) -> SystemConfig {
    cfg.tenant_mix.enabled = true;
    cfg.tenant_mix.tenants = tenants;
    cfg.tenant_mix.scenario = scenario;
    cfg
}

/// HBM3 (fast) + DDR5 (slow), the paper's first technology combination.
pub fn hbm3_ddr5(dp: DesignPoint) -> SystemConfig {
    base(
        format!("{}/hbm3+ddr5", dp.label()),
        hbm3(),
        ddr5(1),
        hybrid_for(dp, FAST_BYTES, SLOW_BYTES, BLOCK_BYTES),
    )
}

/// DDR5 (fast) + NVM (slow), the paper's second technology combination.
pub fn ddr5_nvm(dp: DesignPoint) -> SystemConfig {
    base(
        format!("{}/ddr5+nvm", dp.label()),
        ddr5(2),
        nvm(),
        hybrid_for(dp, FAST_BYTES, SLOW_BYTES, BLOCK_BYTES),
    )
}

/// Rescale a preset to a different slow-to-fast capacity ratio (Fig. 12a).
/// Fast capacity is fixed; the slow tier grows/shrinks.
pub fn with_capacity_ratio(mut cfg: SystemConfig, ratio: u64) -> SystemConfig {
    cfg.hybrid.slow_bytes = cfg.hybrid.fast_bytes * ratio;
    cfg.name = format!("{}@r{}", cfg.name, ratio);
    cfg
}

/// Enable sub-blocked fills (the Baryon/Hybrid2 extension; ablation).
pub fn with_subblocking(mut cfg: SystemConfig) -> SystemConfig {
    cfg.hybrid.subblock = true;
    cfg.name = format!("{}+sub", cfg.name);
    cfg
}

/// Rescale a preset to a different migration block size (Fig. 12b).
pub fn with_block_bytes(mut cfg: SystemConfig, block: u32) -> SystemConfig {
    cfg.hybrid.block_bytes = block;
    // Keep per-set data ways constant where possible.
    let fast_blocks = (cfg.hybrid.fast_bytes / block as u64) as u32;
    cfg.hybrid.num_sets = cfg.hybrid.num_sets.min(fast_blocks).max(1);
    cfg.name = format!("{}@b{}", cfg.name, block);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_table_cost_matches_paper_math() {
        // (32 + 1) * 4 / 256 = 51.6% of the fast tier at ratio 32:1.
        let cfg = hbm3_ddr5(DesignPoint::MemPod);
        let h = cfg.hybrid;
        let entries = h.fast_blocks() + h.slow_blocks();
        let table_bytes = entries * 4;
        let frac = table_bytes as f64 / h.fast_bytes as f64;
        assert!((frac - 0.5156).abs() < 0.001, "frac = {frac}");
    }

    #[test]
    fn alloy_is_direct_mapped() {
        let cfg = hbm3_ddr5(DesignPoint::AlloyCache);
        assert_eq!(cfg.hybrid.num_sets as u64, cfg.hybrid.fast_blocks());
    }

    #[test]
    fn ratio_rescale() {
        let cfg = with_capacity_ratio(hbm3_ddr5(DesignPoint::TrimmaCache), 64);
        assert_eq!(cfg.hybrid.capacity_ratio(), 64);
        cfg.validate().unwrap();
    }

    #[test]
    fn block_rescale_valid() {
        for b in [64u32, 256, 1024, 4096] {
            let cfg = with_block_bytes(hbm3_ddr5(DesignPoint::TrimmaCache), b);
            cfg.validate().unwrap_or_else(|e| panic!("block {b}: {e}"));
        }
    }
}

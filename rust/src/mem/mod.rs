//! Memory device timing models: banked DRAM with row buffers (HBM3, DDR5)
//! and constant-latency bandwidth-limited NVM (Optane-like).
//!
//! The model is cycle-accounting rather than fully event-driven: every bank
//! keeps a `next_free` time and an open row; an access arriving at cycle
//! `now` waits for its bank, pays tCAS / tRCD+tCAS / tRP+tRCD+tCAS depending
//! on the row-buffer state, then occupies the bank for the burst-transfer
//! time. This is the level of fidelity first-order hybrid-memory studies
//! need (queueing + row locality + bandwidth ceilings) at simulation speeds
//! of tens of millions of accesses per second.

use crate::config::MemTech;
use crate::types::{AccessKind, Cycle};

#[derive(Debug, Clone, Copy, Default)]
struct Bank {
    next_free: Cycle,
    /// Currently open row id, or `u64::MAX` for closed.
    open_row: u64,
}

/// Outcome of a device access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemResult {
    /// Cycle at which data is available (read) or accepted (write).
    pub done: Cycle,
    /// True if the access hit an open row (DRAM only).
    pub row_hit: bool,
}

/// A single memory device (one tier).
#[derive(Debug, Clone)]
pub struct MemDevice {
    tech: MemTech,
    /// Precomputed 1/bytes_per_cycle: turns the per-access transfer-time
    /// division into a multiply (hot path: ~8 device accesses per miss).
    inv_bpc: f64,
    /// `log2(row_bytes * channels * banks)`: row-id extraction by shift.
    row_span_bits: u32,
    banks: Vec<Bank>,
    /// Per-channel data-bus free time: transfers on one channel serialize,
    /// which is what actually bounds throughput under load (a single DDR5
    /// channel shared by 16 cores saturates long before its banks do).
    bus_free: Vec<Cycle>,
    channels: u32,
    banks_per_channel: u32,
    /// Cumulative bytes transferred (for utilization reporting).
    pub bytes_transferred: u64,
    /// Cumulative accesses.
    pub accesses: u64,
    row_hits: u64,
}

impl MemDevice {
    pub fn new(tech: MemTech) -> Self {
        let (channels, banks_per_channel) = match tech {
            MemTech::Dram { channels, banks_per_channel, .. } => (channels, banks_per_channel),
            MemTech::Nvm { channels, banks_per_channel, .. } => (channels, banks_per_channel),
        };
        let bpc = match tech {
            MemTech::Dram { bytes_per_cycle, .. } => bytes_per_cycle,
            MemTech::Nvm { bytes_per_cycle, .. } => bytes_per_cycle,
        };
        let row_bytes = match tech {
            MemTech::Dram { row_bytes, .. } => row_bytes as u64,
            MemTech::Nvm { .. } => 4096,
        };
        let row_span = row_bytes * channels as u64 * banks_per_channel as u64;
        assert!(row_span.is_power_of_two(), "row span must be a power of two");
        MemDevice {
            tech,
            inv_bpc: 1.0 / bpc,
            row_span_bits: row_span.trailing_zeros(),
            banks: vec![Bank { next_free: 0, open_row: u64::MAX }; (channels * banks_per_channel) as usize],
            bus_free: vec![0; channels as usize],
            channels,
            banks_per_channel,
            bytes_transferred: 0,
            accesses: 0,
            row_hits: 0,
        }
    }

    pub fn tech(&self) -> &MemTech {
        &self.tech
    }

    /// Map a device byte address to (bank index, row id). Blocks interleave
    /// across channels first (256 B granularity), then banks, so contiguous
    /// blocks spread across channels as real controllers do.
    #[inline]
    fn map(&self, addr: u64) -> (usize, u64) {
        let block = addr >> 8; // 256 B channel-interleave granularity
        let ch = (block % self.channels as u64) as u32;
        let within = block / self.channels as u64;
        let bank = (within % self.banks_per_channel as u64) as u32;
        let row = addr >> self.row_span_bits;
        ((ch * self.banks_per_channel + bank) as usize, row)
    }

    /// Issue an access of `bytes` at `addr`, arriving at `now`.
    /// The bank is occupied until completion; callers decide whether the
    /// returned latency is on the critical path (demand) or not (migration,
    /// metadata updates).
    pub fn access(&mut self, addr: u64, bytes: u32, kind: AccessKind, now: Cycle) -> MemResult {
        let (bank_idx, row) = self.map(addr);
        let ch = bank_idx / self.banks_per_channel as usize;
        let bank = &mut self.banks[bank_idx];
        let start = now.max(bank.next_free);
        let transfer = (bytes as f64 * self.inv_bpc).ceil() as u64;
        let (lat, row_hit) = match self.tech {
            MemTech::Dram { t_rcd, t_cas, t_rp, .. } => {
                let (lat, hit) = if bank.open_row == row {
                    (t_cas, true)
                } else if bank.open_row == u64::MAX {
                    (t_rcd + t_cas, false)
                } else {
                    (t_rp + t_rcd + t_cas, false)
                };
                bank.open_row = row;
                (lat, hit)
            }
            MemTech::Nvm { read_lat, write_lat, .. } => {
                let lat = match kind {
                    AccessKind::Read => read_lat,
                    AccessKind::Write => write_lat,
                };
                (lat, false)
            }
        };
        // The data burst must win the (per-channel) shared bus after the
        // array access completes; transfers on a channel serialize.
        let bus_start = (start + lat).max(self.bus_free[ch]);
        let done = bus_start + transfer;
        self.bus_free[ch] = done;
        bank.next_free = done;
        self.bytes_transferred += bytes as u64;
        self.accesses += 1;
        self.row_hits += row_hit as u64;
        MemResult { done, row_hit }
    }

    /// Row-buffer hit rate so far (always 0 for NVM).
    pub fn row_hit_rate(&self) -> f64 {
        if self.accesses == 0 { 0.0 } else { self.row_hits as f64 / self.accesses as f64 }
    }

    /// Earliest cycle at which the bank holding `addr` is free (peek).
    pub fn bank_free_at(&self, addr: u64) -> Cycle {
        let (bank_idx, _) = self.map(addr);
        self.banks[bank_idx].next_free
    }

    /// Unloaded (no-queue) access latency in cycles for a `bytes`-sized
    /// read with a closed row: the best case a demand access can see.
    pub fn unloaded_latency(&self, bytes: u32) -> u64 {
        match self.tech {
            MemTech::Dram { t_rcd, t_cas, bytes_per_cycle, .. } => {
                t_rcd + t_cas + (bytes as f64 / bytes_per_cycle).ceil() as u64
            }
            MemTech::Nvm { read_lat, bytes_per_cycle, .. } => {
                read_lat + (bytes as f64 / bytes_per_cycle).ceil() as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn hbm() -> MemDevice {
        MemDevice::new(presets::hbm3())
    }

    #[test]
    fn first_access_pays_rcd_cas() {
        let mut d = hbm();
        let r = d.access(0, 64, AccessKind::Read, 0);
        // 48 + 48 + ceil(64/16) = 100
        assert_eq!(r.done, 100);
        assert!(!r.row_hit);
    }

    #[test]
    fn second_access_same_row_is_cas_only() {
        let mut d = hbm();
        d.access(0, 64, AccessKind::Read, 0);
        let r = d.access(64, 64, AccessKind::Read, 200);
        // Same 8 kB row, open: 48 + 4 = 52 after arrival.
        assert_eq!(r.done, 252);
        assert!(r.row_hit);
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let mut d = hbm();
        d.access(0, 64, AccessKind::Read, 0);
        // Same bank, different row: stride by channels*banks*row_bytes.
        let far = 16u64 * 16 * 8192;
        let r = d.access(far, 64, AccessKind::Read, 200);
        assert_eq!(r.done, 200 + 48 + 48 + 48 + 4);
        assert!(!r.row_hit);
    }

    #[test]
    fn bank_queueing_serializes() {
        let mut d = hbm();
        let a = d.access(0, 256, AccessKind::Read, 0);
        // Same bank (same address), arrives while busy: must wait.
        let b = d.access(0, 256, AccessKind::Read, 1);
        assert!(b.done > a.done);
        assert_eq!(b.done, a.done + 48 + 16); // row hit + transfer
    }

    #[test]
    fn different_channels_dont_queue() {
        let mut d = hbm();
        let a = d.access(0, 256, AccessKind::Read, 0);
        let b = d.access(256, 256, AccessKind::Read, 0); // next block -> next channel
        assert_eq!(a.done, b.done);
    }

    #[test]
    fn nvm_read_write_asymmetry() {
        let mut d = MemDevice::new(presets::nvm());
        let r = d.access(0, 256, AccessKind::Read, 0);
        let w = d.access(256, 256, AccessKind::Write, 0); // other channel
        assert_eq!(r.done, 246 + 128);
        assert_eq!(w.done, 739 + 128);
    }

    #[test]
    fn traffic_accounting() {
        let mut d = hbm();
        d.access(0, 256, AccessKind::Read, 0);
        d.access(512, 64, AccessKind::Write, 0);
        assert_eq!(d.bytes_transferred, 320);
        assert_eq!(d.accesses, 2);
    }

    #[test]
    fn unloaded_latency_matches_first_access() {
        let mut d = hbm();
        assert_eq!(d.unloaded_latency(64), d.access(0, 64, AccessKind::Read, 0).done);
    }
}

//! CPU cache hierarchy: per-core L1D and L2 plus a shared LLC, matching the
//! paper's Table 1 geometry. The hierarchy filters each core's access
//! stream; only LLC misses (and LLC dirty evictions) reach the hybrid
//! memory controller, exactly as in the zsim setup the paper uses.
//!
//! Caches are set-associative, write-back, write-allocate, LRU. Dirty
//! evictions are written back into the next level (without a fetch); dirty
//! LLC evictions surface as memory writebacks.

use crate::config::CacheConfig;
use crate::types::{AccessKind, Cycle, PhysAddr};

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    last_use: u64,
}

/// One set-associative write-back cache.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: u64,
    ways: u32,
    line_bits: u32,
    lines: Vec<Line>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

/// Result of a single-level access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LineEvent {
    pub hit: bool,
    /// Byte address of a dirty line evicted to make room, if any.
    pub writeback: Option<PhysAddr>,
}

impl Cache {
    pub fn new(cfg: &CacheConfig) -> Self {
        let sets = cfg.num_sets();
        assert!(sets.is_power_of_two(), "cache sets must be a power of two");
        Cache {
            sets,
            ways: cfg.ways,
            line_bits: cfg.line_bytes.trailing_zeros(),
            lines: vec![Line::default(); (sets * cfg.ways as u64) as usize],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: PhysAddr) -> u64 {
        (addr >> self.line_bits) & (self.sets - 1)
    }

    #[inline]
    fn tag_of(&self, addr: PhysAddr) -> u64 {
        addr >> self.line_bits
    }

    /// Demand access. On miss, allocates the line (fetch modelled by the
    /// caller descending the hierarchy).
    pub fn access(&mut self, addr: PhysAddr, kind: AccessKind) -> LineEvent {
        self.touch(addr, kind, true)
    }

    /// Insert a line arriving as a writeback from an upper level: the line
    /// becomes resident and dirty, but a miss here is not counted as a
    /// demand miss and does not trigger a fetch.
    pub fn writeback_insert(&mut self, addr: PhysAddr) -> Option<PhysAddr> {
        let ev = self.touch(addr, AccessKind::Write, false);
        ev.writeback
    }

    fn touch(&mut self, addr: PhysAddr, kind: AccessKind, demand: bool) -> LineEvent {
        self.tick += 1;
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = (set * self.ways as u64) as usize;
        let ways = self.ways as usize;

        let mut victim = base;
        let mut victim_use = u64::MAX;
        for i in base..base + ways {
            let l = &mut self.lines[i];
            if l.valid && l.tag == tag {
                l.last_use = self.tick;
                l.dirty |= kind.is_write();
                if demand {
                    self.hits += 1;
                }
                return LineEvent { hit: true, writeback: None };
            }
            let use_key = if l.valid { l.last_use } else { 0 };
            if use_key < victim_use {
                victim_use = use_key;
                victim = i;
            }
        }
        if demand {
            self.misses += 1;
        }
        let l = &mut self.lines[victim];
        let writeback = if l.valid && l.dirty {
            Some(l.tag << self.line_bits)
        } else {
            None
        };
        *l = Line { tag, valid: true, dirty: kind.is_write(), last_use: self.tick };
        LineEvent { hit: false, writeback }
    }

    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 { 0.0 } else { self.hits as f64 / n as f64 }
    }
}

/// Upper bound on dirty LLC evictions one access can surface: at most one
/// per cache level crossed (L1 evict cascading to memory, L2 evict
/// cascading to memory, LLC evict).
pub const MAX_WRITEBACKS: usize = 3;

/// What the hierarchy tells the memory system about one core access.
///
/// Writebacks are stored inline (`[PhysAddr; MAX_WRITEBACKS]` + length)
/// instead of a `Vec`: this struct is built once per simulated access, and
/// the old heap-backed list was the last steady-state allocation on the
/// simulation hot path.
#[derive(Debug, Clone, Copy, Default)]
pub struct HierarchyResult {
    /// On-chip latency (cache lookups) in cycles.
    pub latency: Cycle,
    /// True if the access missed everywhere and needs memory.
    pub llc_miss: bool,
    /// Level that served the access: 1, 2, 3, or 0 for memory.
    pub hit_level: u8,
    wb: [PhysAddr; MAX_WRITEBACKS],
    wb_len: u8,
}

impl HierarchyResult {
    /// Dirty LLC evictions that must be written to memory.
    #[inline]
    pub fn writebacks(&self) -> &[PhysAddr] {
        &self.wb[..self.wb_len as usize]
    }

    #[inline]
    fn push_writeback(&mut self, addr: PhysAddr) {
        self.wb[self.wb_len as usize] = addr;
        self.wb_len += 1;
    }
}

/// Per-core L1D + L2 with a shared LLC.
#[derive(Debug)]
pub struct Hierarchy {
    l1: Vec<Cache>,
    l2: Vec<Cache>,
    llc: Cache,
    l1_lat: Cycle,
    l2_lat: Cycle,
    llc_lat: Cycle,
}

impl Hierarchy {
    pub fn new(cores: u32, l1: &CacheConfig, l2: &CacheConfig, llc: &CacheConfig) -> Self {
        Hierarchy {
            l1: (0..cores).map(|_| Cache::new(l1)).collect(),
            l2: (0..cores).map(|_| Cache::new(l2)).collect(),
            llc: Cache::new(llc),
            l1_lat: l1.latency,
            l2_lat: l2.latency,
            llc_lat: llc.latency,
        }
    }

    /// Run one access from `core` through the hierarchy.
    pub fn access(&mut self, core: usize, addr: PhysAddr, kind: AccessKind) -> HierarchyResult {
        let mut res = HierarchyResult { latency: self.l1_lat, ..Default::default() };

        let e1 = self.l1[core].access(addr, kind);
        if let Some(wb) = e1.writeback {
            if let Some(wb2) = self.l2[core].writeback_insert(wb) {
                if let Some(wb3) = self.llc.writeback_insert(wb2) {
                    res.push_writeback(wb3);
                }
            }
        }
        if e1.hit {
            res.hit_level = 1;
            return res;
        }

        res.latency += self.l2_lat;
        let e2 = self.l2[core].access(addr, kind);
        if let Some(wb) = e2.writeback {
            if let Some(wb2) = self.llc.writeback_insert(wb) {
                res.push_writeback(wb2);
            }
        }
        if e2.hit {
            res.hit_level = 2;
            return res;
        }

        res.latency += self.llc_lat;
        let e3 = self.llc.access(addr, kind);
        if let Some(wb) = e3.writeback {
            res.push_writeback(wb);
        }
        if e3.hit {
            res.hit_level = 3;
            return res;
        }

        res.llc_miss = true;
        res
    }

    /// Total demand accesses issued into the hierarchy (L1 hits + misses
    /// over all cores) — the `cache_accesses` stat of the end-of-run
    /// report.
    pub fn accesses(&self) -> u64 {
        self.l1.iter().map(|c| c.hits + c.misses).sum()
    }

    pub fn l1_hits(&self) -> u64 {
        self.l1.iter().map(|c| c.hits).sum()
    }
    pub fn l2_hits(&self) -> u64 {
        self.l2.iter().map(|c| c.hits).sum()
    }
    pub fn llc_hits(&self) -> u64 {
        self.llc.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CacheConfig {
        CacheConfig { size_bytes: 1024, ways: 2, line_bytes: 64, latency: 1 }
    }

    #[test]
    fn hit_after_fill() {
        let mut c = Cache::new(&tiny());
        assert!(!c.access(0x100, AccessKind::Read).hit);
        assert!(c.access(0x100, AccessKind::Read).hit);
        assert!(c.access(0x13f, AccessKind::Read).hit); // same line
        assert!(!c.access(0x140, AccessKind::Read).hit); // next line
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = Cache::new(&tiny()); // 8 sets, 2 ways
        let set_stride = 8 * 64; // same set
        c.access(0, AccessKind::Read);
        c.access(set_stride as u64, AccessKind::Read);
        c.access(0, AccessKind::Read); // refresh way 0
        c.access(2 * set_stride as u64, AccessKind::Read); // evicts set_stride
        assert!(c.access(0, AccessKind::Read).hit);
        assert!(!c.access(set_stride as u64, AccessKind::Read).hit);
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::new(&tiny());
        let set_stride = 8 * 64u64;
        c.access(0, AccessKind::Write);
        c.access(set_stride, AccessKind::Read);
        let ev = c.access(2 * set_stride, AccessKind::Read); // evicts dirty 0
        assert_eq!(ev.writeback, Some(0));
    }

    #[test]
    fn clean_eviction_no_writeback() {
        let mut c = Cache::new(&tiny());
        let set_stride = 8 * 64u64;
        c.access(0, AccessKind::Read);
        c.access(set_stride, AccessKind::Read);
        let ev = c.access(2 * set_stride, AccessKind::Read);
        assert_eq!(ev.writeback, None);
    }

    #[test]
    fn hierarchy_filters_and_charges_latency() {
        let cfg = tiny();
        let mut h = Hierarchy::new(2, &cfg, &cfg, &cfg);
        let r1 = h.access(0, 0x1000, AccessKind::Read);
        assert!(r1.llc_miss);
        assert_eq!(r1.latency, 3); // 1+1+1
        let r2 = h.access(0, 0x1000, AccessKind::Read);
        assert!(!r2.llc_miss);
        assert_eq!(r2.hit_level, 1);
        // Other core misses its private L1/L2 but hits shared LLC.
        let r3 = h.access(1, 0x1000, AccessKind::Read);
        assert_eq!(r3.hit_level, 3);
    }

    #[test]
    fn llc_dirty_eviction_surfaces() {
        let cfg = CacheConfig { size_bytes: 128, ways: 1, line_bytes: 64, latency: 1 };
        let mut h = Hierarchy::new(1, &cfg, &cfg, &cfg);
        h.access(0, 0, AccessKind::Write);
        // Push the dirty line out of L1 -> L2 -> LLC and then out of LLC.
        // With 2 sets x 1 way everywhere, addresses mapping to set 0:
        let s = 128u64;
        let mut wbs = vec![];
        for i in 1..=6 {
            wbs.extend(h.access(0, i * s, AccessKind::Read).writebacks().iter().copied());
        }
        assert!(wbs.contains(&0), "dirty line should eventually reach memory: {wbs:?}");
    }

    #[test]
    fn hit_rate_counts() {
        let mut c = Cache::new(&tiny());
        c.access(0, AccessKind::Read);
        c.access(0, AccessKind::Read);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }
}

//! Bloom-filter alternative to the IdCache — implemented to *demonstrate*
//! the paper's §3.4 argument for rejecting it, not to use it.
//!
//! The paper: "due to the false positives in Bloom filters, we cannot use
//! them to store the identity-mapping set; doing so may incorrectly
//! classify an address with non-identity mapping into the identity-mapping
//! set" — i.e. a false positive would silently return *stale data from the
//! wrong device address*. [`BloomIdFilter`] counts exactly those
//! would-be-wrong classifications so the ablation bench can quantify the
//! correctness violation rate at iRC-equivalent SRAM budgets (see
//! `examples/bloom_ablation` rows in EXPERIMENTS.md).
//!
//! The filter itself is a standard blocked Bloom filter with `K` hashes
//! over a power-of-two bit array, with deletion unsupported (another
//! practical reason the paper's sector-cache design wins: identity sets
//! churn on every migration).

use crate::types::BlockId;

/// A blocked Bloom filter over block ids.
#[derive(Debug, Clone)]
pub struct BloomIdFilter {
    bits: Vec<u64>,
    mask: u64,
    k: u32,
    /// Number of inserted keys (for load/FPR estimation).
    pub inserted: u64,
}

impl BloomIdFilter {
    /// `budget_bytes`: SRAM budget (the iRC IdCache uses ~16 kB in
    /// Table 1); `k`: hash functions.
    pub fn new(budget_bytes: usize, k: u32) -> Self {
        let nbits = (budget_bytes * 8).next_power_of_two();
        BloomIdFilter {
            bits: vec![0u64; nbits / 64],
            mask: nbits as u64 - 1,
            k,
            inserted: 0,
        }
    }

    #[inline]
    fn hash(&self, key: BlockId, i: u32) -> u64 {
        // Two independent 64-bit mixes combined (Kirsch-Mitzenmacher).
        let h1 = key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 17;
        let h2 = key.wrapping_mul(0xC2B2_AE3D_27D4_EB4F) >> 13 | 1;
        h1.wrapping_add((i as u64).wrapping_mul(h2)) & self.mask
    }

    pub fn insert(&mut self, key: BlockId) {
        for i in 0..self.k {
            let b = self.hash(key, i);
            self.bits[(b / 64) as usize] |= 1 << (b % 64);
        }
        self.inserted += 1;
    }

    /// Probabilistic membership: true means "maybe identity".
    pub fn contains(&self, key: BlockId) -> bool {
        (0..self.k).all(|i| {
            let b = self.hash(key, i);
            self.bits[(b / 64) as usize] & (1 << (b % 64)) != 0
        })
    }

    /// Measured false-positive rate over `probes` keys known to be absent.
    pub fn measured_fpr(&self, absent_keys: impl Iterator<Item = BlockId>) -> f64 {
        let mut total = 0u64;
        let mut fp = 0u64;
        for k in absent_keys {
            total += 1;
            fp += self.contains(k) as u64;
        }
        if total == 0 { 0.0 } else { fp as f64 / total as f64 }
    }

    /// Theoretical FPR at the current load.
    pub fn expected_fpr(&self) -> f64 {
        let m = (self.mask + 1) as f64;
        let n = self.inserted as f64;
        let k = self.k as f64;
        (1.0 - (-k * n / m).exp()).powf(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Rng64;

    #[test]
    fn no_false_negatives() {
        let mut f = BloomIdFilter::new(16 << 10, 4);
        for k in (0..10_000u64).map(|i| i * 7 + 1) {
            f.insert(k);
        }
        for k in (0..10_000u64).map(|i| i * 7 + 1) {
            assert!(f.contains(k), "bloom filters never false-negative");
        }
    }

    #[test]
    fn false_positives_exist_at_identity_set_scale() {
        // The identity set of a 32:1 system is ~2M blocks; a 16 kB filter
        // is hopelessly overloaded — exactly the paper's point.
        let mut f = BloomIdFilter::new(16 << 10, 4);
        let mut rng = Rng64::new(42);
        for _ in 0..2_000_000u64 {
            f.insert(rng.next_u64() | 1);
        }
        let fpr = f.measured_fpr((0..10_000u64).map(|i| i * 2)); // even keys: absent
        assert!(
            fpr > 0.5,
            "overloaded filter must misclassify heavily (fpr = {fpr})"
        );
    }

    #[test]
    fn fpr_matches_theory_at_moderate_load() {
        let mut f = BloomIdFilter::new(64 << 10, 4);
        let mut rng = Rng64::new(7);
        for _ in 0..50_000u64 {
            f.insert(rng.next_u64() | 1);
        }
        let measured = f.measured_fpr((0..100_000u64).map(|i| i * 2));
        let expected = f.expected_fpr();
        assert!(
            (measured - expected).abs() < 0.05,
            "measured {measured} vs expected {expected}"
        );
    }

    #[test]
    fn every_false_positive_is_a_correctness_violation() {
        // A block with a *non-identity* mapping that the filter claims is
        // identity would be read from the wrong address. Count them.
        let mut f = BloomIdFilter::new(16 << 10, 4);
        let identity: Vec<u64> = (0..500_000u64).map(|i| i * 3 + 1).collect();
        for &k in &identity {
            f.insert(k);
        }
        let moved: Vec<u64> = (0..50_000u64).map(|i| i * 3).collect(); // disjoint
        let violations = moved.iter().filter(|&&k| f.contains(k)).count();
        assert!(
            violations > 0,
            "at realistic scale the filter returns wrong data at least once"
        );
    }
}

//! Trimma's identity-mapping-aware remap cache (iRC, §3.4 / Fig. 6).
//!
//! Under the same SRAM budget as a conventional remap cache, iRC splits the
//! storage into:
//!
//! * **NonIdCache** — a conventional remap cache, slightly smaller
//!   (2048 sets x 6 ways in Table 1), holding only *non-identity* entries;
//! * **IdCache** — a sector-cache-style structure (256 sets x 16 ways,
//!   hash-indexed) whose lines cover a *super-block* of 32 contiguous
//!   blocks (8 kB) with one bit each: bit = 1 means "known identity
//!   mapping", bit = 0 means "non-identity or unknown".
//!
//! Both are probed in parallel. An IdCache hit with bit = 1 resolves the
//! access with *no* off-chip metadata traffic and no pointer storage; the
//! compressed format lets the same SRAM cover 32x more identity entries,
//! raising the overall remap-cache hit rate (54% -> 67% in the paper).
//!
//! Bloom filters cannot replace the IdCache: a false positive would
//! misclassify a moved block as identity and return wrong data (§3.4).

use super::remap_cache::RemapCache;
use crate::types::BlockId;

/// Result of an iRC probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IrcProbe {
    /// NonIdCache hit: the stored device index.
    HitNonId(u32),
    /// IdCache hit with bit = 1: use the physical address as-is.
    HitId,
    /// IdCache line present but bit = 0 (known non-identity or unknown) and
    /// NonIdCache missed: off-chip walk required.
    BitZeroMiss,
    /// Neither structure has the line.
    Miss,
}

/// The identity-mapping-aware remap cache.
#[derive(Debug, Clone)]
pub struct Irc {
    nonid: RemapCache,
    id: RemapCache,
    superblock_blocks: u64,
}

impl Irc {
    pub fn new(
        nonid_sets: u32,
        nonid_ways: u32,
        id_sets: u32,
        id_ways: u32,
        superblock_blocks: u32,
    ) -> Self {
        assert!(
            superblock_blocks as usize <= 32,
            "IdCache lines use a 32-bit vector (4 B pointer footprint)"
        );
        Irc {
            nonid: RemapCache::new(nonid_sets, nonid_ways),
            id: RemapCache::with_index(id_sets, id_ways, true),
            superblock_blocks: superblock_blocks as u64,
        }
    }

    #[inline]
    fn superblock_of(&self, key: BlockId) -> (BlockId, u32) {
        (key / self.superblock_blocks, (key % self.superblock_blocks) as u32)
    }

    /// The SoA lane addresses a [`Irc::probe`] of `key` will touch, in
    /// both components: the NonIdCache set for `key` itself and the
    /// hash-indexed IdCache set for `key`'s super-block (the same index
    /// math the probe uses, see [`RemapCache::prefetch_targets`]).
    /// Read-only with no LRU/stats side effects — batched translate
    /// (DESIGN.md §15) only hands these to the prefetch shim.
    #[inline]
    pub fn prefetch_targets(&self, key: BlockId) -> [*const u8; 6] {
        let [n0, n1, n2] = self.nonid.prefetch_targets(key);
        let (sb, _) = self.superblock_of(key);
        let [i0, i1, i2] = self.id.prefetch_targets(sb);
        [n0, n1, n2, i0, i1, i2]
    }

    /// Probe both components in parallel (single SRAM latency). Runs once
    /// per LLC miss on Trimma design points; both component probes are
    /// allocation-free scans over the SoA lanes of [`RemapCache`].
    #[inline]
    pub fn probe(&mut self, key: BlockId) -> IrcProbe {
        if let Some(v) = self.nonid.probe(key) {
            return IrcProbe::HitNonId(v);
        }
        let (sb, bit) = self.superblock_of(key);
        match self.id.probe(sb) {
            Some(bits) if bits & (1 << bit) != 0 => IrcProbe::HitId,
            Some(_) => IrcProbe::BitZeroMiss,
            None => IrcProbe::Miss,
        }
    }

    /// Fill after an off-chip walk that found a non-identity entry.
    #[inline]
    pub fn fill_nonid(&mut self, key: BlockId, device: u32) {
        self.nonid.insert(key, device);
        // Keep any IdCache bit for this block consistent (must be 0).
        let (sb, bit) = self.superblock_of(key);
        self.id.modify(sb, |bits| bits & !(1 << bit));
    }

    /// Fill after a walk that found identity mapping(s). `bits` has bit `i`
    /// set iff block `superblock * superblock_blocks + i` is identity —
    /// the walk fetched the whole leaf block, so the controller knows the
    /// status of every neighbour for free.
    pub fn fill_id_vector(&mut self, superblock: BlockId, bits: u32) {
        self.id.insert(superblock, bits);
    }

    /// Consistency on table update (§3.4: "we simply invalidate"): drop the
    /// NonIdCache entry and clear the IdCache bit for this block.
    pub fn on_update(&mut self, key: BlockId) {
        self.nonid.invalidate(key);
        let (sb, bit) = self.superblock_of(key);
        self.id.modify(sb, |bits| bits & !(1 << bit));
    }

    pub fn superblock_blocks(&self) -> u64 {
        self.superblock_blocks
    }

    /// (NonIdCache entries, IdCache lines) — for capacity reporting.
    pub fn capacity(&self) -> (u64, u64) {
        (self.nonid.capacity(), self.id.capacity())
    }

    /// (live NonIdCache entries, live IdCache lines) — occupancy
    /// introspection for capacity-pressure tests and the verify oracle.
    pub fn live_entries(&self) -> (u64, u64) {
        (self.nonid.live_entries(), self.id.live_entries())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn irc() -> Irc {
        Irc::new(64, 4, 16, 4, 32)
    }

    #[test]
    fn miss_on_empty() {
        let mut c = irc();
        assert_eq!(c.probe(100), IrcProbe::Miss);
    }

    #[test]
    fn nonid_hit() {
        let mut c = irc();
        c.fill_nonid(100, 7);
        assert_eq!(c.probe(100), IrcProbe::HitNonId(7));
    }

    #[test]
    fn id_vector_hit_and_bit_zero() {
        let mut c = irc();
        // Blocks 64..96 form super-block 2; mark 64 and 65 identity.
        c.fill_id_vector(2, 0b11);
        assert_eq!(c.probe(64), IrcProbe::HitId);
        assert_eq!(c.probe(65), IrcProbe::HitId);
        assert_eq!(c.probe(66), IrcProbe::BitZeroMiss);
        assert_eq!(c.probe(96), IrcProbe::Miss); // next super-block
    }

    #[test]
    fn one_line_covers_32_blocks() {
        let mut c = irc();
        c.fill_id_vector(0, u32::MAX);
        for b in 0..32 {
            assert_eq!(c.probe(b), IrcProbe::HitId);
        }
    }

    #[test]
    fn update_invalidates_both_paths() {
        let mut c = irc();
        c.fill_nonid(100, 7);
        c.fill_id_vector(100 / 32, 1 << (100 % 32));
        c.on_update(100);
        // NonId entry dropped; IdCache bit cleared -> BitZeroMiss.
        assert_eq!(c.probe(100), IrcProbe::BitZeroMiss);
    }

    #[test]
    fn fill_nonid_clears_stale_id_bit() {
        let mut c = irc();
        c.fill_id_vector(3, u32::MAX); // all identity
        c.fill_nonid(96, 5); // block 96 = super-block 3, bit 0: moved
        assert_eq!(c.probe(96), IrcProbe::HitNonId(5));
        // After the NonId entry is evicted/invalidated the bit must not
        // falsely claim identity.
        c.on_update(96);
        assert_eq!(c.probe(96), IrcProbe::BitZeroMiss);
    }

    #[test]
    fn nonid_priority_over_id_bit() {
        let mut c = irc();
        c.fill_id_vector(0, 0); // line present, all bits 0
        c.fill_nonid(5, 9);
        assert_eq!(c.probe(5), IrcProbe::HitNonId(9));
    }

    #[test]
    fn all_identity_sector_resolves_every_block() {
        // A fully-identity sector: one line answers all 32 blocks with no
        // off-chip traffic and no pointer storage.
        let mut c = irc();
        c.fill_id_vector(5, u32::MAX);
        for b in 5 * 32..6 * 32 {
            assert_eq!(c.probe(b), IrcProbe::HitId, "block {b}");
        }
        let (nonid_live, id_live) = c.live_entries();
        assert_eq!(nonid_live, 0, "identity coverage must cost no NonId entries");
        assert_eq!(id_live, 1);
        // Neighbouring sectors are unknown, not identity.
        assert_eq!(c.probe(4 * 32), IrcProbe::Miss);
        assert_eq!(c.probe(6 * 32), IrcProbe::Miss);
    }

    #[test]
    fn single_bit_flip_then_eviction_never_fakes_identity() {
        // The §3.4 safety argument: once a block moves, no sequence of
        // fills/evictions/updates may ever classify it identity again
        // until the table says so. Flip one bit out of a full sector,
        // evict the NonId entry, and probe.
        let mut c = irc();
        c.fill_id_vector(3, u32::MAX); // all 32 identity
        c.fill_nonid(96, 5); // block 96 = sector 3 bit 0 moves
        assert_eq!(c.probe(96), IrcProbe::HitNonId(5));
        // Its sector bit must have flipped to 0 already.
        c.on_update(96); // NonId entry dropped (e.g. table update)
        assert_eq!(
            c.probe(96),
            IrcProbe::BitZeroMiss,
            "a moved block must walk, never claim identity"
        );
        // The other 31 blocks of the sector still short-circuit.
        for b in 97..128 {
            assert_eq!(c.probe(b), IrcProbe::HitId, "block {b}");
        }
    }

    #[test]
    fn nonid_capacity_pressure_falls_back_to_bit_zero() {
        // Tiny NonIdCache (2 sets x 1 way): conflicting non-identity
        // entries evict each other; the evicted block's IdCache bit stayed
        // 0, so probes degrade to a safe walk (BitZeroMiss), never HitId.
        let mut c = Irc::new(2, 1, 2, 1, 32);
        c.fill_id_vector(0, u32::MAX); // sector 0: blocks 0..32 identity
        let conflicting = [0u64, 2, 4, 6]; // all NonId set 0
        for &k in &conflicting {
            c.fill_nonid(k, 77);
        }
        let mut nonid_hits = 0;
        for &k in &conflicting {
            match c.probe(k) {
                IrcProbe::HitNonId(77) => nonid_hits += 1,
                IrcProbe::BitZeroMiss => {} // evicted: safe fallback
                other => panic!("block {k}: moved block classified {other:?}"),
            }
        }
        assert!(nonid_hits <= 1, "1-way set cannot hold {nonid_hits} entries");
        let (live, _) = c.live_entries();
        assert!(live <= 2, "NonIdCache capacity is 2, holds {live}");
    }

    #[test]
    fn id_capacity_pressure_evicts_whole_sectors() {
        // Tiny IdCache (2 sets x 1 way): filling more sectors than lines
        // must evict whole identity vectors — evicted sectors probe as
        // Miss (unknown), which is safe; and the NonIdCache is untouched.
        let mut c = Irc::new(2, 1, 2, 1, 32);
        c.fill_nonid(1, 42);
        let sectors = [10u64, 11, 12, 13, 14];
        for &sb in &sectors {
            c.fill_id_vector(sb, u32::MAX);
        }
        let mut id_hits = 0;
        for &sb in &sectors {
            match c.probe(sb * 32) {
                IrcProbe::HitId => id_hits += 1,
                IrcProbe::Miss => {} // sector evicted: unknown, walk
                other => panic!("sector {sb}: {other:?}"),
            }
        }
        assert!(id_hits <= 2, "2 lines cannot cover {id_hits} sectors");
        let (_, id_live) = c.live_entries();
        assert!(id_live <= 2);
        // The non-identity path is independent of IdCache pressure.
        assert_eq!(c.probe(1), IrcProbe::HitNonId(42));
    }
}

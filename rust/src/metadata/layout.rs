//! Set-associative partition of the two tiers (paper Fig. 4) and the
//! unified per-set index space.
//!
//! Blocks interleave across sets by their low-order block-id bits, so both
//! tiers stripe uniformly over sets. Within a set:
//!
//! ```text
//! device idx:  0 .. data_ways        basic fast data area (cache/flat ways)
//!              data_ways .. F        reserved metadata region (tables live
//!                                    here; unallocated blocks are donated
//!                                    as extra ways by Trimma)
//!              F .. F+S              the set's slow-tier blocks
//! ```

use crate::config::{HybridConfig, MetadataScheme};
use crate::types::{ilog2, BlockId};

/// Geometry of the set partition. Cheap to copy; shared by tables,
/// controllers, and workload address mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SetLayout {
    pub num_sets: u32,
    /// `log2(num_sets)` — set math compiles to shifts/masks (validated
    /// power of two), which matters: these run on every simulated access.
    pub set_bits: u32,
    /// Fast-tier blocks per set (data area + metadata region).
    pub fast_per_set: u64,
    /// Slow-tier blocks per set.
    pub slow_per_set: u64,
    /// Reserved metadata blocks per set (capped at `fast_per_set`).
    pub meta_per_set: u64,
    /// Basic data ways per set: `fast_per_set - meta_per_set`.
    pub data_ways: u64,
    pub block_bytes: u32,
}

impl SetLayout {
    /// Build a layout with an explicit metadata reservation per set.
    pub fn new(
        num_sets: u32,
        fast_bytes: u64,
        slow_bytes: u64,
        block_bytes: u32,
        meta_per_set: u64,
    ) -> Self {
        let fast_blocks = fast_bytes / block_bytes as u64;
        let slow_blocks = slow_bytes / block_bytes as u64;
        assert_eq!(fast_blocks % num_sets as u64, 0);
        assert_eq!(slow_blocks % num_sets as u64, 0);
        let fast_per_set = fast_blocks / num_sets as u64;
        let slow_per_set = slow_blocks / num_sets as u64;
        let meta_per_set = meta_per_set.min(fast_per_set);
        assert!(num_sets.is_power_of_two());
        SetLayout {
            num_sets,
            set_bits: num_sets.trailing_zeros(),
            fast_per_set,
            slow_per_set,
            meta_per_set,
            data_ways: fast_per_set - meta_per_set,
            block_bytes,
        }
    }

    /// Build a layout sized for a hybrid config, reserving metadata space
    /// according to the metadata scheme (tag schemes reserve nothing:
    /// their tags are embedded with the data, per the paper's optimistic
    /// baseline treatment).
    pub fn for_config(h: &HybridConfig, ideal: bool) -> Self {
        let basic = SetLayout::new(h.num_sets, h.fast_bytes, h.slow_bytes, h.block_bytes, 0);
        let reserved = if ideal {
            0
        } else {
            match h.scheme {
                MetadataScheme::Linear => {
                    linear_reserved_blocks(basic.indices_per_set(), h.block_bytes)
                }
                MetadataScheme::Irt { levels } => {
                    irt_reserved_blocks(basic.indices_per_set(), h.block_bytes, levels)
                }
                MetadataScheme::TagAlloy | MetadataScheme::TagLohHill => 0,
            }
        };
        SetLayout::new(h.num_sets, h.fast_bytes, h.slow_bytes, h.block_bytes, reserved)
    }

    /// Total per-set index space: fast + slow.
    #[inline]
    pub fn indices_per_set(&self) -> u64 {
        self.fast_per_set + self.slow_per_set
    }

    /// True if a per-set device index is on the fast tier.
    #[inline]
    pub fn is_fast_idx(&self, idx: u64) -> bool {
        idx < self.fast_per_set
    }

    /// True if a per-set device index falls inside the metadata region.
    #[inline]
    pub fn is_meta_idx(&self, idx: u64) -> bool {
        idx >= self.data_ways && idx < self.fast_per_set
    }

    /// Map a global slow-tier block to `(set, per-set index)`.
    #[inline]
    pub fn slow_block_to_idx(&self, block: BlockId) -> (u32, u64) {
        let set = (block & (self.num_sets as u64 - 1)) as u32;
        (set, self.fast_per_set + (block >> self.set_bits))
    }

    /// Map a global fast-tier block to `(set, per-set index)`.
    #[inline]
    pub fn fast_block_to_idx(&self, block: BlockId) -> (u32, u64) {
        let set = (block & (self.num_sets as u64 - 1)) as u32;
        (set, block >> self.set_bits)
    }

    /// Global fast-tier block for a per-set fast index.
    #[inline]
    pub fn fast_global(&self, set: u32, idx: u64) -> BlockId {
        debug_assert!(self.is_fast_idx(idx));
        (idx << self.set_bits) | set as u64
    }

    /// Global slow-tier block for a per-set slow index.
    #[inline]
    pub fn slow_global(&self, set: u32, idx: u64) -> BlockId {
        debug_assert!(!self.is_fast_idx(idx));
        ((idx - self.fast_per_set) << self.set_bits) | set as u64
    }

    /// Device *byte* address for a per-set index (fast tier addresses and
    /// slow tier addresses live in separate device spaces).
    #[inline]
    pub fn device_byte_addr(&self, set: u32, idx: u64) -> u64 {
        if self.is_fast_idx(idx) {
            self.fast_global(set, idx) * self.block_bytes as u64
        } else {
            self.slow_global(set, idx) * self.block_bytes as u64
        }
    }

    /// Byte address (in the fast tier) of the `n`-th reserved metadata
    /// block of `set` — used to time table-walk DRAM accesses.
    #[inline]
    pub fn meta_block_addr(&self, set: u32, n: u64) -> u64 {
        let idx = self.data_ways + (n % self.meta_per_set.max(1));
        self.fast_global(set, idx) * self.block_bytes as u64
    }

    /// Cheap key for blocks known to be on the slow tier (hot path of the
    /// remap caches): equals `key(slow_block_to_idx(block))`.
    #[inline]
    pub fn slow_key(&self, block: BlockId) -> u64 {
        (self.fast_per_set << self.set_bits) + block
    }

    #[inline]
    pub fn block_offset_bits(&self) -> u32 {
        ilog2(self.block_bytes as u64)
    }

    /// Globally unique key for `(set, idx)` — used by the remap caches.
    /// Contiguous physical blocks get contiguous keys (blocks interleave
    /// over sets by their low bits), which is what the IdCache's
    /// super-block grouping relies on.
    #[inline]
    pub fn key(&self, set: u32, idx: u64) -> u64 {
        (idx << self.set_bits) | set as u64
    }

    /// Inverse of [`SetLayout::key`]. Returns `None` if out of range.
    #[inline]
    pub fn key_inverse(&self, key: u64) -> Option<(u32, u64)> {
        let set = (key & (self.num_sets as u64 - 1)) as u32;
        let idx = key >> self.set_bits;
        (idx < self.indices_per_set()).then_some((set, idx))
    }
}

/// Reserved blocks per set for a linear table: 4 B per index, rounded up to
/// whole blocks.
pub fn linear_reserved_blocks(indices_per_set: u64, block_bytes: u32) -> u64 {
    (indices_per_set * 4).div_ceil(block_bytes as u64)
}

/// Per-level block counts for an iRT over `indices_per_set` entries.
/// Level 0 holds 4 B leaf entries; upper levels hold 1-bit-per-child
/// vectors. `levels == 4` uses the Tag-Tables-style 6-bit (64-ary) slicing;
/// otherwise index blocks are full bit vectors (`block_bytes * 8` children).
pub fn irt_level_blocks(indices_per_set: u64, block_bytes: u32, levels: u32) -> Vec<u64> {
    assert!((1..=4).contains(&levels));
    let leaf_fanout = (block_bytes / 4) as u64;
    let index_fanout = if levels == 4 { 64 } else { (block_bytes as u64) * 8 };
    let mut blocks = vec![indices_per_set.div_ceil(leaf_fanout)];
    for _ in 1..levels {
        let prev = *blocks.last().unwrap();
        blocks.push(prev.div_ceil(index_fanout));
    }
    blocks
}

/// Total reserved blocks per set for an iRT (all levels, worst case).
pub fn irt_reserved_blocks(indices_per_set: u64, block_bytes: u32, levels: u32) -> u64 {
    irt_level_blocks(indices_per_set, block_bytes, levels).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let l = SetLayout::new(8, 1 << 20, 32 << 20, 256, 100);
        for block in [0u64, 1, 7, 8, 12345, 130000] {
            let (set, idx) = l.slow_block_to_idx(block);
            assert!(!l.is_fast_idx(idx));
            assert_eq!(l.slow_global(set, idx), block);
        }
        for block in [0u64, 5, 4095] {
            let (set, idx) = l.fast_block_to_idx(block);
            assert!(l.is_fast_idx(idx));
            assert_eq!(l.fast_global(set, idx), block);
        }
    }

    #[test]
    fn meta_region_position() {
        let l = SetLayout::new(4, 1 << 20, 8 << 20, 256, 128);
        assert_eq!(l.fast_per_set, 1024);
        assert_eq!(l.data_ways, 896);
        assert!(l.is_meta_idx(896));
        assert!(l.is_meta_idx(1023));
        assert!(!l.is_meta_idx(895));
        assert!(!l.is_meta_idx(1024)); // slow space
    }

    #[test]
    fn linear_reservation_matches_paper_math() {
        // 32:1 ratio, 256 B blocks: table = 33/32 * 4/256 of one set's
        // index space => 51.6% of the fast blocks.
        let l = SetLayout::new(1, 16 << 20, 512 << 20, 256, 0);
        let r = linear_reserved_blocks(l.indices_per_set(), 256);
        let frac = r as f64 / l.fast_per_set as f64;
        assert!((frac - 0.5156).abs() < 0.002, "frac={frac}");
    }

    #[test]
    fn irt_reservation_tiny_intermediate() {
        // 2-level iRT: leaves equal the linear table, plus ~1/2048 overhead.
        let l = SetLayout::new(1, 16 << 20, 512 << 20, 256, 0);
        let lv = irt_level_blocks(l.indices_per_set(), 256, 2);
        assert_eq!(lv.len(), 2);
        let linear = linear_reserved_blocks(l.indices_per_set(), 256);
        assert_eq!(lv[0], linear);
        assert!(lv[1] <= linear / 2048 + 1);
    }

    #[test]
    fn irt_four_level_uses_64ary() {
        let lv = irt_level_blocks(1 << 20, 256, 4);
        assert_eq!(lv[0], (1 << 20) / 64);
        assert_eq!(lv[1], lv[0] / 64);
        assert_eq!(lv[2], lv[1].div_ceil(64));
        assert_eq!(lv[3], 1);
    }

    #[test]
    fn reservation_caps_at_fast_capacity() {
        // Extreme 512:1 ratio: linear table would exceed the fast tier.
        let fast = 1u64 << 20;
        let slow = 512u64 << 20;
        let basic = SetLayout::new(1, fast, slow, 256, 0);
        let r = linear_reserved_blocks(basic.indices_per_set(), 256);
        let l = SetLayout::new(1, fast, slow, 256, r);
        assert_eq!(l.meta_per_set, l.fast_per_set);
        assert_eq!(l.data_ways, 0);
    }

    #[test]
    fn for_config_reserves_by_scheme() {
        use crate::config::presets::{self, DesignPoint};
        let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        let l = SetLayout::for_config(&cfg.hybrid, false);
        assert!(l.meta_per_set > 0);
        let cfg2 = presets::hbm3_ddr5(DesignPoint::AlloyCache);
        let l2 = SetLayout::for_config(&cfg2.hybrid, false);
        assert_eq!(l2.meta_per_set, 0);
        let l3 = SetLayout::for_config(&cfg.hybrid, true);
        assert_eq!(l3.meta_per_set, 0);
    }
}

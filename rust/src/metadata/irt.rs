//! Trimma's indirection-based remap table (iRT, §3.2 / Fig. 5).
//!
//! A per-set radix tree, fully managed in hardware:
//!
//! * The whole (worst-case) tree is linearized breadth-first into a
//!   reserved, contiguous fast-memory region, so every entry has a *fixed*
//!   address derivable from its tag bits — walks of all levels can issue in
//!   parallel, and allocation never moves entries.
//! * Leaf blocks hold `block_bytes / 4` four-byte remapped block IDs.
//!   Intermediate blocks hold one *bit* per child ("allocated?"), so a
//!   256 B index block covers 2048 children (11-bit tag chunks). With
//!   `levels == 4` the index fanout drops to 64 (6-bit chunks), mimicking
//!   Tag Tables for the Fig. 13a ablation. `levels == 1` degenerates to the
//!   linear table (every leaf permanently resident, no bit vector).
//! * A lookup that finds an unallocated block at any level returns the
//!   identity mapping — unmoved and unallocated data need no metadata.
//! * Unallocated *reserved* blocks (leaf or intermediate, never the root
//!   level) are donated to the set as extra cache slots (§3.3); allocation
//!   takes them back with priority, evicting any data cached there.
//!
//! ## Storage
//!
//! All per-set state lives in flat, stride-indexed arrays shared across
//! sets (entry `set * k + idx`, block `set * total_blocks + level_offset[l]
//! + b`), with the per-block "allocated?" flags packed into a u64 bitset.
//! The hot `lookup`/`is_identity` paths are a single indexed load (plus one
//! bit test), with no nested-`Vec` pointer chasing and no per-access
//! allocation — this sits on the critical path of every simulated LLC miss.

use super::layout::{irt_level_blocks, SetLayout};
use super::{MetaEvent, IDENTITY};

/// The indirection-based remap table.
#[derive(Debug, Clone)]
pub struct IrtTable {
    levels: u32,
    /// Index-space size per set (entry-array stride).
    k: u64,
    leaf_fanout: u64,
    index_fanout: u64,
    /// Blocks per level (0 = leaf, last = root).
    level_blocks: Vec<u64>,
    /// Offset of each level's first block within the metadata region
    /// (leaves first, then each index level, root last).
    level_offset: Vec<u64>,
    /// Sum of `level_blocks` (block-array stride per set).
    total_blocks: u64,
    data_ways: u64,
    fast_per_set: u64,
    block_bytes: u32,
    num_sets: u32,
    /// Dense entry array over all sets, `set * k + idx`; `IDENTITY` = absent.
    entries: Vec<u32>,
    /// Packed per-block allocation bits, bit `set * total_blocks +
    /// level_offset[l] + b`. Root-level bits are never set (the root is
    /// implicitly always allocated).
    alloc: Vec<u64>,
    /// Live-children count per block, same indexing as `alloc`. Level 0
    /// counts non-identity entries in the leaf; level `l` counts allocated
    /// blocks of level `l-1`. Maintained for the root level too (no dealloc
    /// there, but useful for invariants).
    counts: Vec<u32>,
    /// Per set: allocated non-root blocks (drives metadata size accounting).
    allocated_nonroot: Vec<u64>,
    /// Per set: reserved blocks currently donatable (unallocated, with a
    /// real slot).
    donated: Vec<u64>,
}

impl IrtTable {
    pub fn new(layout: &SetLayout, levels: u32) -> Self {
        assert!((1..=4).contains(&levels));
        let k = layout.indices_per_set();
        assert!(k < IDENTITY as u64, "index space exceeds 4 B entry range");
        let leaf_fanout = (layout.block_bytes / 4) as u64;
        let index_fanout = if levels == 4 { 64 } else { (layout.block_bytes as u64) * 8 };
        let level_blocks = irt_level_blocks(k, layout.block_bytes, levels);
        let mut level_offset = Vec::with_capacity(level_blocks.len());
        let mut off = 0;
        for &n in &level_blocks {
            level_offset.push(off);
            off += n;
        }
        let total_blocks: u64 = off;

        // Initial per-set donation: unallocated non-root blocks whose slot
        // actually exists in the (possibly capped) reserved region.
        let root = levels as usize - 1;
        let mut donated_per_set = 0u64;
        for (l, &n) in level_blocks.iter().enumerate() {
            if l != root {
                let first_slot = layout.data_ways + level_offset[l];
                donated_per_set += if first_slot >= layout.fast_per_set {
                    0
                } else {
                    (layout.fast_per_set - first_slot).min(n)
                };
            }
        }

        let num_sets = layout.num_sets;
        let n_entries = (num_sets as u64 * k) as usize;
        let n_blocks = (num_sets as u64 * total_blocks) as usize;
        IrtTable {
            levels,
            k,
            leaf_fanout,
            index_fanout,
            level_blocks,
            level_offset,
            total_blocks,
            data_ways: layout.data_ways,
            fast_per_set: layout.fast_per_set,
            block_bytes: layout.block_bytes,
            num_sets,
            entries: vec![IDENTITY; n_entries],
            alloc: vec![0u64; n_blocks.div_ceil(64)],
            counts: vec![0u32; n_blocks],
            allocated_nonroot: vec![0; num_sets as usize],
            donated: vec![donated_per_set; num_sets as usize],
        }
    }

    pub fn levels(&self) -> u32 {
        self.levels
    }

    #[inline]
    fn entry_index(&self, set: u32, idx: u64) -> usize {
        (set as u64 * self.k + idx) as usize
    }

    /// Flat index of block `b` of `level` in `set` (for `counts` and the
    /// `alloc` bit position).
    #[inline]
    fn block_index(&self, set: u32, level: usize, block: u64) -> u64 {
        set as u64 * self.total_blocks + self.level_offset[level] + block
    }

    #[inline]
    fn alloc_bit(&self, set: u32, level: usize, block: u64) -> bool {
        let p = self.block_index(set, level, block);
        (self.alloc[(p >> 6) as usize] >> (p & 63)) & 1 != 0
    }

    #[inline]
    fn set_alloc_bit(&mut self, set: u32, level: usize, block: u64, on: bool) {
        let p = self.block_index(set, level, block);
        let w = &mut self.alloc[(p >> 6) as usize];
        if on {
            *w |= 1u64 << (p & 63);
        } else {
            *w &= !(1u64 << (p & 63));
        }
    }

    /// Resolve `idx`: absent entry (or unallocated leaf) means identity.
    #[inline]
    pub fn lookup(&self, set: u32, idx: u64) -> u64 {
        let e = self.entries[self.entry_index(set, idx)];
        if e == IDENTITY { idx } else { e as u64 }
    }

    /// The exact addresses a [`IrtTable::lookup`]/[`IrtTable::is_identity`]
    /// of `(set, idx)` will touch: the packed 4 B entry word and the `u64`
    /// word of the alloc bitset holding the covering leaf's allocation bit
    /// (for a 1-level table there is no leaf shortcut, so both slots point
    /// at the entry word). Read-only, no side effects — the batched
    /// translate stage (DESIGN.md §15) only hands these to the prefetch
    /// shim, which never dereferences them.
    #[inline]
    pub fn prefetch_targets(&self, set: u32, idx: u64) -> [*const u8; 2] {
        let entry: *const u8 = self.entries[self.entry_index(set, idx)..].as_ptr().cast();
        if self.levels > 1 {
            let p = self.block_index(set, 0, idx / self.leaf_fanout);
            [entry, self.alloc[(p >> 6) as usize..].as_ptr().cast()]
        } else {
            [entry, entry]
        }
    }

    /// Identity check with the leaf-allocation shortcut: an unallocated
    /// leaf implies identity for all 64 entries it covers, without touching
    /// the (large) entry array — the alloc bitset is tiny and stays in
    /// cache, which makes the iRC super-block fill cheap.
    #[inline]
    pub fn is_identity(&self, set: u32, idx: u64) -> bool {
        if self.levels > 1 {
            let lb = idx / self.leaf_fanout;
            if !self.alloc_bit(set, 0, lb) {
                return true;
            }
        }
        self.entries[self.entry_index(set, idx)] == IDENTITY
    }

    /// True if the leaf block covering `idx` is currently allocated.
    #[inline]
    pub fn leaf_allocated(&self, set: u32, idx: u64) -> bool {
        if self.levels == 1 {
            return true;
        }
        self.alloc_bit(set, 0, idx / self.leaf_fanout)
    }

    /// Per-set fast slot of a reserved block `(level, block)`, if it exists
    /// within the (possibly capped) region.
    #[inline]
    fn slot_of(&self, level: usize, block: u64) -> Option<u64> {
        let slot = self.data_ways + self.level_offset[level] + block;
        (slot < self.fast_per_set).then_some(slot)
    }

    /// Per-set fast slot of the leaf block covering `idx` (test helper).
    pub fn slot_of_leaf_for(&self, _layout: &SetLayout, idx: u64) -> Option<u64> {
        self.slot_of(0, idx / self.leaf_fanout)
    }

    /// Install `idx -> device`. Emits [`MetaEvent::BlockAllocated`] for
    /// every reserved block the update brings to life.
    pub fn set_mapping(&mut self, set: u32, idx: u64, device: u64, out: &mut Vec<MetaEvent>) {
        if device == idx {
            self.clear_mapping(set, idx, out);
            return;
        }
        let ei = self.entry_index(set, idx);
        let prev = self.entries[ei];
        self.entries[ei] = device as u32;
        if prev != IDENTITY {
            return; // overwrite: counts unchanged
        }
        // identity -> non-identity: bump the leaf count and cascade allocs.
        let levels = self.levels as usize;
        let mut b = idx / self.leaf_fanout;
        for l in 0..levels {
            let ci = self.block_index(set, l, b) as usize;
            self.counts[ci] += 1;
            if self.counts[ci] > 1 || l == levels - 1 {
                break; // block already live, or root (always live)
            }
            self.set_alloc_bit(set, l, b, true);
            self.allocated_nonroot[set as usize] += 1;
            let slot = self.data_ways + self.level_offset[l] + b;
            if slot < self.fast_per_set {
                self.donated[set as usize] -= 1;
                out.push(MetaEvent::BlockAllocated { slot });
            }
            b /= self.index_fanout;
        }
    }

    /// Restore `idx` to identity. Emits [`MetaEvent::BlockFreed`] for every
    /// reserved block that becomes empty.
    pub fn clear_mapping(&mut self, set: u32, idx: u64, out: &mut Vec<MetaEvent>) {
        let ei = self.entry_index(set, idx);
        let prev = self.entries[ei];
        if prev == IDENTITY {
            return;
        }
        self.entries[ei] = IDENTITY;
        let levels = self.levels as usize;
        let mut b = idx / self.leaf_fanout;
        for l in 0..levels {
            let ci = self.block_index(set, l, b) as usize;
            self.counts[ci] -= 1;
            if self.counts[ci] > 0 || l == levels - 1 {
                break;
            }
            self.set_alloc_bit(set, l, b, false);
            self.allocated_nonroot[set as usize] -= 1;
            let slot = self.data_ways + self.level_offset[l] + b;
            if slot < self.fast_per_set {
                self.donated[set as usize] += 1;
                out.push(MetaEvent::BlockFreed { slot });
            }
            b /= self.index_fanout;
        }
    }

    /// Metadata bytes resident across all sets: allocated non-root blocks
    /// plus the always-resident root level (levels == 1: everything).
    pub fn metadata_bytes_used(&self) -> u64 {
        if self.levels == 1 {
            return self.num_sets as u64 * self.level_blocks[0] * self.block_bytes as u64;
        }
        let root_blocks = *self.level_blocks.last().unwrap();
        let total: u64 = self
            .allocated_nonroot
            .iter()
            .map(|&a| a + root_blocks)
            .sum();
        total * self.block_bytes as u64
    }

    /// Is the reserved block at per-set fast slot `slot` donatable?
    pub fn slot_is_donatable(&self, set: u32, slot: u64) -> bool {
        if self.levels == 1 || slot < self.data_ways || slot >= self.fast_per_set {
            return false;
        }
        let off = slot - self.data_ways;
        let root = self.levels as usize - 1;
        for l in 0..self.levels as usize {
            let start = self.level_offset[l];
            if off >= start && off < start + self.level_blocks[l] {
                if l == root {
                    return false;
                }
                return !self.alloc_bit(set, l, off - start);
            }
        }
        false
    }

    /// Total donatable blocks across sets (Trimma's extra cache capacity).
    pub fn donated_blocks(&self) -> u64 {
        self.donated.iter().sum()
    }

    /// Donatable (reserved, unallocated, slot-backed) blocks in one set —
    /// the verify oracle checks this against the controller's slot states.
    pub fn donated_blocks_in_set(&self, set: u32) -> u64 {
        self.donated[set as usize]
    }

    /// Live non-identity entries in one set (sum of leaf-level counts).
    pub fn nonidentity_entries(&self, set: u32) -> u64 {
        let base = self.block_index(set, 0, 0) as usize;
        let n = self.level_blocks[0] as usize;
        self.counts[base..base + n].iter().map(|&c| c as u64).sum()
    }

    /// Allocated leaf blocks in one set (test/stat helper).
    pub fn allocated_leaf_blocks(&self, set: u32) -> u64 {
        if self.levels == 1 {
            return self.level_blocks[0];
        }
        (0..self.level_blocks[0]).filter(|&b| self.alloc_bit(set, 0, b)).count() as u64
    }

    /// Offsets (within the reserved region) of the blocks a walk for `idx`
    /// touches, one per level — all fetched in parallel thanks to the fixed
    /// linearized layout. Used by the controller to time DRAM accesses.
    pub fn walk_offsets(&self, idx: u64, out: &mut Vec<u64>) {
        out.clear();
        let mut b = idx / self.leaf_fanout;
        for l in 0..self.levels as usize {
            out.push(self.level_offset[l] + b);
            b /= self.index_fanout;
        }
    }

    /// Reserved blocks per set (worst case, uncapped).
    pub fn reserved_blocks_per_set(&self) -> u64 {
        self.total_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> SetLayout {
        SetLayout::new(4, 1 << 20, 8 << 20, 256, 600)
    }

    fn irt(levels: u32) -> IrtTable {
        IrtTable::new(&layout(), levels)
    }

    #[test]
    fn default_identity_everywhere() {
        let t = irt(2);
        for idx in [0u64, 63, 64, 9215] {
            assert_eq!(t.lookup(0, idx), idx);
            assert!(!t.leaf_allocated(0, idx));
        }
    }

    #[test]
    fn first_mapping_allocates_leaf() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        assert_eq!(t.lookup(0, 100), 5);
        assert!(t.leaf_allocated(0, 100));
        assert_eq!(ev.len(), 1);
        assert!(matches!(ev[0], MetaEvent::BlockAllocated { .. }));
    }

    #[test]
    fn second_mapping_same_leaf_no_event() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        ev.clear();
        t.set_mapping(0, 101, 6, &mut ev); // same 64-entry leaf
        assert!(ev.is_empty());
    }

    #[test]
    fn clearing_last_entry_frees_leaf() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        t.set_mapping(0, 101, 6, &mut ev);
        ev.clear();
        t.clear_mapping(0, 100, &mut ev);
        assert!(ev.is_empty(), "leaf still has an entry");
        t.clear_mapping(0, 101, &mut ev);
        assert_eq!(ev.len(), 1);
        assert!(matches!(ev[0], MetaEvent::BlockFreed { .. }));
        assert!(!t.leaf_allocated(0, 100));
        assert_eq!(t.lookup(0, 101), 101);
    }

    #[test]
    fn overwrite_does_not_double_count() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        ev.clear();
        t.set_mapping(0, 100, 9, &mut ev); // overwrite
        assert!(ev.is_empty());
        assert_eq!(t.lookup(0, 100), 9);
        t.clear_mapping(0, 100, &mut ev);
        assert_eq!(ev.len(), 1); // single free
    }

    #[test]
    fn setting_identity_value_clears() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        ev.clear();
        t.set_mapping(0, 100, 100, &mut ev); // identity
        assert_eq!(t.lookup(0, 100), 100);
        assert_eq!(ev.len(), 1);
        assert!(matches!(ev[0], MetaEvent::BlockFreed { .. }));
    }

    #[test]
    fn donation_accounting() {
        let l = layout();
        let mut t = IrtTable::new(&l, 2);
        let initial = t.donated_blocks();
        assert!(initial > 0);
        let mut ev = Vec::new();
        t.set_mapping(0, 0, 5, &mut ev);
        assert_eq!(t.donated_blocks(), initial - 1);
        t.clear_mapping(0, 0, &mut ev);
        assert_eq!(t.donated_blocks(), initial);
    }

    #[test]
    fn donatable_slot_queries() {
        let l = layout();
        let mut t = IrtTable::new(&l, 2);
        let slot = t.slot_of_leaf_for(&l, 0).unwrap();
        assert!(t.slot_is_donatable(0, slot));
        let mut ev = Vec::new();
        t.set_mapping(0, 0, 5, &mut ev);
        assert!(!t.slot_is_donatable(0, slot));
        // Data-area slots are never "donatable".
        assert!(!t.slot_is_donatable(0, 0));
    }

    #[test]
    fn metadata_size_grows_and_shrinks() {
        let mut t = irt(2);
        let base = t.metadata_bytes_used(); // root level only
        let mut ev = Vec::new();
        t.set_mapping(0, 0, 5, &mut ev);
        t.set_mapping(0, 8_000, 6, &mut ev); // a different leaf
        assert_eq!(t.metadata_bytes_used(), base + 2 * 256);
        t.clear_mapping(0, 0, &mut ev);
        assert_eq!(t.metadata_bytes_used(), base + 256);
    }

    #[test]
    fn single_level_is_always_resident() {
        let l = layout();
        let t = IrtTable::new(&l, 1);
        assert_eq!(t.donated_blocks(), 0);
        assert!(t.leaf_allocated(0, 0));
        let full = l.indices_per_set().div_ceil(64) * 256 * 4;
        assert_eq!(t.metadata_bytes_used(), full);
    }

    #[test]
    fn four_level_cascades() {
        let mut t = irt(4);
        let mut ev = Vec::new();
        t.set_mapping(0, 0, 5, &mut ev);
        // leaf + two intermediate levels allocate (root is implicit).
        assert_eq!(ev.len(), 3);
        ev.clear();
        t.clear_mapping(0, 0, &mut ev);
        assert_eq!(ev.len(), 3);
        assert_eq!(t.donated_blocks(), IrtTable::new(&layout(), 4).donated_blocks());
    }

    #[test]
    fn walk_offsets_are_per_level() {
        let t = irt(2);
        let mut offs = Vec::new();
        t.walk_offsets(130, &mut offs);
        assert_eq!(offs.len(), 2);
        assert_eq!(offs[0], 130 / 64); // leaf block
        assert_eq!(offs[1], t.level_offset[1]); // root block 0
    }

    #[test]
    fn independent_sets() {
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 7, 3, &mut ev);
        assert_eq!(t.lookup(1, 7), 7);
        assert_eq!(t.allocated_leaf_blocks(1), 0);
        assert_eq!(t.allocated_leaf_blocks(0), 1);
    }

    #[test]
    fn insert_remove_round_trip_restores_everything() {
        // Fill one whole leaf (64 entries), remove in a different order;
        // every observable (entries, events, donation, occupancy, size)
        // must return exactly to the initial state.
        let mut t = irt(2);
        let initial_donated = t.donated_blocks();
        let base_bytes = t.metadata_bytes_used();
        let mut ev = Vec::new();
        let leaf_base = 128; // leaf block 2
        for i in 0..64u64 {
            t.set_mapping(0, leaf_base + i, 9000 + i, &mut ev);
        }
        assert_eq!(t.nonidentity_entries(0), 64);
        assert_eq!(t.donated_blocks(), initial_donated - 1);
        ev.clear();
        // Remove in reverse, then re-check with a shuffled order too.
        for i in (0..64u64).rev() {
            assert_eq!(t.lookup(0, leaf_base + i), 9000 + i);
            t.clear_mapping(0, leaf_base + i, &mut ev);
        }
        assert_eq!(ev.len(), 1, "exactly one free when the last entry goes");
        assert_eq!(t.nonidentity_entries(0), 0);
        assert_eq!(t.donated_blocks(), initial_donated);
        assert_eq!(t.metadata_bytes_used(), base_bytes);
        for i in 0..64u64 {
            assert_eq!(t.lookup(0, leaf_base + i), leaf_base + i);
        }
        // Clearing an already-identity entry is a no-op, not an underflow.
        ev.clear();
        t.clear_mapping(0, leaf_base, &mut ev);
        assert!(ev.is_empty());
    }

    #[test]
    fn donated_accounting_at_full_occupancy() {
        // Allocate every leaf of set 0: donation must bottom out at zero
        // with slots conserved exactly (alloc events == leaves), and free
        // everything back to the initial donation.
        let l = layout();
        let mut t = IrtTable::new(&l, 2);
        let leaves = l.indices_per_set().div_ceil(64);
        let initial = t.donated_blocks_in_set(0);
        assert_eq!(initial, leaves, "all leaf slots fit in this layout");
        let mut ev = Vec::new();
        let mut allocs = 0;
        for b in 0..leaves {
            t.set_mapping(0, b * 64, b * 64 + 1, &mut ev);
            allocs += ev
                .drain(..)
                .filter(|e| matches!(e, MetaEvent::BlockAllocated { .. }))
                .count();
        }
        assert_eq!(allocs as u64, leaves);
        assert_eq!(t.donated_blocks_in_set(0), 0, "fully occupied: nothing to donate");
        assert_eq!(t.nonidentity_entries(0), leaves);
        // Other sets keep their full donation.
        assert_eq!(t.donated_blocks_in_set(1), initial);
        for b in 0..leaves {
            t.clear_mapping(0, b * 64, &mut ev);
        }
        assert_eq!(t.donated_blocks_in_set(0), initial);
    }

    #[test]
    fn level_walk_with_zero_nonidentity_entries() {
        // A set with no non-identity entries: walks still produce one
        // fixed offset per level (the hardware always probes them in
        // parallel), every lookup is identity via the alloc-bitmap
        // shortcut, and occupancy introspection reads zero.
        let t = irt(2);
        let mut offs = Vec::new();
        for idx in [0u64, 63, 64, 9215] {
            t.walk_offsets(idx, &mut offs);
            assert_eq!(offs.len(), 2, "idx {idx}");
            assert_eq!(offs[0], idx / 64);
            assert!(t.is_identity(0, idx));
            assert!(!t.leaf_allocated(0, idx));
        }
        assert_eq!(t.nonidentity_entries(0), 0);
        assert_eq!(t.allocated_leaf_blocks(0), 0);
        // After a set+clear cycle the shortcut holds again.
        let mut t = irt(2);
        let mut ev = Vec::new();
        t.set_mapping(0, 100, 5, &mut ev);
        t.clear_mapping(0, 100, &mut ev);
        assert!(t.is_identity(0, 100));
        assert!(!t.leaf_allocated(0, 100));
        assert_eq!(t.nonidentity_entries(0), 0);
    }

    #[test]
    fn alloc_bitset_isolates_adjacent_sets_and_levels() {
        // The packed bitset shares words across sets/levels when block
        // counts are not multiples of 64: flipping one bit must never leak
        // into a neighbouring set's or level's view.
        let mut t = irt(2);
        let mut ev = Vec::new();
        let last_leaf = (t.level_blocks[0] - 1) * 64; // final leaf of set 0
        t.set_mapping(0, last_leaf, 1, &mut ev);
        assert!(t.leaf_allocated(0, last_leaf));
        // Set 1's first leaf (adjacent bit range) must be untouched.
        assert!(!t.leaf_allocated(1, 0));
        assert_eq!(t.nonidentity_entries(1), 0);
        // Root level of set 0 reports non-donatable regardless.
        ev.clear();
        t.clear_mapping(0, last_leaf, &mut ev);
        assert_eq!(ev.len(), 1);
        assert!(!t.leaf_allocated(0, last_leaf));
    }
}

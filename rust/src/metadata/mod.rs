//! Metadata structures for physical-to-device address remapping — the
//! paper's core subject.
//!
//! * [`layout`] — the set-associative partition of both tiers (Fig. 4) and
//!   the unified per-set index space shared by all tables.
//! * [`linear`] — the conventional linear remap table baseline.
//! * [`irt`] — Trimma's indirection-based remap table (§3.2, Fig. 5).
//! * [`remap_cache`] — the conventional on-chip remap cache.
//! * [`irc`] — Trimma's identity-mapping-aware remap cache (§3.4, Fig. 6).
//!
//! ## Unified per-set index space
//!
//! Within a set, device slots are numbered `0..F+S`: indices `[0, F)` are
//! the set's fast-tier blocks (the basic data area first, then the reserved
//! metadata region), indices `[F, F+S)` are its slow-tier blocks. A mapping
//! is a function `phys_idx -> device_idx` over this space; *identity* means
//! the block has not moved. Tables only ever store non-identity mappings
//! plus, when a saved metadata slot caches a block, the forward + inverted
//! pair (§3.3).

// Panic audit: the remaining `unwrap`s in the table implementations are
// on structural invariants the tables themselves maintain (a sorted
// scratch vector containing the probed key, a non-empty level list built
// in the constructor); violating them is a table bug, not a runtime
// condition, and the invariants are locked by the module tests and the
// verify oracle.
#![allow(clippy::unwrap_used, clippy::expect_used)]

pub mod bloom;
pub mod irc;
pub mod irt;
pub mod layout;
pub mod linear;
pub mod remap_cache;

pub use layout::SetLayout;

/// Sentinel meaning "no entry: identity mapping".
pub const IDENTITY: u32 = u32::MAX;

/// Side effects of a table update that the hybrid controller must act on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetaEvent {
    /// A reserved metadata block became live (its index bit was set).
    /// `slot` is the per-set fast device index it occupies; any data block
    /// cached there must be evicted immediately (metadata priority, §3.3).
    BlockAllocated { slot: u64 },
    /// A metadata block became empty and donatable again.
    BlockFreed { slot: u64 },
}

/// Cost of one off-chip table walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalkCost {
    /// Fast-memory accesses issued (iRT: one per level, in parallel).
    pub accesses: u32,
    /// Whether the accesses are parallel (fixed entry addresses) or serial.
    pub parallel: bool,
}

/// The off-chip remap table: either the linear baseline or Trimma's iRT.
#[derive(Debug, Clone)]
pub enum Table {
    Linear(linear::LinearTable),
    Irt(irt::IrtTable),
}

impl Table {
    /// Resolve a per-set physical index to its device index.
    #[inline]
    pub fn lookup(&self, set: u32, idx: u64) -> u64 {
        match self {
            Table::Linear(t) => t.lookup(set, idx),
            Table::Irt(t) => t.lookup(set, idx),
        }
    }

    /// The addresses the next [`Table::lookup`]/[`Table::is_identity`] of
    /// `(set, idx)` will touch (linear: the one stride-indexed entry word,
    /// duplicated; iRT: entry word + leaf alloc-bitset word). Read-only
    /// with no side effects — see the per-table hooks.
    #[inline]
    pub fn prefetch_targets(&self, set: u32, idx: u64) -> [*const u8; 2] {
        match self {
            Table::Linear(t) => {
                let p = t.prefetch_target(set, idx);
                [p, p]
            }
            Table::Irt(t) => t.prefetch_targets(set, idx),
        }
    }

    /// True if `idx` currently has an identity mapping (iRT short-circuits
    /// through its leaf-allocation bitmap).
    #[inline]
    pub fn is_identity(&self, set: u32, idx: u64) -> bool {
        match self {
            Table::Linear(t) => t.lookup(set, idx) == idx,
            Table::Irt(t) => t.is_identity(set, idx),
        }
    }

    /// Install `phys -> device`. Returns metadata block alloc/free events.
    pub fn set_mapping(&mut self, set: u32, idx: u64, device: u64, out: &mut Vec<MetaEvent>) {
        match self {
            Table::Linear(t) => t.set_mapping(set, idx, device),
            Table::Irt(t) => t.set_mapping(set, idx, device, out),
        }
    }

    /// Restore `idx` to identity. Returns metadata block events.
    pub fn clear_mapping(&mut self, set: u32, idx: u64, out: &mut Vec<MetaEvent>) {
        match self {
            Table::Linear(t) => t.clear_mapping(set, idx),
            Table::Irt(t) => t.clear_mapping(set, idx, out),
        }
    }

    pub fn walk_cost(&self) -> WalkCost {
        match self {
            Table::Linear(_) => WalkCost { accesses: 1, parallel: true },
            Table::Irt(t) => WalkCost { accesses: t.levels(), parallel: true },
        }
    }

    /// Bytes of metadata currently resident in the fast tier.
    pub fn metadata_bytes_used(&self) -> u64 {
        match self {
            Table::Linear(t) => t.metadata_bytes_used(),
            Table::Irt(t) => t.metadata_bytes_used(),
        }
    }

    /// Whether the reserved metadata block at per-set fast slot `slot` is
    /// currently donatable (unallocated).
    pub fn slot_is_donatable(&self, set: u32, slot: u64) -> bool {
        match self {
            Table::Linear(_) => false,
            Table::Irt(t) => t.slot_is_donatable(set, slot),
        }
    }

    /// Count of currently donated (unallocated, reserved) blocks, all sets.
    pub fn donated_blocks(&self) -> u64 {
        match self {
            Table::Linear(_) => 0,
            Table::Irt(t) => t.donated_blocks(),
        }
    }

    /// Donated blocks in one set (0 for the linear table, which never
    /// donates).
    pub fn donated_blocks_in_set(&self, set: u32) -> u64 {
        match self {
            Table::Linear(_) => 0,
            Table::Irt(t) => t.donated_blocks_in_set(set),
        }
    }

    /// Live non-identity entries in one set.
    pub fn nonidentity_entries(&self, set: u32) -> u64 {
        match self {
            Table::Linear(t) => t.nonidentity_entries(set),
            Table::Irt(t) => t.nonidentity_entries(set),
        }
    }
}

#[cfg(test)]
mod prop_tests {
    //! Property tests (hand-rolled, seeded — proptest is unavailable in
    //! this offline environment): iRT must agree with the linear-table
    //! oracle under arbitrary operation sequences, and its allocation
    //! bookkeeping must exactly reflect which leaf ranges contain
    //! non-identity entries. Each property runs over 64 random op
    //! sequences; failures print the seed for reproduction.

    use super::irt::IrtTable;
    use super::layout::SetLayout;
    use super::linear::LinearTable;
    use super::*;
    use crate::types::Rng64;

    fn small_layout() -> SetLayout {
        // 4 sets, fast 1 MiB, slow 8 MiB, 256 B blocks.
        SetLayout::new(4, 1 << 20, 8 << 20, 256, 128)
    }

    #[test]
    fn irt_matches_linear_oracle() {
        for case in 0..64u64 {
            let mut rng = Rng64::new(0xA110C ^ case);
            let layout = small_layout();
            let k = layout.indices_per_set();
            let mut irt = IrtTable::new(&layout, 2);
            let mut lin = LinearTable::new(&layout);
            let mut ev = Vec::new();
            let n_ops = 1 + rng.next_below(200);
            for _ in 0..n_ops {
                let set = rng.next_below(4) as u32;
                let a = rng.next_below(k);
                let b = rng.next_below(k);
                if rng.chance(0.4) {
                    irt.clear_mapping(set, a, &mut ev);
                    lin.clear_mapping(set, a);
                } else {
                    irt.set_mapping(set, a, b, &mut ev);
                    lin.set_mapping(set, a, b);
                }
                ev.clear();
            }
            for set in 0..4 {
                for i in (0..k).step_by(7) {
                    assert_eq!(
                        irt.lookup(set, i),
                        lin.lookup(set, i),
                        "case {case}, set {set}, idx {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn irt_alloc_tracks_nonidentity() {
        for case in 0..64u64 {
            let mut rng = Rng64::new(0xB10C ^ case);
            let layout = small_layout();
            let k = layout.indices_per_set();
            let mut irt = IrtTable::new(&layout, 2);
            let mut ev = Vec::new();
            let mut alloc_events = 0i64;
            let n_ops = 1 + rng.next_below(300);
            for _ in 0..n_ops {
                let a = rng.next_below(k.min(2000));
                let b = rng.next_below(k.min(2000));
                if rng.chance(0.4) {
                    irt.clear_mapping(0, a, &mut ev);
                } else {
                    irt.set_mapping(0, a, b, &mut ev);
                }
                for e in ev.drain(..) {
                    match e {
                        MetaEvent::BlockAllocated { .. } => alloc_events += 1,
                        MetaEvent::BlockFreed { .. } => alloc_events -= 1,
                    }
                }
            }
            // Net allocation events equal live allocated leaf blocks (the
            // op range touches only leaves whose slots exist).
            let live = irt.allocated_leaf_blocks(0) as i64;
            assert_eq!(alloc_events, live, "case {case}");
            // Every non-identity entry lives in a non-donatable leaf slot.
            for i in 0..k {
                if irt.lookup(0, i) != i {
                    let donatable = irt
                        .slot_of_leaf_for(&layout, i)
                        .map(|s| irt.slot_is_donatable(0, s))
                        .unwrap_or(false);
                    assert!(!donatable, "case {case}, idx {i}");
                }
            }
        }
    }
}

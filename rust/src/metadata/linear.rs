//! The conventional linear remap table baseline (§2.2): one 4 B entry for
//! *every* block across both tiers, stored in the fast memory. A lookup is
//! a single fast-memory access; the cost is the storage — at a 32:1
//! slow-to-fast ratio the table consumes ~52% of the fast tier, and it
//! grows linearly with the slow capacity.
//!
//! Entries live in one flat array indexed `set * k + idx` (no per-set
//! `Vec` indirection): like the iRT, the lookup is a single indexed load
//! on the simulator's critical path.

use super::layout::{linear_reserved_blocks, SetLayout};
use super::IDENTITY;

/// Linear remap table over the unified per-set index space.
#[derive(Debug, Clone)]
pub struct LinearTable {
    /// Index-space size per set (entry-array stride).
    k: u64,
    num_sets: u32,
    /// Dense entry array over all sets, `set * k + idx`. `IDENTITY` encodes
    /// `device == phys` internally, but unlike iRT, *storage is charged for
    /// every entry*.
    entries: Vec<u32>,
    reserved_blocks_per_set: u64,
    block_bytes: u32,
}

impl LinearTable {
    pub fn new(layout: &SetLayout) -> Self {
        let k = layout.indices_per_set();
        assert!(k < IDENTITY as u64, "index space exceeds 4 B entry range");
        LinearTable {
            k,
            num_sets: layout.num_sets,
            entries: vec![IDENTITY; (layout.num_sets as u64 * k) as usize],
            reserved_blocks_per_set: linear_reserved_blocks(k, layout.block_bytes),
            block_bytes: layout.block_bytes,
        }
    }

    #[inline]
    fn at(&self, set: u32, idx: u64) -> usize {
        (set as u64 * self.k + idx) as usize
    }

    /// The address a [`LinearTable::lookup`] of `(set, idx)` will touch —
    /// the one 4 B entry word at stride `set * k + idx`. Read-only, no
    /// side effects; consumed by the batched translate stage's prefetch
    /// walk (DESIGN.md §15), which never dereferences it.
    #[inline]
    pub fn prefetch_target(&self, set: u32, idx: u64) -> *const u8 {
        self.entries[self.at(set, idx)..].as_ptr().cast()
    }

    #[inline]
    pub fn lookup(&self, set: u32, idx: u64) -> u64 {
        let e = self.entries[self.at(set, idx)];
        if e == IDENTITY { idx } else { e as u64 }
    }

    #[inline]
    pub fn set_mapping(&mut self, set: u32, idx: u64, device: u64) {
        let i = self.at(set, idx);
        self.entries[i] = if device == idx { IDENTITY } else { device as u32 };
    }

    #[inline]
    pub fn clear_mapping(&mut self, set: u32, idx: u64) {
        let i = self.at(set, idx);
        self.entries[i] = IDENTITY;
    }

    /// The full table is always resident: `K * 4` bytes per set (rounded to
    /// blocks), regardless of how many mappings are identity.
    pub fn metadata_bytes_used(&self) -> u64 {
        self.num_sets as u64 * self.reserved_blocks_per_set * self.block_bytes as u64
    }

    pub fn reserved_blocks_per_set(&self) -> u64 {
        self.reserved_blocks_per_set
    }

    /// Live non-identity entries in one set (occupancy introspection for
    /// the verify oracle; storage is charged in full regardless).
    pub fn nonidentity_entries(&self, set: u32) -> u64 {
        let base = self.at(set, 0);
        self.entries[base..base + self.k as usize]
            .iter()
            .filter(|&&e| e != IDENTITY)
            .count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> SetLayout {
        SetLayout::new(4, 1 << 20, 8 << 20, 256, 0)
    }

    #[test]
    fn default_is_identity() {
        let t = LinearTable::new(&layout());
        assert_eq!(t.lookup(0, 0), 0);
        assert_eq!(t.lookup(3, 1234), 1234);
    }

    #[test]
    fn set_and_clear() {
        let mut t = LinearTable::new(&layout());
        t.set_mapping(1, 100, 7);
        assert_eq!(t.lookup(1, 100), 7);
        assert_eq!(t.lookup(0, 100), 100); // other set unaffected
        t.clear_mapping(1, 100);
        assert_eq!(t.lookup(1, 100), 100);
    }

    #[test]
    fn storing_identity_explicitly_is_identity() {
        let mut t = LinearTable::new(&layout());
        t.set_mapping(0, 5, 5);
        assert_eq!(t.lookup(0, 5), 5);
    }

    #[test]
    fn nonidentity_count_is_per_set() {
        let mut t = LinearTable::new(&layout());
        t.set_mapping(2, 10, 20);
        t.set_mapping(2, 11, 21);
        t.set_mapping(3, 10, 20);
        assert_eq!(t.nonidentity_entries(2), 2);
        assert_eq!(t.nonidentity_entries(3), 1);
        assert_eq!(t.nonidentity_entries(0), 0);
    }

    #[test]
    fn storage_is_constant_and_full() {
        let l = layout();
        let mut t = LinearTable::new(&l);
        let before = t.metadata_bytes_used();
        assert!(before >= l.indices_per_set() * 4 * 4); // 4 sets
        t.set_mapping(0, 1, 2);
        t.set_mapping(2, 3, 4);
        assert_eq!(t.metadata_bytes_used(), before);
    }
}

//! The conventional on-chip remap cache (§2.2): a small SRAM
//! set-associative cache over remap-table entries, indexed by physical
//! block id. It stores *every* kind of entry — identity mappings occupy a
//! full entry (tag + 4 B pointer) just like non-identity ones, which is
//! exactly the inefficiency iRC attacks.
//!
//! Storage is structure-of-arrays: one flat tag array, one value array,
//! one LRU-timestamp array, all indexed by `set * ways + way`. A probe
//! touches only the (dense) tag lane plus one timestamp write, instead of
//! striding over padded per-entry structs — this is the simulator's single
//! hottest loop, run once per LLC miss. Validity is encoded as
//! `last_use != 0`: the tick counter starts at 1, so every live entry has
//! a non-zero timestamp and no separate valid bit is needed.
//!
//! Because validity rides on the timestamp, the tick counter must never
//! wrap: a wrapped tick would mint stamp 0 — the invalidity sentinel — on
//! a live entry, silently dropping it (and a *saturated* tick would freeze
//! LRU order). The counter therefore renormalizes before reaching
//! `tick_limit`: live stamps are rank-compressed to `1..=live` (they are
//! pairwise distinct, so relative LRU order is preserved exactly) and the
//! tick restarts above them. With the default limit of `u64::MAX` the
//! renormalization is unreachable in practice; tests force a tiny limit to
//! exercise it.
//!
//! Audit note: no controller reset path (`Controller::reset_stats`, end of
//! warmup) touches the cache or its tick — stats resets only swap the
//! [`crate::stats::Stats`] struct — so an invalidated entry (stamp 0) can
//! never be resurrected by a post-reset clock rewind.

use crate::types::BlockId;

/// Set-associative LRU cache from physical block id to a 4 B device index.
#[derive(Debug, Clone)]
pub struct RemapCache {
    sets: u64,
    ways: u32,
    /// Tag lane, `set * ways + way`.
    tags: Vec<u64>,
    /// Value lane (the 4 B device pointer).
    vals: Vec<u32>,
    /// LRU timestamp lane; 0 = invalid entry.
    last: Vec<u64>,
    tick: u64,
    /// Renormalize before the tick reaches this bound (see module docs).
    tick_limit: u64,
    /// Preallocated sort buffer for renormalization (no steady-state
    /// allocation, see `tests/alloc_free.rs`).
    scratch: Vec<u64>,
    hash_index: bool,
}

impl RemapCache {
    pub fn new(sets: u32, ways: u32) -> Self {
        Self::with_index(sets, ways, false)
    }

    /// `hash_index = true` applies a multiplicative hash before the modulo
    /// (used by the IdCache to spread super-block ids, after Kharbutli et
    /// al.'s prime-based indexing).
    pub fn with_index(sets: u32, ways: u32, hash_index: bool) -> Self {
        assert!(sets.is_power_of_two());
        let n = (sets * ways) as usize;
        RemapCache {
            sets: sets as u64,
            ways,
            tags: vec![0; n],
            vals: vec![0; n],
            last: vec![0; n],
            tick: 0,
            tick_limit: u64::MAX,
            scratch: Vec::with_capacity(n),
            hash_index,
        }
    }

    /// Test constructor: force a tiny tick width so the wrap-avoidance
    /// renormalization actually fires. Behaviour must be bit-identical to
    /// the unlimited cache (see `tick_renormalization_preserves_lru`).
    pub fn with_tick_limit(sets: u32, ways: u32, tick_limit: u64) -> Self {
        let mut c = Self::with_index(sets, ways, false);
        assert!(tick_limit as u128 > (sets as u128) * (ways as u128), "limit must exceed capacity");
        c.tick_limit = tick_limit;
        c
    }

    /// Advance the LRU clock, renormalizing first if the next tick would
    /// reach the limit. Every live stamp is unique (each comes from a
    /// distinct `bump`), so rank-compressing them to `1..=live` preserves
    /// LRU order exactly while freeing the rest of the counter range.
    #[inline]
    fn bump(&mut self) -> u64 {
        if self.tick >= self.tick_limit - 1 {
            self.renormalize();
        }
        self.tick += 1;
        self.tick
    }

    #[cold]
    fn renormalize(&mut self) {
        self.scratch.clear();
        self.scratch.extend(self.last.iter().copied().filter(|&t| t != 0));
        self.scratch.sort_unstable();
        for t in self.last.iter_mut() {
            if *t != 0 {
                // Stamps are pairwise distinct, so the search always hits.
                *t = self.scratch.binary_search(t).unwrap() as u64 + 1;
            }
        }
        self.tick = self.scratch.len() as u64;
    }

    #[inline]
    fn set_of(&self, key: BlockId) -> u64 {
        let k = if self.hash_index {
            key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 16
        } else {
            key
        };
        k & (self.sets - 1)
    }

    /// The exact SoA lane addresses a [`RemapCache::probe`] of `key` will
    /// touch — the start of the key's set in the tag, timestamp, and value
    /// lanes (a set's ways are contiguous in each lane, so one line per
    /// lane covers the whole scan for realistic way counts). Read-only:
    /// no LRU tick, no stats, no mutation — the batched translate stage
    /// (DESIGN.md §15) feeds these to
    /// [`prefetch_read`](crate::hybrid::prefetch::prefetch_read), which
    /// never dereferences them.
    #[inline]
    pub fn prefetch_targets(&self, key: BlockId) -> [*const u8; 3] {
        let base = (self.set_of(key) * self.ways as u64) as usize;
        [
            self.tags[base..].as_ptr().cast(),
            self.last[base..].as_ptr().cast(),
            self.vals[base..].as_ptr().cast(),
        ]
    }

    /// Look up `key`; LRU-refreshes on hit.
    #[inline]
    pub fn probe(&mut self, key: BlockId) -> Option<u32> {
        let tick = self.bump();
        let base = (self.set_of(key) * self.ways as u64) as usize;
        for i in base..base + self.ways as usize {
            if self.last[i] != 0 && self.tags[i] == key {
                self.last[i] = tick;
                return Some(self.vals[i]);
            }
        }
        None
    }

    /// Insert or overwrite `key -> value`, evicting LRU if needed.
    pub fn insert(&mut self, key: BlockId, value: u32) {
        self.bump();
        let base = (self.set_of(key) * self.ways as u64) as usize;
        let mut victim = base;
        let mut victim_use = u64::MAX;
        for i in base..base + self.ways as usize {
            if self.last[i] != 0 && self.tags[i] == key {
                victim = i;
                break;
            }
            // Invalid entries carry timestamp 0 and are claimed first.
            if self.last[i] < victim_use {
                victim_use = self.last[i];
                victim = i;
            }
        }
        self.tags[victim] = key;
        self.vals[victim] = value;
        self.last[victim] = self.tick;
    }

    /// Read-modify-write the value for `key` if present, without LRU
    /// refresh. Returns the previous value.
    pub fn modify(&mut self, key: BlockId, f: impl FnOnce(u32) -> u32) -> Option<u32> {
        let base = (self.set_of(key) * self.ways as u64) as usize;
        for i in base..base + self.ways as usize {
            if self.last[i] != 0 && self.tags[i] == key {
                let prev = self.vals[i];
                self.vals[i] = f(prev);
                return Some(prev);
            }
        }
        None
    }

    /// Drop `key` if present. Returns true if an entry was invalidated.
    pub fn invalidate(&mut self, key: BlockId) -> bool {
        let base = (self.set_of(key) * self.ways as u64) as usize;
        for i in base..base + self.ways as usize {
            if self.last[i] != 0 && self.tags[i] == key {
                self.last[i] = 0;
                return true;
            }
        }
        false
    }

    /// Total entry capacity.
    pub fn capacity(&self) -> u64 {
        self.sets * self.ways as u64
    }

    /// Currently valid entries (occupancy introspection).
    pub fn live_entries(&self) -> u64 {
        self.last.iter().filter(|&&t| t != 0).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_miss_then_hit() {
        let mut c = RemapCache::new(4, 2);
        assert_eq!(c.probe(10), None);
        c.insert(10, 99);
        assert_eq!(c.probe(10), Some(99));
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut c = RemapCache::new(4, 2);
        // Keys 0, 4, 8 share set 0.
        c.insert(0, 1);
        c.insert(4, 2);
        c.probe(0); // refresh 0
        c.insert(8, 3); // evicts 4
        assert_eq!(c.probe(0), Some(1));
        assert_eq!(c.probe(4), None);
        assert_eq!(c.probe(8), Some(3));
    }

    #[test]
    fn insert_overwrites_in_place() {
        let mut c = RemapCache::new(4, 2);
        c.insert(10, 1);
        c.insert(10, 2);
        assert_eq!(c.probe(10), Some(2));
        // Only one way consumed: a second key in the set still fits.
        c.insert(14, 3);
        assert_eq!(c.probe(10), Some(2));
        assert_eq!(c.probe(14), Some(3));
    }

    #[test]
    fn invalidate_removes() {
        let mut c = RemapCache::new(4, 2);
        c.insert(10, 1);
        assert!(c.invalidate(10));
        assert!(!c.invalidate(10));
        assert_eq!(c.probe(10), None);
    }

    #[test]
    fn invalidated_way_is_reused_first() {
        let mut c = RemapCache::new(4, 2);
        c.insert(0, 1);
        c.insert(4, 2);
        c.invalidate(0);
        c.insert(8, 3); // must claim the invalidated way, not evict 4
        assert_eq!(c.probe(4), Some(2));
        assert_eq!(c.probe(8), Some(3));
        assert_eq!(c.live_entries(), 2);
    }

    #[test]
    fn modify_in_place() {
        let mut c = RemapCache::new(4, 2);
        c.insert(10, 0b01);
        assert_eq!(c.modify(10, |v| v | 0b10), Some(0b01));
        assert_eq!(c.probe(10), Some(0b11));
        assert_eq!(c.modify(11, |v| v), None);
    }

    #[test]
    fn tick_renormalization_preserves_lru() {
        // Force a tick width small enough to renormalize hundreds of times
        // over the run; a wrapped (or saturated) counter would diverge from
        // the unlimited reference the first time an LRU decision flips or
        // a live entry picks up stamp 0 and vanishes.
        let mut limited = RemapCache::with_tick_limit(16, 4, 512);
        let mut reference = RemapCache::new(16, 4);
        let mut x = 0x2545_F491_4F6C_DD1Du64;
        for step in 0..200_000u64 {
            // xorshift* — deterministic mixed op/key stream.
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            let key = (x >> 33) % 96; // ~1.5x capacity: constant eviction
            match x % 8 {
                0..=3 => assert_eq!(limited.probe(key), reference.probe(key), "step {step}"),
                4..=5 => {
                    limited.insert(key, step as u32);
                    reference.insert(key, step as u32);
                }
                6 => assert_eq!(
                    limited.modify(key, |v| v ^ 1),
                    reference.modify(key, |v| v ^ 1),
                    "step {step}"
                ),
                _ => assert_eq!(limited.invalidate(key), reference.invalidate(key), "step {step}"),
            }
            assert!(limited.tick <= 512, "tick escaped the limit at step {step}");
            assert_eq!(limited.live_entries(), reference.live_entries(), "step {step}");
        }
        // The limited clock really did cycle (renormalization exercised).
        assert!(limited.tick < reference.tick);
    }

    #[test]
    fn renormalization_restarts_clock_above_live_stamps() {
        let mut c = RemapCache::with_tick_limit(4, 2, 16);
        for k in 0..6u64 {
            c.insert(k, k as u32);
        }
        c.renormalize();
        // Stamps compress to 1..=live and the clock resumes above them, so
        // a post-renormalization refresh still outranks every old stamp.
        assert_eq!(c.tick, c.live_entries());
        c.probe(4); // set 0 holds {0, 4}: refresh 4
        c.insert(8, 9); // must evict 0, the stale way
        assert_eq!(c.probe(0), None);
        assert_eq!(c.probe(4), Some(4));
        assert_eq!(c.probe(8), Some(9));
    }

    #[test]
    fn hash_index_spreads_strided_keys() {
        // Strided keys alias to one set with modulo indexing but spread
        // under the hash index.
        let mut plain = RemapCache::new(16, 1);
        let mut hashed = RemapCache::with_index(16, 1, true);
        for k in (0..16u64).map(|i| i * 16) {
            plain.insert(k, 1);
            hashed.insert(k, 1);
        }
        let plain_live = (0..16u64).map(|i| i * 16).filter(|&k| plain.probe(k).is_some()).count();
        let hashed_live = (0..16u64).map(|i| i * 16).filter(|&k| hashed.probe(k).is_some()).count();
        assert_eq!(plain_live, 1);
        assert!(hashed_live > 8, "hash index should retain most: {hashed_live}");
    }
}

//! OS-address to `(set, per-set index)` translation with first-touch page
//! allocation, standing in for the OS page allocator of the paper's setup.
//!
//! * Cache mode: all pages live in the slow tier (the fast tier is an
//!   OS-invisible cache).
//! * Flat mode: pages are allocated to the fast tier's data area first,
//!   until it is exhausted, then to the slow tier — the first-touch policy
//!   both MemPod and Trimma-F use in the paper (§4 Baselines).
//!
//! Translation is at 4 kB page granularity (or the block size, if larger);
//! block-level striping over sets is inherited from [`SetLayout`].

use crate::config::Mode;
use crate::metadata::SetLayout;
use crate::types::PhysAddr;

const PAGE_BYTES: u64 = 4096;
const UNMAPPED: u64 = u64::MAX;

/// First-touch page mapper.
pub struct AddrMapper {
    layout: SetLayout,
    mode: Mode,
    /// OS page -> first *global block number* of the page's frame.
    /// Fast frames are encoded as `block`, slow frames as `SLOW_BIT | block`.
    pages: Vec<u64>,
    page_blocks: u64,
    page_bytes: u64,
    next_fast_page: u64,
    fast_pages: u64,
    next_slow_page: u64,
    slow_pages: u64,
}

const SLOW_BIT: u64 = 1 << 63;

impl AddrMapper {
    pub fn new(layout: SetLayout, mode: Mode) -> Self {
        let page_bytes = PAGE_BYTES.max(layout.block_bytes as u64);
        let page_blocks = page_bytes / layout.block_bytes as u64;
        let fast_data_blocks = layout.data_ways * layout.num_sets as u64;
        let slow_blocks = layout.slow_per_set * layout.num_sets as u64;
        let fast_pages = match mode {
            Mode::Cache => 0,
            Mode::Flat => fast_data_blocks / page_blocks,
        };
        let slow_pages = slow_blocks / page_blocks;
        let os_pages = (fast_pages + slow_pages) as usize;
        AddrMapper {
            layout,
            mode,
            pages: vec![UNMAPPED; os_pages],
            page_blocks,
            page_bytes,
            next_fast_page: 0,
            fast_pages,
            next_slow_page: 0,
            slow_pages,
        }
    }

    /// OS-visible capacity in bytes.
    pub fn os_capacity(&self) -> u64 {
        (self.fast_pages + self.slow_pages) * self.page_bytes
    }

    /// Translate an OS physical address, allocating its page on first
    /// touch. Addresses beyond capacity wrap (workloads are sized to fit).
    pub fn translate(&mut self, addr: PhysAddr) -> (u32, u64) {
        let page = (addr / self.page_bytes) % self.pages.len() as u64;
        let off_block = (addr % self.page_bytes) / self.layout.block_bytes as u64;
        let mut frame = self.pages[page as usize];
        if frame == UNMAPPED {
            frame = self.allocate();
            self.pages[page as usize] = frame;
        }
        if frame & SLOW_BIT != 0 {
            let block = (frame & !SLOW_BIT) + off_block;
            self.layout.slow_block_to_idx(block)
        } else {
            let block = frame + off_block;
            // Fast data blocks are enumerated idx-major: n -> (n % sets,
            // n / sets) stays inside the data area by construction.
            let set = (block % self.layout.num_sets as u64) as u32;
            (set, block / self.layout.num_sets as u64)
        }
    }

    /// Shard-aware translation — the sharded front end's per-miss path
    /// (`sim::ShardedSimulation`): translate `addr` and route the
    /// resulting global `(set, idx)` through `plan`, returning
    /// `(slice, local set, idx)` — the slice that owns the access plus
    /// the coordinates in that slice's local set space, ready for
    /// `ShardFeeder::push_routed`. Per-set indices are slice-invariant
    /// (slices keep the full config's per-set geometry), so only the set
    /// is relabelled; panics if the set ever leaves the planned space.
    #[inline]
    pub fn translate_sliced(
        &mut self,
        addr: PhysAddr,
        plan: &crate::engine::sharded::ShardPlan,
    ) -> (u32, u32, u64) {
        let (set, idx) = self.translate(addr);
        let (slice, local) = plan.route_set(set);
        (slice, local, idx)
    }

    fn allocate(&mut self) -> u64 {
        if self.mode == Mode::Flat && self.next_fast_page < self.fast_pages {
            let p = self.next_fast_page;
            self.next_fast_page += 1;
            p * self.page_blocks
        } else {
            let p = self.next_slow_page % self.slow_pages.max(1);
            self.next_slow_page += 1;
            SLOW_BIT | (p * self.page_blocks)
        }
    }

    /// Pages currently resident in the fast tier's flat area.
    pub fn fast_pages_allocated(&self) -> u64 {
        self.next_fast_page
    }

    /// Page granularity of this mapper, in bytes (4 kB or the block size,
    /// whichever is larger).
    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Visit every allocated OS page as `(page base address, landed in
    /// the fast tier's flat area)` — end-of-run occupancy attribution for
    /// the multi-tenant front end ([`crate::sim::tenants`]). Page-table
    /// state only (front-end, stream-order first-touch), so the walk is
    /// identical across shard counts and front-end modes.
    pub fn for_each_allocated_page(&self, mut f: impl FnMut(u64, bool)) {
        for (i, &frame) in self.pages.iter().enumerate() {
            if frame != UNMAPPED {
                f(i as u64 * self.page_bytes, frame & SLOW_BIT == 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> SetLayout {
        SetLayout::new(4, 1 << 20, 8 << 20, 256, 600)
    }

    #[test]
    fn cache_mode_everything_slow() {
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Cache);
        assert_eq!(m.os_capacity(), 8 << 20);
        for a in [0u64, 4096, 123456, (8 << 20) - 1] {
            let (_, idx) = m.translate(a);
            assert!(!l.is_fast_idx(idx), "addr {a:#x} must be slow");
        }
    }

    #[test]
    fn flat_mode_first_touch_prefers_fast() {
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Flat);
        let (_, idx) = m.translate(0);
        assert!(l.is_fast_idx(idx));
        assert!(idx < l.data_ways, "must land in the data area");
        // Touch more pages than the fast area holds: later ones go slow.
        let fast_cap = m.fast_pages * m.page_bytes;
        let (_, idx2) = m.translate(fast_cap + 4096);
        // (fast exhausted only after all fast pages touched)
        for p in 1..m.fast_pages {
            m.translate(p * 4096);
        }
        let (_, idx3) = m.translate(fast_cap + 8192);
        let _ = idx2;
        assert!(!l.is_fast_idx(idx3), "fast area exhausted -> slow");
    }

    #[test]
    fn translation_is_stable() {
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Flat);
        let a = m.translate(777 * 4096 + 300);
        let b = m.translate(777 * 4096 + 300);
        assert_eq!(a, b);
    }

    #[test]
    fn same_page_blocks_are_contiguous_keys() {
        // Blocks of one page must produce contiguous remap-cache keys
        // (the IdCache super-block relies on it).
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Cache);
        let base = 10 * 4096;
        let (s0, i0) = m.translate(base);
        let k0 = l.key(s0, i0);
        for b in 1..16u64 {
            let (s, i) = m.translate(base + b * 256);
            assert_eq!(l.key(s, i), k0 + b);
        }
    }

    #[test]
    fn never_maps_into_metadata_region() {
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Flat);
        for p in 0..(m.fast_pages + 10) {
            let (_, idx) = m.translate(p * 4096);
            assert!(!l.is_meta_idx(idx), "page {p} hit the metadata region");
        }
    }

    #[test]
    fn sliced_translation_matches_plain_translation() {
        use crate::engine::sharded::ShardPlan;
        let l = layout();
        let plan = ShardPlan::new(&l, 2);
        let mut a = AddrMapper::new(l, Mode::Cache);
        let mut b = AddrMapper::new(l, Mode::Cache);
        for p in 0..64u64 {
            let addr = p * 4096 + 128;
            let (set, idx) = a.translate(addr);
            let (slice, local, idx2) = b.translate_sliced(addr, &plan);
            assert_eq!(idx, idx2);
            assert_eq!(plan.slice_of(set), slice);
            assert_eq!(slice * plan.sets_per_slice() + local, set);
        }
    }

    #[test]
    fn allocated_page_walk_matches_allocation() {
        let l = layout();
        let mut m = AddrMapper::new(l, Mode::Flat);
        for p in 0..10u64 {
            m.translate(p * 4096);
        }
        let (mut total, mut fast) = (0u64, 0u64);
        m.for_each_allocated_page(|addr, is_fast| {
            assert_eq!(addr % 4096, 0);
            total += 1;
            if is_fast {
                fast += 1;
            }
        });
        assert_eq!(total, 10);
        assert_eq!(fast, m.fast_pages_allocated().min(10));
    }

    #[test]
    fn big_blocks_use_block_pages() {
        let l = SetLayout::new(1, 1 << 20, 8 << 20, 8192, 10);
        let m = AddrMapper::new(l, Mode::Cache);
        assert_eq!(m.page_bytes, 8192);
    }
}

//! The multi-tenant serving front end (`TenantMix`, DESIGN.md §12): run a
//! [`TenantMixWorkload`] through the unified [`ExecCore`] with a
//! [`TenantRecorder`] tap attributing every access back to its owning
//! tenant by address slab, on either execution model:
//!
//! * [`run_closed`] — the **closed-loop** model: real controller
//!   latencies feed the per-tenant miss-latency histograms, so
//!   p50/p99 are meaningful; oracle-capable (`cfg.hybrid.verify`).
//! * [`run_sharded`] — the **open-loop** sharded/pipelined model: every
//!   miss is charged the constant nominal latency, so the histogram
//!   degenerates to one bucket (documented, deterministic) while the
//!   per-tenant access/miss counters and occupancy shares stay exact.
//!   Because the tap observes the front end's pure access stream, the
//!   per-tenant stats are byte-identical across shard counts and across
//!   the inline vs pipelined front end, run to run — the same
//!   determinism contract the merged stats already carry (locked by
//!   `rust/tests/tenant_parity.rs`).
//!
//! Fast-tier occupancy share is taken at end of run from the first-touch
//! mapper's page table — front-end state, so it is shard-invariant too.

use super::core::{AccessTap, ClosedLoop, ExecCore, OpenLoop};
use super::mapper::AddrMapper;
use super::SimReport;
use crate::config::{SystemConfig, TenantMixConfig};
use crate::engine::sharded::ShardedSession;
use crate::engine::{AnyController, Session};
use crate::mem::MemDevice;
use crate::types::{AccessKind, Cycle, MemAccess};
use crate::workloads::tenants::{tenant_of, TenantMixWorkload};
use crate::workloads::UnknownWorkload;

/// A preallocated fixed-geometry latency histogram: `buckets` buckets of
/// `cycles_per_bucket` cycles each, the last bucket absorbing overflow.
/// Integer-only, so percentile readouts are deterministic.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: Box<[u64]>,
    cycles_per_bucket: u32,
}

impl LatencyHist {
    /// Allocate the histogram (geometry fixed for the run).
    pub fn new(cycles_per_bucket: u32, buckets: u32) -> Self {
        LatencyHist {
            counts: vec![0; buckets.max(1) as usize].into_boxed_slice(),
            cycles_per_bucket: cycles_per_bucket.max(1),
        }
    }

    /// Record one latency sample.
    #[inline]
    pub fn record(&mut self, lat: Cycle) {
        let b = (lat / self.cycles_per_bucket as u64).min(self.counts.len() as u64 - 1);
        self.counts[b as usize] += 1;
    }

    /// Total recorded samples.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The `p`-th percentile (0 < p <= 100), reported as the lower bound
    /// in cycles of the bucket holding that sample; `0` when empty.
    pub fn percentile(&self, p: f64) -> Cycle {
        let total = self.total();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return i as u64 * self.cycles_per_bucket as u64;
            }
        }
        (self.counts.len() as u64 - 1) * self.cycles_per_bucket as u64
    }

    /// Zero all counts, keeping the geometry (the end-of-warmup reset).
    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    /// Raw bucket counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

/// Measured per-tenant statistics of one multi-tenant run.
#[derive(Debug, Clone)]
pub struct TenantStats {
    /// Tenant id (slab index).
    pub tenant: u32,
    /// The workload this tenant drew from the mix.
    pub workload: String,
    /// Accesses issued by this tenant (post-warmup).
    pub accesses: u64,
    /// Read accesses.
    pub reads: u64,
    /// Write accesses.
    pub writes: u64,
    /// Accesses that missed the LLC and reached the hybrid memory.
    pub llc_misses: u64,
    /// Sum of the per-miss stall latencies, cycles.
    pub miss_lat_sum: u64,
    /// Preallocated miss-latency histogram (p50/p99 readouts).
    pub hist: LatencyHist,
    /// Pages of this tenant's slab resident in the fast tier's flat area
    /// at end of run.
    pub fast_pages: u64,
    /// Allocated pages of this tenant's slab at end of run.
    pub total_pages: u64,
}

impl TenantStats {
    fn new(tenant: u32, workload: String, t: &TenantMixConfig) -> Self {
        TenantStats {
            tenant,
            workload,
            accesses: 0,
            reads: 0,
            writes: 0,
            llc_misses: 0,
            miss_lat_sum: 0,
            hist: LatencyHist::new(t.hist_cycles_per_bucket, t.hist_buckets),
            fast_pages: 0,
            total_pages: 0,
        }
    }

    /// Cache hit rate in thousandths (integer, determinism-friendly).
    pub fn hit_rate_milli(&self) -> u64 {
        if self.accesses == 0 {
            0
        } else {
            (self.accesses - self.llc_misses) * 1000 / self.accesses
        }
    }

    /// Median miss latency (histogram bucket lower bound, cycles).
    pub fn p50_miss_lat(&self) -> Cycle {
        self.hist.percentile(50.0)
    }

    /// Tail miss latency (histogram bucket lower bound, cycles).
    pub fn p99_miss_lat(&self) -> Cycle {
        self.hist.percentile(99.0)
    }

    /// Fast-tier occupancy share in thousandths of the tenant's
    /// allocated pages.
    pub fn fast_share_milli(&self) -> u64 {
        if self.total_pages == 0 {
            0
        } else {
            self.fast_pages * 1000 / self.total_pages
        }
    }

    /// Deterministic single-line serialization (integers only), the
    /// per-tenant analogue of [`crate::stats::Stats::canonical`]: used by
    /// the parity tests to lock byte-identical per-tenant stats across
    /// shard counts and front-end modes.
    pub fn canonical(&self) -> String {
        format!(
            "tenant={} workload={} accesses={} reads={} writes={} llc_misses={} \
             hit_milli={} miss_lat_sum={} hist_total={} p50={} p99={} \
             fast_pages={} total_pages={}",
            self.tenant,
            self.workload,
            self.accesses,
            self.reads,
            self.writes,
            self.llc_misses,
            self.hit_rate_milli(),
            self.miss_lat_sum,
            self.hist.total(),
            self.p50_miss_lat(),
            self.p99_miss_lat(),
            self.fast_pages,
            self.total_pages,
        )
    }
}

/// The [`AccessTap`] that attributes the unified core's access stream to
/// tenants by address slab. All storage is preallocated at construction;
/// the end-of-warmup [`AccessTap::reset`] zeroes counts in place.
pub struct TenantRecorder {
    slab: u64,
    stats: Vec<TenantStats>,
}

impl TenantRecorder {
    /// Build for `wl`'s slab carve-out and tenant list.
    pub fn new(wl: &TenantMixWorkload, t: &TenantMixConfig) -> Self {
        TenantRecorder {
            slab: wl.slab(),
            stats: wl
                .tenant_names()
                .iter()
                .enumerate()
                .map(|(i, name)| TenantStats::new(i as u32, name.clone(), t))
                .collect(),
        }
    }

    /// Attribute end-of-run fast-tier occupancy from the first-touch
    /// mapper's page table (front-end state: shard-invariant).
    pub fn finalize_occupancy(&mut self, mapper: &AddrMapper) {
        let n = self.stats.len() as u32;
        mapper.for_each_allocated_page(|addr, is_fast| {
            let s = &mut self.stats[tenant_of(addr, self.slab, n) as usize];
            s.total_pages += 1;
            if is_fast {
                s.fast_pages += 1;
            }
        });
    }

    /// Consume the recorder, yielding the per-tenant stats.
    pub fn into_stats(self) -> Vec<TenantStats> {
        self.stats
    }
}

impl AccessTap for TenantRecorder {
    #[inline]
    fn record(&mut self, _core: usize, acc: &MemAccess, llc_miss: bool, miss_lat: Cycle) {
        let n = self.stats.len() as u32;
        let s = &mut self.stats[tenant_of(acc.addr, self.slab, n) as usize];
        s.accesses += 1;
        match acc.kind {
            AccessKind::Read => s.reads += 1,
            AccessKind::Write => s.writes += 1,
        }
        if llc_miss {
            s.llc_misses += 1;
            s.miss_lat_sum += miss_lat;
            s.hist.record(miss_lat);
        }
    }

    fn reset(&mut self) {
        for s in self.stats.iter_mut() {
            s.accesses = 0;
            s.reads = 0;
            s.writes = 0;
            s.llc_misses = 0;
            s.miss_lat_sum = 0;
            s.hist.reset();
        }
    }
}

/// End-of-run report of a multi-tenant run: the merged system-wide
/// [`SimReport`] plus one [`TenantStats`] per tenant.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// The merged system-wide report (canonical-stats machinery).
    pub merged: SimReport,
    /// Per-tenant statistics, indexed by tenant id.
    pub tenants: Vec<TenantStats>,
}

impl TenantReport {
    /// All per-tenant canonical lines joined with `\n` — the byte-exact
    /// artifact the parity tests compare.
    pub fn canonical_tenants(&self) -> String {
        self.tenants.iter().map(TenantStats::canonical).collect::<Vec<_>>().join("\n")
    }
}

/// Closed-loop multi-tenant run: real controller latencies feed the
/// per-tenant histograms (meaningful p50/p99) and `cfg.hybrid.verify`
/// shadows the controller with the differential oracle as usual.
pub fn run_closed(cfg: &SystemConfig) -> Result<TenantReport, UnknownWorkload> {
    let wl = TenantMixWorkload::new(cfg)?;
    let mut rec = TenantRecorder::new(&wl, &cfg.tenant_mix);
    let ctrl = AnyController::from_config(cfg, false);
    let mapper = AddrMapper::new(*ctrl.layout(), cfg.hybrid.mode);
    let label = wl.name().to_string();
    let mut core = ExecCore::new(cfg, Box::new(wl), mapper);
    let mut sink = ClosedLoop::new(Session::with_controller(label, ctrl));
    core.run_tapped(&mut sink, &mut rec);
    let mut rep = sink.session_mut().report();
    core.finalize_report(&mut rep.stats);
    rec.finalize_occupancy(core.mapper());
    Ok(TenantReport { merged: rep, tenants: rec.into_stats() })
}

/// Open-loop sharded multi-tenant run over an already-built
/// [`ShardedSession`], optionally with the pipelined front end. Misses
/// are charged the constant nominal latency (see the module docs), so
/// per-tenant stats — counters, degenerate histogram, occupancy — are
/// byte-identical across shard counts and front-end modes.
pub fn run_sharded(
    cfg: &SystemConfig,
    session: ShardedSession,
    pipeline: bool,
) -> Result<TenantReport, UnknownWorkload> {
    let wl = TenantMixWorkload::new(cfg)?;
    let mut rec = TenantRecorder::new(&wl, &cfg.tenant_mix);
    let mapper = AddrMapper::new(*session.full_layout(), cfg.hybrid.mode);
    let nominal = MemDevice::new(cfg.fast_mem).unloaded_latency(64);
    let mut core = ExecCore::new(cfg, Box::new(wl), mapper);
    let mut session = session;
    {
        let core = &mut core;
        let rec = &mut rec;
        session.run_stream(move |feed| {
            if pipeline {
                super::core::run_pipelined(core, feed, nominal, rec);
            } else {
                core.run_tapped(&mut OpenLoop::new(feed, nominal), rec);
            }
        });
    }
    let mut rep = session.finish();
    core.finalize_report(&mut rep.stats);
    rec.finalize_occupancy(core.mapper());
    Ok(TenantReport { merged: rep, tenants: rec.into_stats() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::config::TenantScenario;

    fn tiny(tenants: u32, scenario: TenantScenario) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = 4;
        cfg.workload.cores = 2;
        cfg.workload.accesses_per_core = 1500;
        cfg.workload.warmup_per_core = 500;
        cfg = presets::with_tenants(cfg, tenants, scenario);
        cfg.tenant_mix.phase_len = 256;
        cfg
    }

    #[test]
    fn hist_percentiles_are_bucket_lower_bounds() {
        let mut h = LatencyHist::new(10, 8);
        assert_eq!(h.percentile(99.0), 0);
        for lat in [5u64, 15, 15, 25, 1000] {
            h.record(lat);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.percentile(50.0), 10); // 3rd of 5 samples: bucket 1
        assert_eq!(h.percentile(99.0), 70); // overflow bucket (last)
        h.reset();
        assert_eq!(h.total(), 0);
        assert_eq!(h.counts().len(), 8);
    }

    #[test]
    fn closed_loop_run_attributes_every_measured_access() {
        let cfg = tiny(4, TenantScenario::Steady);
        let rep = run_closed(&cfg).unwrap();
        assert_eq!(rep.tenants.len(), 4);
        let total: u64 = rep.tenants.iter().map(|t| t.accesses).sum();
        // Every measured core access is attributed to exactly one tenant.
        let measured = cfg.workload.cores as u64 * cfg.workload.accesses_per_core;
        assert_eq!(total, measured);
        for t in &rep.tenants {
            assert_eq!(t.accesses, t.reads + t.writes);
            assert_eq!(t.llc_misses, t.hist.total());
            assert!(t.total_pages > 0, "tenant {} allocated no pages", t.tenant);
        }
        assert!(rep.merged.stats.mem_accesses > 0);
        // Real latencies: some miss landed beyond the first bucket.
        assert!(rep.tenants.iter().any(|t| t.p99_miss_lat() > 0));
    }

    #[test]
    fn sharded_and_closed_runs_agree_on_attribution_counts() {
        let cfg = tiny(3, TenantScenario::Steady);
        let closed = run_closed(&cfg).unwrap();
        let session = crate::engine::EngineBuilder::from_config(cfg.clone())
            .shards(2)
            .build_sharded()
            .unwrap();
        let sharded = run_sharded(&cfg, session, false).unwrap();
        // The access stream is identical in both models (open-loop clocks
        // differ, but generation is schedule-pure), so per-tenant access
        // counts agree; latency-derived fields of course differ.
        for (c, s) in closed.tenants.iter().zip(&sharded.tenants) {
            assert_eq!(c.workload, s.workload);
            assert_eq!(c.accesses, s.accesses);
            assert_eq!((c.reads, c.writes), (s.reads, s.writes));
        }
    }
}

//! The simulation engine: a 16-core trace-driven, cycle-accounting model
//! in the spirit of the paper's zsim setup.
//!
//! Each core is an in-order stream: it retires `gap_instrs` non-memory
//! instructions (at [`NONMEM_CPI`] cycles each), then issues one memory
//! access through its private L1/L2 and the shared LLC (`crate::cachesim`);
//! LLC misses go to the hybrid memory controller, whose demand latency
//! stalls the core. Dirty LLC evictions are posted writes: they reach the
//! controller (and occupy memory banks) without stalling.
//!
//! Cores interleave by always advancing the core with the smallest local
//! clock, so cross-core contention on shared banks is modelled in rough
//! timestamp order. Performance = instructions / slowest-core-cycles, whose
//! ratio between designs is the paper's weighted-speedup comparison.
//!
//! All of that — warmup, the laggard-core schedule, cache filtering,
//! first-touch translation, double-buffered trace generation, and the
//! end-of-run stat fill — lives in exactly **one** place: the unified
//! [`ExecCore`] of [`core`](self::core), parameterized over a
//! [`MissSink`] that decides where LLC-missing traffic goes and what it
//! costs. The two execution models are thin shells over it:
//!
//! * [`Simulation`] — the **closed-loop** model ([`ClosedLoop`] sink):
//!   every post-LLC access streams through a [`Session`] and the
//!   controller's simulated latency feeds back into the issuing core's
//!   clock. Generic over the controller type (defaulting to the
//!   enum-dispatched [`AnyController`]), so the whole per-access chain
//!   monomorphizes. This is the model behind every paper figure.
//! * [`ShardedSimulation`] — the **open-loop** throughput model
//!   ([`OpenLoop`] sink): post-LLC accesses are routed by set into a
//!   [`ShardedSession`]'s per-slice worker queues
//!   ([`crate::engine::sharded`]) at a constant nominal latency; merged
//!   statistics are byte-identical for every shard count, and — with
//!   [`ShardedSimulation::pipelined`] — for the pipelined front end too,
//!   which moves shard routing onto a dedicated stage so generation and
//!   cache filtering overlap it (see [`core`](self::core) for the
//!   determinism argument).

pub mod core;
pub mod mapper;
pub mod tenants;

pub use self::core::{AccessTap, ClosedLoop, ExecCore, MissSink, NoTap, OpenLoop};
pub use self::tenants::{LatencyHist, TenantReport, TenantStats};

use crate::config::SystemConfig;
use crate::engine::sharded::ShardedSession;
use crate::engine::{AnyController, Session};
use crate::hybrid::Controller;
use crate::mem::MemDevice;
use crate::stats::Stats;
use crate::types::Cycle;
use crate::workloads::Workload;
use mapper::AddrMapper;

/// Cycles per non-memory instruction (4-wide-ish core).
pub const NONMEM_CPI: f64 = 0.4;

/// A complete single-workload simulation — the closed-loop shell over
/// [`ExecCore`] + [`ClosedLoop`].
pub struct Simulation<C: Controller = AnyController> {
    core: ExecCore,
    sink: ClosedLoop<C>,
}

/// End-of-run report: the controller's stats plus CPU-side counters.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub stats: Stats,
}

impl SimReport {
    pub fn performance(&self) -> f64 {
        self.stats.performance()
    }
}

impl Simulation<AnyController> {
    /// Simulate `cfg`'s design point on `workload`. Prefer assembling
    /// through [`crate::engine::EngineBuilder`], which also resolves the
    /// workload by name.
    pub fn new(cfg: &SystemConfig, workload: Box<dyn Workload>) -> Self {
        Self::with_controller(cfg, workload, AnyController::from_config(cfg, false))
    }

    /// Build with the metadata-free Ideal oracle (Fig. 1's upper bound).
    pub fn new_ideal(cfg: &SystemConfig, workload: Box<dyn Workload>) -> Self {
        Self::with_controller(cfg, workload, AnyController::from_config(cfg, true))
    }
}

impl<C: Controller> Simulation<C> {
    /// Build with an explicit controller (custom [`Controller`]
    /// implementations plug in here; the dispatch-parity tests drive a
    /// boxed `dyn Controller` through the same loop this way).
    pub fn with_controller(cfg: &SystemConfig, workload: Box<dyn Workload>, ctrl: C) -> Self {
        let mapper = AddrMapper::new(*ctrl.layout(), cfg.hybrid.mode);
        let label = workload.name().to_string();
        Simulation {
            core: ExecCore::new(cfg, workload, mapper),
            sink: ClosedLoop::new(Session::with_controller(label, ctrl)),
        }
    }

    /// The underlying streaming session (controller, layout, stats).
    pub fn session(&self) -> &Session<C> {
        self.sink.session()
    }

    /// Run warmup + measurement; returns the report.
    pub fn run(&mut self) -> SimReport {
        self.run_tapped(&mut NoTap)
    }

    /// [`Simulation::run`] with an [`AccessTap`] observing every access
    /// (the trace recorder hangs off this; `run` delegates here with the
    /// zero-sized [`NoTap`], so untapped runs monomorphize unchanged).
    pub fn run_tapped<T: self::core::AccessTap>(&mut self, tap: &mut T) -> SimReport {
        self.core.run_tapped(&mut self.sink, tap);
        let mut rep = self.sink.session_mut().report();
        self.core.finalize_report(&mut rep.stats);
        rep
    }
}

/// The sharded run path: the same unified [`ExecCore`] front end as
/// [`Simulation`], but **open-loop** — post-LLC accesses are routed by set
/// into a [`ShardedSession`]'s per-slice queues and simulated on worker
/// threads, while the core clocks advance by a constant nominal memory
/// latency per LLC miss instead of the controller's simulated latency.
///
/// Dropping the latency feedback is what buys parallelism: with it, the
/// next access's timestamp depends on the previous access's simulated
/// result and the pipeline serializes at depth one. Without it, the whole
/// access stream (addresses, interleaving, and timestamps) is a pure
/// function of config + workload, so every slice sees an identical
/// sub-stream no matter how many workers drain the queues — the merged
/// stats are byte-identical across shard counts (locked by
/// `rust/tests/sharded_parity.rs`) and across the inline vs
/// [`pipelined`](ShardedSimulation::pipelined) front end (locked by
/// `rust/tests/pipeline_parity.rs`). Timing-derived stats are therefore
/// mutually comparable between sharded runs but **not** with the
/// closed-loop [`Simulation::run`]; see DESIGN.md §9–§10.
pub struct ShardedSimulation {
    core: ExecCore,
    session: ShardedSession,
    nominal_mem_lat: Cycle,
    pipeline: bool,
}

impl ShardedSimulation {
    /// Assemble the sharded run for `cfg`'s workload knobs over an
    /// already-built [`ShardedSession`] (from
    /// [`EngineBuilder::build_sharded`](crate::engine::EngineBuilder::build_sharded),
    /// which is also the preferred way to construct the whole thing via
    /// [`EngineBuilder::run_sharded`](crate::engine::EngineBuilder::run_sharded)).
    ///
    /// The nominal per-miss clock charge is the fast tier's unloaded 64 B
    /// latency: it keeps timestamps controller-independent.
    pub fn new(cfg: &SystemConfig, workload: Box<dyn Workload>, session: ShardedSession) -> Self {
        let mapper = AddrMapper::new(*session.full_layout(), cfg.hybrid.mode);
        let nominal_mem_lat = MemDevice::new(cfg.fast_mem).unloaded_latency(64);
        ShardedSimulation {
            core: ExecCore::new(cfg, workload, mapper),
            session,
            nominal_mem_lat,
            pipeline: false,
        }
    }

    /// Toggle the pipelined front end: shard routing moves to a dedicated
    /// router stage, overlapping trace generation + cache filtering with
    /// it (and with the shard workers). Merged canonical stats are
    /// byte-identical either way — see [`core`](self::core) for why.
    pub fn pipelined(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// The underlying sharded session (plan, slices, layout).
    pub fn session(&self) -> &ShardedSession {
        &self.session
    }

    /// Run warmup + measurement across the plan's worker threads and
    /// return the merged report.
    pub fn run(mut self) -> SimReport {
        let core = &mut self.core;
        let nominal = self.nominal_mem_lat;
        let pipeline = self.pipeline;
        self.session.run_stream(|feed| {
            if pipeline {
                self::core::run_pipelined(core, feed, nominal, &mut NoTap);
            } else {
                core.run(&mut OpenLoop::new(feed, nominal));
            }
        });
        let mut rep = self.session.finish();
        self.core.finalize_report(&mut rep.stats);
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn tiny_cfg(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = match dp {
            DesignPoint::AlloyCache => (cfg.hybrid.fast_bytes / 256) as u32,
            DesignPoint::LohHill => (cfg.hybrid.fast_bytes / 8192) as u32,
            _ => 4,
        };
        cfg.workload.cores = 4;
        cfg.workload.accesses_per_core = 3000;
        cfg.workload.warmup_per_core = 1000;
        cfg
    }

    #[test]
    fn runs_and_reports() {
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let wl = crate::workloads::by_name("gap_pr", &cfg).unwrap();
        let mut sim = Simulation::new(&cfg, wl);
        let rep = sim.run();
        assert!(rep.stats.instructions > 0);
        assert!(rep.stats.max_core_cycles > 0);
        assert!(rep.performance() > 0.0);
        assert!(rep.stats.mem_accesses > 0, "workload must miss the LLC");
    }

    #[test]
    fn ideal_beats_linear_cache() {
        // The metadata-free oracle must outperform the linear-table design
        // (which burns half the fast tier on the table and walks it).
        let mk = |dp, ideal: bool| {
            let cfg = tiny_cfg(dp);
            let wl = crate::workloads::by_name("ycsb_a", &cfg).unwrap();
            let mut sim = if ideal {
                Simulation::new_ideal(&cfg, wl)
            } else {
                Simulation::new(&cfg, wl)
            };
            sim.run().performance()
        };
        let ideal = mk(DesignPoint::Ideal, true);
        let linear = mk(DesignPoint::LinearCache, false);
        assert!(
            ideal > linear,
            "ideal ({ideal:.4}) must beat linear-table ({linear:.4})"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let run = || {
            let wl = crate::workloads::by_name("505.mcf_r", &cfg).unwrap();
            Simulation::new(&cfg, wl).run().stats.max_core_cycles
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn all_design_points_run_every_mode() {
        for dp in DesignPoint::ALL {
            let cfg = tiny_cfg(*dp);
            let wl = crate::workloads::by_name("519.lbm_r", &cfg).unwrap();
            let mut sim = if *dp == DesignPoint::Ideal {
                Simulation::new_ideal(&cfg, wl)
            } else {
                Simulation::new(&cfg, wl)
            };
            let rep = sim.run();
            assert!(rep.stats.mem_accesses > 0, "{dp:?}");
        }
    }

    #[test]
    fn boxed_dyn_controller_still_plugs_in() {
        // The generic loop accepts a legacy boxed trait object; parity
        // with the enum path is locked in tests/engine_parity.rs.
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let wl = crate::workloads::by_name("gap_pr", &cfg).unwrap();
        let ctrl: Box<dyn Controller> = Box::new(AnyController::from_config(&cfg, false));
        let rep = Simulation::with_controller(&cfg, wl, ctrl).run();
        assert!(rep.stats.mem_accesses > 0);
    }

    #[test]
    fn pipelined_sharded_run_reports() {
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let wl = crate::workloads::by_name("adv_drift", &cfg).unwrap();
        let session = crate::engine::EngineBuilder::from_config(cfg.clone())
            .shards(2)
            .build_sharded()
            .unwrap();
        let rep = ShardedSimulation::new(&cfg, wl, session).pipelined(true).run();
        assert!(rep.stats.mem_accesses > 0);
        assert!(rep.stats.instructions > 0);
    }
}

//! The simulation engine: a 16-core trace-driven, cycle-accounting model
//! in the spirit of the paper's zsim setup.
//!
//! Each core is an in-order stream: it retires `gap_instrs` non-memory
//! instructions (at [`NONMEM_CPI`] cycles each), then issues one memory
//! access through its private L1/L2 and the shared LLC (`crate::cachesim`);
//! LLC misses go to the hybrid memory controller, whose demand latency
//! stalls the core. Dirty LLC evictions are posted writes: they reach the
//! controller (and occupy memory banks) without stalling.
//!
//! Cores interleave by always advancing the core with the smallest local
//! clock, so cross-core contention on shared banks is modelled in rough
//! timestamp order. Performance = instructions / slowest-core-cycles, whose
//! ratio between designs is the paper's weighted-speedup comparison.
//!
//! The controller side is a streaming [`Session`]: the trace/cache front
//! end produces controller-level [`Access`]es and pushes them through
//! [`Session::push`] / [`Session::push_batch`]. [`Simulation`] is generic
//! over the controller type (defaulting to the enum-dispatched
//! [`AnyController`]), so the whole per-access chain monomorphizes — no
//! virtual dispatch on the hot path for any design point.
//!
//! [`ShardedSimulation`] is the parallel sibling: the same front end,
//! run open-loop, with post-LLC accesses routed by set into a
//! [`ShardedSession`]'s per-slice worker queues
//! ([`crate::engine::sharded`]); its merged statistics are byte-identical
//! for every shard count.

pub mod mapper;

use crate::cachesim::{Hierarchy, MAX_WRITEBACKS};
use crate::config::SystemConfig;
use crate::engine::sharded::{ShardFeeder, ShardedSession};
use crate::engine::{AnyController, Session};
use crate::hybrid::{Access, Controller};
use crate::mem::MemDevice;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle};
use crate::workloads::Workload;
use mapper::AddrMapper;

/// Cycles per non-memory instruction (4-wide-ish core).
pub const NONMEM_CPI: f64 = 0.4;

/// A complete single-workload simulation.
pub struct Simulation<C: Controller = AnyController> {
    hierarchy: Hierarchy,
    session: Session<C>,
    mapper: AddrMapper,
    workload: Box<dyn Workload>,
    clocks: Vec<Cycle>,
    instrs: Vec<u64>,
    cores: u32,
    accesses_per_core: u64,
    warmup_per_core: u64,
    block_bytes: u32,
}

/// End-of-run report: the controller's stats plus CPU-side counters.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub stats: Stats,
}

impl SimReport {
    pub fn performance(&self) -> f64 {
        self.stats.performance()
    }
}

impl Simulation<AnyController> {
    /// Simulate `cfg`'s design point on `workload`. Prefer assembling
    /// through [`crate::engine::EngineBuilder`], which also resolves the
    /// workload by name.
    pub fn new(cfg: &SystemConfig, workload: Box<dyn Workload>) -> Self {
        Self::with_controller(cfg, workload, AnyController::from_config(cfg, false))
    }

    /// Build with the metadata-free Ideal oracle (Fig. 1's upper bound).
    pub fn new_ideal(cfg: &SystemConfig, workload: Box<dyn Workload>) -> Self {
        Self::with_controller(cfg, workload, AnyController::from_config(cfg, true))
    }
}

impl<C: Controller> Simulation<C> {
    /// Build with an explicit controller (custom [`Controller`]
    /// implementations plug in here; the dispatch-parity tests drive a
    /// boxed `dyn Controller` through the same loop this way).
    pub fn with_controller(cfg: &SystemConfig, workload: Box<dyn Workload>, ctrl: C) -> Self {
        let cores = cfg.workload.cores;
        let mapper = AddrMapper::new(*ctrl.layout(), cfg.hybrid.mode);
        let session = Session::with_controller(workload.name().to_string(), ctrl);
        Simulation {
            hierarchy: Hierarchy::new(cores, &cfg.l1d, &cfg.l2, &cfg.llc),
            mapper,
            session,
            workload,
            clocks: vec![0; cores as usize],
            instrs: vec![0; cores as usize],
            cores,
            accesses_per_core: cfg.workload.accesses_per_core,
            warmup_per_core: cfg.workload.warmup_per_core,
            block_bytes: cfg.hybrid.block_bytes,
        }
    }

    /// The underlying streaming session (controller, layout, stats).
    pub fn session(&self) -> &Session<C> {
        &self.session
    }

    /// 64 B line offset within the migration block.
    #[inline]
    fn line_of(&self, addr: u64) -> u32 {
        ((addr % self.block_bytes as u64) / 64) as u32
    }

    /// Advance one access on `core`. Returns instructions retired.
    fn step(&mut self, core: usize) -> u64 {
        let acc = self.workload.next(core);
        let gap_cycles = (acc.gap_instrs as f64 * NONMEM_CPI) as Cycle;
        self.clocks[core] += gap_cycles;
        let now = self.clocks[core];

        let hr = self.hierarchy.access(core, acc.addr, acc.kind);
        let mut lat = hr.latency;
        if hr.llc_miss {
            let (set, idx) = self.mapper.translate(acc.addr);
            let line = self.line_of(acc.addr);
            lat += self.session.push(Access {
                set,
                idx,
                line,
                kind: acc.kind,
                now: now + hr.latency,
            });
        }
        // Posted writebacks: charge banks/stats, do not stall the core.
        // Batched through the session's block entry point — one dispatch
        // for the whole (inline, at most MAX_WRITEBACKS-long) list.
        let wbs = hr.writebacks();
        if !wbs.is_empty() {
            let mut batch = [Access::default(); MAX_WRITEBACKS];
            for (i, wb) in wbs.iter().enumerate() {
                let (set, idx) = self.mapper.translate(*wb);
                batch[i] = Access {
                    set,
                    idx,
                    line: self.line_of(*wb),
                    kind: AccessKind::Write,
                    now: now + lat,
                };
            }
            self.session.push_batch(&batch[..wbs.len()]);
        }
        self.clocks[core] += lat;
        let retired = acc.gap_instrs as u64 + 1;
        self.instrs[core] += retired;
        retired
    }

    /// Run warmup + measurement; returns the report.
    pub fn run(&mut self) -> SimReport {
        // Warmup: populate caches, tables, and migration state.
        for _ in 0..self.warmup_per_core {
            for core in 0..self.cores as usize {
                self.step(core);
            }
        }
        self.session.reset_stats();
        let warm_clocks = self.clocks.clone();
        for i in self.instrs.iter_mut() {
            *i = 0;
        }

        // Measurement: advance the laggard core each iteration so shared
        // bank contention is seen in (approximate) timestamp order.
        let mut remaining: Vec<u64> = vec![self.accesses_per_core; self.cores as usize];
        let mut live = self.cores as usize;
        while live > 0 {
            let mut core = usize::MAX;
            let mut best = Cycle::MAX;
            for c in 0..self.cores as usize {
                if remaining[c] > 0 && self.clocks[c] < best {
                    best = self.clocks[c];
                    core = c;
                }
            }
            self.step(core);
            remaining[core] -= 1;
            if remaining[core] == 0 {
                live -= 1;
            }
        }

        let mut rep = self.session.report();
        rep.stats.instructions = self.instrs.iter().sum();
        rep.stats.max_core_cycles = self
            .clocks
            .iter()
            .zip(&warm_clocks)
            .map(|(c, w)| c - w)
            .max()
            .unwrap_or(0);
        rep.stats.total_core_cycles = self
            .clocks
            .iter()
            .zip(&warm_clocks)
            .map(|(c, w)| c - w)
            .sum();
        rep.stats.l1_hits = self.hierarchy.l1_hits();
        rep.stats.l2_hits = self.hierarchy.l2_hits();
        rep.stats.llc_hits = self.hierarchy.llc_hits();
        rep.stats.cache_accesses = self.hierarchy.accesses();
        rep
    }
}

/// The sharded run path: the same trace/cache front end as [`Simulation`],
/// but **open-loop** — post-LLC accesses are routed by set into a
/// [`ShardedSession`]'s per-slice queues and simulated on worker threads,
/// while the core clocks advance by a constant nominal memory latency per
/// LLC miss instead of the controller's simulated latency.
///
/// Dropping the latency feedback is what buys parallelism: with it, the
/// next access's timestamp depends on the previous access's simulated
/// result and the pipeline serializes at depth one. Without it, the whole
/// access stream (addresses, interleaving, and timestamps) is a pure
/// function of config + workload, so every slice sees an identical
/// sub-stream no matter how many workers drain the queues — the merged
/// stats are byte-identical across shard counts (locked by
/// `rust/tests/sharded_parity.rs`). Timing-derived stats are therefore
/// mutually comparable between sharded runs but **not** with the
/// closed-loop [`Simulation::run`]; see DESIGN.md §9.
pub struct ShardedSimulation {
    frontend: Frontend,
    session: ShardedSession,
}

/// The single-threaded trace/cache front end of a sharded run.
struct Frontend {
    hierarchy: Hierarchy,
    mapper: AddrMapper,
    plan: crate::engine::sharded::ShardPlan,
    workload: Box<dyn Workload>,
    clocks: Vec<Cycle>,
    warm_clocks: Vec<Cycle>,
    instrs: Vec<u64>,
    cores: u32,
    accesses_per_core: u64,
    warmup_per_core: u64,
    block_bytes: u32,
    /// Constant per-miss clock charge (the fast tier's unloaded 64 B
    /// latency): keeps timestamps controller-independent.
    nominal_mem_lat: Cycle,
}

impl ShardedSimulation {
    /// Assemble the sharded run for `cfg`'s workload knobs over an
    /// already-built [`ShardedSession`] (from
    /// [`EngineBuilder::build_sharded`](crate::engine::EngineBuilder::build_sharded),
    /// which is also the preferred way to construct the whole thing via
    /// [`EngineBuilder::run_sharded`](crate::engine::EngineBuilder::run_sharded)).
    pub fn new(cfg: &SystemConfig, workload: Box<dyn Workload>, session: ShardedSession) -> Self {
        let cores = cfg.workload.cores;
        let mapper = AddrMapper::new(*session.full_layout(), cfg.hybrid.mode);
        let nominal_mem_lat = MemDevice::new(cfg.fast_mem).unloaded_latency(64);
        ShardedSimulation {
            frontend: Frontend {
                hierarchy: Hierarchy::new(cores, &cfg.l1d, &cfg.l2, &cfg.llc),
                mapper,
                plan: *session.plan(),
                workload,
                clocks: vec![0; cores as usize],
                warm_clocks: vec![0; cores as usize],
                instrs: vec![0; cores as usize],
                cores,
                accesses_per_core: cfg.workload.accesses_per_core,
                warmup_per_core: cfg.workload.warmup_per_core,
                block_bytes: cfg.hybrid.block_bytes,
                nominal_mem_lat,
            },
            session,
        }
    }

    /// The underlying sharded session (plan, slices, layout).
    pub fn session(&self) -> &ShardedSession {
        &self.session
    }

    /// Run warmup + measurement across the plan's worker threads and
    /// return the merged report.
    pub fn run(mut self) -> SimReport {
        let frontend = &mut self.frontend;
        self.session.run_stream(|feed| frontend.run(feed));
        let mut rep = self.session.finish();
        let fe = &self.frontend;
        rep.stats.instructions = fe.instrs.iter().sum();
        rep.stats.max_core_cycles = fe
            .clocks
            .iter()
            .zip(&fe.warm_clocks)
            .map(|(c, w)| c - w)
            .max()
            .unwrap_or(0);
        rep.stats.total_core_cycles = fe
            .clocks
            .iter()
            .zip(&fe.warm_clocks)
            .map(|(c, w)| c - w)
            .sum();
        rep.stats.l1_hits = fe.hierarchy.l1_hits();
        rep.stats.l2_hits = fe.hierarchy.l2_hits();
        rep.stats.llc_hits = fe.hierarchy.llc_hits();
        rep.stats.cache_accesses = fe.hierarchy.accesses();
        rep
    }
}

impl Frontend {
    /// 64 B line offset within the migration block.
    #[inline]
    fn line_of(&self, addr: u64) -> u32 {
        ((addr % self.block_bytes as u64) / 64) as u32
    }

    /// Advance one access on `core`, feeding post-LLC traffic to the
    /// shards. Mirrors [`Simulation::step`] except the clock charge for an
    /// LLC miss is the nominal latency, not the controller's answer.
    fn step(&mut self, core: usize, feed: &mut ShardFeeder) {
        let acc = self.workload.next(core);
        let gap_cycles = (acc.gap_instrs as f64 * NONMEM_CPI) as Cycle;
        self.clocks[core] += gap_cycles;
        let now = self.clocks[core];

        let hr = self.hierarchy.access(core, acc.addr, acc.kind);
        let mut lat = hr.latency;
        if hr.llc_miss {
            let (slice, set, idx) = self.mapper.translate_sliced(acc.addr, &self.plan);
            feed.push_routed(slice, Access {
                set,
                idx,
                line: self.line_of(acc.addr),
                kind: acc.kind,
                now: now + hr.latency,
            });
            lat += self.nominal_mem_lat;
        }
        for wb in hr.writebacks() {
            let (slice, set, idx) = self.mapper.translate_sliced(*wb, &self.plan);
            feed.push_routed(slice, Access {
                set,
                idx,
                line: self.line_of(*wb),
                kind: AccessKind::Write,
                now: now + lat,
            });
        }
        self.clocks[core] += lat;
        self.instrs[core] += acc.gap_instrs as u64 + 1;
    }

    /// Warmup + measurement over the feed: the same schedule as
    /// [`Simulation::run`] (round-robin warmup, laggard-core
    /// measurement), with the stats reset routed through the stream so
    /// each slice resets at a deterministic point of its sub-stream.
    fn run(&mut self, feed: &mut ShardFeeder) {
        for _ in 0..self.warmup_per_core {
            for core in 0..self.cores as usize {
                self.step(core, feed);
            }
        }
        feed.reset_stats();
        self.warm_clocks.copy_from_slice(&self.clocks);
        for i in self.instrs.iter_mut() {
            *i = 0;
        }

        let mut remaining: Vec<u64> = vec![self.accesses_per_core; self.cores as usize];
        let mut live = self.cores as usize;
        while live > 0 {
            let mut core = usize::MAX;
            let mut best = Cycle::MAX;
            for c in 0..self.cores as usize {
                if remaining[c] > 0 && self.clocks[c] < best {
                    best = self.clocks[c];
                    core = c;
                }
            }
            self.step(core, feed);
            remaining[core] -= 1;
            if remaining[core] == 0 {
                live -= 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};

    fn tiny_cfg(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = match dp {
            DesignPoint::AlloyCache => (cfg.hybrid.fast_bytes / 256) as u32,
            DesignPoint::LohHill => (cfg.hybrid.fast_bytes / 8192) as u32,
            _ => 4,
        };
        cfg.workload.cores = 4;
        cfg.workload.accesses_per_core = 3000;
        cfg.workload.warmup_per_core = 1000;
        cfg
    }

    #[test]
    fn runs_and_reports() {
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let wl = crate::workloads::by_name("gap_pr", &cfg).unwrap();
        let mut sim = Simulation::new(&cfg, wl);
        let rep = sim.run();
        assert!(rep.stats.instructions > 0);
        assert!(rep.stats.max_core_cycles > 0);
        assert!(rep.performance() > 0.0);
        assert!(rep.stats.mem_accesses > 0, "workload must miss the LLC");
    }

    #[test]
    fn ideal_beats_linear_cache() {
        // The metadata-free oracle must outperform the linear-table design
        // (which burns half the fast tier on the table and walks it).
        let mk = |dp, ideal: bool| {
            let cfg = tiny_cfg(dp);
            let wl = crate::workloads::by_name("ycsb_a", &cfg).unwrap();
            let mut sim = if ideal {
                Simulation::new_ideal(&cfg, wl)
            } else {
                Simulation::new(&cfg, wl)
            };
            sim.run().performance()
        };
        let ideal = mk(DesignPoint::Ideal, true);
        let linear = mk(DesignPoint::LinearCache, false);
        assert!(
            ideal > linear,
            "ideal ({ideal:.4}) must beat linear-table ({linear:.4})"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let run = || {
            let wl = crate::workloads::by_name("505.mcf_r", &cfg).unwrap();
            Simulation::new(&cfg, wl).run().stats.max_core_cycles
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn all_design_points_run_every_mode() {
        for dp in DesignPoint::ALL {
            let cfg = tiny_cfg(*dp);
            let wl = crate::workloads::by_name("519.lbm_r", &cfg).unwrap();
            let mut sim = if *dp == DesignPoint::Ideal {
                Simulation::new_ideal(&cfg, wl)
            } else {
                Simulation::new(&cfg, wl)
            };
            let rep = sim.run();
            assert!(rep.stats.mem_accesses > 0, "{dp:?}");
        }
    }

    #[test]
    fn boxed_dyn_controller_still_plugs_in() {
        // The generic loop accepts a legacy boxed trait object; parity
        // with the enum path is locked in tests/engine_parity.rs.
        let cfg = tiny_cfg(DesignPoint::TrimmaCache);
        let wl = crate::workloads::by_name("gap_pr", &cfg).unwrap();
        let ctrl: Box<dyn Controller> = Box::new(AnyController::from_config(&cfg, false));
        let rep = Simulation::with_controller(&cfg, wl, ctrl).run();
        assert!(rep.stats.mem_accesses > 0);
    }
}

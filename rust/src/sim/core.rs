//! The unified execution core: **one** trace/cache front end and
//! scheduling loop, parameterized over where LLC-missing traffic goes.
//!
//! Before this module existed the crate carried two forked copies of the
//! same ~80-line warmup + laggard-core skeleton — `Simulation::run`
//! (closed loop) and the sharded `Frontend::run` (open loop) — and a
//! scheduling fix applied to one silently diverged the other. Now there
//! is exactly one copy: [`ExecCore`] owns the CPU side of a run (cache
//! hierarchy, first-touch mapper, workload, per-core clocks and retired
//! instruction counters) and drives the one `step`/`run` loop; the
//! execution *model* is a [`MissSink`] implementation:
//!
//! * [`ClosedLoop`] — wraps an [`engine::Session`](crate::engine::Session)
//!   and feeds each post-LLC access straight into the controller,
//!   charging the core the controller's **real simulated latency**. This
//!   is the paper-figure execution model.
//! * [`OpenLoop`] — routes each post-LLC access into a
//!   [`ShardFeeder`]'s per-slice queues and charges a **constant nominal
//!   latency** instead (the latency feedback would serialize the
//!   pipeline; see [`crate::engine::sharded`]). This is the sharded
//!   throughput model.
//!
//! Both sinks monomorphize: `ExecCore::run::<ClosedLoop<AnyController>>`
//! and `ExecCore::run::<OpenLoop>` are separate compiled loops with no
//! dynamic dispatch on the per-access path.
//!
//! ## The pipelined front end
//!
//! On top of the unified core, the open-loop path can run **pipelined**
//! ([`ShardedSimulation::pipelined`](crate::sim::ShardedSimulation::pipelined),
//! `EngineBuilder::pipeline(true)`, `trimma run/bench --pipeline`): trace
//! generation + L1/L2/LLC filtering + address translation stay on the
//! calling thread, while the *shard routing* stage (per-slice batch
//! accumulation and SPSC hand-off to the shard workers) moves to a
//! dedicated router thread, connected by one more SPSC ring of
//! pre-routed `(slice, Access)` batches. Routing is where the front end
//! absorbs worker back-pressure (a full shard queue spins the pusher), so
//! hoisting it off the generation thread lets generation and filtering
//! run ahead while the router waits — the ROADMAP's "front end is the
//! Amdahl bottleneck" scale step.
//!
//! Trace generation itself is batch-granular and double-buffered: the
//! core keeps two [`Workload::next_batch`] buffers per core (one
//! draining, one standing by) so the virtual workload dispatch is paid
//! once per [`GEN_BATCH`] accesses, not once per access. Workload streams
//! are per-core pure (see the [`Workload::next_batch`] contract), so
//! batched generation is access-for-access identical to per-access
//! generation.
//!
//! ## Why pipelining preserves determinism
//!
//! The pipelined and inline open-loop runs produce **byte-identical**
//! merged canonical stats (locked by `rust/tests/pipeline_parity.rs`):
//!
//! 1. clocks never depend on the routed work — an LLC miss charges the
//!    constant nominal latency, so the access stream (addresses,
//!    interleaving, timestamps) is the same pure function of
//!    config + workload in both modes;
//! 2. translation (the stateful first-touch mapper) happens on the
//!    generating thread in stream order, before the hand-off;
//! 3. the hand-off ring is FIFO and the router applies batches in
//!    arrival order, so every slice still consumes exactly the serial
//!    order restricted to its own sets, with the end-of-warmup reset
//!    marker at the same in-stream point.

use crate::cachesim::{Hierarchy, MAX_WRITEBACKS};
use crate::config::SystemConfig;
use crate::engine::sharded::{spsc_channel, Producer, ShardFeeder, ShardPlan};
use crate::engine::Session;
use crate::hybrid::{Access, Controller};
use crate::sim::mapper::AddrMapper;
use crate::sim::NONMEM_CPI;
use crate::stats::Stats;
use crate::types::{AccessKind, Cycle, MemAccess, PhysAddr};
use crate::workloads::Workload;

/// Accesses generated per [`Workload::next_batch`] call (per buffer; the
/// core double-buffers, so up to `2 * GEN_BATCH` accesses per core are in
/// flight ahead of consumption).
pub const GEN_BATCH: usize = 64;

/// Pre-routed accesses per batch on the pipelined front end's hand-off
/// ring.
const PIPE_BATCH: usize = 256;
/// Hand-off ring capacity (messages) between the generation and routing
/// stages of the pipelined front end.
const PIPE_QUEUE_MSGS: usize = 256;

/// Where the unified core's LLC-missing traffic goes — the execution
/// model of a run. Implementations receive the first-touch mapper (owned
/// by the core, handed down so translation stays in stream order) and
/// decide both *where* the access lands and *what stall* the issuing core
/// pays for it.
pub trait MissSink {
    /// One LLC-missing demand access at physical `addr` (64 B line `line`
    /// within its migration block), arriving at cycle `now`. Returns the
    /// stall charged to the issuing core, in cycles.
    fn demand(
        &mut self,
        mapper: &mut AddrMapper,
        addr: PhysAddr,
        line: u32,
        kind: AccessKind,
        now: Cycle,
    ) -> Cycle;

    /// Posted dirty-LLC writebacks of one step, as `(addr, line)` pairs
    /// (at most `MAX_WRITEBACKS` = one per cache level crossed), all
    /// timestamped `now`. Writebacks charge banks and statistics but
    /// never stall the core.
    fn writebacks(&mut self, mapper: &mut AddrMapper, wbs: &[(PhysAddr, u32)], now: Cycle);

    /// End-of-warmup statistics reset, delivered at its exact in-stream
    /// point (after every warmup access, before the first measured one).
    fn reset_stats(&mut self);
}

/// Observation hook on the unified core's access stream, orthogonal to
/// the [`MissSink`]: the core calls [`AccessTap::record`] once per access
/// (after cache filtering and the sink's demand charge) and
/// [`AccessTap::reset`] at the end-of-warmup point. The default
/// [`NoTap`] is a zero-sized no-op, so untapped runs compile to exactly
/// the pre-tap loop. The multi-tenant front end
/// ([`crate::sim::tenants`]) uses a tap to attribute each access to its
/// owning tenant by address slab.
pub trait AccessTap {
    /// One completed access: the issuing `core`, the generated `acc`,
    /// whether it missed the LLC, and the stall the sink charged for it
    /// (`0` on an LLC hit).
    fn record(&mut self, core: usize, acc: &MemAccess, llc_miss: bool, miss_lat: Cycle);

    /// End-of-warmup reset, delivered at the same in-stream point as
    /// [`MissSink::reset_stats`].
    fn reset(&mut self);
}

/// The zero-cost default tap: observes nothing.
pub struct NoTap;

impl AccessTap for NoTap {
    #[inline]
    fn record(&mut self, _core: usize, _acc: &MemAccess, _llc_miss: bool, _miss_lat: Cycle) {}
    #[inline]
    fn reset(&mut self) {}
}

/// The closed-loop sink: every post-LLC access goes through a streaming
/// [`Session`] and the controller's simulated demand latency feeds back
/// into the issuing core's clock. This is the execution model of all
/// paper figures.
pub struct ClosedLoop<C: Controller> {
    session: Session<C>,
}

impl<C: Controller> ClosedLoop<C> {
    /// Wrap a session as a miss sink.
    pub fn new(session: Session<C>) -> Self {
        ClosedLoop { session }
    }

    /// The wrapped streaming session.
    pub fn session(&self) -> &Session<C> {
        &self.session
    }

    /// Mutable access to the wrapped session (end-of-run reporting).
    pub fn session_mut(&mut self) -> &mut Session<C> {
        &mut self.session
    }
}

impl<C: Controller> MissSink for ClosedLoop<C> {
    #[inline]
    fn demand(
        &mut self,
        mapper: &mut AddrMapper,
        addr: PhysAddr,
        line: u32,
        kind: AccessKind,
        now: Cycle,
    ) -> Cycle {
        let (set, idx) = mapper.translate(addr);
        self.session.push(Access { set, idx, line, kind, now })
    }

    #[inline]
    fn writebacks(&mut self, mapper: &mut AddrMapper, wbs: &[(PhysAddr, u32)], now: Cycle) {
        // Batched through the session's block entry point — one dispatch
        // for the whole (inline, at most MAX_WRITEBACKS-long) list.
        let mut batch = [Access::default(); MAX_WRITEBACKS];
        for (i, (addr, line)) in wbs.iter().enumerate() {
            let (set, idx) = mapper.translate(*addr);
            batch[i] = Access { set, idx, line: *line, kind: AccessKind::Write, now };
        }
        self.session.push_batch(&batch[..wbs.len()]);
    }

    fn reset_stats(&mut self) {
        self.session.reset_stats();
    }
}

/// The open-loop sink: every post-LLC access is routed by set into a
/// [`ShardFeeder`]'s per-slice queues (simulated elsewhere — inline or on
/// shard worker threads) and the issuing core is charged a constant
/// nominal memory latency, keeping the access stream independent of the
/// controller's answers. This is the sharded throughput model; see
/// [`crate::engine::sharded`] for the determinism argument.
pub struct OpenLoop<'a> {
    feed: &'a mut ShardFeeder,
    plan: ShardPlan,
    nominal_mem_lat: Cycle,
}

impl<'a> OpenLoop<'a> {
    /// Route into `feed`, charging `nominal_mem_lat` per demand miss.
    pub fn new(feed: &'a mut ShardFeeder, nominal_mem_lat: Cycle) -> Self {
        let plan = *feed.plan();
        OpenLoop { feed, plan, nominal_mem_lat }
    }
}

impl MissSink for OpenLoop<'_> {
    #[inline]
    fn demand(
        &mut self,
        mapper: &mut AddrMapper,
        addr: PhysAddr,
        line: u32,
        kind: AccessKind,
        now: Cycle,
    ) -> Cycle {
        let (slice, set, idx) = mapper.translate_sliced(addr, &self.plan);
        self.feed.push_routed(slice, Access { set, idx, line, kind, now });
        self.nominal_mem_lat
    }

    #[inline]
    fn writebacks(&mut self, mapper: &mut AddrMapper, wbs: &[(PhysAddr, u32)], now: Cycle) {
        let mut batch = [(0u32, Access::default()); MAX_WRITEBACKS];
        for (i, (addr, line)) in wbs.iter().enumerate() {
            let (slice, set, idx) = mapper.translate_sliced(*addr, &self.plan);
            batch[i] =
                (slice, Access { set, idx, line: *line, kind: AccessKind::Write, now });
        }
        self.feed.push_routed_batch(&batch[..wbs.len()]);
    }

    fn reset_stats(&mut self) {
        self.feed.reset_stats();
    }
}

// ------------------------------------------------------------ pipeline

/// One message on the pipelined front end's hand-off ring.
enum PipeMsg {
    /// Pre-routed `(slice, local access)` pairs, in stream order.
    Batch(Vec<(u32, Access)>),
    /// End-of-warmup marker, at its in-stream point.
    ResetStats,
}

/// The pipelined open-loop sink: translation happens here (generation
/// thread, stream order — the mapper is stateful), but the routed pairs
/// are shipped to the router thread in [`PIPE_BATCH`]-sized batches
/// instead of being pushed into the (possibly back-pressured) shard
/// queues directly.
struct PipelineSink {
    tx: Producer<PipeMsg>,
    plan: ShardPlan,
    buf: Vec<(u32, Access)>,
    nominal_mem_lat: Cycle,
}

impl PipelineSink {
    fn flush(&mut self) {
        if !self.buf.is_empty() {
            let batch = std::mem::replace(&mut self.buf, Vec::with_capacity(PIPE_BATCH));
            self.tx.send(PipeMsg::Batch(batch));
        }
    }

    #[inline]
    fn push(&mut self, slice: u32, a: Access) {
        self.buf.push((slice, a));
        if self.buf.len() == PIPE_BATCH {
            self.flush();
        }
    }
}

impl MissSink for PipelineSink {
    #[inline]
    fn demand(
        &mut self,
        mapper: &mut AddrMapper,
        addr: PhysAddr,
        line: u32,
        kind: AccessKind,
        now: Cycle,
    ) -> Cycle {
        let (slice, set, idx) = mapper.translate_sliced(addr, &self.plan);
        self.push(slice, Access { set, idx, line, kind, now });
        self.nominal_mem_lat
    }

    #[inline]
    fn writebacks(&mut self, mapper: &mut AddrMapper, wbs: &[(PhysAddr, u32)], now: Cycle) {
        for (addr, line) in wbs {
            let (slice, set, idx) = mapper.translate_sliced(*addr, &self.plan);
            self.push(slice, Access { set, idx, line: *line, kind: AccessKind::Write, now });
        }
    }

    fn reset_stats(&mut self) {
        self.flush();
        self.tx.send(PipeMsg::ResetStats);
    }
}

/// Run `core` open-loop with the pipelined front end: the scheduling loop
/// (generation + cache filtering + translation) runs on the calling
/// thread, the shard-routing stage on a dedicated router thread that
/// drains the hand-off ring into `feed` in arrival order. Merged stats
/// are byte-identical to the inline [`OpenLoop`] run (see the module
/// docs for why).
// Panic audit: the router `join()` expect is the intentional survivor —
// the router thread only panics if a controller panicked under it, and
// propagating that panic (not swallowing it into a half-merged run) is
// the correct behavior for a deterministic simulation.
#[allow(clippy::expect_used)]
pub(super) fn run_pipelined<T: AccessTap>(
    core: &mut ExecCore,
    feed: &mut ShardFeeder,
    nominal_mem_lat: Cycle,
    tap: &mut T,
) {
    let plan = *feed.plan();
    let (tx, mut rx) = spsc_channel::<PipeMsg>(PIPE_QUEUE_MSGS);
    std::thread::scope(|s| {
        let router = s.spawn(move || {
            while let Some(msg) = rx.recv() {
                match msg {
                    PipeMsg::Batch(batch) => feed.push_routed_batch(&batch),
                    PipeMsg::ResetStats => feed.reset_stats(),
                }
            }
        });
        let mut sink =
            PipelineSink { tx, plan, buf: Vec::with_capacity(PIPE_BATCH), nominal_mem_lat };
        core.run_tapped(&mut sink, tap);
        sink.flush();
        drop(sink); // disconnect: the router drains and exits
        router.join().expect("pipeline router thread panicked");
    });
}

// ----------------------------------------------------------- exec core

/// One core's double-buffered trace-generation state: `cur` drains while
/// `next` stands by full; on exhaustion they swap and the standby buffer
/// refills through one [`Workload::next_batch`] call.
struct GenBuf {
    cur: Box<[MemAccess]>,
    next: Box<[MemAccess]>,
    pos: usize,
}

/// The unified execution core: the CPU side of a run (cache hierarchy,
/// first-touch mapper, workload, per-core clocks and instruction
/// counters) plus the **single** warmup + laggard-core scheduling loop,
/// generic over the [`MissSink`] that consumes post-LLC traffic.
///
/// [`Simulation`](crate::sim::Simulation) (closed loop) and
/// [`ShardedSimulation`](crate::sim::ShardedSimulation) (open loop,
/// optionally pipelined) are thin shells over this type.
pub struct ExecCore {
    hierarchy: Hierarchy,
    mapper: AddrMapper,
    workload: Box<dyn Workload>,
    gen: Vec<GenBuf>,
    clocks: Vec<Cycle>,
    warm_clocks: Vec<Cycle>,
    instrs: Vec<u64>,
    cores: u32,
    accesses_per_core: u64,
    warmup_per_core: u64,
    block_bytes: u32,
}

impl ExecCore {
    /// Assemble the core for `cfg`'s workload knobs. The mapper is built
    /// by the caller against the run's layout (full or sharded), since
    /// that is an execution-model decision.
    pub fn new(cfg: &SystemConfig, mut workload: Box<dyn Workload>, mapper: AddrMapper) -> Self {
        let cores = cfg.workload.cores;
        let gen = (0..cores as usize)
            .map(|core| {
                let mut cur = vec![MemAccess::read(0, 0); GEN_BATCH].into_boxed_slice();
                let mut next = vec![MemAccess::read(0, 0); GEN_BATCH].into_boxed_slice();
                workload.next_batch(core, &mut cur);
                workload.next_batch(core, &mut next);
                GenBuf { cur, next, pos: 0 }
            })
            .collect();
        ExecCore {
            hierarchy: Hierarchy::new(cores, &cfg.l1d, &cfg.l2, &cfg.llc),
            mapper,
            workload,
            gen,
            clocks: vec![0; cores as usize],
            warm_clocks: vec![0; cores as usize],
            instrs: vec![0; cores as usize],
            cores,
            accesses_per_core: cfg.workload.accesses_per_core,
            warmup_per_core: cfg.workload.warmup_per_core,
            block_bytes: cfg.hybrid.block_bytes,
        }
    }

    /// 64 B line offset within the migration block.
    #[inline]
    fn line_of(&self, addr: u64) -> u32 {
        ((addr % self.block_bytes as u64) / 64) as u32
    }

    /// Next access of `core`'s stream, from the double-buffered
    /// generation stage.
    #[inline]
    fn next_access(&mut self, core: usize) -> MemAccess {
        let b = &mut self.gen[core];
        if b.pos == GEN_BATCH {
            std::mem::swap(&mut b.cur, &mut b.next);
            self.workload.next_batch(core, &mut b.next);
            b.pos = 0;
        }
        let a = b.cur[b.pos];
        b.pos += 1;
        a
    }

    /// Advance one access on `core`: retire the gap instructions, filter
    /// through L1/L2/LLC, hand LLC misses and posted writebacks to the
    /// sink, report the completed access to the tap, and charge the core
    /// the cache latency plus whatever stall the sink returns.
    fn step<S: MissSink, T: AccessTap>(&mut self, core: usize, sink: &mut S, tap: &mut T) {
        let acc = self.next_access(core);
        let gap_cycles = (acc.gap_instrs as f64 * NONMEM_CPI) as Cycle;
        self.clocks[core] += gap_cycles;
        let now = self.clocks[core];

        let hr = self.hierarchy.access(core, acc.addr, acc.kind);
        let mut lat = hr.latency;
        let mut miss_lat = 0;
        if hr.llc_miss {
            let line = self.line_of(acc.addr);
            miss_lat = sink.demand(&mut self.mapper, acc.addr, line, acc.kind, now + hr.latency);
            lat += miss_lat;
        }
        tap.record(core, &acc, hr.llc_miss, miss_lat);
        // Posted writebacks: charge banks/stats, do not stall the core.
        let wbs = hr.writebacks();
        if !wbs.is_empty() {
            let mut batch = [(0u64, 0u32); MAX_WRITEBACKS];
            for (i, wb) in wbs.iter().enumerate() {
                batch[i] = (*wb, self.line_of(*wb));
            }
            sink.writebacks(&mut self.mapper, &batch[..wbs.len()], now + lat);
        }
        self.clocks[core] += lat;
        self.instrs[core] += acc.gap_instrs as u64 + 1;
    }

    /// Run warmup + measurement into `sink` — **the** scheduling loop of
    /// the crate. Warmup steps every core round-robin to populate caches,
    /// tables, and migration state; the in-stream stats reset follows;
    /// measurement then always advances the laggard core (the smallest
    /// local clock), so cross-core contention on shared banks is modelled
    /// in rough timestamp order.
    pub fn run<S: MissSink>(&mut self, sink: &mut S) {
        self.run_tapped(sink, &mut NoTap);
    }

    /// [`ExecCore::run`] with an [`AccessTap`] observing every access.
    /// `run` delegates here with the zero-sized [`NoTap`], so the untapped
    /// loop monomorphizes to exactly the pre-tap code.
    pub fn run_tapped<S: MissSink, T: AccessTap>(&mut self, sink: &mut S, tap: &mut T) {
        for _ in 0..self.warmup_per_core {
            for core in 0..self.cores as usize {
                self.step(core, sink, tap);
            }
        }
        sink.reset_stats();
        tap.reset();
        self.warm_clocks.copy_from_slice(&self.clocks);
        for i in self.instrs.iter_mut() {
            *i = 0;
        }

        let mut remaining: Vec<u64> = vec![self.accesses_per_core; self.cores as usize];
        let mut live = self.cores as usize;
        while live > 0 {
            let mut core = usize::MAX;
            let mut best = Cycle::MAX;
            for c in 0..self.cores as usize {
                if remaining[c] > 0 && self.clocks[c] < best {
                    best = self.clocks[c];
                    core = c;
                }
            }
            self.step(core, sink, tap);
            remaining[core] -= 1;
            if remaining[core] == 0 {
                live -= 1;
            }
        }
    }

    /// The run's first-touch mapper (end-of-run occupancy introspection).
    pub fn mapper(&self) -> &AddrMapper {
        &self.mapper
    }

    /// Fill the CPU-side counters of an end-of-run report: instructions
    /// retired, max/total measured core cycles (warmup excluded), cache
    /// hit counters, and total hierarchy accesses. The one stat-fill both
    /// run paths share (it used to be copy-pasted in each).
    pub fn finalize_report(&self, stats: &mut Stats) {
        stats.instructions = self.instrs.iter().sum();
        stats.max_core_cycles = self
            .clocks
            .iter()
            .zip(&self.warm_clocks)
            .map(|(c, w)| c - w)
            .max()
            .unwrap_or(0);
        stats.total_core_cycles =
            self.clocks.iter().zip(&self.warm_clocks).map(|(c, w)| c - w).sum();
        stats.l1_hits = self.hierarchy.l1_hits();
        stats.l2_hits = self.hierarchy.l2_hits();
        stats.llc_hits = self.hierarchy.llc_hits();
        stats.cache_accesses = self.hierarchy.accesses();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{self, DesignPoint};
    use crate::engine::AnyController;
    use crate::sim::Simulation;
    use crate::workloads::{self, adversarial::ADVERSARIAL};

    fn tiny(dp: DesignPoint) -> SystemConfig {
        let mut cfg = presets::hbm3_ddr5(dp);
        cfg.hybrid.fast_bytes = 1 << 20;
        cfg.hybrid.slow_bytes = 32 << 20;
        cfg.hybrid.num_sets = match dp {
            DesignPoint::AlloyCache => {
                (cfg.hybrid.fast_bytes / cfg.hybrid.block_bytes as u64) as u32
            }
            DesignPoint::LohHill => (cfg.hybrid.fast_bytes / 8192) as u32,
            _ => 4,
        };
        cfg.workload.cores = 2;
        cfg.workload.accesses_per_core = 1500;
        cfg.workload.warmup_per_core = 500;
        cfg
    }

    /// An independently written re-implementation of the **pre-refactor**
    /// closed loop (per-access `Workload::next`, its own warmup pass and
    /// laggard-core selection, its own end-of-run stat fill), kept as the
    /// golden-equivalence oracle for the unified core. Deliberately not a
    /// textual copy of `ExecCore::run` — the point of the differential
    /// test is that two separately written loops agree.
    struct Reference {
        hierarchy: Hierarchy,
        session: Session<AnyController>,
        mapper: AddrMapper,
        workload: Box<dyn Workload>,
        clocks: Vec<Cycle>,
        instrs: Vec<u64>,
        block_bytes: u32,
    }

    impl Reference {
        fn new(cfg: &SystemConfig, ideal: bool, wl: &str) -> Reference {
            let workload = workloads::by_name(wl, cfg).unwrap();
            let ctrl = AnyController::from_config(cfg, ideal);
            let mapper = AddrMapper::new(*ctrl.layout(), cfg.hybrid.mode);
            Reference {
                hierarchy: Hierarchy::new(cfg.workload.cores, &cfg.l1d, &cfg.l2, &cfg.llc),
                session: Session::with_controller(wl.to_string(), ctrl),
                mapper,
                workload,
                clocks: vec![0; cfg.workload.cores as usize],
                instrs: vec![0; cfg.workload.cores as usize],
                block_bytes: cfg.hybrid.block_bytes,
            }
        }

        fn step(&mut self, core: usize) {
            let acc = self.workload.next(core);
            self.clocks[core] += (acc.gap_instrs as f64 * NONMEM_CPI) as Cycle;
            let now = self.clocks[core];
            let hr = self.hierarchy.access(core, acc.addr, acc.kind);
            let mut lat = hr.latency;
            let line = |b: u32, addr: u64| ((addr % b as u64) / 64) as u32;
            if hr.llc_miss {
                let (set, idx) = self.mapper.translate(acc.addr);
                lat += self.session.push(Access {
                    set,
                    idx,
                    line: line(self.block_bytes, acc.addr),
                    kind: acc.kind,
                    now: now + hr.latency,
                });
            }
            let wbs = hr.writebacks();
            if !wbs.is_empty() {
                let mut batch = [Access::default(); MAX_WRITEBACKS];
                for (i, wb) in wbs.iter().enumerate() {
                    let (set, idx) = self.mapper.translate(*wb);
                    batch[i] = Access {
                        set,
                        idx,
                        line: line(self.block_bytes, *wb),
                        kind: AccessKind::Write,
                        now: now + lat,
                    };
                }
                self.session.push_batch(&batch[..wbs.len()]);
            }
            self.clocks[core] += lat;
            self.instrs[core] += acc.gap_instrs as u64 + 1;
        }

        fn run(mut self, warmup: u64, accesses: u64) -> Stats {
            let n = self.clocks.len();
            for _ in 0..warmup {
                for core in 0..n {
                    self.step(core);
                }
            }
            self.session.reset_stats();
            let warm = self.clocks.clone();
            self.instrs.iter_mut().for_each(|i| *i = 0);

            let mut left = vec![accesses; n];
            let mut done = 0usize;
            while done < n {
                // First-minimum tie-break, like the production loop.
                let core = (0..n)
                    .filter(|&c| left[c] > 0)
                    .min_by_key(|&c| self.clocks[c])
                    .unwrap();
                self.step(core);
                left[core] -= 1;
                if left[core] == 0 {
                    done += 1;
                }
            }

            let mut rep = self.session.report();
            rep.stats.instructions = self.instrs.iter().sum();
            rep.stats.max_core_cycles =
                self.clocks.iter().zip(&warm).map(|(c, w)| c - w).max().unwrap_or(0);
            rep.stats.total_core_cycles =
                self.clocks.iter().zip(&warm).map(|(c, w)| c - w).sum();
            rep.stats.l1_hits = self.hierarchy.l1_hits();
            rep.stats.l2_hits = self.hierarchy.l2_hits();
            rep.stats.llc_hits = self.hierarchy.llc_hits();
            rep.stats.cache_accesses = self.hierarchy.accesses();
            rep.stats
        }
    }

    /// The golden-equivalence matrix: the unified closed-loop core must
    /// reproduce the pre-refactor canonical stat vector byte-for-byte on
    /// every design point x adversarial scenario.
    #[test]
    fn unified_core_matches_the_pre_refactor_closed_loop() {
        for dp in DesignPoint::ALL {
            let cfg = tiny(*dp);
            let ideal = *dp == DesignPoint::Ideal;
            for wl in ADVERSARIAL {
                let want = Reference::new(&cfg, ideal, wl)
                    .run(cfg.workload.warmup_per_core, cfg.workload.accesses_per_core)
                    .canonical();
                let workload = workloads::by_name(wl, &cfg).unwrap();
                let ctrl = AnyController::from_config(&cfg, ideal);
                let got = Simulation::with_controller(&cfg, workload, ctrl)
                    .run()
                    .stats
                    .canonical();
                assert_eq!(got, want, "{dp:?}/{wl}: unified core diverged from the reference");
            }
        }
    }

    /// The generation stage's double buffering never changes the stream:
    /// interleaving next_access across cores replays each per-core stream
    /// exactly, across batch boundaries.
    #[test]
    fn double_buffered_generation_replays_the_per_access_stream() {
        let cfg = tiny(DesignPoint::TrimmaCache);
        let layout = *AnyController::from_config(&cfg, false).layout();
        let mapper = AddrMapper::new(layout, cfg.hybrid.mode);
        let wl = workloads::by_name("adv_drift", &cfg).unwrap();
        let mut core = ExecCore::new(&cfg, wl, mapper);
        let mut plain = workloads::by_name("adv_drift", &cfg).unwrap();
        for i in 0..(3 * GEN_BATCH + 7) {
            for c in 0..cfg.workload.cores as usize {
                assert_eq!(core.next_access(c), plain.next(c), "core {c} step {i}");
            }
        }
    }
}

//! `cargo bench` target regenerating the paper's fig13a+fig13b rows at a reduced
//! scale and timing the harness. Full-scale regeneration:
//! `trimma sweep --figure fig13a` (see DESIGN.md §3).

use trimma::bench_util::Bench;
use trimma::coordinator::figures;

fn main() {
    let mut b = Bench::new("fig13_config");
    for fig in "fig13a+fig13b".split('+') {
        let (tables, dt) = b.once(fig, || figures::run_figure(fig, 0.05, 0).expect("known figure"));
        println!("  ({} rows in {:.1}s)", tables.iter().map(|t| t.rows.len()).sum::<usize>(), dt);
        for t in tables {
            println!("{}", t.markdown());
        }
    }
}

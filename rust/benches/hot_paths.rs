//! Micro-benchmarks of the simulator's hot paths — the targets of the
//! performance pass (EXPERIMENTS.md §Perf). Run via `cargo bench`.

use trimma::bench_util::Bench;
use trimma::cachesim::Hierarchy;
use trimma::config::presets::{self, DesignPoint};
use trimma::hybrid::{build_controller, Controller};
use trimma::mem::MemDevice;
use trimma::metadata::irc::Irc;
use trimma::metadata::irt::IrtTable;
use trimma::metadata::remap_cache::RemapCache;
use trimma::metadata::SetLayout;
use trimma::sim::Simulation;
use trimma::types::{AccessKind, Rng64};
use trimma::workloads::synth::TraceGen;
use trimma::workloads::{by_name, suite};

fn main() {
    let b = Bench::new("hot_paths");

    // ---- metadata structures ----
    let layout = SetLayout::new(4, 16 << 20, 512 << 20, 256, 33000);
    let mut irt = IrtTable::new(&layout, 2);
    let mut ev = Vec::new();
    let k = layout.indices_per_set();
    let mut rng = Rng64::new(7);
    for _ in 0..10_000 {
        irt.set_mapping(0, rng.next_below(k), rng.next_below(k), &mut ev);
        ev.clear();
    }
    let mut i = 0u64;
    b.iter("irt_lookup", || {
        i = (i + 9973) % k;
        irt.lookup(0, i)
    });
    b.iter("irt_update_cycle", || {
        i = (i + 9973) % k;
        irt.set_mapping(0, i, (i + 5) % k, &mut ev);
        irt.clear_mapping(0, i, &mut ev);
        ev.clear();
    });

    let mut rc = RemapCache::new(2048, 8);
    for j in 0..16384u64 {
        rc.insert(j, j as u32);
    }
    b.iter("remap_cache_probe", || {
        i = i.wrapping_add(977);
        rc.probe(i % 40000)
    });

    let mut irc = Irc::new(2048, 6, 256, 16, 32);
    for j in 0..8192u64 {
        irc.fill_nonid(j * 3, j as u32);
        irc.fill_id_vector(j, 0xAAAA_5555);
    }
    b.iter("irc_probe", || {
        i = i.wrapping_add(977);
        irc.probe(i % 300_000)
    });

    // ---- devices / caches ----
    let mut dev = MemDevice::new(presets::hbm3());
    let mut t = 0u64;
    b.iter("dram_access", || {
        i = i.wrapping_add(0x40_0001);
        t += 30;
        dev.access(i % (16 << 20), 64, AccessKind::Read, t)
    });

    let cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    let mut h = Hierarchy::new(16, &cfg.l1d, &cfg.l2, &cfg.llc);
    b.iter("cache_hierarchy_access", || {
        i = i.wrapping_add(4093 * 64);
        h.access((i % 16) as usize, i % (256 << 20), AccessKind::Read)
    });

    // ---- trace generation ----
    let gen = TraceGen::new(suite::profile("gap_pr").unwrap(), 512 << 20, 16);
    let mut step = 0u32;
    b.iter("trace_gen_access", || {
        step = step.wrapping_add(1);
        gen.gen(3, step)
    });

    // ---- full controller access ----
    let mut ctrl = build_controller(&cfg, false);
    let f = ctrl.layout().fast_per_set;
    let span = ctrl.layout().slow_per_set;
    let mut now = 0u64;
    b.iter("trimma_controller_access", || {
        i = i.wrapping_add(104729);
        now += 40;
        ctrl.access((i % 16) as u32, f + i % span, 0, AccessKind::Read, now)
    });

    // ---- end-to-end simulation throughput ----
    let mut cfg2 = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    cfg2.workload.accesses_per_core = 40_000;
    cfg2.workload.warmup_per_core = 5_000;
    let wl = by_name("gap_pr", &cfg2).unwrap();
    let (rep, dt) = b.once("sim_gap_pr_40k_per_core", || {
        Simulation::new(&cfg2, wl).run()
    });
    println!(
        "  -> {:.2} M instrs/s, {:.2} M mem-steps/s",
        rep.stats.instructions as f64 / 1e6 / dt,
        (16.0 * 45_000.0) / 1e6 / dt
    );
}

//! Micro-benchmarks of the simulator's hot paths — the targets of the
//! performance pass (EXPERIMENTS.md §Perf). Run via `cargo bench`; the
//! same suite backs `trimma bench [--quick] --json`, which additionally
//! emits the schema-versioned JSON report the CI perf gate consumes.

use trimma::bench_util::Bench;
use trimma::coordinator::bench::{
    run_decay_sweep, run_hot_paths, run_pipeline_sweep, run_sharded_sweep, run_sim_sweep,
    run_tenant_sweep, SHARD_COUNTS,
};
use trimma::coordinator::geomean;

fn main() {
    let mut b = Bench::new("hot_paths");
    run_hot_paths(&mut b);
    let tputs = run_sim_sweep(&mut b, false);
    println!("  -> geomean {:.2} M mem-steps/s over the sim sweep", geomean(&tputs));
    run_sharded_sweep(&mut b, false, SHARD_COUNTS);
    run_pipeline_sweep(&mut b, false, 4);
    run_decay_sweep(&mut b, false, 4);
    run_tenant_sweep(&mut b, false, 4);
}

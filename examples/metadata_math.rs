//! Analytic reproduction of the paper's §3.2 storage arithmetic — no
//! simulation, just the geometry the iRT/linear-table comparison rests on.
//!
//! Paper claims checked here:
//! * linear table at 32:1, 4 B entries, 256 B blocks = ~52% of fast memory;
//! * 2-level iRT intermediate level <= 1/2048 = 0.05% worst case;
//! * densely packed iRT best case = 4/256 = 1.6% (+ intermediate);
//! * at 64:1 the linear table exceeds the entire fast tier.
//!
//! ```sh
//! cargo run --release --example metadata_math
//! ```

use trimma::metadata::layout::{irt_level_blocks, linear_reserved_blocks, SetLayout};

fn main() {
    println!("== Trimma §3.2 metadata storage arithmetic ==\n");
    let fast: u64 = 16 << 20;
    let block = 256u32;

    println!(
        "{:<8} {:>14} {:>14} {:>16} {:>14}",
        "ratio", "linear(%fast)", "iRT-resv(%)", "iRT-interm(%)", "iRT-best(%)"
    );
    for ratio in [8u64, 16, 32, 64] {
        let slow = fast * ratio;
        let l = SetLayout::new(1, fast, slow, block, 0);
        let k = l.indices_per_set();
        let lin = linear_reserved_blocks(k, block);
        let lv = irt_level_blocks(k, block, 2);
        let fast_blocks = fast / block as u64;
        // Best case: remapped entries (2 per fast data block: forward +
        // inverted) densely packed into leaf blocks.
        let leaf_fanout = (block / 4) as u64;
        let best_leaves = (2 * fast_blocks).div_ceil(leaf_fanout);
        println!(
            "{:<8} {:>13.1}% {:>13.1}% {:>15.3}% {:>13.1}%",
            format!("{ratio}:1"),
            lin as f64 / fast_blocks as f64 * 100.0,
            lv.iter().sum::<u64>() as f64 / fast_blocks as f64 * 100.0,
            lv[1] as f64 / lv[0] as f64 * 100.0,
            (best_leaves + lv[1]) as f64 / fast_blocks as f64 * 100.0,
        );
    }

    println!("\npaper: linear @32:1 = (32+1)*4/256 = 51.6%; intermediate <= 1/2048 = 0.049%;");
    println!("       best-case iRT ~ 2x fast-blocks entries densely packed; @64:1 linear > 100%.");

    // Per-set capacity limit of 4 B leaf entries (§3.2: 1 TB per set).
    let per_set = (1u64 << 32) * block as u64;
    println!(
        "\n4 B leaf entries support {} TB per set; 1024 sets cover {} PB.",
        per_set >> 40,
        (per_set << 10) >> 50
    );

    // Sanity assertions (these mirror unit tests; the example doubles as a
    // runnable spec).
    let l = SetLayout::new(1, fast, fast * 32, block, 0);
    let lin = linear_reserved_blocks(l.indices_per_set(), block);
    let frac = lin as f64 / (fast / block as u64) as f64;
    assert!((frac - 0.5156).abs() < 0.002);
    let l64 = SetLayout::new(1, fast, fast * 64, block, 0);
    let lin64 = linear_reserved_blocks(l64.indices_per_set(), block);
    assert!(lin64 > fast / block as u64, "64:1 linear table exceeds fast mem");
    println!("\nall §3.2 assertions hold.");
}

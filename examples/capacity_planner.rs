//! Capacity planner: a downstream-user tool. Given a hybrid memory
//! configuration (fast/slow sizes, block granularity), report what each
//! metadata scheme costs and how much effective cache capacity Trimma
//! recovers — the back-of-envelope a memory-system architect would run
//! before adopting the design.
//!
//! ```sh
//! cargo run --release --example capacity_planner -- 16 512 256
//! #                         fast MiB ^   slow ^  block bytes ^
//! ```

use trimma::metadata::layout::{irt_level_blocks, linear_reserved_blocks, SetLayout};

fn main() {
    let args: Vec<u64> = std::env::args()
        .skip(1)
        .map(|a| a.parse().expect("numeric args: fast_mib slow_mib block_bytes"))
        .collect();
    let fast_mib = *args.first().unwrap_or(&16);
    let slow_mib = *args.get(1).unwrap_or(&512);
    let block = *args.get(2).unwrap_or(&256) as u32;

    let fast = fast_mib << 20;
    let slow = slow_mib << 20;
    let layout = SetLayout::new(1, fast, slow, block, 0);
    let k = layout.indices_per_set();
    let fast_blocks = fast / block as u64;

    println!("== hybrid memory capacity plan ==");
    println!("fast {fast_mib} MiB, slow {slow_mib} MiB (ratio {}:1), {block} B blocks\n", slow / fast);

    let lin = linear_reserved_blocks(k, block);
    println!("linear remap table:");
    println!("  entries:           {k} x 4 B");
    println!(
        "  fast mem consumed: {} KiB = {:.1}% of fast tier{}",
        lin * block as u64 >> 10,
        lin as f64 / fast_blocks as f64 * 100.0,
        if lin >= fast_blocks { "  (!!! exceeds fast tier)" } else { "" }
    );

    for levels in [2u32, 4] {
        let lv = irt_level_blocks(k, block, levels);
        let resv: u64 = lv.iter().sum();
        // Typical live occupancy: entries for ~2x the fast data blocks
        // (forward + inverted), spread over ~25%-occupied leaves (the
        // paper's measured average is 11% of fast memory).
        let live_entries = 2 * fast_blocks;
        let leaf_fanout = (block / 4) as u64;
        let typical = (live_entries * 4 / leaf_fanout).div_ceil(block as u64 / 4).max(1)
            * 4 // 25% leaf occupancy
            + lv[1..].iter().sum::<u64>();
        println!("\n{levels}-level iRT:");
        println!(
            "  reserved region:   {} KiB ({:.1}% of fast; donatable when idle)",
            resv * block as u64 >> 10,
            resv as f64 / fast_blocks as f64 * 100.0
        );
        println!(
            "  typical resident:  ~{} KiB ({:.1}% of fast)",
            typical * block as u64 >> 10,
            typical as f64 / fast_blocks as f64 * 100.0
        );
        println!(
            "  recovered as cache: ~{} KiB extra DRAM-cache capacity",
            (resv.saturating_sub(typical)) * block as u64 >> 10
        );
    }

    println!("\ncache-style tag matching: no table, but associativity is capped");
    println!("  (>16 ways needs multiple tag bursts per lookup — see fig1).");
}

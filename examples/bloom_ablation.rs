//! §3.4 ablation: why the IdCache cannot be a Bloom filter.
//!
//! The paper rejects Bloom filters for the identity-mapping set because a
//! false positive returns data from the *wrong device address* — a silent
//! correctness violation, not a performance miss. This driver quantifies
//! that: it builds the identity set of a Trimma system at several capacity
//! ratios, inserts it into a Bloom filter with the same SRAM budget as the
//! iRC IdCache (16 kB), and counts how many *moved* (non-identity) blocks
//! the filter would misclassify as identity.
//!
//! ```sh
//! cargo run --release --example bloom_ablation
//! ```

use trimma::metadata::bloom::BloomIdFilter;
use trimma::types::Rng64;

fn main() {
    println!("== Bloom-filter-as-IdCache ablation (paper §3.4) ==\n");
    println!(
        "{:<8} {:>14} {:>12} {:>14} {:>18}",
        "ratio", "identity_set", "fpr", "moved_blocks", "wrong-data reads"
    );
    for ratio in [8u64, 16, 32, 64] {
        let fast_blocks = (16u64 << 20) / 256;
        let slow_blocks = fast_blocks * ratio;
        // Typical steady state: ~2x fast-blocks entries are non-identity
        // (forward + inverted); the rest of the slow tier is identity.
        let moved = 2 * fast_blocks;
        let identity = slow_blocks - fast_blocks;

        // iRC IdCache budget in Table 1: 256 sets x 16 ways x 4 B ~ 16 kB.
        let mut filter = BloomIdFilter::new(16 << 10, 4);
        let mut rng = Rng64::new(ratio);
        for _ in 0..identity {
            filter.insert(rng.next_u64() | 1);
        }
        // Probe with keys disjoint from the inserted set (even keys).
        let fpr = filter.measured_fpr((0..100_000u64).map(|i| i * 2));
        let wrong = (moved as f64 * fpr) as u64;
        println!(
            "{:<8} {:>14} {:>11.1}% {:>14} {:>18}",
            format!("{ratio}:1"),
            identity,
            fpr * 100.0,
            moved,
            wrong
        );
    }
    println!(
        "\nEvery 'wrong-data read' is a silent correctness violation — reads\n\
         served from a stale address. The sector-cache IdCache never false-\n\
         positives (explicit tags), which is why Trimma uses it instead."
    );
}

//! Regenerate any paper figure from the library API (the CLI's
//! `trimma sweep` exposes the same thing; this example shows the
//! programmatic route).
//!
//! ```sh
//! cargo run --release --example figures -- fig9 0.1
//! ```

use trimma::coordinator::figures;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fig = args.first().map(String::as_str).unwrap_or("fig9");
    let scale: f64 = args.get(1).map(|s| s.parse().expect("scale")).unwrap_or(0.1);
    println!("regenerating {fig} at scale {scale} ...");
    match figures::run_figure(fig, scale, 0) {
        Ok(tables) => {
            for t in tables {
                println!("{}", t.markdown());
            }
            println!("(CSV written under results/)");
        }
        Err(e) => {
            eprintln!("{e}. known figures: {:?}", figures::ALL_FIGURES);
            std::process::exit(2);
        }
    }
}

//! Quickstart: build a Trimma-C system on HBM3+DDR5, run PageRank, and
//! print the headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use trimma::config::presets::{self, DesignPoint};
use trimma::sim::Simulation;
use trimma::workloads;

fn main() {
    // A preset mirroring the paper's Table 1 (scaled capacities, 32:1).
    let mut cfg = presets::hbm3_ddr5(DesignPoint::TrimmaCache);
    cfg.workload.accesses_per_core = 200_000;
    cfg.workload.warmup_per_core = 50_000;

    let wl = workloads::by_name("gap_pr", &cfg).expect("workload");
    println!("running gap_pr on {} ...", cfg.name);
    let report = Simulation::new(&cfg, wl).run();

    let s = &report.stats;
    println!("performance (IPC proxy): {:.4}", report.performance());
    println!("fast-mem serve rate:     {:.1}%", s.fast_serve_rate() * 100.0);
    println!("remap-cache hit rate:    {:.1}%", s.rc_hit_rate() * 100.0);
    println!(
        "metadata resident:       {:.1}% of reserved ({} slots donated as cache)",
        s.metadata_occupancy() * 100.0,
        s.donated_slots
    );
    let (m, f, sl) = s.amat_breakdown();
    println!("AMAT (meta/fast/slow):   {m:.1} / {f:.1} / {sl:.1} cycles");
}

//! Quickstart: build a Trimma-C system on HBM3+DDR5 through the engine
//! builder, run PageRank, and print the headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use trimma::config::presets::DesignPoint;
use trimma::engine::{EngineBuilder, MemoryPreset};

fn main() {
    // One typed path from design point + memory preset + workload to a
    // running simulation (presets mirror the paper's Table 1, scaled
    // capacities, 32:1 ratio). Raw config knobs go through `configure`.
    let report = EngineBuilder::new(DesignPoint::TrimmaCache)
        .memory(MemoryPreset::Hbm3Ddr5)
        .workload("gap_pr")
        .configure(|cfg| {
            cfg.workload.accesses_per_core = 200_000;
            cfg.workload.warmup_per_core = 50_000;
        })
        .run()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });

    let s = &report.stats;
    println!("ran {} (enum-dispatched engine)", report.name);
    println!("performance (IPC proxy): {:.4}", report.performance());
    println!("fast-mem serve rate:     {:.1}%", s.fast_serve_rate() * 100.0);
    println!("remap-cache hit rate:    {:.1}%", s.rc_hit_rate() * 100.0);
    println!(
        "metadata resident:       {:.1}% of reserved ({} slots donated as cache)",
        s.metadata_occupancy() * 100.0,
        s.donated_slots
    );
    let (m, f, sl) = s.amat_breakdown();
    println!("AMAT (meta/fast/slow):   {m:.1} / {f:.1} / {sl:.1} cycles");
}

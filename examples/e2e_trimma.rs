//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. Loads the AOT-compiled Pallas trace generator (`make artifacts`)
//!    through the PJRT CPU client — Layer 1+2, no python at runtime.
//! 2. Feeds the generated access stream through the 16-core cache
//!    hierarchy into the hybrid memory controller — Layer 3.
//! 3. Runs the same workload under Trimma-C, Alloy Cache, and the
//!    linear-table design, and reports the paper's headline comparison
//!    (speedup, serve rate, metadata size, remap-cache hit rate).
//!
//! Results for the recorded run live in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_trimma
//! ```

use trimma::config::presets::{self, DesignPoint};
use trimma::config::SystemConfig;
use trimma::engine::AnyController;
use trimma::sim::Simulation;
use trimma::workloads::pjrt::PjrtWorkload;
use trimma::workloads::suite;
use trimma::workloads::synth::TraceGen;

fn cfg_for(dp: DesignPoint) -> SystemConfig {
    let mut cfg = presets::hbm3_ddr5(dp);
    cfg.workload.accesses_per_core = 120_000;
    cfg.workload.warmup_per_core = 40_000;
    cfg
}

fn run_one(dp: DesignPoint, workload: &str) -> trimma::sim::SimReport {
    let cfg = cfg_for(dp);
    let profile = suite::profile(workload).expect("workload");
    let gen = TraceGen::new(profile, suite::os_capacity(&cfg), cfg.workload.cores);
    // Layer 1+2: batched generation through the AOT artifact.
    let wl = PjrtWorkload::from_trace_gen(
        &gen,
        workload,
        cfg.workload.cores,
        cfg.workload.seed as u32,
    )
    .expect("artifacts missing? run `make artifacts`");
    // Layer 3: the hybrid memory system under test, enum-dispatched.
    let ctrl = AnyController::from_config(&cfg, false);
    let t0 = std::time::Instant::now();
    let rep = Simulation::with_controller(&cfg, Box::new(wl), ctrl).run();
    eprintln!(
        "  [{}] {:.1}s wall, {:.1} M instrs/s",
        dp.label(),
        t0.elapsed().as_secs_f64(),
        rep.stats.instructions as f64 / 1e6 / t0.elapsed().as_secs_f64()
    );
    rep
}

fn main() {
    let workload = std::env::args().nth(1).unwrap_or_else(|| "557.xz_r".into());
    println!("=== end-to-end: {workload} on HBM3+DDR5 (PJRT-generated trace) ===");

    let alloy = run_one(DesignPoint::AlloyCache, &workload);
    let linear = run_one(DesignPoint::LinearCache, &workload);
    let trimma = run_one(DesignPoint::TrimmaCache, &workload);

    let base = alloy.performance();
    println!("\n{:<12} {:>9} {:>11} {:>11} {:>13} {:>9}",
        "design", "speedup", "serve_rate", "rc_hit", "meta_bytes", "amat");
    for (name, r) in [("alloy", &alloy), ("linear-c", &linear), ("trimma-c", &trimma)] {
        let s = &r.stats;
        let (m, f, sl) = s.amat_breakdown();
        println!(
            "{:<12} {:>8.3}x {:>10.1}% {:>10.1}% {:>13} {:>9.1}",
            name,
            r.performance() / base,
            s.fast_serve_rate() * 100.0,
            s.rc_hit_rate() * 100.0,
            s.metadata_bytes_used,
            m + f + sl,
        );
    }
    let speedup = trimma.performance() / base;
    println!(
        "\nheadline: Trimma-C is {speedup:.2}x vs Alloy Cache on {workload} \
         (paper reports 1.33x avg, up to 1.68x across the suite)"
    );
    assert!(
        speedup > 1.0,
        "Trimma should outperform the direct-mapped baseline on this workload"
    );
}

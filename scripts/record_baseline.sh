#!/usr/bin/env sh
# Record the perf baseline and the golden stat snapshots on a machine with
# a Rust toolchain, making the CI perf gate and golden-drift guard live.
# See EXPERIMENTS.md §Perf (baseline refresh) and ROADMAP.md open items.
#
# Usage: ./scripts/record_baseline.sh   (from the repository root)
set -eu

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — run this on a machine with a Rust toolchain" >&2
    exit 1
fi

echo "==> recording BENCH_baseline.json (quick suite, tag 'baseline')"
cargo run --release -- bench --quick --tag baseline --json BENCH_baseline.json --shards 2 --pipeline --decay --faults --tenants --trace --prefetch

echo "==> blessing rust/tests/golden/stats.json and trace_stats.json"
TRIMMA_BLESS=1 cargo test -q --test golden
TRIMMA_BLESS=1 cargo test -q --test trace_corpus

echo "==> verifying the blessed snapshots are stable"
cargo test -q --test golden
cargo test -q --test trace_corpus

echo
echo "Done. Commit the refreshed files:"
echo "  git add BENCH_baseline.json rust/tests/golden/stats.json rust/tests/golden/trace_stats.json"
git status --short BENCH_baseline.json rust/tests/golden/stats.json rust/tests/golden/trace_stats.json

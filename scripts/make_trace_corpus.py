#!/usr/bin/env python3
"""Generate the golden trace corpus under rust/tests/golden/traces/.

These are tiny, hand-constructed TRIMTRC1 files (format spec:
rust/src/trace/format.rs module docs) that pin the on-disk format
independently of the Rust writer: rust/tests/trace_corpus.rs must parse,
validate, and replay them forever, whatever the writer evolves into. The
script is deterministic — re-running it reproduces byte-identical files —
and self-verifies each file against the spec before writing.

Layout (all little-endian):
    file    := header chunk* index
    header  := magic[8] version:u32 cores:u32 fingerprint:u64
               total_records:u64 accesses_per_core:u64 warmup_per_core:u64
               seed:u64 footprint_bytes:u64 chunk_records:u32 encoding:u32
               index_offset:u64 chunk_count:u32 name_len:u32
               name[name_len] header_crc:u32
    chunk   := core:u32 record_count:u32 payload_len:u32
               payload[payload_len] chunk_crc:u32
    index   := { core:u32 record_count:u32 payload_len:u32 offset:u64 }
               * chunk_count, then index_crc:u32
"""

import struct
import zlib
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent.parent / "rust" / "tests" / "golden" / "traces"
MAGIC = b"TRIMTRC1"
VERSION = 1
RAW, DELTA = 0, 1
WRITE_BIT = 1 << 63
LINE = 64

assert zlib.crc32(b"123456789") == 0xCBF43926  # IEEE reflected CRC32


def zigzag(v: int) -> int:
    return (v << 1) ^ (v >> 63) if v >= 0 else ((-v) << 1) - 1


def varint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def encode(encoding: int, records) -> bytes:
    out = bytearray()
    if encoding == RAW:
        for addr, write, gap in records:
            out += struct.pack("<QI", addr | (WRITE_BIT if write else 0), gap)
    else:
        prev = 0
        for addr, write, gap in records:
            out += varint(zigzag(addr - prev))
            prev = addr
            out += varint((gap << 1) | (1 if write else 0))
    return bytes(out)


def build(name, cores, warmup, accesses, seed, footprint, chunk_records, encoding, gen):
    """Assemble one trace file's bytes. `gen(core, i)` -> (addr, write, gap)."""
    per_core = warmup + accesses
    streams = [[gen(c, i) for i in range(per_core)] for c in range(cores)]
    nm = name.encode()
    header_len = 88 + len(nm) + 4

    chunks = []  # (core, count, payload)
    for start in range(0, per_core, chunk_records):
        for core in range(cores):
            recs = streams[core][start : start + chunk_records]
            chunks.append((core, len(recs), encode(encoding, recs)))

    body = bytearray()
    index = []  # (core, count, payload_len, offset)
    offset = header_len
    for core, count, payload in chunks:
        ch = struct.pack("<III", core, count, len(payload)) + payload
        ch += struct.pack("<I", zlib.crc32(ch))
        index.append((core, count, len(payload), offset))
        body += ch
        offset += len(ch)

    index_offset = offset
    idx = bytearray()
    for core, count, plen, off in index:
        idx += struct.pack("<IIIQ", core, count, plen, off)
    idx += struct.pack("<I", zlib.crc32(bytes(idx)))

    total = cores * per_core
    fingerprint = fnv1a(name, seed, footprint)
    fixed = MAGIC + struct.pack(
        "<IIQQQQQQIIQII",
        VERSION, cores, fingerprint, total, accesses, warmup, seed,
        footprint, chunk_records, encoding, index_offset, len(chunks), len(nm),
    )
    assert len(fixed) == 88, len(fixed)
    header = fixed + nm
    header += struct.pack("<I", zlib.crc32(header))
    assert len(header) == header_len
    return bytes(header) + bytes(body) + bytes(idx), streams


def fnv1a(name, seed, footprint):
    h = 0xCBF29CE484222325
    for b in name.encode() + struct.pack("<QQ", seed, footprint):
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def verify(blob, cores, per_core, chunk_records):
    """Independent re-parse: the checks TraceReader::open + validate run."""
    assert blob[:8] == MAGIC
    (ver, ncores, _fp, total, acc, warm, _seed, _fpr, crec, _enc, ioff,
     ccount, nlen) = struct.unpack_from("<IIQQQQQQIIQII", blob, 8)
    assert ver == VERSION and ncores == cores and crec == chunk_records
    assert total == cores * per_core and warm + acc == per_core and ioff != 0
    hlen = 88 + nlen + 4
    (hcrc,) = struct.unpack_from("<I", blob, hlen - 4)
    assert zlib.crc32(blob[: hlen - 4]) == hcrc, "header CRC"
    entries = blob[ioff : ioff + ccount * 20]
    (icrc,) = struct.unpack_from("<I", blob, ioff + ccount * 20)
    assert zlib.crc32(entries) == icrc, "index CRC"
    per_core_seen = [0] * cores
    for i in range(ccount):
        core, count, plen, off = struct.unpack_from("<IIIQ", entries, i * 20)
        assert 1 <= count <= chunk_records and hlen <= off and off + 12 + plen + 4 <= ioff
        ch = blob[off : off + 12 + plen]
        (ccrc,) = struct.unpack_from("<I", blob, off + 12 + plen)
        assert zlib.crc32(ch) == ccrc, f"chunk {i} CRC"
        per_core_seen[core] += count
    assert all(n == per_core for n in per_core_seen)


def main():
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    specs = [
        # Raw encoding, exactly one chunk per core: the simplest well-formed
        # file. Strided sweep with a periodic write.
        dict(
            name="corpus_seq_raw", cores=2, warmup=64, accesses=192, seed=7,
            footprint=1 << 20, chunk_records=256, encoding=RAW,
            gen=lambda c, i: (((c * 4096 + i * LINE) % (1 << 20)) // LINE * LINE,
                              i % 7 == 3, i % 5),
        ),
        # Delta encoding across several chunks per core, with backward jumps
        # (negative deltas) so the zigzag path is pinned.
        dict(
            name="corpus_stride_delta", cores=2, warmup=32, accesses=288,
            seed=23, footprint=1 << 20, chunk_records=128, encoding=DELTA,
            gen=lambda c, i: ((((i * 2879 + c * 131) % 8192) * LINE),
                              i % 3 == 1, i % 9),
        ),
        # Single core, delta, ragged final chunk (100 + 100 + 56 records).
        dict(
            name="corpus_solo_delta", cores=1, warmup=16, accesses=240,
            seed=99, footprint=1 << 19, chunk_records=100, encoding=DELTA,
            gen=lambda c, i: ((((i * 7919) % 4096) * LINE), i % 4 == 0, i % 6),
        ),
    ]
    for s in specs:
        blob, _ = build(**s)
        verify(blob, s["cores"], s["warmup"] + s["accesses"], s["chunk_records"])
        path = OUT_DIR / f"{s['name']}.trimtrc"
        path.write_bytes(blob)
        print(f"{path.name}: {len(blob)} bytes, cores={s['cores']}, "
              f"records/core={s['warmup'] + s['accesses']}")


if __name__ == "__main__":
    main()
